"""Durability-tier benchmark: erasure coding vs replication under chaos.

Runs the ``durability`` scenario (an ``ec:6+2`` blob and a ``rep:3``
twin, continuously read) three ways: a no-chaos baseline, a chaos run
that kills ``m = 2`` shard providers mid-flight and injects silent
bitrot on a third, and a same-seed replay of the chaos run.  A scrub
client repairs under a per-round maintenance budget throughout.

Gates (``BENCH_durability.json``, asserted here and by CI):

* zero failed reads in the chaos run — losing any ``m`` of the
  ``k + m`` shard providers is masked by decode-on-read, and the
  replicated twin fails over to surviving copies,
* the injected corruption is detected (digest probe) and repaired, and
  the final verification round finds zero damaged pages and zero
  losses,
* every scrub round's repair traffic stays within the maintenance
  budget,
* measured storage overhead: ``ec:6+2`` <= 1.5x the logical bytes
  (vs >= 2.9x for the 3-way replicated twin) — the durability
  economics that motivate the tier,
* same-seed chaos runs replay identical trace digests.
"""

from __future__ import annotations

import json
import os

from benchmarks.common import Reporter
from repro.core.service import BlobSeerService
from repro.core.scenarios import build_env, run_scenario

N_CLIENTS = 8
OPS_PER_CLIENT = 3
SEED = 17
KILL_PROVIDERS = ("prov-0000", "prov-0001")   # m = 2 of the ec:6+2 geometry
CORRUPT_PROVIDER = "prov-0003"
SCRUB_BUDGET = 2 * 1024 * 1024


def _run(failures=()):
    env = build_env(N_CLIENTS, seed=SEED, ops_per_client=OPS_PER_CLIENT,
                    scenario="durability")
    env.state["scrub_budget"] = SCRUB_BUDGET
    result = run_scenario("durability", N_CLIENTS, seed=SEED, env=env,
                          failures=failures)
    return env, result


def _readers(result) -> dict:
    total = {"failed_reads": 0, "failed_reads_ec": 0, "failed_reads_rep": 0,
             "ops": 0}
    for res in result.client_results.values():
        if isinstance(res, dict) and "failed_reads" in res:
            for k in total:
                total[k] += res[k]
    return total


def _overhead(policy: str) -> float:
    """Stored-bytes / logical-bytes for one small single-policy blob."""
    svc = BlobSeerService(n_providers=12, n_meta_shards=2)
    c = svc.client("w")
    bid = c.create(psize=4096)
    svc.set_blob_placement(bid, policy)
    payload = bytes(range(256)) * 16          # one full 4 KiB page
    logical = 0
    for _ in range(8):
        c.append(bid, payload)
        logical += len(payload)
    stored = sum(p.stored_bytes() for p in svc.pm.all_providers())
    return stored / logical


def run(rep: Reporter) -> None:
    env0, base = _run()
    assert not base.errors, base.errors
    kill_at = 0.25 * base.makespan

    failures = [(kill_at, KILL_PROVIDERS[0]),
                (kill_at * 1.2, KILL_PROVIDERS[1]),
                (kill_at * 0.8, f"corrupt:{CORRUPT_PROVIDER}")]
    env1, chaos = _run(failures)
    env2, replay = _run(failures)
    assert not chaos.errors, chaos.errors

    scrub = chaos.client_results["durability-000"]
    readers = _readers(chaos)
    ec_overhead = _overhead("ec:6+2")
    rep_overhead = _overhead("rep:3")

    gate = {
        "failed_reads": readers["failed_reads"],
        "failed_reads_ec": readers["failed_reads_ec"],
        "corrupt_detected": scrub["corrupt_found"] >= 1,
        "repaired_pages": scrub["repaired_pages"],
        "max_round_repair_bytes": scrub["max_round_repair_bytes"],
        "budget_respected":
            scrub["max_round_repair_bytes"] <= SCRUB_BUDGET,
        "lost_pages": len(scrub["lost"]),
        "final_damaged": scrub["final_damaged"],
        "final_losses": len(scrub["final_losses"]),
        "ec_overhead_x": round(ec_overhead, 4),
        "rep_overhead_x": round(rep_overhead, 4),
        "digest_match": chaos.trace_digest == replay.trace_digest,
    }
    assert gate["failed_reads"] == 0, gate
    assert gate["corrupt_detected"], gate
    assert gate["repaired_pages"] > 0, gate
    assert gate["budget_respected"], gate
    assert gate["lost_pages"] == 0, gate
    assert gate["final_damaged"] == 0, gate
    assert gate["final_losses"] == 0, gate
    assert gate["ec_overhead_x"] <= 1.5, gate
    assert gate["rep_overhead_x"] >= 2.9, gate
    assert gate["digest_match"], gate

    rep.add("durability_baseline", 0.0,
            f"n={N_CLIENTS};ops={base.ops};makespan={base.makespan:.4f}s")
    rep.add("durability_chaos", 0.0,
            f"kills={len(KILL_PROVIDERS)};ops={chaos.ops};"
            f"repaired={gate['repaired_pages']};"
            f"repair_bytes={chaos.rpc['provider_repair_bytes']};"
            f"makespan={chaos.makespan:.4f}s")
    rep.add("durability_gate", 0.0,
            f"failed_reads={gate['failed_reads']};"
            f"ec_overhead={gate['ec_overhead_x']}x;"
            f"rep_overhead={gate['rep_overhead_x']}x;"
            f"digest_match={gate['digest_match']}")

    out = os.path.join(os.getcwd(), "BENCH_durability.json")
    with open(out, "w") as f:
        json.dump({
            "bench": "durability",
            "n_clients": N_CLIENTS,
            "ops_per_client": OPS_PER_CLIENT,
            "seed": SEED,
            "scrub_budget_bytes": SCRUB_BUDGET,
            "kill_at_s": kill_at,
            "killed": list(KILL_PROVIDERS),
            "corrupted": CORRUPT_PROVIDER,
            "baseline": {
                "ops": base.ops, "makespan_s": base.makespan,
                "trace_digest": base.trace_digest,
            },
            "chaos": {
                "ops": chaos.ops, "makespan_s": chaos.makespan,
                "scrub": scrub,
                "readers": readers,
                "repair_pages": chaos.rpc["provider_repair_pages"],
                "repair_bytes": chaos.rpc["provider_repair_bytes"],
                "locate_lookups": chaos.rpc["provider_locate_lookups"],
                "trace_digest": chaos.trace_digest,
            },
            "overhead": {"ec:6+2": ec_overhead, "rep:3": rep_overhead},
            "gate": gate,
        }, f, indent=2)
        f.write("\n")


if __name__ == "__main__":
    run(Reporter())
