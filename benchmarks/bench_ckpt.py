"""Incremental checkpointing over BlobSeer (beyond-paper application).

Simulates a training lineage: full state save, then saves where only a
fraction of leaves changed (optimizer moments move, embeddings frozen).
Reports pages written vs total (the COW dedup the digest kernels buy)
and restore correctness/throughput.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import Reporter, timer
from repro.checkpoint import BlobCheckpointer
from repro.core import BlobSeerService


def run(rep: Reporter) -> None:
    svc = BlobSeerService(n_providers=8, n_meta_shards=8)
    c = svc.client()
    ck = BlobCheckpointer(c, psize=64 * 1024, header_pages=8)
    rng = np.random.default_rng(0)
    state = {
        "params": {f"layer{i}": jnp.asarray(rng.standard_normal(200_000),
                                            jnp.float32) for i in range(8)},
        "frozen_embed": jnp.asarray(rng.standard_normal(500_000), jnp.float32),
        "step": jnp.zeros((), jnp.int32),
    }
    t0 = timer()
    s0 = ck.save(state, step=0)
    full_s = timer() - t0
    rep.add("ckpt_full_save", full_s * 1e6,
            f"bytes={s0.total_bytes/1e6:.1f}MB pages={s0.pages_total}")

    # delta saves: 2 of 8 layers change per step
    deltas = []
    for step in range(1, 6):
        for i in (step % 8, (step + 1) % 8):
            state["params"][f"layer{i}"] = state["params"][f"layer{i}"] + 0.01
        state["step"] = jnp.asarray(step, jnp.int32)
        t0 = timer()
        s = ck.save(state, step=step)
        deltas.append((timer() - t0, s))
    avg_us = sum(d for d, _ in deltas) / len(deltas) * 1e6
    last = deltas[-1][1]
    rep.add("ckpt_delta_save", avg_us,
            f"pages_written={last.pages_written}/{last.pages_total} "
            f"sharing={last.sharing_fraction:.0%} "
            f"bytes_written={last.written_bytes/1e6:.1f}MB")

    t0 = timer()
    got = ck.restore(jax.eval_shape(lambda: state))
    restore_s = timer() - t0
    ok = np.allclose(np.asarray(got["params"]["layer1"]),
                     np.asarray(state["params"]["layer1"]))
    rep.add("ckpt_restore", restore_s * 1e6,
            f"bw={s0.total_bytes/restore_s/1e6:.0f}MBps correct={ok}")

    # branch cost: O(1) bytes
    t0 = timer()
    child = ck.branch()
    rep.add("ckpt_branch", (timer() - t0) * 1e6, "bytes_copied=0 (COW fork)")
