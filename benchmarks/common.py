"""Shared benchmark plumbing.

Wall-clock on this 1-core container measures Python control-plane speed;
the paper's *bandwidth* figures are reproduced on the simulated wire
(Grid'5000 constants measured in the paper: 117.5 MB/s TCP, 0.1 ms
latency) — every remote byte/request is accounted per endpoint, so
simulated makespans capture client-NIC serialization and provider
contention exactly like the testbed did.

Row contract (benchmarks/run.py): ``name,us_per_call,derived``.
"""

from __future__ import annotations

import sys
import time
from dataclasses import dataclass
from typing import List, Optional


@dataclass
class Row:
    name: str
    us_per_call: float
    derived: str

    def emit(self) -> str:
        return f"{self.name},{self.us_per_call:.1f},{self.derived}"


class Reporter:
    def __init__(self) -> None:
        self.rows: List[Row] = []

    def add(self, name: str, us_per_call: float, derived: str) -> None:
        row = Row(name, us_per_call, derived)
        self.rows.append(row)
        print(row.emit())
        sys.stdout.flush()


def timer():
    return time.perf_counter()
