"""Append path benchmarks: paper Fig 2(a) + the scale-out write plane.

Part 1 (paper Fig 2a): a single client appends fixed-size chunks until
the blob reaches the target size, for page sizes 64 KB / 256 KB and
50 / 175 co-deployed data+metadata providers (the paper's two
deployments, scaled in total bytes for a 1-core container).  Derived
bandwidth = chunk bytes over the growth of the client endpoint's
simulated busy time — the metric the paper plots; expect near-flat
curves with dips when the page count crosses a power of two (one more
metadata-tree level per append).

Part 2 (the PR-5 contract, asserted): 64 concurrent simulated appenders
on the virtual-time harness, per-op ``append`` (the pre-PR client
behavior, one assign + one complete control RPC per append) vs the
``append_burst`` scenario (batched ``assign_versions_many`` /
``metadata_complete_many``).  The gate: at 64 appenders the batched
write plane must cut version-manager round trips per append at least
2x (or gain 2x aggregate append throughput), and the burst scenario
must replay an identical same-seed trace digest.  Emits
``BENCH_append.json`` next to the CSV rows.
"""

from __future__ import annotations

import json
import os

from benchmarks.common import Reporter, timer
from repro.core import BlobSeerService
from repro.core.scenarios import run_scenario

N_APPENDERS = 64
OPS_PER_CLIENT = 2
SEED = 7


def _fig2a(rep: Reporter, total_mb: int, chunk_mb: int) -> list:
    rows = []
    for n_providers in (50, 175):
        for psize_kb in (64, 256):
            svc = BlobSeerService(n_providers=n_providers,
                                  n_meta_shards=n_providers)
            client = svc.client("appender")
            bid = client.create(psize=psize_kb * 1024)
            chunk = b"\xab" * (chunk_mb * 1024 * 1024)
            sim_bw = []
            t0 = timer()
            for i in range(total_mb // chunk_mb):
                before = svc.wire.stats(client.name).sim_busy_until
                client.append(bid, chunk)
                after = svc.wire.stats(client.name).sim_busy_until
                sim_bw.append(len(chunk) / max(after - before, 1e-9) / 1e6)
            wall = timer() - t0
            n_appends = total_mb // chunk_mb
            rep.add(
                f"append_p{n_providers}_ps{psize_kb}k",
                wall / n_appends * 1e6,
                f"sim_bw_first={sim_bw[0]:.1f}MBps sim_bw_last={sim_bw[-1]:.1f}MBps "
                f"sim_bw_min={min(sim_bw):.1f}MBps blob={total_mb}MB "
                f"meta_nodes={svc.dht.total_keys()}",
            )
            rows.append({
                "providers": n_providers, "psize_kb": psize_kb,
                "blob_mb": total_mb, "sim_bw_first_mbps": sim_bw[0],
                "sim_bw_last_mbps": sim_bw[-1],
                "sim_bw_min_mbps": min(sim_bw),
                "meta_nodes": svc.dht.total_keys(),
            })
    return rows


def _scale_row(result) -> dict:
    rpc = result.rpc
    return {
        "scenario": result.scenario,
        "n_clients": result.n_clients,
        "appends": result.ops,
        "aggregate_mbps": result.aggregate_mbps,
        "makespan_s": result.makespan,
        "vm_ops": rpc["vm_ops"],
        "vm_round_trips": rpc["vm_round_trips"],
        "vm_batched_ops": rpc["vm_batched_ops"],
        "vm_round_trips_per_append": rpc["vm_round_trips"] / result.ops,
        "wire_round_trips": rpc["wire_round_trips"],
        "provider_write_rounds": rpc["provider_write_rounds"],
        "provider_write_pages": rpc["provider_write_pages"],
        "trace_digest": result.trace_digest,
    }


def _scale_experiment(rep: Reporter) -> dict:
    base = run_scenario("appenders", N_APPENDERS, seed=SEED,
                        ops_per_client=OPS_PER_CLIENT * 4)
    burst = run_scenario("append_burst", N_APPENDERS, seed=SEED,
                         ops_per_client=OPS_PER_CLIENT)
    replay = run_scenario("append_burst", N_APPENDERS, seed=SEED,
                          ops_per_client=OPS_PER_CLIENT)

    digest_match = burst.trace_digest == replay.trace_digest
    assert digest_match, (
        f"append_burst same-seed replay diverged: "
        f"{burst.trace_digest} != {replay.trace_digest}"
    )
    assert not base.errors and not burst.errors

    b, s = _scale_row(base), _scale_row(burst)
    vm_reduction = (b["vm_round_trips_per_append"] /
                    s["vm_round_trips_per_append"])
    throughput_gain = s["aggregate_mbps"] / max(b["aggregate_mbps"], 1e-9)
    # The asserted PR gate: batched writer verbs must amortize the
    # version manager at least 2x per append at 64 concurrent
    # appenders (or win 2x aggregate throughput outright).
    assert vm_reduction >= 2.0 or throughput_gain >= 2.0, (
        f"write plane gate failed: vm_reduction={vm_reduction:.2f} "
        f"throughput_gain={throughput_gain:.2f}"
    )

    rep.add("append_scale_baseline", 0.0,
            f"n={N_APPENDERS};appends={b['appends']};"
            f"vm_rpc_per_append={b['vm_round_trips_per_append']:.2f};"
            f"agg={b['aggregate_mbps']:.1f}MBps")
    rep.add("append_scale_burst", 0.0,
            f"n={N_APPENDERS};appends={s['appends']};"
            f"vm_rpc_per_append={s['vm_round_trips_per_append']:.2f};"
            f"agg={s['aggregate_mbps']:.1f}MBps;"
            f"digest_match={digest_match}")
    rep.add("append_scale_gate", 0.0,
            f"vm_rpc_reduction_x{vm_reduction:.2f};"
            f"throughput_x{throughput_gain:.2f};gate>=2.0_passed")

    return {
        "n_appenders": N_APPENDERS,
        "seed": SEED,
        "baseline": b,
        "burst": s,
        "vm_rpc_reduction": vm_reduction,
        "throughput_gain": throughput_gain,
        "digest_match": digest_match,
    }


def run(rep: Reporter, *, total_mb: int = 32, chunk_mb: int = 2) -> None:
    fig2a = _fig2a(rep, total_mb, chunk_mb)
    scale = _scale_experiment(rep)

    out = os.path.join(os.getcwd(), "BENCH_append.json")
    with open(out, "w") as f:
        json.dump({"bench": "append", "fig2a": fig2a, "scale": scale},
                  f, indent=2)
        f.write("\n")


if __name__ == "__main__":
    run(Reporter())
