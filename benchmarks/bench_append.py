"""Paper Fig 2(a): append bandwidth as the blob grows.

A single client appends fixed-size chunks until the blob reaches the
target size, for page sizes 64 KB / 256 KB and 50 / 175 co-deployed
data+metadata providers (the paper's two deployments, scaled in total
bytes for a 1-core container).  Derived bandwidth = chunk bytes over the
growth of the client endpoint's simulated busy time — the metric the
paper plots; expect near-flat curves with dips when the page count
crosses a power of two (one more metadata-tree level per append).
"""

from __future__ import annotations

from benchmarks.common import Reporter, timer
from repro.core import BlobSeerService


def run(rep: Reporter, *, total_mb: int = 32, chunk_mb: int = 2) -> None:
    for n_providers in (50, 175):
        for psize_kb in (64, 256):
            svc = BlobSeerService(n_providers=n_providers,
                                  n_meta_shards=n_providers)
            client = svc.client("appender")
            bid = client.create(psize=psize_kb * 1024)
            chunk = b"\xab" * (chunk_mb * 1024 * 1024)
            sim_bw = []
            t0 = timer()
            for i in range(total_mb // chunk_mb):
                before = svc.wire.stats(client.name).sim_busy_until
                client.append(bid, chunk)
                after = svc.wire.stats(client.name).sim_busy_until
                sim_bw.append(len(chunk) / max(after - before, 1e-9) / 1e6)
            wall = timer() - t0
            n_appends = total_mb // chunk_mb
            rep.add(
                f"append_p{n_providers}_ps{psize_kb}k",
                wall / n_appends * 1e6,
                f"sim_bw_first={sim_bw[0]:.1f}MBps sim_bw_last={sim_bw[-1]:.1f}MBps "
                f"sim_bw_min={min(sim_bw):.1f}MBps blob={total_mb}MB "
                f"meta_nodes={svc.dht.total_keys()}",
            )
