"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run append read # subset

Emits ``name,us_per_call,derived`` CSV rows.
"""

from __future__ import annotations

import sys

from benchmarks.common import Reporter

BENCHES = ["append", "read", "meta", "space", "gc", "cache", "ckpt",
           "failover", "durability", "watch", "ring", "kernels",
           "roofline", "concurrency", "e2e"]


def main() -> None:
    which = sys.argv[1:] or BENCHES
    rep = Reporter()
    print("name,us_per_call,derived")
    for name in which:
        if name == "append":
            from benchmarks import bench_append as m
        elif name == "read":
            from benchmarks import bench_read as m
        elif name == "meta":
            from benchmarks import bench_meta as m
        elif name == "space":
            from benchmarks import bench_space as m
        elif name == "gc":
            from benchmarks import bench_gc as m
        elif name == "cache":
            from benchmarks import bench_cache as m
        elif name == "ckpt":
            from benchmarks import bench_ckpt as m
        elif name == "failover":
            from benchmarks import bench_failover as m
        elif name == "durability":
            from benchmarks import bench_durability as m
        elif name == "watch":
            from benchmarks import bench_watch as m
        elif name == "ring":
            from benchmarks import bench_ring as m
        elif name == "kernels":
            from benchmarks import bench_kernels as m
        elif name == "roofline":
            from benchmarks import bench_roofline as m
        elif name == "concurrency":
            from benchmarks import bench_concurrency as m
        elif name == "e2e":
            from benchmarks import bench_e2e as m
        else:
            raise SystemExit(f"unknown bench {name!r}; known: {BENCHES}")
        m.run(rep)


if __name__ == "__main__":
    main()
