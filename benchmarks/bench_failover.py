"""HA control plane benchmark: VM leader death mid-append-burst.

The paper (§3.1) centralizes version assignment in one version manager
and concedes it is a single point of failure.  The HA control plane
replicates each lineage shard's journal to follower endpoints and fails
over by lease takeover, so this benchmark kills the leader of one
lineage mid-``append_many`` burst and asserts the contract:

* the burst completes — zero failed client ops, zero published
  versions lost, zero versions double-assigned (checked by exact
  version cover per lineage: the union of every client's assigned
  versions must be exactly ``1..N``),
* exactly one failover fires (the killed lineage's; healthy lineages
  never elect),
* untouched lineages see **zero added publication round trips**: their
  leader endpoints' wire request counts are identical between the
  no-kill baseline and the kill run,
* same-seed kill runs replay identical trace digests (the failover
  path is deterministic under the virtual clock).

Emits ``BENCH_failover.json`` with a ``gate`` dict CI asserts on.
"""

from __future__ import annotations

import json
import os
from collections import defaultdict

from benchmarks.common import Reporter
from repro.core.scenarios import build_env, run_scenario

N_CLIENTS = 12
OPS_PER_CLIENT = 3
SEED = 11
KILL_FRACTION = 0.4   # of the baseline makespan — mid-burst, not at a seam


def _run(failures=()):
    env = build_env(N_CLIENTS, seed=SEED, ops_per_client=OPS_PER_CLIENT,
                    scenario="vm_failover")
    result = run_scenario("vm_failover", N_CLIENTS, seed=SEED, env=env,
                          failures=failures)
    return env, result


def _version_cover(result) -> dict:
    """Per-lineage sorted version lists across all clients."""
    cover = defaultdict(list)
    for res in result.client_results.values():
        if isinstance(res, dict) and "versions" in res:
            cover[res["lineage"]].extend(res["versions"])
    return {lin: sorted(vs) for lin, vs in cover.items()}


def _leader_requests(env) -> dict:
    """Wire request count at each lineage's current leader endpoint.

    For untouched lineages (the only ones the gate compares) the
    current leader is still the original one, so the count is
    comparable across runs."""
    out = {}
    for idx, bid in enumerate(env.state["blobs"]):
        ep = env.svc.vm.leader_endpoint(bid)
        out[idx] = (ep, env.svc.wire.stats(ep).requests)
    return out


def run(rep: Reporter) -> None:
    env0, base = _run()
    assert not base.errors, base.errors
    kill_time = KILL_FRACTION * base.makespan

    failures = [(kill_time, "vm-leader:0")]
    env1, kill = _run(failures)
    env2, replay = _run(failures)

    cover = _version_cover(kill)
    expected_per_lineage = {
        lin: len(vs) for lin, vs in _version_cover(base).items()
    }
    lost = doubled = 0
    for lin, vs in sorted(cover.items()):
        want = list(range(1, expected_per_lineage[lin] + 1))
        doubled += len(vs) - len(set(vs))
        lost += len(set(want) - set(vs))

    base_reqs = _leader_requests(env0)
    kill_reqs = _leader_requests(env1)
    # lineage 0 is the killed one; every other lineage's leader must
    # have served exactly the same number of requests as the baseline.
    untouched_delta = sum(
        abs(kill_reqs[i][1] - base_reqs[i][1])
        for i in base_reqs if i != 0
    )

    gate = {
        "lost_published_versions": lost,
        "double_assigned": doubled,
        "failed_ops": len(kill.errors),
        "failovers": kill.rpc["vm_failovers"],
        "untouched_rpc_delta": untouched_delta,
        "digest_match": kill.trace_digest == replay.trace_digest,
        "completed": kill.ops == base.ops,
    }
    assert gate["lost_published_versions"] == 0, gate
    assert gate["double_assigned"] == 0, gate
    assert gate["failed_ops"] == 0, gate
    assert gate["failovers"] == 1, gate
    assert gate["untouched_rpc_delta"] == 0, gate
    assert gate["digest_match"], gate
    assert gate["completed"], gate

    rep.add("failover_baseline", 0.0,
            f"n={N_CLIENTS};ops={base.ops};makespan={base.makespan:.4f}s;"
            f"wal_records={base.rpc['vm_wal_records']}")
    rep.add("failover_kill", 0.0,
            f"kill_t={kill_time:.4f}s;ops={kill.ops};"
            f"makespan={kill.makespan:.4f}s;"
            f"failovers={gate['failovers']};"
            f"slowdown_x{kill.makespan / max(base.makespan, 1e-12):.2f}")
    rep.add("failover_gate", 0.0,
            f"lost={lost};doubled={doubled};failed={gate['failed_ops']};"
            f"untouched_delta={untouched_delta};"
            f"digest_match={gate['digest_match']}")

    out = os.path.join(os.getcwd(), "BENCH_failover.json")
    with open(out, "w") as f:
        json.dump({
            "bench": "failover",
            "n_clients": N_CLIENTS,
            "ops_per_client": OPS_PER_CLIENT,
            "seed": SEED,
            "kill_time": kill_time,
            "baseline": {
                "ops": base.ops, "makespan_s": base.makespan,
                "wal_records": base.rpc["vm_wal_records"],
                "wal_stream_batches": base.rpc["vm_wal_stream_batches"],
                "trace_digest": base.trace_digest,
            },
            "kill": {
                "ops": kill.ops, "makespan_s": kill.makespan,
                "failovers": kill.rpc["vm_failovers"],
                "trace_digest": kill.trace_digest,
            },
            "leader_requests": {
                "baseline": {i: r for i, (_, r) in base_reqs.items()},
                "kill": {i: r for i, (_, r) in kill_reqs.items()},
            },
            "gate": gate,
        }, f, indent=2)
        f.write("\n")


if __name__ == "__main__":
    run(Reporter())
