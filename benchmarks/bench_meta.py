"""Metadata-scheme microbenchmarks (paper §4 analysis).

* nodes created per update as the blob grows (O(log N) sharing),
* READ_META node fetches for random ranges at several blob depths,
* version-manager assignment throughput (the only serialization point —
  the paper argues it is negligible; measure it).
"""

from __future__ import annotations

import random

from benchmarks.common import Reporter, timer
from repro.core import BlobSeerService
from repro.core import segment_tree as st


def run(rep: Reporter) -> None:
    svc = BlobSeerService(n_providers=16, n_meta_shards=16)
    c = svc.client()
    psize = 1024
    bid = c.create(psize=psize)

    # --- nodes created per one-page overwrite at growing blob sizes ---
    for pages_exp in (6, 10, 14):
        pages = 1 << pages_exp
        size = c.get_size(bid, c.get_recent(bid))
        grow = pages * psize - size
        if grow > 0:
            c.append(bid, b"g" * grow)
        before = svc.dht.total_keys()
        c.write(bid, b"o" * psize, (pages // 2) * psize)
        created = svc.dht.total_keys() - before
        rep.add(f"meta_nodes_per_write_2e{pages_exp}p", 0.0,
                f"created={created} expected={pages_exp + 1} (log2 N + 1)")

    # --- READ_META fetches for random 64-page ranges ---
    v = c.get_recent(bid)
    root_pages = svc.vm.root_pages_published(bid, v)
    rnd = random.Random(0)
    owner = c._owner_fn(bid)
    n_iter = 200
    t0 = timer()
    fetched = 0
    for _ in range(n_iter):
        p0 = rnd.randrange(0, root_pages - 64)
        pd = st.read_meta(svc.dht, owner, v, root_pages, p0, p0 + 64)
        fetched += len(pd)
    wall = timer() - t0
    rep.add("read_meta_64page_range", wall / n_iter * 1e6,
            f"leaves_per_query={fetched / n_iter:.1f} root_pages={root_pages}")

    # --- RPC accounting: batched (level-synchronous) vs per-node gets ---
    # One 64-page READ_META straight against the DHT (no client cache).
    # ``get_keys`` is the number of tree nodes visited — exactly the
    # serial DHT round trips the old per-node descent paid; ``get_rounds``
    # is the batched latency waves the level-synchronous traversal pays
    # (bounded by tree depth + 1).
    svc.dht.reset_rpc_counters()
    p0 = root_pages // 4
    st.read_meta(svc.dht, owner, v, root_pages, p0, p0 + 64)
    ctr = svc.dht.rpc_counters()
    depth = root_pages.bit_length()  # levels in the tree = log2(root)+1
    reduction = ctr["get_keys"] / max(ctr["get_rounds"], 1)
    rep.add("read_meta_64page_rpc", 0.0,
            f"batched_rounds={ctr['get_rounds']} shard_rpcs={ctr['get_shard_rpcs']} "
            f"per_node_gets={ctr['get_keys']} reduction={reduction:.1f}x "
            f"depth+1={depth}")

    # --- version-manager assignment throughput (serialization point) ---
    n = 2000
    bid2 = c.create(psize=64)
    c.append(bid2, b"x" * 64)
    t0 = timer()
    for i in range(n):
        info = svc.vm.assign_version(bid2, None, 64, client="bench",
                                     pd=(("pid", 0, ("prov-0000",), 64),))
        svc.vm.register_pd(bid2, info.version, (("pid", 0, ("prov-0000",), 64),))
        svc.vm.metadata_complete(bid2, info.version)
    wall = timer() - t0
    rep.add("version_manager_assign_publish", wall / n * 1e6,
            f"ops_per_s={n / wall:.0f}")
