"""Versioning space efficiency (paper §4.3).

Write a base blob, then produce many versions each overwriting a small
fraction; report physical pages stored vs the logical bytes a naive
copy-per-version scheme would burn, plus metadata sharing.
"""

from __future__ import annotations

import random

from benchmarks.common import Reporter
from repro.core import BlobSeerService


def run(rep: Reporter) -> None:
    svc = BlobSeerService(n_providers=16, n_meta_shards=8)
    c = svc.client()
    psize = 4096
    pages = 512
    bid = c.create(psize=psize)
    c.write(bid, b"B" * psize * pages, 0)
    rnd = random.Random(0)
    n_versions = 50
    touched = 4  # pages overwritten per version
    for i in range(n_versions):
        p = rnd.randrange(0, pages - touched)
        c.write(bid, bytes([i % 256]) * psize * touched, p * psize)
    report = svc.storage_report()
    logical = (n_versions + 1) * pages * psize
    physical = report["page_bytes"]
    rep.add(
        "space_cow_50_versions", 0.0,
        f"physical_MB={physical/1e6:.1f} naive_copies_MB={logical/1e6:.1f} "
        f"saving={1 - physical/logical:.1%} meta_nodes={report['metadata_nodes']}",
    )
