"""Elastic membership benchmark: ring rebalance cost, live join/drain,
flash-crowd mitigation (paper §3 "dynamic provider set", arXiv
2201.13292 reconfiguration).

Four contracts, each asserted and written to ``BENCH_ring.json``:

* **Rebalance is near-minimal.**  A provider join must move no more
  payload than the bytes the ring now owes the joiner; a drain no more
  than the bytes the drainer held.  Both minima are computed from the
  page inventory alone (not from the migration plan), and the payload
  actually moved — ``provider_migrated_payload_bytes`` — must stay
  within ``REBALANCE_SLACK`` (1.25x) of them.
* **Zero failed ops under churn.**  The ``rolling_restart`` (drain →
  deregister → rejoin x3, readers throughout) and ``scale_out`` (two
  joins mid-run, appenders + readers throughout) scenarios finish with
  every client's ``failed_reads == 0`` and no errors: the old owner
  serves every page until its move lands and the relocation pointer
  flips.
* **Flash-crowd load flattens.**  The ``flash_crowd`` scenario runs
  twice from the same seed — balancer on vs off — and the cumulative
  per-provider served-read load (read *after* the run, so the
  measurement can't race the crowd) must spread over more providers
  with a strictly lower peak when mitigation widens the hot pages.
* **Churn replays deterministically.**  The same seed with the same
  ``join:``/``drain:``/``flashcrowd:`` chaos schedule produces
  identical trace digests.
"""

from __future__ import annotations

import json
import os

from benchmarks.common import Reporter
from repro.core.scenarios import build_env, run_scenario

SEED = 17
N_CLIENTS = 12
OPS = 3
PRELOAD_CHUNKS = 24          # 96 pages x 64 KiB = 6 MiB inventory
REBALANCE_SLACK = 1.25       # moved payload vs theoretical minimum

CHAOS = [(0.02, "drain:prov-0005"), (0.05, "flashcrowd:0"),
         (0.08, "join:prov-0005")]


def _uniq(provs):
    return tuple(dict.fromkeys(provs))


def _resident_bytes(svc, pid: str) -> int:
    """Bytes of live inventory with a copy on ``pid`` — journaled
    holders overridden by the relocation overlay, computed from the
    inventory alone, independent of any migration plan."""
    total = 0
    for lg, (_blob, provs, length) in svc.vm.page_locations().items():
        overlay = svc.pm.relocated(lg)
        holders = overlay if overlay else _uniq(provs)
        if pid in holders:
            total += length
    return total


def _payload_moved(svc) -> int:
    return svc.pm.rpc_counters()["migrated_payload_bytes"]


def _rebalance() -> dict:
    """Join then drain one provider on a preloaded 2-way-replicated
    deployment; compare moved payload against the inventory minima."""
    env = build_env(2, seed=SEED, scenario="scale_out",
                    data_replication=2)
    c = env.client("bench-setup")
    blob = c.create(psize=env.psize)
    for k in range(PRELOAD_CHUNKS):
        c.append(blob, bytes([(k % 251) + 1]) * env.chunk)
    version = c.get_recent(blob)
    svc = env.svc

    # --- join: minimum = bytes the ring owes the joiner (it ends up
    # resident there; nothing else should have been carried).
    joiner = "prov-bench-join"
    before = _payload_moved(svc)
    plan = svc.join_provider(joiner)
    join_stats = svc.run_migration(plan)
    join_moved = _payload_moved(svc) - before
    join_min = _resident_bytes(svc, joiner)

    # --- drain: minimum = bytes the drainer held when the drain began.
    drainer = "prov-0003"
    drain_min = _resident_bytes(svc, drainer)
    before = _payload_moved(svc)
    drain_stats = svc.drain_provider(drainer)
    drain_moved = _payload_moved(svc) - before

    # the blob must read back byte-identical after both reconfigurations
    reader = env.client("bench-reader")
    for k in range(PRELOAD_CHUNKS):
        data = reader.read(blob, version, k * env.chunk, env.chunk)
        assert data == bytes([(k % 251) + 1]) * env.chunk, k

    # metadata-plane elasticity rides along: grow then shrink the DHT
    meta_before = dict(svc.dht.rpc_counters())
    svc.add_meta_shard("meta-bench")
    svc.drain_meta_shard("meta-bench")
    meta_keys_moved = (svc.dht.rpc_counters()["migrate_keys"]
                       - meta_before.get("migrate_keys", 0))
    assert reader.get_size(blob, version) == PRELOAD_CHUNKS * env.chunk

    return {
        "join_payload_bytes": join_moved,
        "join_min_bytes": join_min,
        "join_ratio": join_moved / max(join_min, 1),
        "join_moves": join_stats["moves"],
        "drain_payload_bytes": drain_moved,
        "drain_min_bytes": drain_min,
        "drain_ratio": drain_moved / max(drain_min, 1),
        "drain_moves": drain_stats["moves"] + drain_stats["stragglers"],
        "meta_keys_moved": meta_keys_moved,
    }


def _failed_ops(result) -> int:
    return sum(res.get("failed_reads", 0)
               for res in result.client_results.values()
               if isinstance(res, dict))


def _flash_crowd_twin(mitigate: bool):
    env = build_env(N_CLIENTS, seed=SEED, scenario="flash_crowd",
                    ops_per_client=OPS)
    env.state["flashcrowd_mitigate"] = mitigate
    result = run_scenario("flash_crowd", N_CLIENTS, seed=SEED, env=env)
    assert not result.errors, result.errors
    # Cumulative per-provider served-read load, read AFTER the run:
    # the in-run balancer snapshot can race the crowd's tail.
    load = sorted(env.svc.pm.read_load().values(), reverse=True)
    return env, result, load


def run(rep: Reporter) -> None:
    reb = _rebalance()
    assert reb["join_min_bytes"] > 0, reb
    assert reb["join_ratio"] <= REBALANCE_SLACK, reb
    assert reb["drain_min_bytes"] > 0, reb
    assert reb["drain_ratio"] <= REBALANCE_SLACK, reb

    rolling = run_scenario("rolling_restart", N_CLIENTS, seed=SEED,
                           ops_per_client=OPS)
    assert not rolling.errors, rolling.errors
    scale = run_scenario("scale_out", N_CLIENTS, seed=SEED,
                         ops_per_client=OPS)
    assert not scale.errors, scale.errors
    failed = _failed_ops(rolling) + _failed_ops(scale)

    _, mit_res, mit_load = _flash_crowd_twin(True)
    _, raw_res, raw_load = _flash_crowd_twin(False)
    widened = sum(res.get("widened_pages", 0)
                  for res in mit_res.client_results.values()
                  if isinstance(res, dict))
    crowd_failed = _failed_ops(mit_res) + _failed_ops(raw_res)

    chaos1 = run_scenario("scale_out", N_CLIENTS, seed=SEED,
                          ops_per_client=OPS, failures=CHAOS)
    assert not chaos1.errors, chaos1.errors
    chaos2 = run_scenario("scale_out", N_CLIENTS, seed=SEED,
                          ops_per_client=OPS, failures=CHAOS)
    digest_match = chaos1.trace_digest == chaos2.trace_digest

    gate = {
        "join_ratio": reb["join_ratio"],
        "drain_ratio": reb["drain_ratio"],
        "rebalance_slack": REBALANCE_SLACK,
        "failed_ops": failed + crowd_failed,
        "widened_pages": widened,
        "peak_load_mitigated": mit_load[0],
        "peak_load_unmitigated": raw_load[0],
        "peak_ratio": mit_load[0] / max(raw_load[0], 1),
        "serving_providers_mitigated": len(mit_load),
        "serving_providers_unmitigated": len(raw_load),
        "digest_match": digest_match,
    }
    assert gate["failed_ops"] == 0, gate
    assert gate["widened_pages"] > 0, gate
    assert gate["peak_load_mitigated"] < gate["peak_load_unmitigated"], gate
    assert (gate["serving_providers_mitigated"]
            > gate["serving_providers_unmitigated"]), gate
    assert gate["digest_match"], gate

    rep.add("ring_rebalance", 0.0,
            f"join_ratio={reb['join_ratio']:.3f};"
            f"drain_ratio={reb['drain_ratio']:.3f};"
            f"join_moves={reb['join_moves']};"
            f"drain_moves={reb['drain_moves']};"
            f"meta_keys={reb['meta_keys_moved']}")
    rep.add("ring_churn", 0.0,
            f"rolling_makespan={rolling.makespan:.4f}s;"
            f"scale_makespan={scale.makespan:.4f}s;"
            f"failed_ops={failed}")
    rep.add("ring_flash_crowd", 0.0,
            f"peak_mit={mit_load[0]};peak_raw={raw_load[0]};"
            f"spread_mit={len(mit_load)};spread_raw={len(raw_load)};"
            f"widened={widened}")
    rep.add("ring_chaos_replay", 0.0,
            f"digest_match={digest_match};"
            f"makespan={chaos1.makespan:.4f}s")

    out = os.path.join(os.getcwd(), "BENCH_ring.json")
    with open(out, "w") as f:
        json.dump({
            "bench": "ring",
            "seed": SEED,
            "n_clients": N_CLIENTS,
            "ops_per_client": OPS,
            "preload_chunks": PRELOAD_CHUNKS,
            "rebalance": reb,
            "churn": {
                "rolling_makespan_s": rolling.makespan,
                "scale_out_makespan_s": scale.makespan,
                "failed_ops": failed,
            },
            "flash_crowd": {
                "load_mitigated": mit_load,
                "load_unmitigated": raw_load,
                "widened_pages": widened,
                "failed_ops": crowd_failed,
            },
            "chaos": {
                "schedule": [[t, s] for t, s in CHAOS],
                "trace_digest": chaos1.trace_digest,
                "digest_match": digest_match,
            },
            "gate": gate,
        }, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {out}", flush=True)
