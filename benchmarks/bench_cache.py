"""Read-path cache hierarchy: RPCs and bytes-on-wire vs cache budget.

Runs the ``hot_set`` scenario (64 simulated readers hammering a small
hot set of one blob, deterministic virtual time) across page-cache
budgets, from disabled to default, and reports

* data-plane read RPCs (``provider_read_rounds``) and logical page
  fetches (``provider_read_pages``),
* total wire round trips and bytes actually moved,
* bytes the caches kept off the wire (``wire_local_hit_bytes``),
* page-cache hit/miss/eviction/single-flight counters,

plus a sequential-reader row showing sibling-page prefetch
(``read_prefetch_pages``) hiding the next read's data-plane latency.

Perf contract (asserted): at the default budget the 64-reader hot-set
scenario issues at most HALF the data-plane read RPCs of a cache-free
run (it is ~16x in practice), and two same-seed runs replay identical
trace digests (the cache is part of the deterministic schedule, not a
source of nondeterminism).

Emits ``BENCH_cache.json`` (machine-readable, for the perf trajectory)
next to the CSV rows.
"""

from __future__ import annotations

import json
import os

from benchmarks.common import Reporter, timer
from repro.core import BlobSeerService
from repro.core.scenarios import run_scenario
from repro.core.service import DEFAULT_PAGE_CACHE_BYTES as DEFAULT_BUDGET

N_CLIENTS = 64
OPS_PER_CLIENT = 4
SEED = 1
PSIZE = 64 * 1024
CHUNK_PAGES = 4
BUDGETS = (0, 256 * 1024, DEFAULT_BUDGET)


def _hot_set_round(budget: int) -> dict:
    t0 = timer()
    r = run_scenario(
        "hot_set", N_CLIENTS, seed=SEED, ops_per_client=OPS_PER_CLIENT,
        psize=PSIZE, chunk_pages=CHUNK_PAGES, page_cache_bytes=budget,
    )
    if r.errors:
        raise RuntimeError(f"hot_set budget={budget}: {r.errors}")
    wall = timer() - t0
    return {
        "budget_bytes": budget,
        "n_clients": N_CLIENTS,
        "ops": r.ops,
        "read_rpc_rounds": r.rpc["provider_read_rounds"],
        "read_pages_fetched": r.rpc["provider_read_pages"],
        "wire_round_trips": r.rpc["wire_round_trips"],
        "bytes_on_wire": r.bytes_moved,
        "bytes_saved": r.rpc["wire_local_hit_bytes"],
        "page_cache_hits": r.rpc["page_cache_hits"],
        "page_cache_misses": r.rpc["page_cache_misses"],
        "page_cache_evictions": r.rpc["page_cache_evictions"],
        "single_flight_waits": r.rpc["page_cache_inflight_waits"],
        "node_cache_hits": r.rpc["node_cache_hits"],
        "aggregate_mbps": r.aggregate_mbps,
        "makespan_s": r.makespan,
        "trace_digest": r.trace_digest,
        "wall_seconds": wall,
    }


def _prefetch_round(prefetch_pages: int) -> dict:
    """One simulated sequential reader: sibling-page prefetch turns the
    next read's blocking data-plane rounds into fire-and-forget traffic
    issued a read earlier, so the reader's virtual makespan drops even
    though the RPC and byte counts stay the same (latency *hiding*, not
    latency removal)."""
    from repro.core import Simulator, Wire

    sim = Simulator(seed=SEED)
    svc = BlobSeerService(n_providers=8, n_meta_shards=4,
                          wire=Wire(clock=sim),
                          read_prefetch_pages=prefetch_pages)
    setup = svc.client("setup")
    bid = setup.create(psize=PSIZE)
    chunk = CHUNK_PAGES * PSIZE
    n_chunks = 16
    for _ in range(n_chunks):
        setup.append(bid, b"\x5a" * chunk)
    v = setup.get_recent(bid)
    svc.reset_rpc_counters()

    def prog():
        c = svc.client("seq")
        for k in range(n_chunks):
            c.read(bid, v, k * chunk, chunk)
        return {"ops": n_chunks}

    sim.spawn(prog, name="seq")
    sim.run()
    rep = svc.rpc_report()
    return {
        "prefetch_pages": prefetch_pages,
        "reads": n_chunks,
        "makespan_s": sim.now(),
        "read_rpc_rounds": rep["provider_read_rounds"],
        "prefetch_fills": rep["page_cache_prefetch_fills"],
    }


def run(rep: Reporter) -> None:
    rounds = [_hot_set_round(b) for b in BUDGETS]
    for r in rounds:
        rep.add(
            f"cache_hotset_budget{r['budget_bytes'] // 1024}k",
            r["wall_seconds"] / max(r["ops"], 1) * 1e6,
            f"read_rpcs={r['read_rpc_rounds']};"
            f"pages_fetched={r['read_pages_fetched']};"
            f"wire_rt={r['wire_round_trips']};"
            f"hits={r['page_cache_hits']};"
            f"sf_waits={r['single_flight_waits']};"
            f"saved={r['bytes_saved'] / 1e6:.1f}MB",
        )

    base, best = rounds[0], rounds[-1]
    reduction = base["read_rpc_rounds"] / max(best["read_rpc_rounds"], 1)
    assert reduction >= 2.0, (
        f"default cache budget must cut the hot-set data-plane RPCs >= 2x: "
        f"{base['read_rpc_rounds']} -> {best['read_rpc_rounds']} "
        f"({reduction:.2f}x)"
    )
    # determinism: the cache is part of the schedule, replays are exact
    again = _hot_set_round(DEFAULT_BUDGET)
    assert again["trace_digest"] == best["trace_digest"], (
        "same-seed hot_set runs diverged with the cache enabled"
    )
    rep.add("cache_hotset_rpc_reduction", 0.0,
            f"x{reduction:.1f}_fewer_read_rpcs;replay=identical")

    prefetch = [_prefetch_round(p) for p in (0, CHUNK_PAGES)]
    for r in prefetch:
        rep.add(
            f"cache_prefetch{r['prefetch_pages']}",
            0.0,
            f"seq_makespan={r['makespan_s'] * 1e3:.2f}ms;"
            f"read_rpcs={r['read_rpc_rounds']};"
            f"prefetch_fills={r['prefetch_fills']}",
        )
    assert prefetch[1]["makespan_s"] < prefetch[0]["makespan_s"], (
        "sibling-page prefetch must shorten the sequential reader's "
        f"virtual makespan: {prefetch[0]['makespan_s']:.6f}s -> "
        f"{prefetch[1]['makespan_s']:.6f}s"
    )

    out = os.path.join(os.getcwd(), "BENCH_cache.json")
    with open(out, "w") as f:
        json.dump({
            "bench": "cache", "scenario": "hot_set", "seed": SEED,
            "n_clients": N_CLIENTS, "ops_per_client": OPS_PER_CLIENT,
            "psize": PSIZE, "chunk_pages": CHUNK_PAGES,
            "rpc_reduction_at_default_budget": reduction,
            "rounds": rounds, "prefetch": prefetch,
        }, f, indent=2)
        f.write("\n")


if __name__ == "__main__":
    run(Reporter())
