"""Paper Fig 2(b): aggregate read bandwidth under concurrent readers.

One client appends until the blob holds ``total_mb``; then N in
{1, 25, 50, 100, 175} readers each read a disjoint chunk (the paper's
"concurrently read distinct 64 MB chunks", scaled).  Readers are driven
sequentially in wall time — the simulated wire accounts every endpoint's
busy time independently of issue order, so the derived makespan models
true concurrency (client NICs + provider contention), which is what the
paper measured.  Expect a mild per-reader decline (60 -> 49 MB/s in the
paper at 175 readers).
"""

from __future__ import annotations

from benchmarks.common import Reporter, timer
from repro.core import BlobSeerService


def run(rep: Reporter, *, total_mb: int = 128, chunk_mb: int = 8) -> None:
    n_nodes = 175
    # page cache OFF: the paper's readers run on 175 *distinct* nodes;
    # a shared in-process cache would serve the wrapped-around chunks
    # locally and fake the provider contention this figure measures.
    # The cached regime has its own benchmark (bench_cache).
    svc = BlobSeerService(n_providers=n_nodes - 2, n_meta_shards=n_nodes - 2,
                          placement="two_choice", page_cache_bytes=0)
    writer = svc.client("writer")
    bid = writer.create(psize=64 * 1024)
    payload = b"\xcd" * (4 * 1024 * 1024)
    for _ in range(total_mb // 4):
        writer.append(bid, payload)
    version = writer.get_recent(bid)
    size = writer.get_size(bid, version)

    for n_readers in (1, 25, 50, 100, 175):
        svc.reset_rpc_counters()
        chunk = chunk_mb * 1024 * 1024
        t0 = timer()
        for r in range(n_readers):
            c = svc.client(f"reader-{r}")
            # distinct chunks while they last, then strided overlap — at
            # 128 pages/chunk over 173 providers the page->provider
            # collisions are what bound aggregate bandwidth (paper Fig 2b)
            off = (r * chunk) % (size - chunk)
            c.read(bid, version, off, chunk)
        wall = timer() - t0
        makespan = svc.wire.sim_span()
        total_bytes = n_readers * chunk
        agg = total_bytes / max(makespan, 1e-9) / 1e6
        per = agg / n_readers
        rpc = svc.rpc_report()
        rep.add(
            f"read_concurrent_n{n_readers}",
            wall / n_readers * 1e6,
            f"sim_per_reader={per:.1f}MBps sim_aggregate={agg:.1f}MBps "
            f"chunk={chunk_mb}MB "
            f"rpcs_per_reader={rpc['wire_round_trips'] / n_readers:.1f} "
            f"pages_per_reader={rpc['provider_read_pages'] / n_readers:.1f}",
        )
