"""Roofline summary: aggregates the dry-run artifacts into the §Roofline
table (single-pod).  Requires ``experiments/dryrun/*.json`` (produced by
``python -m repro.launch.dryrun``); emits one row per (arch x shape).
"""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import Reporter

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def run(rep: Reporter) -> None:
    paths = sorted(glob.glob(os.path.join(DRYRUN_DIR, "*_single_*.json")))
    if not paths:
        rep.add("roofline", 0.0, "no dryrun artifacts; run repro.launch.dryrun first")
        return
    for p in paths:
        rec = json.load(open(p))
        if rec.get("status") != "ok":
            continue
        r = rec["roofline"]
        rep.add(
            f"roofline_{rec['arch']}_{rec['shape']}",
            r["step_time_s"] * 1e6,
            f"bottleneck={r['bottleneck']} compute_ms={r['compute_s']*1e3:.2f} "
            f"memory_ms={r['memory_s']*1e3:.2f} "
            f"collective_ms={r['collective_s']*1e3:.2f} "
            f"mfu_bound={r['mfu_bound'] if r['mfu_bound'] is None else round(r['mfu_bound'], 3)}",
        )
