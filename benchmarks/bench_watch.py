"""Subscription plane benchmark: watch/notify vs the poll baseline.

BlobSeer clients learn of new versions by polling ``get_recent`` — one
control-plane RPC per watcher per poll round, O(W) for W watchers no
matter how few publications happen.  The subscription plane registers
watch leases per lineage shard and pushes batched, coalesced,
fire-and-forget notify sends per *inbox endpoint*, so a burst of K
publications costs O(K x endpoints-with-watchers) RPCs, never O(W).

This benchmark runs 10k simulated watchers (multiplexed over 16 gateway
inboxes) against 8 pinned writers and asserts the contract:

* notify RPC count is identical at 1k and 10k watchers (it scales with
  publications and endpoints, not watcher count),
* the poll twin spends >= 10x more control-plane RPCs for the same
  information,
* every lease's delivered stream is exactly ``1..final`` — per-watcher
  monotone, nothing skipped past ``from_version``, no duplicates —
  both in the quiet run and with a lineage leader killed mid-burst
  (the promoted follower resumes deliveries with no gap and no dup),
* same-seed kill runs replay identical trace digests.

Emits ``BENCH_watch.json`` with a ``gate`` dict CI asserts on.
"""

from __future__ import annotations

import json
import os

from benchmarks.common import Reporter
from repro.core.scenarios import BURST, N_WATCH_WRITERS, build_env, \
    run_scenario

N_GATEWAYS = 16
N_CLIENTS = N_WATCH_WRITERS + N_GATEWAYS
OPS_PER_CLIENT = 3
SEED = 13
WATCHERS = 10_000
SMALL_WATCHERS = 1_000
KILL_FRACTION = 0.4   # of the baseline makespan — mid-burst, not at a seam

FINAL = OPS_PER_CLIENT * BURST  # last version every watcher must see


def _run(scenario: str, watchers: int, failures=()):
    env = build_env(N_CLIENTS, seed=SEED, ops_per_client=OPS_PER_CLIENT,
                    scenario=scenario)
    env.state["watchers"] = watchers
    result = run_scenario(scenario, N_CLIENTS, seed=SEED, env=env,
                          failures=failures)
    return env, result


def _delivery_audit(result) -> dict:
    """Check every lease's delivered stream against ``1..FINAL``."""
    want = list(range(1, FINAL + 1))
    leases = missed = duplicated = out_of_order = 0
    for res in result.client_results.values():
        if not (isinstance(res, dict) and "delivered" in res):
            continue
        for stream in res["delivered"].values():
            leases += 1
            if sorted(set(stream)) != sorted(stream):
                duplicated += 1
            if stream != sorted(stream):
                out_of_order += 1
            if set(want) - set(stream):
                missed += 1
    return {"leases": leases, "missed": missed, "duplicated": duplicated,
            "out_of_order": out_of_order}


def _poll_rpcs(result) -> int:
    return sum(res.get("poll_rpcs", 0)
               for res in result.client_results.values()
               if isinstance(res, dict))


def run(rep: Reporter) -> None:
    _, base = _run("watchers", WATCHERS)
    assert not base.errors, base.errors
    _, small = _run("watchers", SMALL_WATCHERS)
    assert not small.errors, small.errors
    _, poll = _run("watchers_poll", WATCHERS)
    assert not poll.errors, poll.errors

    kill_time = KILL_FRACTION * base.makespan
    failures = [(kill_time, "vm-leader:0")]
    _, kill = _run("watchers", WATCHERS, failures=failures)
    assert not kill.errors, kill.errors
    _, replay = _run("watchers", WATCHERS, failures=failures)

    notify_rpcs = base.rpc["watch_notify_rpcs"]
    notify_rpcs_small = small.rpc["watch_notify_rpcs"]
    poll_rpcs = _poll_rpcs(poll)
    audit = _delivery_audit(base)
    kill_audit = _delivery_audit(kill)

    gate = {
        "watchers": WATCHERS,
        "notify_rpcs": notify_rpcs,
        "notify_rpcs_at_1k": notify_rpcs_small,
        "publication_scaled": notify_rpcs == notify_rpcs_small,
        "poll_rpcs": poll_rpcs,
        "rpc_ratio": poll_rpcs / max(notify_rpcs, 1),
        "missed_deliveries": audit["missed"] + kill_audit["missed"],
        "duplicated_deliveries": (audit["duplicated"]
                                  + kill_audit["duplicated"]),
        "out_of_order_deliveries": (audit["out_of_order"]
                                    + kill_audit["out_of_order"]),
        "failovers": kill.rpc["vm_failovers"],
        "digest_match": kill.trace_digest == replay.trace_digest,
    }
    assert audit["leases"] == WATCHERS, audit
    assert kill_audit["leases"] == WATCHERS, kill_audit
    assert gate["publication_scaled"], gate
    assert gate["rpc_ratio"] >= 10.0, gate
    assert gate["missed_deliveries"] == 0, gate
    assert gate["duplicated_deliveries"] == 0, gate
    assert gate["out_of_order_deliveries"] == 0, gate
    assert gate["failovers"] == 1, gate
    assert gate["digest_match"], gate

    rep.add("watch_notify", 0.0,
            f"watchers={WATCHERS};notify_rpcs={notify_rpcs};"
            f"entries={base.rpc['watch_notify_entries']};"
            f"versions={base.rpc['watch_notify_versions']};"
            f"makespan={base.makespan:.4f}s")
    rep.add("watch_poll_twin", 0.0,
            f"watchers={WATCHERS};poll_rpcs={poll_rpcs};"
            f"ratio_x{gate['rpc_ratio']:.1f};"
            f"makespan={poll.makespan:.4f}s")
    rep.add("watch_failover", 0.0,
            f"kill_t={kill_time:.4f}s;failovers={gate['failovers']};"
            f"missed={kill_audit['missed']};"
            f"duplicated={kill_audit['duplicated']};"
            f"digest_match={gate['digest_match']}")

    out = os.path.join(os.getcwd(), "BENCH_watch.json")
    with open(out, "w") as f:
        json.dump({
            "bench": "watch",
            "n_clients": N_CLIENTS,
            "n_gateways": N_GATEWAYS,
            "ops_per_client": OPS_PER_CLIENT,
            "burst": BURST,
            "final_version": FINAL,
            "seed": SEED,
            "kill_time": kill_time,
            "baseline": {
                "watchers": WATCHERS,
                "notify_rpcs": notify_rpcs,
                "notify_entries": base.rpc["watch_notify_entries"],
                "notify_versions": base.rpc["watch_notify_versions"],
                "dropped_sends": base.rpc["watch_dropped_sends"],
                "makespan_s": base.makespan,
                "trace_digest": base.trace_digest,
            },
            "small": {
                "watchers": SMALL_WATCHERS,
                "notify_rpcs": notify_rpcs_small,
            },
            "poll_twin": {
                "watchers": WATCHERS,
                "poll_rpcs": poll_rpcs,
                "makespan_s": poll.makespan,
            },
            "kill": {
                "failovers": kill.rpc["vm_failovers"],
                "makespan_s": kill.makespan,
                "trace_digest": kill.trace_digest,
            },
            "gate": gate,
        }, f, indent=2)
        f.write("\n")


if __name__ == "__main__":
    run(Reporter())
