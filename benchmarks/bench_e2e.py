"""End-to-end train/serve loop: checkpoint bytes scale with the delta.

Runs the ``train_serve`` scenario (trainers streaming corpus shards,
a checkpointer committing deltas through the content-hash dedup
handshake, a serving tier reading recent checkpoints, GC racing
everyone on the virtual clock) and asserts the PR gate:

* steady-state checkpoint bytes-on-wire per step <= 1.25 x (d% of
  model bytes) where each step dirties d% of the model's pages — the
  wire cost scales with the delta, not the model;
* >= 2x total bytes-on-wire reduction vs a dedup-disabled twin on the
  same seed (the twin re-ships the full model on checkpointer
  restart; the dedup handshake ships only the manifest+commit pages);
* branch-then-checkpoint shares pages by refcount, not copy (the fork
  save adds O(1) pages to the store, not O(model));
* the handshake costs <= 1 control round trip per write burst
  (``dedup_lookup_rounds`` <= number of save bursts);
* same-seed replay produces an identical trace digest (the e2e loop
  is deterministic);
* the twin's ``dedup_*`` counters stay zero (``dedup=False`` keeps
  the PR-5 wire schedule).

Emits ``BENCH_e2e.json`` next to the CSV rows.
"""

from __future__ import annotations

import json
import os

from benchmarks.common import Reporter
from repro.core.scenarios import run_scenario

N_CLIENTS = 6
SEED = 3
STEPS = 6          # ops_per_client -> steady checkpoint steps
SLACK = 1.25       # metadata/manifest overhead allowance per step


def _ckpt_row(result) -> dict:
    ck = result.client_results[f"{result.scenario}-000"]
    rpc = result.rpc
    total = sum(ck["per_step_wire"]) + ck["restart_wire"] + ck["branch_wire"]
    return {
        "per_step_wire": ck["per_step_wire"],
        "restart_wire": ck["restart_wire"],
        "restart_pages_scanned": ck["restart_pages_scanned"],
        "branch_wire": ck["branch_wire"],
        "branch_pages_added": ck["branch_pages_added"],
        "branch_pages_written": ck["branch_pages_written"],
        "model_bytes": ck["model_bytes"],
        "dirty_frac": ck["dirty_frac"],
        "total_ckpt_wire": total,
        "dedup_lookup_rounds": rpc["dedup_lookup_rounds"],
        "dedup_hits": rpc["dedup_hits"],
        "dedup_hit_bytes": rpc["dedup_hit_bytes"],
        "dedup_registered": rpc["dedup_registered"],
        "wire_round_trips": rpc["wire_round_trips"],
        "makespan_s": result.makespan,
        "trace_digest": result.trace_digest,
    }


def _run(**kwargs):
    return run_scenario("train_serve", N_CLIENTS, seed=SEED,
                        n_providers=8, n_meta_shards=4,
                        ops_per_client=STEPS, **kwargs)


def run(rep: Reporter) -> None:
    base = _run()
    replay = _run()
    twin = _run(dedup=False)

    assert not base.errors, base.errors
    assert not twin.errors, twin.errors
    digest_match = base.trace_digest == replay.trace_digest
    assert digest_match, (
        f"train_serve same-seed replay diverged: "
        f"{base.trace_digest} != {replay.trace_digest}"
    )

    b, t = _ckpt_row(base), _ckpt_row(twin)

    # Gate 1: steady-state delta scaling.  Each step dirties
    # dirty_frac of the model; the wire must carry at most that plus
    # SLACK for metadata tree nodes, manifest and commit pages.
    step_budget = SLACK * b["dirty_frac"] * b["model_bytes"]
    worst_step = max(b["per_step_wire"])
    assert worst_step <= step_budget, (
        f"checkpoint step shipped {worst_step} B > budget "
        f"{step_budget:.0f} B (= {SLACK} x {b['dirty_frac']:.1%} of "
        f"{b['model_bytes']} B model)"
    )

    # Gate 2: >= 2x reduction vs the dedup-disabled twin, same seed.
    reduction = t["total_ckpt_wire"] / max(b["total_ckpt_wire"], 1)
    assert reduction >= 2.0, (
        f"dedup gate failed: twin shipped {t['total_ckpt_wire']} B, "
        f"dedup shipped {b['total_ckpt_wire']} B -> {reduction:.2f}x"
    )

    # Gate 3: branch shares by refcount, not copy — the fork save adds
    # a few metadata/manifest pages, never ~model_pages copies.
    assert b["branch_pages_added"] <= 4, (
        f"branch save added {b['branch_pages_added']} pages; "
        f"shared pages are being copied, not refcounted"
    )

    # Gate 4: one control round trip per save burst.  Bursts = STEPS
    # steady saves + the restart save + the branch save.
    bursts = STEPS + 2
    assert b["dedup_lookup_rounds"] <= bursts, (
        f"{b['dedup_lookup_rounds']} dedup lookup rounds for "
        f"{bursts} write bursts; handshake is not batched"
    )

    # Gate 5: dedup=False leaves the index untouched.
    twin_dedup = {k: v for k, v in twin.rpc.items()
                  if k.startswith("dedup_") and v}
    assert not twin_dedup, f"dedup=False twin touched the index: {twin_dedup}"

    rep.add("e2e_ckpt_steady", 0.0,
            f"n={N_CLIENTS};steps={STEPS};"
            f"worst_step={worst_step}B;budget={step_budget:.0f}B;"
            f"dirty={b['dirty_frac']:.1%}")
    rep.add("e2e_ckpt_restart", 0.0,
            f"scanned={b['restart_pages_scanned']}pages;"
            f"wire={b['restart_wire']}B;twin_wire={t['restart_wire']}B;"
            f"hits={b['dedup_hits']}")
    rep.add("e2e_ckpt_branch", 0.0,
            f"pages_added={b['branch_pages_added']};"
            f"wire={b['branch_wire']}B")
    rep.add("e2e_gate", 0.0,
            f"reduction_x{reduction:.2f};lookup_rounds="
            f"{b['dedup_lookup_rounds']}/{bursts}bursts;"
            f"digest_match={digest_match};gate>=2.0_passed")

    out = os.path.join(os.getcwd(), "BENCH_e2e.json")
    with open(out, "w") as f:
        json.dump({
            "bench": "e2e",
            "n_clients": N_CLIENTS,
            "seed": SEED,
            "steps": STEPS,
            "dedup": b,
            "twin": t,
            "step_budget_bytes": step_budget,
            "reduction": reduction,
            "digest_match": digest_match,
        }, f, indent=2)
        f.write("\n")


if __name__ == "__main__":
    run(Reporter())
