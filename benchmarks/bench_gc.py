"""GC benchmark: reclaimed bytes, mark rounds, sweep RPCs vs history.

Sweeps history length H at a fixed retention window (keep-last-K) and
measures one GC round per deployment.  The claim under test: the mark
phase costs what the *live set* costs — batched tree walks over the K
kept snapshots, at most depth+1 latency waves per tree — while sweep
RPCs track the retired delta, not total history.  A history 16x longer
must not make marking meaningfully more expensive.

Emits ``BENCH_gc.json`` (machine-readable, for the perf trajectory)
next to the CSV rows.
"""

from __future__ import annotations

import json
import os

from benchmarks.common import Reporter, timer
from repro.core import BlobSeerService
from repro.core.gc import collect_garbage

KEEP_LAST = 8
PSIZE = 4096
CHUNK = 4 * PSIZE
PRELOAD_CHUNKS = 32   # fixed live extent: the blob never grows past this
HISTORIES = (16, 64, 256)


def _one_round(history: int) -> dict:
    svc = BlobSeerService(n_providers=8, n_meta_shards=8)
    c = svc.client("loader")
    bid = c.create(psize=PSIZE)
    c.set_retention(bid, keep_last=KEEP_LAST)
    # fixed-size blob + overwrite-only history: the live set (what kept
    # snapshots reach) stays constant while retired history grows, so
    # any growth in mark cost would be a scaling bug, not bigger data
    for i in range(PRELOAD_CHUNKS):
        c.append(bid, bytes([i % 251 + 1]) * CHUNK)
    for i in range(history):
        payload = bytes([(i * 7) % 251 + 1]) * CHUNK
        c.write(bid, payload, (i % PRELOAD_CHUNKS) * CHUNK)
    bytes_before = svc.storage_report()["page_bytes"]
    svc.reset_rpc_counters()

    t0 = timer()
    stats = collect_garbage(svc)
    dt = timer() - t0
    rep = svc.rpc_report()

    return {
        "history": history,
        "keep_last": KEEP_LAST,
        "retired_versions": stats["retired_versions"],
        "kept_versions": stats["kept_versions"],
        "reclaimed_bytes": stats["reclaimed_bytes"],
        "bytes_before": bytes_before,
        "bytes_after": svc.storage_report()["page_bytes"],
        "mark_rounds": stats["mark_rounds"],
        "mark_keys": stats["mark_keys"],
        "live_nodes": stats["live_nodes"],
        "swept_nodes": stats["swept_nodes"],
        "swept_pages": stats["swept_pages"],
        "sweep_rpcs": rep["dht_delete_shard_rpcs"] + rep["provider_sweep_rounds"],
        "wire_round_trips": rep["wire_round_trips"],
        "wall_seconds": dt,
    }


def run(rep: Reporter) -> None:
    results = [_one_round(h) for h in HISTORIES]
    for r in results:
        rep.add(
            f"gc_hist{r['history']}",
            r["wall_seconds"] * 1e6,
            f"reclaimed={r['reclaimed_bytes'] / 1e6:.2f}MB;"
            f"retired={r['retired_versions']};"
            f"mark_rounds={r['mark_rounds']};mark_keys={r['mark_keys']};"
            f"sweep_rpcs={r['sweep_rpcs']}",
        )

    # Perf contract: mark cost scales with the live set, not history.
    # 16x more history, same retention window => the mark's batched
    # rounds grow only with tree depth (log of blob size) and its key
    # count only with the kept snapshots' trees.
    first, last = results[0], results[-1]
    assert last["reclaimed_bytes"] > first["reclaimed_bytes"] > 0
    assert last["mark_keys"] <= 2 * first["mark_keys"], (
        f"mark keys grew with history: {first['mark_keys']} -> {last['mark_keys']}"
    )
    assert last["mark_rounds"] <= first["mark_rounds"] + 1, (
        f"mark rounds grew with history: {first['mark_rounds']} -> "
        f"{last['mark_rounds']}"
    )
    growth = last["sweep_rpcs"] / max(first["sweep_rpcs"], 1)
    rep.add("gc_mark_scaling", 0.0,
            f"mark_keys_x{last['mark_keys'] / first['mark_keys']:.2f}_"
            f"for_history_x{last['history'] / first['history']:.0f};"
            f"sweep_rpc_x{growth:.2f}")

    out = os.path.join(os.getcwd(), "BENCH_gc.json")
    with open(out, "w") as f:
        json.dump({"bench": "gc", "keep_last": KEEP_LAST,
                   "psize": PSIZE, "chunk": CHUNK,
                   "rounds": results}, f, indent=2)
        f.write("\n")


if __name__ == "__main__":
    run(Reporter())
