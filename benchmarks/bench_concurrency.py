"""Paper §5 scaling experiments on the deterministic virtual-time harness.

Sweeps N concurrent simulated clients through the four §5 workloads —
N readers of one blob, N appenders, N writers to disjoint ranges, and a
mixed read/write load — and emits per-scenario aggregate-throughput
curves plus RPC-round counts from ``rpc_report()``.  A 256-client
experiment runs in a couple of wall-clock seconds because every blocking
point advances a virtual clock instead of sleeping; the schedule itself
is produced by the per-endpoint wire queueing model (Grid'5000
constants: 117.5 MB/s, 0.1 ms), so the curves reproduce the paper's
contention behavior, not Python thread timing.

## Concurrency harness quickstart

Every run is bit-reproducible from its seed::

    from repro.core.scenarios import run_scenario
    r = run_scenario("appenders", 256, seed=1)
    r.trace_digest    # identical across runs with the same seed
    r.aggregate_mbps  # simulated aggregate throughput
    r.rpc             # per-operation RPC/round-trip counters

To write your own scenario (or inject failures at virtual times), see
``repro/core/scenarios.py``; to schedule arbitrary client programs, see
``repro/core/sim.py`` (``Simulator.spawn`` / ``run``).

CLI::

    PYTHONPATH=src python -m benchmarks.bench_concurrency --max-n 256
    PYTHONPATH=src python -m benchmarks.bench_concurrency \
        --scenarios readers,mixed --seed 7 --skip-determinism-check
"""

from __future__ import annotations

import argparse
import sys
import time

from benchmarks.common import Reporter
from repro.core.scenarios import SCENARIOS, run_scenario

DEFAULT_SEED = 1
DEFAULT_MAX_N = 256


def _sweep_ns(max_n: int):
    n = 1
    while n < max_n:
        yield n
        n *= 2
    yield max_n


def run(rep: Reporter, *, max_n: int = DEFAULT_MAX_N, seed: int = DEFAULT_SEED,
        scenarios=None, verify_determinism: bool = True) -> None:
    """Emit the scaling curves; raises if a seeded replay diverges."""
    names = list(scenarios or SCENARIOS)
    diverged = []
    for name in names:
        for n in _sweep_ns(max_n):
            r = run_scenario(name, n, seed=seed)
            if r.errors:
                raise RuntimeError(f"{name} n={n}: {r.errors}")
            rep.add(
                f"concurrency_{name}_n{n}",
                r.wall_seconds / max(r.ops, 1) * 1e6,
                f"sim_aggregate={r.aggregate_mbps:.1f}MBps "
                f"makespan={r.makespan * 1e3:.2f}ms "
                f"rpc_rounds={r.rpc['wire_round_trips']} "
                f"rpc_rounds_per_client={r.rpc['wire_round_trips'] / n:.1f} "
                f"events={r.events} trace={r.trace_digest[:12]}",
            )
            if verify_determinism and n == max_n:
                again = run_scenario(name, n, seed=seed)
                same = again.trace_digest == r.trace_digest
                if not same:
                    diverged.append(name)
                rep.add(
                    f"concurrency_{name}_replay_n{n}", 0.0,
                    f"deterministic={'yes' if same else 'NO'} "
                    f"trace={again.trace_digest[:12]}",
                )
    if diverged:
        raise RuntimeError(
            f"determinism check FAILED: traces diverged across same-seed "
            f"replays of {diverged}"
        )


def main() -> None:
    ap = argparse.ArgumentParser(
        description=sys.modules[__name__].__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    ap.add_argument("--scenarios", default=",".join(SCENARIOS),
                    help=f"comma list from {list(SCENARIOS)}")
    ap.add_argument("--max-n", type=int, default=DEFAULT_MAX_N,
                    help="largest client count in the 1,2,4,... sweep")
    ap.add_argument("--seed", type=int, default=DEFAULT_SEED,
                    help="scheduler seed; same seed => identical event trace")
    ap.add_argument("--skip-determinism-check", action="store_true",
                    help="skip the replay (same seed, compare traces) pass")
    args = ap.parse_args()

    names = [s for s in args.scenarios.split(",") if s]
    unknown = [s for s in names if s not in SCENARIOS]
    if unknown:
        ap.error(f"unknown scenarios {unknown}; known: {list(SCENARIOS)}")

    rep = Reporter()
    print("name,us_per_call,derived")
    t0 = time.perf_counter()
    run(rep, max_n=args.max_n, seed=args.seed, scenarios=names,
        verify_determinism=not args.skip_determinism_check)
    print(f"# total wall time: {time.perf_counter() - t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
