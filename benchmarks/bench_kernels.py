"""Kernel-path throughput on the CPU oracle path (jit'd ref).

Real TPU numbers come from the roofline analysis; here we verify the
digest/delta pipeline sustains enough host-side throughput to never gate
checkpointing, and time the blockwise attention path the 32k cells use.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import Reporter, timer
from repro.kernels import ops


def _bench(fn, *args, iters=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = timer()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (timer() - t0) / iters


def run(rep: Reporter) -> None:
    rng = np.random.default_rng(0)

    x = jnp.asarray(rng.standard_normal(8 * 1024 * 1024 // 4), jnp.float32)  # 8MB
    dt = _bench(lambda a: ops.page_digest(a, page_bytes=64 * 1024), x)
    rep.add("page_digest_8MB", dt * 1e6, f"bw={8 / dt:.0f}MBps")

    d1 = ops.page_digest(x, page_bytes=64 * 1024)
    d2 = d1.at[3, 0].add(1)
    dt = _bench(ops.delta_mask, d1, d2)
    rep.add("delta_mask_128pages", dt * 1e6, f"pages_per_s={128/dt:.0f}")

    q = jnp.asarray(rng.standard_normal((1, 8, 1024, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((1, 2, 8192, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((1, 2, 8192, 64)), jnp.bfloat16)
    from repro.models.layers import attention_core
    attn = jax.jit(lambda q, k, v: attention_core(
        q, k, v, causal=True, window=None, q_offset=7168, softcap=None))
    dt = _bench(attn, q, k, v)
    flops = 4 * 1 * 8 * 1024 * 8192 * 64 / 2
    rep.add("blockwise_attn_1k_q_8k_kv", dt * 1e6,
            f"gflops={flops/dt/1e9:.1f}")

    a = jnp.asarray(rng.uniform(0.9, 0.999, (4, 2048, 256)), jnp.float32)
    xs = jnp.asarray(rng.standard_normal((4, 2048, 256)), jnp.float32)
    dt = _bench(ops.linear_scan, a, xs)
    rep.add("linear_scan_4x2048x256", dt * 1e6,
            f"elems_per_s={a.size/dt/1e6:.0f}M")
