"""Docs gate: run doc snippets verbatim + check intra-repo links.

Two checks, both run by the CI docs job (and runnable locally):

1. **Snippet execution** — every ```` ```python ```` fenced block in the
   given markdown files is executed, blocks of one file sharing a
   namespace (so a later block may use names an earlier block defined).
   The documentation layer cannot rot silently: if a documented
   walkthrough stops working, the docs job fails.

2. **Intra-repo link check** — every markdown link/image target in
   every tracked ``*.md`` that is not an external URL must resolve to
   an existing file or directory (anchors are stripped).  A renamed doc
   or module breaks the job instead of the reader.

Usage::

    PYTHONPATH=src python tools/check_docs.py            # both checks
    PYTHONPATH=src python tools/check_docs.py --links-only
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

# markdown files whose ```python blocks must execute cleanly
SNIPPET_FILES = [
    "docs/write-path.md",
    "docs/concurrency.md",
    "docs/checkpoint.md",
    "docs/durability.md",
    "docs/watch.md",
    "docs/membership.md",
]

_FENCE = re.compile(r"^```python\s*$(.*?)^```\s*$", re.M | re.S)
# [text](target) and ![alt](target); ignores ``` fenced regions crudely
# by stripping them first
_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)\)")
_FENCED_REGION = re.compile(r"```.*?```", re.S)


def run_snippets(paths) -> int:
    failures = 0
    for rel in paths:
        path = REPO / rel
        blocks = _FENCE.findall(path.read_text())
        if not blocks:
            continue
        ns: dict = {"__name__": f"docsnippet:{rel}"}
        for i, block in enumerate(blocks):
            try:
                exec(compile(block, f"{rel}[snippet {i}]", "exec"), ns)
            except Exception as e:  # noqa: BLE001 - report and fail the job
                print(f"FAIL {rel} snippet {i}: {e!r}")
                failures += 1
            else:
                print(f"ok   {rel} snippet {i}")
    return failures


def check_links() -> int:
    failures = 0
    md_files = [p for p in REPO.rglob("*.md")
                if ".git" not in p.parts and "node_modules" not in p.parts]
    for md in md_files:
        text = _FENCED_REGION.sub("", md.read_text())
        for target in _LINK.findall(text):
            if re.match(r"^[a-z][a-z0-9+.-]*:", target):  # http:, mailto:, ...
                continue
            if target.startswith("#"):
                continue  # same-file anchor
            rel = target.split("#", 1)[0]
            resolved = (md.parent / rel).resolve()
            if not resolved.exists():
                print(f"BROKEN LINK {md.relative_to(REPO)}: ({target})")
                failures += 1
    return failures


def main() -> int:
    failures = 0
    if "--links-only" not in sys.argv:
        failures += run_snippets(SNIPPET_FILES)
    failures += check_links()
    if failures:
        print(f"{failures} docs check(s) failed")
        return 1
    print("docs checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
