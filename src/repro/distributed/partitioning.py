"""Partitioning rules: logical axes -> mesh axes, with divisibility guards.

Strategies (select with ``--strategy`` or per-arch defaults):

* ``tp``        — Megatron-style tensor parallelism over "model"
                  (heads / d_ff / vocab / experts), pure DP over
                  "data" (+ "pod").  Parameters replicated across DP.
* ``tp_fsdp``   — ``tp`` + ZeRO-3: the "embed" dimension of every
                  weight is sharded over ("pod", "data"); XLA inserts
                  all-gathers on use and reduce-scatters on grads.
                  Required for the 32B/76B cells (replicated params
                  would not fit 16 GB/chip).
* ``tp_fsdp_sp``— ``tp_fsdp`` + sequence sharding of activations
                  (long-prefill cells).

A physical axis is silently dropped for a given array dimension when the
dimension is not divisible by the axis size (e.g. kv_heads=8 on a
16-way "model" axis, vocab=49155 which is odd) — the guard keeps every
(arch x mesh) cell lowerable; the §Roofline table shows what it costs.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# rule tables
# ---------------------------------------------------------------------------

_DP = ("pod", "data")     # data-parallel super-axis (collapses if absent)

RULESETS: Dict[str, Dict[str, Any]] = {
    "tp": {
        # parameters
        "vocab": "model",
        "embed": None,
        "mlp": "model",
        "q_heads": "model",
        "kv_heads": "model",
        "head": None,
        "experts": "model",
        "rnn": "model",
        "rnn_up": "model",
        "rnn_gate": "model",
        "rnn_gates": "model",
        "gates": None,
        "conv": None,
        "layers": None,
        # activations
        "batch": _DP,
        "seq": None,
        "embed_act": None,
        "heads_act": "model",
        "kv_act": "model",
        "kv_seq": None,
        "mlp_act": "model",
        "experts_act": "model",
        "vocab_act": "model",
    },
}

RULESETS["tp_fsdp"] = dict(RULESETS["tp"], embed=_DP)
RULESETS["tp_fsdp_sp"] = dict(RULESETS["tp_fsdp"], seq="data")
# Serving: KV cache seq-dim sharding kicks in when kv_heads doesn't divide
# the model axis (GQA kv=8 on 16-way TP) — the used-axis guard in spec_for
# prefers kv_heads and falls back to kv_seq automatically.
RULESETS["tp_serve"] = dict(RULESETS["tp"], kv_seq="model")
# Head-dim cache sharding: decode writes (dynamic_update_slice at a traced
# position) stay LOCAL because the seq dim is unsharded; the dh-contraction
# produces partial scores all-reduced per token.  Fixes the DUS-induced
# cache gather that blows HBM for kv_heads-indivisible archs (§Perf-D).
RULESETS["tp_serve_hd"] = dict(RULESETS["tp"], kv_seq=None, head="model")

SHARD_DECODE_FLAG = "__shard_decode__"
# Hand-scheduled decode: seq-sharded cache + shard_map flash-combine
# (distributed/decode_attn.py) — local cache writes, O(B·H·dh) combine
# collectives.  Selected when kv_heads don't divide the model axis or
# the GSPMD path's aliasing is insufficient (§Perf-D round 2).
RULESETS["tp_serve_sm"] = dict(RULESETS["tp_serve"], **{SHARD_DECODE_FLAG: True})

_ALL = ("pod", "data", "model")
# Pure data-parallel layout for small models on big meshes: params
# replicated (ZeRO shards the embed dim across ALL chips for storage),
# batch sharded over every mesh axis, no tensor parallelism — kills the
# per-layer TP all-reduces that dominate small-d_model archs at 256 chips.
RULESETS["dp_fsdp"] = {
    "vocab": None, "embed": _ALL, "mlp": None, "q_heads": None,
    "kv_heads": None, "head": None, "experts": None, "rnn": None,
    "rnn_up": None, "rnn_gate": None, "rnn_gates": None, "gates": None,
    "conv": None, "layers": None,
    "batch": _ALL, "seq": None, "embed_act": None, "heads_act": None,
    "kv_act": None, "kv_seq": None, "mlp_act": None, "experts_act": None,
    "vocab_act": None,
}

UNEVEN_FLAG = "__uneven__"


def get_rules(strategy: str) -> Dict[str, Any]:
    """Resolve a strategy name.  Suffixes compose:

    * ``_uneven`` relaxes the divisibility guard (GSPMD pads): 40 heads
      on a 16-way axis shard as ceil(40/16)=3 per device (1.2x padding)
      instead of replicating 16x;
    * ``_zero2`` is consumed by the step builder (hoisted param gather)
      and does not change the rule table.
    """
    base = strategy
    uneven = False
    for _ in range(2):
        if base.endswith("_uneven"):
            uneven = True
            base = base[: -len("_uneven")]
        if base.endswith("_zero2"):
            base = base[: -len("_zero2")]
    rules = dict(RULESETS[base])
    if uneven:
        rules[UNEVEN_FLAG] = True
    return rules


# ---------------------------------------------------------------------------
# spec construction with divisibility guards
# ---------------------------------------------------------------------------


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, str):
        return mesh.shape.get(axis, 1) if axis in mesh.axis_names else 0
    return math.prod(_axis_size(mesh, a) for a in axis)


def spec_for(
    mesh: Mesh,
    rules: Dict[str, Any],
    names: Sequence[Optional[str]],
    shape: Sequence[int],
) -> P:
    """PartitionSpec for one array given its logical names + shape."""
    parts = []
    used: set = set()
    uneven_ok = bool(rules.get(UNEVEN_FLAG))
    for dim, name in zip(shape, names):
        axis = rules.get(name) if name is not None else None
        if axis is None:
            parts.append(None)
            continue
        flat = (axis,) if isinstance(axis, str) else tuple(axis)
        flat = tuple(a for a in flat if a in mesh.axis_names and a not in used)
        total = math.prod(mesh.shape[a] for a in flat) if flat else 1
        # divisibility guard: drop trailing axes until it divides —
        # unless uneven sharding is allowed and the dim spans the axis
        # (GSPMD pads; waste factor = ceil(dim/total)*total/dim)
        while flat and dim % total != 0 and not (uneven_ok and dim >= total):
            flat = flat[:-1]
            total = math.prod(mesh.shape[a] for a in flat) if flat else 1
        if not flat:
            parts.append(None)
            continue
        used.update(flat)
        parts.append(flat if len(flat) > 1 else flat[0])
    return P(*parts)


def param_shardings(mesh: Mesh, rules: Dict[str, Any], abstract_params, axes_tree):
    """NamedSharding tree for a (abstract) param tree + its axes twin."""
    def one(p, names):
        return NamedSharding(mesh, spec_for(mesh, rules, names, p.shape))

    return jax.tree.map(one, abstract_params, axes_tree)


def sharding(mesh: Mesh, rules: Dict[str, Any], names, shape) -> NamedSharding:
    return NamedSharding(mesh, spec_for(mesh, rules, names, shape))


# ---------------------------------------------------------------------------
# batch / cache axes (path-based annotation)
# ---------------------------------------------------------------------------


def batch_axes_for(batch_tree) -> Any:
    """Logical axes for an input batch dict (tokens/labels/embeds)."""
    def one(path, leaf):
        key = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if key in ("tokens", "labels"):
            return ("batch", "seq")
        if key in ("vision_embeds", "enc_embeds"):
            return ("batch", "seq", "embed_act")
        if key in ("token",):
            return ("batch",)
        return tuple([None] * np.ndim(leaf))

    return _map_with_path(one, batch_tree)


def cache_axes_for(cache_tree) -> Any:
    """Logical axes for KV/state caches by leaf name + rank.

    Handles both the decoder layout ({"groups": [stacked...], "rest":
    [...]}) and the enc-dec layout (one stacked tree): any k/v leaf of
    rank 5 carries a leading "layers" axis, rank 4 does not.
    """
    # base (unstacked) logical names per leaf key; a leading "layers"
    # axis is inferred whenever the leaf's rank exceeds the base rank.
    BASE = {
        "k": ("batch", "kv_heads", "kv_seq", "head"),
        "v": ("batch", "kv_heads", "kv_seq", "head"),
        "pos": (None,),
        "conv": ("batch", None, "rnn"),
        "C": ("batch", "q_heads", None, None),
    }
    AMBIG = {  # two legal base forms (mlstm vs slstm states)
        "h": [("batch", "rnn")],
        "n": [("batch", "q_heads", "head"), ("batch", "rnn")],
        "m": [("batch", "q_heads"), ("batch", "rnn")],
        "c": [("batch", "rnn")],
    }

    def one(path, leaf):
        key = None
        for entry in reversed(path):
            if hasattr(entry, "key"):
                key = entry.key
                break
        rank = np.ndim(leaf)
        candidates = [BASE[key]] if key in BASE else AMBIG.get(key, [])
        for base in candidates:
            if rank == len(base):
                return base
            if rank == len(base) + 1:
                return ("layers",) + base
        return tuple([None] * rank)

    return _map_with_path(one, cache_tree)


def memories_axes_for(mem_tree) -> Any:
    """Cross-attention memories: (layers, B, H, T, Dh) leaves."""
    def one(path, leaf):
        rank = np.ndim(leaf)
        if rank == 5:
            return ("layers", "batch", "kv_heads", None, "head")
        return tuple([None] * rank)

    return _map_with_path(one, mem_tree)


def _under_groups(path) -> bool:
    for entry in path:
        if hasattr(entry, "key") and entry.key == "groups":
            return True
    return False


def _map_with_path(fn, tree):
    return jax.tree_util.tree_map_with_path(fn, tree)


def shardings_for_tree(mesh: Mesh, rules, abstract_tree, axes_tree):
    def one(leaf, names):
        return NamedSharding(mesh, spec_for(mesh, rules, names, leaf.shape))

    return jax.tree.map(one, abstract_tree, axes_tree)
