"""Logical-axis sharding (t5x-style).

Model code names array dimensions with *logical* axes ("embed", "mlp",
"q_heads", ...).  A rule table maps logical names to physical mesh axes
("data", "model", "pod", None).  Changing the sharding strategy — the
main lever of the §Perf hillclimb — means changing the rule table only;
no model code is touched.

``constrain(x, *names)`` applies ``with_sharding_constraint`` when a
mesh + rules are active and is a no-op otherwise, so the same model code
runs single-device smoke tests and 512-chip dry-runs.
"""

from __future__ import annotations

import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisRule = Union[str, Tuple[str, ...], None]

_state = threading.local()


def set_logical_rules(rules: Dict[str, AxisRule], mesh: Mesh) -> None:
    _state.rules = dict(rules)
    _state.mesh = mesh


def clear_logical_rules() -> None:
    _state.rules = None
    _state.mesh = None


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


def current_rules() -> Optional[Dict[str, AxisRule]]:
    return getattr(_state, "rules", None)


def logical_to_spec(names: Sequence[Optional[str]]) -> P:
    """Map a tuple of logical axis names to a PartitionSpec."""
    rules = current_rules() or {}
    mesh = current_mesh()
    mesh_axes = set(mesh.axis_names) if mesh is not None else set()
    parts = []
    used: set = set()

    def resolve(name: Optional[str]):
        if name is None:
            return None
        axis = rules.get(name)
        if axis is None:
            return None
        # one physical axis may shard only one dim of a given array, and
        # the axis must exist in the active mesh (e.g. no "pod" single-pod)
        flat = (axis,) if isinstance(axis, str) else tuple(axis)
        free = tuple(a for a in flat if a not in used and a in mesh_axes)
        if not free:
            return None
        used.update(free)
        return free if len(free) > 1 else free[0]

    for n in names:
        parts.append(resolve(n))
    return P(*parts)


def constrain(x: jax.Array, *names: Optional[str]) -> jax.Array:
    """Annotate intermediate ``x`` with a logical sharding (no-op w/o mesh)."""
    mesh = current_mesh()
    if mesh is None or getattr(_state, "rules", None) is None:
        return x
    if x.ndim != len(names):
        raise ValueError(f"rank {x.ndim} vs names {names}")
    spec = logical_to_spec(names)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
