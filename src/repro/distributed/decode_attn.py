"""Hand-scheduled sharded decode attention (shard_map).

GSPMD struggles with seq-sharded KV caches at decode: the
dynamic-update-slice at a traced position and the softmax over the
sharded axis lower to cache-sized gathers (EXPERIMENTS.md §Perf-D).
This module schedules the step explicitly over the "model" axis:

* the cache stays sharded over its sequence dim; the new token's KV is
  written **locally** by the shard that owns the slot (a one-slot
  dynamic-update-slice with a where-select — no cross-shard traffic);
* each shard runs an online-softmax (flash) pass over its own chunk;
* shards combine with three tiny collectives: pmax of the running max
  and psums of the rescaled normalizer/accumulator —
  O(B·H·dh) bytes per layer instead of O(cache).

The query is replicated over "model" (it is one token); batch stays
sharded over the DP axes.  Exact up to float associativity — verified
against the reference decode path in tests/test_decode_attn.py.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

try:  # jax >= 0.8
    from jax import shard_map
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map

import inspect

# jax renamed check_rep -> check_vma; pass whichever this version takes
_CHECK_KW = (
    "check_vma"
    if "check_vma" in inspect.signature(shard_map).parameters
    else "check_rep"
)

AXIS = "model"


def _local_step(q, ck, cv, cpos, k_new, v_new, positions,
                *, causal, window, softcap, n_shards):
    """Runs on ONE shard: local write + local flash + global combine."""
    ax = jax.lax.axis_index(AXIS)
    B, Hq, Tq, D = q.shape
    Hkv = ck.shape[1]
    group = Hq // Hkv
    local_len = ck.shape[2]
    pos = positions[0]
    slot = pos % (local_len * n_shards)
    owner = slot // local_len
    local_slot = slot % local_len
    mine = ax == owner

    # -- local in-place write: owner takes the new KV, others rewrite the
    #    existing slot value (no cross-shard traffic, alias-friendly) --
    old_k = jax.lax.dynamic_slice(ck, (0, 0, local_slot, 0), (B, Hkv, 1, D))
    old_v = jax.lax.dynamic_slice(cv, (0, 0, local_slot, 0), (B, Hkv, 1, D))
    wk = jnp.where(mine, k_new.astype(ck.dtype), old_k)
    wv = jnp.where(mine, v_new.astype(cv.dtype), old_v)
    ck = jax.lax.dynamic_update_slice(ck, wk, (0, 0, local_slot, 0))
    cv = jax.lax.dynamic_update_slice(cv, wv, (0, 0, local_slot, 0))
    old_p = jax.lax.dynamic_slice(cpos, (local_slot,), (1,))
    cpos = jax.lax.dynamic_update_slice(
        cpos, jnp.where(mine, positions, old_p), (local_slot,))

    # -- local flash over this shard's chunk --
    qf = (q.astype(ck.dtype) * (D ** -0.5)).reshape(B, Hkv, group, Tq, D)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, ck,
                   preferred_element_type=jnp.float32)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    mask = cpos[None, :] >= 0
    if causal:
        mask = mask & (cpos[None, :] <= positions[:, None])
    if window is not None:
        mask = mask & (cpos[None, :] > positions[:, None] - window)
    s = jnp.where(mask[None, None, None], s, -1e30)
    m_loc = jnp.max(s, axis=-1)                            # (B,Hkv,g,Tq)
    p = jnp.exp(s - m_loc[..., None])
    p = jnp.where(mask[None, None, None], p, 0.0)
    l_loc = p.sum(-1)
    acc_loc = jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(cv.dtype), cv,
                         preferred_element_type=jnp.float32)

    # -- tiny cross-shard combine --
    m_g = jax.lax.pmax(m_loc, AXIS)
    scale = jnp.exp(m_loc - m_g)
    l_g = jax.lax.psum(l_loc * scale, AXIS)
    acc_g = jax.lax.psum(acc_loc * scale[..., None], AXIS)
    l_g = jnp.where(l_g == 0.0, 1.0, l_g)
    out = (acc_g / l_g[..., None]).reshape(B, Hq, Tq, D).astype(q.dtype)
    return out, ck, cv, cpos


def sharded_decode_attention(
    mesh: Mesh,
    q: jax.Array,              # (B, Hq, 1, D)
    cache: Dict,               # {"k","v","pos"} seq-sharded over AXIS
    k_new: jax.Array,          # (B, Hkv, 1, D)
    v_new: jax.Array,
    positions: jax.Array,      # (1,) absolute position
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    dp_axes: Tuple[str, ...] = ("pod", "data"),
) -> Tuple[jax.Array, Dict]:
    dp = tuple(a for a in dp_axes if a in mesh.axis_names)
    dp_spec = dp if len(dp) > 1 else (dp[0] if dp else None)
    n_shards = mesh.shape[AXIS]
    fn = shard_map(
        lambda q_, ck_, cv_, cp_, kn_, vn_, pos_: _local_step(
            q_, ck_, cv_, cp_, kn_, vn_, pos_,
            causal=causal, window=window, softcap=softcap, n_shards=n_shards,
        ),
        mesh=mesh,
        in_specs=(
            P(dp_spec, None, None, None),      # q replicated over model
            P(dp_spec, None, AXIS, None),      # cache k: seq sharded
            P(dp_spec, None, AXIS, None),      # cache v
            P(AXIS),                           # cache positions
            P(dp_spec, None, None, None),      # new k
            P(dp_spec, None, None, None),      # new v
            P(None),                           # position scalar-vector
        ),
        out_specs=(
            P(dp_spec, None, None, None),
            P(dp_spec, None, AXIS, None),
            P(dp_spec, None, AXIS, None),
            P(AXIS),
        ),
        **{_CHECK_KW: False},
    )
    out, ck, cv, cpos = fn(q, cache["k"], cache["v"], cache["pos"],
                           k_new, v_new, positions)
    return out, {"k": ck, "v": cv, "pos": cpos}
