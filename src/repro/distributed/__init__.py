"""Distribution: logical-axis partitioning, collectives, mesh helpers."""

from repro.distributed.axes import (
    constrain,
    logical_to_spec,
    set_logical_rules,
    clear_logical_rules,
    current_mesh,
)

__all__ = [
    "constrain",
    "logical_to_spec",
    "set_logical_rules",
    "clear_logical_rules",
    "current_mesh",
]
