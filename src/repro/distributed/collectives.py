"""Hand-rolled collectives: gradient compression + overlap helpers.

Int8-compressed gradient all-reduce (1-bit-Adam-family trick, stochastic
rounding): inside ``shard_map`` over the DP axis each shard quantizes to
int8 against a globally agreed scale (one cheap f32 ``pmax`` for the
scale, then the payload moves at 1/4 the bytes of bf16).  Used by the
e2e trainer's ``--grad-compress int8`` flag; the pjit path leaves
reduction to GSPMD (already bf16) — measured deltas live in
EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

try:  # jax >= 0.8
    from jax import shard_map
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map


def _stochastic_round_int8(x: jax.Array, scale: jax.Array, rng: jax.Array) -> jax.Array:
    y = x / scale * 127.0
    lo = jnp.floor(y)
    frac = y - lo
    bern = jax.random.uniform(rng, y.shape) < frac
    return jnp.clip(lo + bern, -127, 127).astype(jnp.int8)


def int8_allreduce_mean(x: jax.Array, rng: jax.Array, *, axis_name: str) -> jax.Array:
    """All-reduce-mean of ``x`` over ``axis_name`` with int8 payload."""
    n = jax.lax.psum(1, axis_name)
    # shared scale so every shard quantizes against the same grid
    scale = jax.lax.pmax(jnp.max(jnp.abs(x)), axis_name) + 1e-12
    q = _stochastic_round_int8(x.astype(jnp.float32), scale, rng)
    s = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return (s.astype(jnp.float32) * scale / 127.0 / n).astype(x.dtype)


def compressed_grad_mean(grads: Any, mesh: Mesh, axis_name: str, rng: jax.Array) -> Any:
    """Tree-wide int8 all-reduce-mean over one mesh axis via shard_map.

    Gradients are assumed replicated along every *other* mesh axis
    (host-level DP use case in examples/train_e2e.py).
    """
    leaves, treedef = jax.tree.flatten(grads)
    rngs = jax.random.split(rng, len(leaves))

    out = []
    for leaf, r in zip(leaves, rngs):
        fn = shard_map(
            functools.partial(int8_allreduce_mean, axis_name=axis_name),
            mesh=mesh,
            in_specs=(P(axis_name), P()),
            out_specs=P(axis_name),
        )
        padded = leaf.reshape(-1)
        n_dev = mesh.shape[axis_name]
        pad = (-padded.shape[0]) % n_dev
        if pad:
            padded = jnp.pad(padded, (0, pad))
        red = fn(padded, r)
        out.append(red[: leaf.size].reshape(leaf.shape).astype(leaf.dtype))
    return jax.tree.unflatten(treedef, out)
