"""Input-shape cells and per-arch applicability.

Every LM-family arch is paired with the four assigned shapes; ``step``
selects which program the dry-run lowers:

* ``train_4k``    -> train_step   (seq 4096, global batch 256)
* ``prefill_32k`` -> prefill_step (seq 32768, global batch 32)
* ``decode_32k``  -> serve_step   (1 new token, KV cache 32768, batch 128)
* ``long_500k``   -> serve_step   (1 new token, state at 524288, batch 1)
  — requires a sub-quadratic arch; skipped for full-attention archs
  (see DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Tuple

from repro.models.config import ModelConfig


@dataclass(frozen=True)
class ShapeCell:
    name: str
    step: str            # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524_288, 1),
}


def applicable(cfg: ModelConfig, shape: ShapeCell) -> Tuple[bool, str]:
    """(runs?, reason-if-skipped)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention arch: 500k decode is quadratic — skipped"
    return True, ""


def cells_for(cfg: ModelConfig) -> List[ShapeCell]:
    return [s for s in SHAPES.values() if applicable(cfg, s)[0]]
