"""qwen3-32b [dense]: 64L d_model=5120 64H (GQA kv=8) d_ff=25600
vocab=151936 — qk_norm, GQA. [hf:Qwen/Qwen3-8B; hf]

Qwen3 uses an explicit head_dim of 128 (64 x 128 = 8192 > d_model) and
per-head RMSNorm on q/k.  Full attention -> long_500k is skipped.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=25600,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    norm_kind="rmsnorm",
    mlp_kind="swiglu",
    block_pattern=("attn",),
)
