"""recurrentgemma-2b [hybrid]: 26L d_model=2560 10H (GQA kv=1) d_ff=7680
vocab=256000 — RG-LRU + local attn, 1:2. [arXiv:2402.19427; hf]

Griffin pattern: (recurrent, recurrent, local-attention) repeating;
26 layers = 8 full patterns + 2 trailing recurrent layers.  Local
attention window 2048, MQA (kv=1).  Sub-quadratic -> long_500k runs.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_ff=7680,
    vocab_size=256000,
    window=2048,
    norm_kind="rmsnorm",
    mlp_kind="geglu",
    block_pattern=("rglru", "rglru", "local"),
    d_rnn=2560,
    conv_width=4,
    tie_embeddings=True,
)
