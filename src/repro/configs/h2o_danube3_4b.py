"""h2o-danube-3-4b [dense]: 24L d_model=3840 32H (GQA kv=8) d_ff=10240
vocab=32000 — llama+mistral mix, SWA. [arXiv:2401.16818; unverified]

Mistral-style sliding-window attention (window 4096) on every layer.
SWA decode state is O(window), so decode cells use a rolling cache.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b",
    family="dense",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    d_ff=10240,
    vocab_size=32000,
    window=4096,
    rope_theta=10_000.0,
    norm_kind="rmsnorm",
    mlp_kind="swiglu",
    block_pattern=("swa",),
)
