"""olmoe-1b-7b [moe]: 16L d_model=2048 16H (GQA kv=16) d_ff=1024
vocab=50304, MoE 64 experts top-8. [arXiv:2409.02060; hf]

d_ff is per-expert; ~1B active of ~7B total parameters.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab_size=50304,
    n_experts=64,
    top_k=8,
    qk_norm=True,
    norm_kind="rmsnorm",
    mlp_kind="swiglu",
    block_pattern=("attn",),
)
