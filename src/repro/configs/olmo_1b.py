"""olmo-1b [dense]: 16L d_model=2048 16H (GQA kv=16) d_ff=8192
vocab=50304 — non-parametric LN. [arXiv:2402.00838; hf]

OLMo: LayerNorm without learnable scale/bias, tied embeddings,
plain-GeLU-free SwiGLU (OLMo uses SwiGLU), full attention (MHA: kv=16).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=50304,
    norm_kind="nonparam_ln",
    mlp_kind="swiglu",
    tie_embeddings=True,
    block_pattern=("attn",),
)
