"""seamless-m4t-large-v2 [audio]: 24L d_model=1024 16H (GQA kv=16)
d_ff=8192 vocab=256206 — enc-dec, multimodal. [arXiv:2308.11596; hf]

Interpreted as 24 layers per stack (24 encoder + 24 decoder), matching
the HF checkpoint layout.  The speech frontend (conformer feature
extractor) is a STUB: ``input_specs`` provides precomputed frame
embeddings (B, S_enc, d_model) as encoder input.  Decode shapes lower
the decoder step; long_500k skipped (full self+cross attention).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,            # decoder layers
    n_enc_layers=24,        # encoder layers
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    norm_kind="layernorm",
    mlp_kind="gelu",
    arch_kind="encdec",
    frontend="audio_stub",
    block_pattern=("attn",),
)
