"""Assigned-architecture configs (exact figures from the assignment).

``get_config(arch_id)`` resolves any of the ten ids; ``ARCH_IDS`` lists
them.  Shape cells live in ``repro.configs.shapes``.
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

ARCH_IDS: List[str] = [
    "qwen3-32b",
    "h2o-danube-3-4b",
    "olmo-1b",
    "qwen1.5-32b",
    "recurrentgemma-2b",
    "olmoe-1b-7b",
    "granite-moe-1b-a400m",
    "xlstm-350m",
    "internvl2-76b",
    "seamless-m4t-large-v2",
]

_MODULES: Dict[str, str] = {
    "qwen3-32b": "qwen3_32b",
    "h2o-danube-3-4b": "h2o_danube3_4b",
    "olmo-1b": "olmo_1b",
    "qwen1.5-32b": "qwen15_32b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "xlstm-350m": "xlstm_350m",
    "internvl2-76b": "internvl2_76b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
}


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG
