"""xlstm-350m [ssm]: 24L d_model=1024 4H (GQA kv=4) d_ff=0 vocab=50304
— sLSTM + mLSTM blocks. [arXiv:2405.04517; unverified]

xLSTM[7:1]: seven mLSTM blocks per sLSTM block; d_ff=0 — the blocks
integrate their own up/down projections.  Attention-free ->
long_500k runs with O(1) state.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    norm_kind="layernorm",
    block_pattern=("mlstm",) * 7 + ("slstm",),
    d_rnn=2048,          # 2x up-projection inside the blocks
    conv_width=4,
    tie_embeddings=True,
)
