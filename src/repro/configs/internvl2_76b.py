"""internvl2-76b [vlm]: 80L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256 — InternViT + (Llama3-70B-style) LM backbone.
[arXiv:2404.16821; unverified]

The InternViT vision frontend is a STUB: ``input_specs`` provides
precomputed patch embeddings (B, n_frontend_tokens, d_model) that
replace the first positions of the sequence.  Full attention ->
long_500k skipped.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    rope_theta=500_000.0,
    norm_kind="rmsnorm",
    mlp_kind="swiglu",
    block_pattern=("attn",),
    frontend="vision_stub",
    n_frontend_tokens=256,
)
