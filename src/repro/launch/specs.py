"""Cell programs: (arch x shape) -> jittable step + abstract inputs.

``build_cell(...)`` returns everything the dry-run needs for one cell:
the step function, ShapeDtypeStruct stand-ins for every input (the
shannon/kernels pattern — weak-type-correct, shardable, no device
allocation), and the in/out shardings derived from the logical rules.

MODEL_FLOPS convention: 6·N_active·tokens for training, 2·N_active·tokens
for inference (prefill counts the prompt, decode counts one token per
sequence).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.shapes import ShapeCell
from repro.distributed import partitioning as PT
from repro.models.config import ModelConfig
from repro.models.zoo import Model, build_model
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.step import TrainStepBuilder

# per-device microbatch target for train cells (keeps remat'd activations
# inside 16 GB HBM for the 32B/76B archs)
_DEFAULT_ACCUM = {"small": 4, "large": 16}
ENC_MEMORY_LEN = 4096  # encoder length backing enc-dec decode cells


def _accum_for(cfg: ModelConfig, cell: ShapeCell, mesh: Mesh) -> int:
    if cell.step != "train":
        return 1
    dp = 1
    for ax in ("pod", "data"):
        dp *= mesh.shape.get(ax, 1)
    local = max(cell.global_batch // dp, 1)
    if cfg.d_model >= 5120:
        return local            # microbatch 1/device for the 32B+ archs
    return min(max(local // 2, 1), 8)


@dataclass
class CellProgram:
    arch: str
    shape: ShapeCell
    step_kind: str
    fn: Callable
    abstract_args: Tuple
    in_shardings: Tuple
    out_shardings: Any
    donate_argnums: Tuple[int, ...]
    model_flops: float
    accum: int

    def jitted(self):
        return jax.jit(
            self.fn,
            in_shardings=self.in_shardings,
            out_shardings=self.out_shardings,
            donate_argnums=self.donate_argnums,
        )


def _batch_abstract(cfg: ModelConfig, cell: ShapeCell, with_labels: bool) -> Dict:
    B, S = cell.global_batch, cell.seq_len
    dt = jnp.dtype(cfg.dtype)
    batch: Dict[str, Any] = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if with_labels:
        batch["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if cfg.frontend == "vision_stub":
        batch["vision_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.n_frontend_tokens, cfg.d_model), dt
        )
    if cfg.arch_kind == "encdec":
        batch["enc_embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), dt)
    return batch


def model_flops_for(cfg: ModelConfig, cell: ShapeCell) -> float:
    n_active = cfg.active_param_count()
    if cell.step == "train":
        return 6.0 * n_active * cell.global_batch * cell.seq_len
    if cell.step == "prefill":
        return 2.0 * n_active * cell.global_batch * cell.seq_len
    return 2.0 * n_active * cell.global_batch  # decode: one token/sequence


def build_cell(
    cfg: ModelConfig,
    cell: ShapeCell,
    mesh: Mesh,
    strategy: str = "tp_fsdp",
    remat_policy: str = "full",
    accum: Optional[int] = None,
) -> CellProgram:
    model = build_model(cfg)
    accum = accum if accum is not None else _accum_for(cfg, cell, mesh)
    builder = TrainStepBuilder(
        model, mesh, strategy=strategy, opt=AdamWConfig(),
        remat_policy=remat_policy, accum=accum,
        zero2="_zero2" in strategy,
    )
    abstract_params, axes_tree = model.abstract()
    mf = model_flops_for(cfg, cell)

    if cell.step == "train":
        state_abs = {
            "params": abstract_params,
            "opt": jax.eval_shape(adamw_init, abstract_params),
            "step": jax.ShapeDtypeStruct((), jnp.int32),
        }
        batch_abs = _batch_abstract(cfg, cell, with_labels=True)
        state_sh = builder.state_shardings(abstract_params, axes_tree)
        batch_sh = builder.batch_shardings(batch_abs)
        return CellProgram(
            arch=cfg.name, shape=cell, step_kind="train",
            fn=builder.train_step_fn(),
            abstract_args=(state_abs, batch_abs),
            in_shardings=(state_sh, batch_sh),
            out_shardings=(state_sh, None),
            donate_argnums=(0,),
            model_flops=mf, accum=accum,
        )

    param_sh = builder.param_shardings(abstract_params, axes_tree)

    if cell.step == "prefill":
        batch_abs = _batch_abstract(cfg, cell, with_labels=False)
        cache_abs = jax.eval_shape(
            lambda: model.init_cache(cell.global_batch, cell.seq_len)
        )
        batch_sh = builder.batch_shardings(batch_abs)
        cache_sh = builder.cache_shardings(cache_abs)
        return CellProgram(
            arch=cfg.name, shape=cell, step_kind="prefill",
            fn=builder.prefill_step_fn(),
            abstract_args=(abstract_params, batch_abs, cache_abs),
            in_shardings=(param_sh, batch_sh, cache_sh),
            out_shardings=None,
            donate_argnums=(2,),
            model_flops=mf, accum=1,
        )

    # decode
    B = cell.global_batch
    token_abs = jax.ShapeDtypeStruct((B,), jnp.int32)
    pos_abs = jax.ShapeDtypeStruct((), jnp.int32)
    cache_abs = jax.eval_shape(lambda: model.init_cache(B, cell.seq_len))
    cache_sh = builder.cache_shardings(cache_abs)
    token_sh = PT.sharding(mesh, builder.rules, ("batch",), (B,))
    pos_sh = NamedSharding(mesh, P())
    args = [abstract_params, token_abs, pos_abs, cache_abs]
    shardings = [param_sh, token_sh, pos_sh, cache_sh]
    if cfg.arch_kind == "encdec":
        n_dec, hkv, dh = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
        dt = jnp.dtype(cfg.dtype)
        mem_abs = (
            jax.ShapeDtypeStruct((n_dec, B, hkv, ENC_MEMORY_LEN, dh), dt),
            jax.ShapeDtypeStruct((n_dec, B, hkv, ENC_MEMORY_LEN, dh), dt),
        )
        args.append(mem_abs)
        shardings.append(builder.memories_shardings(mem_abs))
    return CellProgram(
        arch=cfg.name, shape=cell, step_kind="decode",
        fn=builder.decode_step_fn(),
        abstract_args=tuple(args),
        in_shardings=tuple(shardings),
        out_shardings=None,
        donate_argnums=(3,),
        model_flops=mf, accum=1,
    )
