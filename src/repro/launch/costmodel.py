"""Analytic roofline cost model per (arch x shape x mesh x strategy).

Why analytic: XLA's ``cost_analysis()`` counts every ``while`` body
exactly once, and all our programs scan (layer groups, grad-accum
microbatches, blockwise-attention KV chunks, recurrent time steps) — so
raw HLO FLOPs/bytes undercount by the product of trip counts.  The
compiled artifact remains the source for *memory feasibility*
(``memory_analysis``) and *collective structure* (which collectives, at
what per-call payload); FLOPs/bytes/collective-volume come from this
model, which mirrors the implementation op-for-op.  It is validated
against ``cost_analysis()`` on scan-free configurations (trip counts of
1, no blockwise attention) in ``tests/test_costmodel.py`` and
EXPERIMENTS.md §Dry-run.

Conventions:

* matmul flops = 2·m·n·k; vector ops ignored (standard MFU accounting);
* backward = 2x forward matmul flops; remat "full" adds one forward;
* a tensor dimension that fails the divisibility guard is *replicated*,
  so the corresponding compute is NOT divided by that mesh axis — this
  surfaces e.g. qwen1.5's 40 heads on a 16-way TP axis as real waste;
* collective bytes are ring-transfer payloads: all-reduce moves
  ~2·size, all-gather/reduce-scatter ~1·size per device.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Optional

from jax.sharding import Mesh

from repro.configs.shapes import ShapeCell
from repro.launch import hlo as H
from repro.models.config import ModelConfig

ATTN_KINDS = ("attn", "local", "swa")
MLSTM_CHUNK = 256
BLOCKWISE_THRESHOLD = 4096  # must match models.layers


def _mesh_factor(mesh: Mesh, axes) -> int:
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape.get(a, 1)
    return n


def _div(dim: int, ways: int) -> int:
    """Shard factor with the divisibility guard: replicate when it
    doesn't divide (matching partitioning.spec_for)."""
    return ways if dim % ways == 0 else 1


def _div_eff(dim: int, ways: int, uneven: bool) -> float:
    """Effective shard factor; uneven sharding pads to ceil(dim/ways)."""
    if dim % ways == 0:
        return float(ways)
    if uneven and dim >= ways:
        return dim / math.ceil(dim / ways)
    return 1.0


def avg_attended(T: int, causal: bool, window: Optional[int]) -> float:
    """Average #keys attended per query position."""
    if not causal:
        return float(T)
    if window is None or window >= T:
        return (T + 1) / 2.0
    W = window
    return (W * (W + 1) / 2.0 + (T - W) * W) / T


@dataclass
class CellCosts:
    """Per-device costs + per-component global flops breakdown."""

    flops_per_device: float
    hbm_bytes_per_device: float
    collective_bytes_per_device: float
    global_flops: float
    breakdown: Dict[str, float] = field(default_factory=dict)
    notes: Dict[str, str] = field(default_factory=dict)


# ---------------------------------------------------------------------------
# forward flops per token, by layer kind (global, unsharded)
# ---------------------------------------------------------------------------


def _attn_proj_flops(cfg: ModelConfig) -> float:
    d, H, Hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return 2 * d * H * dh + 2 * 2 * d * Hkv * dh + 2 * H * dh * d


def _attn_score_flops(cfg: ModelConfig, t_eff: float) -> float:
    return 4 * cfg.n_heads * cfg.head_dim * t_eff  # qk^T + pv


def _mlp_flops(cfg: ModelConfig) -> float:
    if cfg.d_ff == 0:
        return 0.0
    n_mat = 3 if cfg.mlp_kind in ("swiglu", "geglu") else 2
    return 2 * n_mat * cfg.d_model * cfg.d_ff


def _moe_flops(cfg: ModelConfig, T: int) -> Dict[str, float]:
    """Per-token flops for one MoE layer (capacity-based einsum impl).

    Routing-group size g (= seq_len unless cfg.moe_group re-groups):
    capacity C = cf·K·g/E, so the dispatch/combine einsums cost
    2·E·C·d = 2·K·cf·g·d per token — linear in g, the §Perf lever.
    """
    d, f, E, K, cf = (cfg.d_model, cfg.d_ff, cfg.n_experts, cfg.top_k,
                      cfg.capacity_factor)
    g = T if cfg.moe_group is None or T <= cfg.moe_group else cfg.moe_group
    C = max(1, int(cf * K * g / E))
    experts = 2 * 3 * d * f * (E * C / g)       # slots incl. padding
    router = 2 * d * E
    dispatch = 2 * 2 * E * C * d
    return {"moe_experts": experts, "moe_router": router,
            "moe_dispatch": dispatch}


def _rglru_flops(cfg: ModelConfig) -> float:
    d, r, W = cfg.d_model, cfg.rnn_width, cfg.conv_width
    return 2 * 2 * d * r + 2 * W * r + 2 * 2 * r * r + 8 * r + 2 * r * d


def _mlstm_flops(cfg: ModelConfig) -> float:
    d, r, Hn = cfg.d_model, cfg.rnn_width, cfg.n_heads
    dh = r // Hn
    up = 2 * d * 2 * r
    qkv = 3 * 2 * r * r
    gates = 2 * r * 2 * Hn
    intra = 4 * MLSTM_CHUNK * r          # chunk scores + av
    inter = 6 * r * dh                   # state read/update amortized
    down = 2 * r * d
    return up + qkv + gates + intra + inter + down + 2 * cfg.conv_width * r


def _slstm_flops(cfg: ModelConfig) -> float:
    d, r = cfg.d_model, cfg.rnn_width
    return 2 * d * 4 * r + 2 * r * 4 * r + 24 * r


def _layer_kinds(cfg: ModelConfig):
    P = len(cfg.block_pattern)
    return [cfg.block_pattern[i % P] for i in range(cfg.n_layers)]


def fwd_flops_per_token(cfg: ModelConfig, T: int, t_eff: float,
                        with_logits: bool = True) -> Dict[str, float]:
    """Global forward flops per token, by component."""
    out: Dict[str, float] = {}

    def add(k, v):
        out[k] = out.get(k, 0.0) + v

    for kind in _layer_kinds(cfg):
        if kind in ATTN_KINDS:
            win = cfg.window if kind in ("local", "swa") else None
            te = t_eff if win is None else min(t_eff, avg_attended(
                int(max(t_eff * 2 - 1, 1)), True, win))
            add("attn_proj", _attn_proj_flops(cfg))
            add("attn_scores", _attn_score_flops(
                cfg, avg_attended(T, True, win) if T > 1 else te))
            if cfg.moe:
                for k, v in _moe_flops(cfg, T).items():
                    add(k, v)
            else:
                add("mlp", _mlp_flops(cfg))
        elif kind == "rglru":
            add("recurrent", _rglru_flops(cfg))
            if cfg.moe:
                for k, v in _moe_flops(cfg, T).items():
                    add(k, v)
            else:
                add("mlp", _mlp_flops(cfg))
        elif kind == "mlstm":
            add("recurrent", _mlstm_flops(cfg))
        elif kind == "slstm":
            add("recurrent", _slstm_flops(cfg))
    if cfg.arch_kind == "encdec":
        # encoder stack (full bidirectional attention over T)
        enc = cfg.n_enc_layers * (
            _attn_proj_flops(cfg) + _attn_score_flops(cfg, T) + _mlp_flops(cfg)
        )
        add("encoder", enc)
        # decoder cross-attention per layer (memory of length T)
        add("cross_attn", cfg.n_layers * (
            _attn_proj_flops(cfg) + _attn_score_flops(cfg, T)))
    if with_logits:
        add("logits", 2 * cfg.d_model * cfg.vocab_size)
    return out


# ---------------------------------------------------------------------------
# shard factors per component
# ---------------------------------------------------------------------------


def _shard_factors(cfg: ModelConfig, mesh: Mesh, batch: int,
                   strategy: str = "tp") -> Dict[str, float]:
    """Effective compute-shard factor per component.

    Compute follows the *activation* sharding constraints, which GSPMD
    honours even for indivisible dims (padded): verified by probe —
    40 q-heads on a 16-way axis compile to the same per-device FLOPs as
    48 heads.  Hence ceil-based effective factors here, while *storage*
    (params/caches, which are jit arguments) keeps the hard guard.
    """
    tp = _mesh_factor(mesh, "model")
    dp = _mesh_factor(mesh, ("pod", "data"))
    if strategy.startswith("dp"):
        dp, tp = mesh.size, 1      # pure data-parallel layout
    eff = lambda dim: _div_eff(dim, tp, uneven=True) if tp > 1 else 1.0
    bshard = _div(batch, dp)
    f = {
        "attn_proj": bshard * eff(cfg.n_heads),
        "attn_scores": bshard * eff(cfg.n_heads),
        "mlp": bshard * (eff(cfg.d_ff) if cfg.d_ff else 1),
        "moe_experts": bshard * eff(cfg.n_experts),
        "moe_router": bshard,
        "moe_dispatch": bshard * eff(cfg.n_experts),
        "recurrent": bshard * eff(cfg.rnn_width),
        "logits": bshard * eff(cfg.vocab_size),
        "encoder": bshard * eff(cfg.n_heads),
        "cross_attn": bshard * eff(cfg.n_heads),
        "optimizer": mesh.size,  # fsdp: fully sharded states
    }
    return f


# ---------------------------------------------------------------------------
# the three terms per cell
# ---------------------------------------------------------------------------


def cell_costs(
    cfg: ModelConfig,
    cell: ShapeCell,
    mesh: Mesh,
    strategy: str,
    remat: str = "full",
    accum: int = 1,
) -> CellCosts:
    import numpy as _np

    B, T = cell.global_batch, cell.seq_len
    tp = _mesh_factor(mesh, "model")
    dp = _mesh_factor(mesh, ("pod", "data"))
    chips = mesh.size
    d = cfg.d_model
    esz = _np.dtype(cfg.dtype).itemsize
    N_active = cfg.active_param_count()
    fsdp = "fsdp" in strategy
    uneven = "_uneven" in strategy
    zero2 = "_zero2" in strategy
    notes: Dict[str, str] = {}

    if cell.step == "train":
        per_tok = fwd_flops_per_token(cfg, T, avg_attended(T, True, None))
        tokens = B * T
        # remat surcharges calibrated against compiled scan-free probes
        # (XLA DCEs part of the recompute): full ~= +0.4 fwd, dots ~= +0.15
        mult = 3.0 + (0.4 if remat == "full" else 0.15 if remat == "dots" else 0.0)
        comp = {k: v * tokens * mult for k, v in per_tok.items()}
        comp["optimizer"] = 12.0 * cfg.param_count()
    elif cell.step == "prefill":
        per_tok = fwd_flops_per_token(cfg, T, avg_attended(T, True, None),
                                      with_logits=False)
        tokens = B * T
        comp = {k: v * tokens for k, v in per_tok.items()}
        comp["logits"] = 2.0 * d * cfg.vocab_size * B  # last position only
    else:  # decode: one token against a cache of length T
        win_cache = min(T, cfg.window) if cfg.window else T
        per_tok = fwd_flops_per_token(cfg, 1, float(T))
        # overwrite attention score term with true cache lengths
        sc = 0.0
        for kind in _layer_kinds(cfg):
            if kind == "attn":
                sc += _attn_score_flops(cfg, float(T))
            elif kind in ("local", "swa"):
                sc += _attn_score_flops(cfg, float(win_cache))
        per_tok["attn_scores"] = sc
        if cfg.arch_kind == "encdec":
            from repro.launch.specs import ENC_MEMORY_LEN
            per_tok["cross_attn"] = cfg.n_layers * (
                _attn_proj_flops(cfg) + _attn_score_flops(cfg, ENC_MEMORY_LEN))
            per_tok["encoder"] = 0.0  # encoder ran at prefill
        comp = {k: v * B for k, v in per_tok.items()}

    shard = _shard_factors(cfg, mesh, B, strategy)
    if strategy.startswith("dp"):
        dp, tp = chips, 1
    flops_dev = 0.0
    global_flops = 0.0
    for k, v in comp.items():
        global_flops += v
        s = shard.get(k, dp)
        flops_dev += v / s
        if tp > 1 and k in ("attn_proj", "attn_scores") and cfg.n_heads % tp:
            pad = math.ceil(cfg.n_heads / tp) * tp / cfg.n_heads
            notes[k] = f"uneven heads on {tp}-way axis: {pad:.2f}x padding"

    # ----------------------------------------------------------- HBM bytes
    # Fused-granularity traffic model: weights/states/caches/stored
    # activations each move once per semantic use.  (HLO "bytes accessed"
    # counts every op unfused and overcounts real HBM traffic several-x;
    # both numbers are recorded in the dry-run artifacts.)
    bytes_dev = 0.0
    L = cfg.n_layers + (cfg.n_enc_layers if cfg.arch_kind == "encdec" else 0)
    p_local = esz * cfg.param_count() / min(tp, chips)  # TP param shard
    if cell.step == "train":
        b_micro = max(B // (dp * accum), 1)
        uses = 2.0 + (1.0 if remat == "full" else 0.0)  # fwd + bwd (+recompute)
        bytes_dev += uses * accum * p_local              # weight reads
        bytes_dev += accum * p_local                     # grad writes
        bytes_dev += 2 * accum * 4.0 * cfg.param_count() / chips  # fp32 accum rmw
        bytes_dev += 7 * 4.0 * cfg.param_count() / chips          # adam + master
        if zero2:
            bytes_dev += p_local                         # resident gathered copy
        act = 20.0 * b_micro * T * d * esz * L  # activations (not model-sharded)
        bytes_dev += accum * act
        if cfg.moe:
            disp = 2.0 * b_micro * T * (cfg.top_k * cfg.capacity_factor * T) * esz
            bytes_dev += accum * disp * cfg.n_layers / _div(cfg.n_experts, tp)
        bytes_dev += accum * b_micro * T * cfg.vocab_size * esz / _div(cfg.vocab_size, tp) * 2
    elif cell.step == "prefill":
        b_loc = max(B // dp, 1)
        bytes_dev += p_local
        bytes_dev += 12.0 * b_loc * T * d * esz * L
        kv_pages = 2 * L * cfg.n_kv_heads * cfg.head_dim * T * b_loc * esz
        bytes_dev += kv_pages / _div(cfg.n_kv_heads, tp)
        bytes_dev += b_loc * cfg.vocab_size * esz / _div(cfg.vocab_size, tp)
    else:
        b_loc = max(B // dp, 1)
        win_cache = min(T, cfg.window) if cfg.window else T
        bytes_dev += p_local                              # all weights once
        kv_bytes = 0.0
        for kind in _layer_kinds(cfg):
            if kind == "attn":
                kv_bytes += 2 * cfg.n_kv_heads * cfg.head_dim * T * b_loc * esz
            elif kind in ("local", "swa"):
                kv_bytes += 2 * cfg.n_kv_heads * cfg.head_dim * win_cache * b_loc * esz
            elif kind == "rglru":
                kv_bytes += (cfg.rnn_width * b_loc * 4) * 2
            elif kind == "mlstm":
                dh = cfg.rnn_width // cfg.n_heads
                kv_bytes += (cfg.n_heads * dh * dh * b_loc * 4) * 2
            elif kind == "slstm":
                kv_bytes += 4 * cfg.rnn_width * b_loc * 4 * 2
        kv_shard = _div_eff(cfg.n_kv_heads, tp, uneven)
        if "tp_serve_hd" in strategy and kv_shard == 1:
            # head-dim cache sharding (partitioning.tp_serve_hd)
            kv_shard = _div(cfg.head_dim, tp)
        elif "tp_serve" in strategy and kv_shard == 1:
            # cache falls back to seq-dim sharding (partitioning.tp_serve)
            kv_shard = _div(win_cache if cfg.window else T, tp)
        bytes_dev += kv_bytes / max(kv_shard, 1)
        bytes_dev += b_loc * cfg.vocab_size * esz / _div(cfg.vocab_size, tp)
        if cfg.arch_kind == "encdec":
            from repro.launch.specs import ENC_MEMORY_LEN
            bytes_dev += (2 * cfg.n_layers * cfg.n_kv_heads * cfg.head_dim
                          * ENC_MEMORY_LEN * b_loc * esz) / _div(cfg.n_kv_heads, tp)

    # ----------------------------------------------------- collective bytes
    coll = 0.0
    act_bytes = lambda b, t: b * t * d * esz
    if cell.step == "train":
        b_micro = max(B // (dp * accum), 1)
        n_ar_layers = sum(1 for k in _layer_kinds(cfg)) * 2
        if cfg.arch_kind == "encdec":
            n_ar_layers += cfg.n_enc_layers * 2 + cfg.n_layers
        if tp > 1:
            coll += accum * n_ar_layers * 2.0 * act_bytes(b_micro, T)
        if fsdp and dp > 1 and zero2:
            coll += 1.0 * p_local                # ONE param all-gather per step
            coll += accum * 1.0 * p_local        # grad reduce-scatter per microbatch
        elif fsdp and dp > 1:
            coll += accum * 2.0 * p_local        # param all-gathers (fwd+bwd)
            coll += accum * 1.0 * p_local        # grad reduce-scatter
        elif dp > 1:
            coll += 2.0 * esz * cfg.param_count() / tp  # grad all-reduce (ring)
        if cfg.moe and _div(cfg.n_experts, tp) > 1:
            C = max(1, int(cfg.capacity_factor * cfg.top_k * T / cfg.n_experts))
            a2a = b_micro * cfg.n_experts * C * d * esz
            coll += accum * cfg.n_layers * 2 * 2 * a2a / tp
    else:
        b_loc = max(B // dp, 1)
        t_q = T if cell.step == "prefill" else 1
        n_ar_layers = len(_layer_kinds(cfg)) * 2
        if cfg.arch_kind == "encdec":
            n_ar_layers += cfg.n_enc_layers * 2 + cfg.n_layers
        if tp > 1:
            coll += n_ar_layers * 2.0 * act_bytes(b_loc, t_q)
        if ("tp_serve_hd" in strategy and cell.step == "decode"
                and cfg.n_kv_heads % tp != 0):
            # partial-score all-reduce per attention layer (dh sharded)
            win_cache = min(T, cfg.window) if cfg.window else T
            for kind in _layer_kinds(cfg):
                if kind == "attn":
                    coll += 2.0 * b_loc * cfg.n_heads * T * 4
                elif kind in ("local", "swa"):
                    coll += 2.0 * b_loc * cfg.n_heads * win_cache * 4
        if cfg.moe and _div(cfg.n_experts, tp) > 1:
            C = max(1, int(cfg.capacity_factor * cfg.top_k * max(t_q, 1)
                           / cfg.n_experts))
            coll += cfg.n_layers * 2 * (b_loc * cfg.n_experts * C * d * esz) / tp

    return CellCosts(
        flops_per_device=flops_dev,
        hbm_bytes_per_device=bytes_dev,
        collective_bytes_per_device=coll,
        global_flops=global_flops,
        breakdown=comp,
        notes=notes,
    )


def analytic_roofline(cfg, cell, mesh, strategy, remat="full", accum=1,
                      model_flops: Optional[float] = None) -> H.Roofline:
    c = cell_costs(cfg, cell, mesh, strategy, remat, accum)
    return H.Roofline(
        flops=c.flops_per_device,
        hbm_bytes=c.hbm_bytes_per_device,
        collective_bytes=c.collective_bytes_per_device,
        n_chips=mesh.size,
        model_flops=model_flops,
    )
