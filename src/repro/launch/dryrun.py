import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves the distribution config is coherent on the
production mesh (16x16 single-pod, 2x16x16 multi-pod) and extracts the
raw material for EXPERIMENTS.md:

* ``compiled.memory_analysis()``  — fits-in-HBM evidence;
* ``compiled.cost_analysis()``    — per-device HLO FLOPs / bytes;
* optimized HLO text              — collective payload bytes.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun                  # everything
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --mesh multi --strategy tp_fsdp
"""

import argparse
import json
import time
import traceback

import jax

from repro.configs import ARCH_IDS, get_config
from repro.configs.shapes import SHAPES, applicable
from repro.launch import hlo as H
from repro.launch.mesh import make_production_mesh


def run_cell(arch: str, shape_name: str, mesh_kind: str, strategy: str,
             out_dir: str, remat: str = "full", accum=None,
             moe_group=None, tag_suffix: str = "") -> dict:
    import dataclasses

    from repro.launch.specs import build_cell  # after XLA_FLAGS

    cfg = get_config(arch)
    if moe_group is not None:
        cfg = dataclasses.replace(cfg, moe_group=moe_group)
    cell = SHAPES[shape_name]
    ok, why = applicable(cfg, cell)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped", "reason": why}
    if strategy == "auto":
        # training wants ZeRO-3 (params would not fit replicated across DP);
        # serving keeps params TP-sharded and resident (an FSDP all-gather
        # per decoded token would drown the step in collectives) and shards
        # the KV cache over kv_heads or, failing divisibility, seq
        strategy = "tp_fsdp" if cell.step == "train" else "tp_serve"
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "strategy": strategy, "remat": remat}
    try:
        prog = build_cell(cfg, cell, mesh, strategy=strategy,
                          remat_policy=remat, accum=accum)
        lowered = prog.jitted().lower(*prog.abstract_args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        ca = H.cost_analysis_dict(compiled)
        txt = compiled.as_text()
        coll = H.collective_stats(txt)
        n_chips = mesh.size

        # Roofline terms come from the analytic cost model (mirrors the
        # implementation; XLA cost_analysis counts scan bodies once and
        # is kept as a diagnostic — see launch/costmodel.py docstring).
        from repro.launch.costmodel import cell_costs
        costs = cell_costs(cfg, cell, mesh, strategy, remat, prog.accum)
        roof = H.Roofline(
            flops=costs.flops_per_device,
            hbm_bytes=costs.hbm_bytes_per_device,
            collective_bytes=costs.collective_bytes_per_device,
            n_chips=n_chips,
            model_flops=prog.model_flops,
        )
        rec.update({
            "status": "ok",
            "accum": prog.accum,
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "memory": {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
            },
            "cost_hlo_raw": {k: float(v) for k, v in ca.items()
                             if isinstance(v, (int, float))},
            "collectives_hlo": {
                "bytes_by_op": coll.bytes_by_op,
                "count_by_op": coll.count_by_op,
                "note": "per-op payloads with scan bodies counted once",
            },
            "analytic_breakdown": {k: float(v) for k, v in costs.breakdown.items()},
            "analytic_notes": costs.notes,
            "roofline": roof.as_dict(),
        })
        print(f"[ok] {arch} {shape_name} {mesh_kind} {strategy}: "
              f"lower {t_lower:.1f}s compile {t_compile:.1f}s "
              f"bottleneck={roof.bottleneck} step={roof.step_time_s*1e3:.2f}ms "
              f"mfu_bound={roof.mfu_bound if roof.mfu_bound is None else round(roof.mfu_bound,3)}")
    except Exception as e:  # a failure here is a bug in the system
        rec.update({"status": "fail", "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-4000:]})
        print(f"[FAIL] {arch} {shape_name} {mesh_kind} {strategy}: {e}")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"{arch}_{shape_name}_{mesh_kind}_{strategy}{tag_suffix}"
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=2, default=str)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=ARCH_IDS + [None])
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default=None, choices=["single", "multi", None])
    ap.add_argument("--strategy", default="auto")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--accum", type=int, default=None)
    ap.add_argument("--moe-group", type=int, default=None)
    ap.add_argument("--tag-suffix", default="")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [args.mesh] if args.mesh else ["single", "multi"]

    results = []
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                results.append(run_cell(arch, shape, mesh_kind, args.strategy,
                                        args.out, args.remat, args.accum,
                                        args.moe_group, args.tag_suffix))
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_fail = sum(r["status"] == "fail" for r in results)
    print(f"\ndry-run: {n_ok} ok / {n_skip} skipped / {n_fail} FAILED "
          f"of {len(results)} cells")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
