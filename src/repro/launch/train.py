"""End-to-end training driver.

Wires every subsystem together: a BlobSeer deployment provides both the
tokenized corpus (append-ingested, snapshot-pinned readers) and the
versioned incremental checkpoint lineage; the model/optimizer run under
a mesh with logical-rule sharding.

Designed to be killed and restarted at any point: on startup it
GET_RECENTs the checkpoint blob and resumes (params, optimizer, step,
data cursor) bit-identically — the fault-tolerance story of DESIGN.md §5
exercised for real by ``tests/test_e2e.py`` and
``examples/train_e2e.py``.

Usage (CPU-friendly default scale)::

    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --steps 50 \
        --d-model 128 --layers 2 --seq 64 --batch 8 --spool /tmp/run1
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint import BlobCheckpointer
from repro.configs import ARCH_IDS, get_config
from repro.core import BlobSeerService
from repro.data import ByteTokenizer, CorpusWriter, ShardedReader
from repro.launch.mesh import make_mesh
from repro.models import build_model
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.step import TrainStepBuilder


def synthesize_corpus(writer: CorpusWriter, tok: ByteTokenizer, n_docs: int,
                      seed: int = 0) -> None:
    """Deterministic synthetic text corpus (number facts + noise)."""
    rng = np.random.default_rng(seed)
    for i in range(n_docs):
        n = int(rng.integers(40, 200))
        words = [f"tok{int(rng.integers(0, 50))}" for _ in range(n // 4)]
        text = f"document {i}: " + " ".join(words)
        writer.append_tokens(tok.encode(text))


def build_runtime(args):
    svc = BlobSeerService(
        n_providers=args.providers, n_meta_shards=4,
        data_replication=args.replication, spool_dir=args.spool,
        wal_path=(args.spool + "/vm.wal") if args.spool else None,
    )
    client = svc.client("trainer")
    return svc, client


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b", choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--heads", type=int, default=4)
    ap.add_argument("--d-ff", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--providers", type=int, default=4)
    ap.add_argument("--replication", type=int, default=1)
    ap.add_argument("--spool", default=None)
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--strategy", default="tp")
    ap.add_argument("--corpus-docs", type=int, default=200)
    ap.add_argument("--resume-blob", default=None)
    ap.add_argument("--corpus-blob", default=None)
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    tok = ByteTokenizer()
    cfg = get_config(args.arch).reduced(
        d_model=args.d_model, n_layers=args.layers, n_heads=args.heads,
        n_kv_heads=min(args.heads, get_config(args.arch).n_kv_heads),
        d_head=args.d_model // args.heads,
        d_ff=args.d_ff if get_config(args.arch).d_ff else 0,
        vocab_size=tok.vocab_size + 1,
    )
    svc, client = build_runtime(args)

    # ---- corpus (ingestion substrate) ----
    writer = CorpusWriter(client, args.corpus_blob, psize=16 * 1024)
    if args.corpus_blob is None:
        synthesize_corpus(writer, tok, args.corpus_docs)

    # ---- model + step ----
    d0, d1 = (int(x) for x in args.mesh.split("x"))
    mesh = make_mesh((d0, d1), ("data", "model"))
    model = build_model(cfg)
    builder = TrainStepBuilder(
        model, mesh, strategy=args.strategy,
        opt=AdamWConfig(lr=args.lr, warmup_steps=10, total_steps=args.steps),
        remat_policy="none", accum=args.accum,
    )
    abstract_params, axes_tree = model.abstract()

    # ---- checkpoint lineage (resume if one exists) ----
    ckpt = BlobCheckpointer(client, args.resume_blob, psize=16 * 1024,
                            header_pages=16)
    state_abs = jax.eval_shape(lambda r: builder.init_state(r), jax.random.PRNGKey(0))
    start_step = 0
    reader_state = None
    try:
        restored, manifest = ckpt.restore(state_abs, with_manifest=True)
        state = jax.tree.map(jnp.asarray, restored)
        ckpt.load_digest_cache()
        start_step = manifest["step"]
        reader_state = manifest["extra"].get("reader")
        if not args.quiet:
            print(f"[resume] blob={ckpt.blob_id} step={start_step}")
    except (FileNotFoundError, KeyError):
        state = builder.init_state(jax.random.PRNGKey(0))

    reader = ShardedReader(client, writer.blob_id, batch=args.batch,
                           seq_len=args.seq, state=reader_state)

    batch_abs = {
        "tokens": jax.ShapeDtypeStruct((args.batch, args.seq), jnp.int32),
        "labels": jax.ShapeDtypeStruct((args.batch, args.seq), jnp.int32),
    }
    step_fn = builder.jit_train_step(abstract_params, axes_tree, batch_abs)

    # ---- loop ----
    losses = []
    t0 = time.time()
    for step in range(start_step, args.steps):
        tokens, labels = reader.next_batch()
        batch = {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
        if not args.quiet and (step % 10 == 0 or step == args.steps - 1):
            print(f"step {step:5d} loss {losses[-1]:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f}")
        if (step + 1) % args.ckpt_every == 0 or step == args.steps - 1:
            stats = ckpt.save(state, step=step + 1,
                              extra={"reader": reader.state_dict()})
            if not args.quiet:
                print(f"[ckpt] v{stats.version} step {stats.step} "
                      f"wrote {stats.pages_written}/{stats.pages_total} pages "
                      f"(sharing {stats.sharing_fraction:.0%})")
    wall = time.time() - t0
    return {
        "losses": losses, "wall_s": wall, "ckpt_blob": ckpt.blob_id,
        "corpus_blob": writer.blob_id, "final_step": args.steps,
        "service": svc, "client": client, "state": state,
    }


if __name__ == "__main__":
    out = main()
    print(f"done: {len(out['losses'])} steps in {out['wall_s']:.1f}s, "
          f"final loss {out['losses'][-1]:.4f}")
