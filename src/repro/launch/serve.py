"""Batched serving driver: prefill + decode with sharded KV caches.

Serves a (reduced or full) arch config with batched requests; greedy or
temperature sampling.  The KV-cache snapshot can be persisted to a
BlobSeer blob between sessions (versioned, branchable prompt caches —
the storage substrate reused on the serving side).

Usage (CPU scale)::

    PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b \
        --prompt "hello world" --max-new 32
"""

from __future__ import annotations

import argparse
import time
from typing import List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.data import ByteTokenizer
from repro.launch.mesh import make_mesh
from repro.models import build_model
from repro.train.step import TrainStepBuilder


def generate(
    model,
    params,
    prompts: List[np.ndarray],
    *,
    max_new: int,
    max_len: int,
    temperature: float = 0.0,
    rng: Optional[jax.Array] = None,
    mesh=None,
    strategy: str = "tp",
) -> List[np.ndarray]:
    """Greedy/temperature generation for a batch of equal-length prompts."""
    cfg = model.cfg
    B = len(prompts)
    T0 = len(prompts[0])
    assert all(len(p) == T0 for p in prompts), "pad prompts to equal length"
    tokens = jnp.asarray(np.stack(prompts).astype(np.int32))
    cache = model.init_cache(B, max_len)

    builder = TrainStepBuilder(model, mesh, strategy=strategy) if mesh else None
    prefill = jax.jit(builder.prefill_step_fn()) if builder else jax.jit(
        lambda p, b, c: model.prefill(p, b, c))
    decode = jax.jit(builder.decode_step_fn()) if builder else jax.jit(
        lambda p, t, i, c: model.decode_step(p, t, i, c))

    batch = {"tokens": tokens}
    logits, cache = prefill(params, batch, cache)
    out = [list(p) for p in prompts]
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    tok = None
    for i in range(max_new):
        if temperature > 0:
            rng, sub = jax.random.split(rng)
            tok = jax.random.categorical(sub, logits / temperature, axis=-1)
        else:
            tok = jnp.argmax(logits, axis=-1)
        tok = tok.astype(jnp.int32)
        for b in range(B):
            out[b].append(int(tok[b]))
        logits, cache = decode(params, tok, jnp.asarray(T0 + i, jnp.int32), cache)
    return [np.asarray(o, dtype=np.int32) for o in out]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b", choices=ARCH_IDS)
    ap.add_argument("--prompt", default="the quick brown fox")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    tok = ByteTokenizer()
    cfg = get_config(args.arch).reduced(
        d_model=args.d_model, n_layers=args.layers,
        vocab_size=tok.vocab_size + 1,
    )
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    ids = tok.encode(args.prompt, add_special=True)
    prompts = [ids for _ in range(args.batch)]
    mesh = make_mesh((1, 1), ("data", "model"))

    t0 = time.time()
    outs = generate(model, params, prompts, max_new=args.max_new,
                    max_len=len(ids) + args.max_new + 1,
                    temperature=args.temperature, mesh=mesh)
    dt = time.time() - t0
    n_tok = args.batch * args.max_new
    print(f"generated {n_tok} tokens in {dt:.2f}s "
          f"({n_tok/dt:.1f} tok/s, untrained model)")
    print("sample:", tok.decode(outs[0][len(ids):]))
    return outs


if __name__ == "__main__":
    main()
