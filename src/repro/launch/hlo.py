"""Compiled-HLO analysis: collective bytes + roofline terms.

``cost_analysis()`` gives HLO FLOPs and bytes but not collective
traffic, so collective bytes are summed from the optimized HLO text:
every ``all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute`` op contributes its *output* shape bytes (the
standard first-order payload estimate; ring all-reduce moves
``2(N-1)/N x`` of that — noted in EXPERIMENTS.md).

Hardware model (TPU v5e, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional

PEAK_FLOPS = 197e12      # bf16 FLOP/s per chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link


def cost_analysis_dict(compiled) -> Dict[str, float]:
    """``compiled.cost_analysis()`` across jax versions.

    Older jax (< 0.5) returns a one-element list of per-computation
    dicts; newer jax returns the dict directly.  Normalize to a dict.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\(?[^)=]*\)?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        nbytes = _DTYPE_BYTES.get(dtype)
        if nbytes is None:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * nbytes
    return total


@dataclass
class CollectiveStats:
    bytes_by_op: Dict[str, int] = field(default_factory=dict)
    count_by_op: Dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_op.values())


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Sum output-shape bytes of every collective in an HLO module.

    ``-start`` variants are counted; their matching ``-done`` (which
    repeats the shape) is skipped to avoid double counting.
    """
    st = CollectiveStats()
    for line in hlo_text.splitlines():
        if "-done(" in line or "-done." in line:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        b = _shape_bytes(shape_str)
        st.bytes_by_op[op] = st.bytes_by_op.get(op, 0) + b
        st.count_by_op[op] = st.count_by_op.get(op, 0) + 1
    return st


@dataclass
class Roofline:
    """Three-term roofline for one compiled per-device module."""

    flops: float                 # per-device HLO flops
    hbm_bytes: float             # per-device HLO bytes accessed
    collective_bytes: float      # per-device collective payload bytes
    n_chips: int
    model_flops: Optional[float] = None  # analytic 6*N*D (global)

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline-optimistic step time: max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_fraction(self) -> Optional[float]:
        """MODEL_FLOPS / (per-device HLO flops x chips)."""
        if self.model_flops is None or self.flops == 0:
            return None
        return self.model_flops / (self.flops * self.n_chips)

    @property
    def mfu_bound(self) -> Optional[float]:
        """Model-FLOPs utilization at the roofline step time."""
        if self.model_flops is None:
            return None
        return self.model_flops / (self.n_chips * PEAK_FLOPS * self.step_time_s)

    def as_dict(self) -> Dict:
        return {
            "flops_per_device": self.flops,
            "hbm_bytes_per_device": self.hbm_bytes,
            "collective_bytes_per_device": self.collective_bytes,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "step_time_s": self.step_time_s,
            "model_flops": self.model_flops,
            "useful_flops_fraction": self.useful_flops_fraction,
            "mfu_bound": self.mfu_bound,
            "n_chips": self.n_chips,
        }
