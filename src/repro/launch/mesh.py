"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches JAX device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before its
first import, and smoke tests must keep seeing one device.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes) -> Mesh:
    """Arbitrary mesh for tests / local runs (e.g. ((1,1),("data","model")))."""
    return jax.make_mesh(tuple(shape), tuple(axes))
