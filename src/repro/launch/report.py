"""Generate EXPERIMENTS.md tables from dry-run artifacts.

    PYTHONPATH=src python -m repro.launch.report experiments/dryrun
"""

from __future__ import annotations

import glob
import json
import os
import sys
from typing import Dict, List


def load(dirpath: str) -> List[Dict]:
    recs = []
    for p in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        recs.append(json.load(open(p)))
    return recs


def fmt_bytes(x) -> str:
    if x is None:
        return "-"
    return f"{x/2**30:.2f}"


def dryrun_table(recs: List[Dict]) -> str:
    out = ["| arch | shape | mesh | strategy | status | compile_s | HBM args+temp (GiB/dev) | HLO collectives (count) |",
           "|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | - | "
                       f"skipped ({r['reason'][:40]}…) | - | - | - |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"{r.get('strategy','-')} | **FAIL** | - | - | - |")
            continue
        mem = r["memory"]
        hbm = (mem.get("argument_bytes") or 0) + (mem.get("temp_bytes") or 0)
        coll = r["collectives_hlo"]["count_by_op"]
        coll_s = " ".join(f"{k.split('-')[-1]}:{v}" for k, v in sorted(coll.items()))
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['strategy']} | ok "
            f"| {r['compile_s']} | {hbm/2**30:.1f} | {coll_s} |")
    return "\n".join(out)


def roofline_table(recs: List[Dict]) -> str:
    out = ["| arch | shape | strategy | compute (ms) | memory (ms) | collective (ms) | bottleneck | step (ms) | MODEL_FLOPS | useful frac | MFU bound |",
           "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["status"] != "ok" or r["mesh"] != "single":
            continue
        rf = r["roofline"]
        uf = rf.get("useful_flops_fraction")
        mfu = rf.get("mfu_bound")
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['strategy']} "
            f"| {rf['compute_s']*1e3:.2f} | {rf['memory_s']*1e3:.2f} "
            f"| {rf['collective_s']*1e3:.2f} | **{rf['bottleneck']}** "
            f"| {rf['step_time_s']*1e3:.2f} | {rf['model_flops']:.2e} "
            f"| {uf:.2f} | {mfu if mfu is None else round(mfu,3)} |")
    return "\n".join(out)


def main() -> None:
    d = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
    recs = load(d)
    print("## Dry-run matrix\n")
    print(dryrun_table(recs))
    print("\n## Roofline (single-pod 16x16)\n")
    print(roofline_table(recs))


if __name__ == "__main__":
    main()
