"""Shared transformer layers: norms, RoPE, MLP, GQA attention.

Everything is a pure function over explicit parameter dicts (leaves
created with ``param_util.leaf`` carry logical sharding axes).  Covers
the dense-family variance across the assigned archs: qk-norm (qwen3),
QKV bias (qwen1.5), non-parametric LN (olmo), SWA (danube3), local
attention (recurrentgemma), GQA everywhere.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed import constrain
from repro.kernels import ops as kops
from repro.models.config import ModelConfig
from repro.models.param_util import leaf, normal, ones, zeros

# Blockwise attention kicks in above this many kv positions (keeps the
# 32k prefill cells inside per-device memory without a Pallas dependency
# in the differentiable path).  Overridable: materializing 4k x 4k f32
# scores is the peak-memory term for wide-head archs at train_4k
# (EXPERIMENTS.md §Perf qwen1.5).
import os as _os

BLOCKWISE_KV_THRESHOLD = int(_os.environ.get("REPRO_BLOCKWISE_THRESHOLD", 4096))
BLOCKWISE_CHUNK = int(_os.environ.get("REPRO_BLOCKWISE_CHUNK", 1024))


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def init_norm(cfg: ModelConfig, dtype) -> Dict:
    if cfg.norm_kind == "rmsnorm":
        return {"scale": leaf(ones((cfg.d_model,), jnp.float32), "embed")}
    if cfg.norm_kind == "layernorm":
        return {
            "scale": leaf(ones((cfg.d_model,), jnp.float32), "embed"),
            "bias": leaf(zeros((cfg.d_model,), jnp.float32), "embed"),
        }
    if cfg.norm_kind == "nonparam_ln":  # OLMo: no learnable affine
        return {}
    raise ValueError(cfg.norm_kind)


def apply_norm(p: Dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    if cfg.norm_kind == "rmsnorm":
        xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + 1e-6)
        return (xf * p["scale"]).astype(dt)
    mean = jnp.mean(xf, -1, keepdims=True)
    var = jnp.mean((xf - mean) ** 2, -1, keepdims=True)
    xf = (xf - mean) * jax.lax.rsqrt(var + 1e-5)
    if cfg.norm_kind == "layernorm":
        xf = xf * p["scale"] + p["bias"]
    return xf.astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, H, T, D); positions: (T,) absolute token positions."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[:, None] * freqs[None, :]  # (T, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    xr1 = x1 * cos - x2 * sin
    xr2 = x2 * cos + x1 * sin
    return jnp.concatenate([xr1, xr2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def init_mlp(rng, cfg: ModelConfig, dtype) -> Dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(rng, 3)
    p = {
        "wi": leaf(normal(ks[0], (d, f), dtype), "embed", "mlp"),
        "wo": leaf(normal(ks[1], (f, d), dtype), "mlp", "embed"),
    }
    if cfg.mlp_kind in ("swiglu", "geglu"):
        p["wg"] = leaf(normal(ks[2], (d, f), dtype), "embed", "mlp")
    return p


def apply_mlp(p: Dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    h = jnp.einsum("btd,df->btf", x, p["wi"])
    if cfg.mlp_kind == "swiglu":
        h = jax.nn.silu(jnp.einsum("btd,df->btf", x, p["wg"])) * h
    elif cfg.mlp_kind == "geglu":
        h = jax.nn.gelu(jnp.einsum("btd,df->btf", x, p["wg"])) * h
    else:
        h = jax.nn.gelu(h)
    h = constrain(h, "batch", None, "mlp_act")
    return jnp.einsum("btf,fd->btd", h, p["wo"])


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def init_attention(rng, cfg: ModelConfig, dtype, cross: bool = False) -> Dict:
    d, h, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(rng, 8)
    p = {
        "wq": leaf(normal(ks[0], (d, h, dh), dtype), "embed", "q_heads", "head"),
        "wk": leaf(normal(ks[1], (d, hkv, dh), dtype), "embed", "kv_heads", "head"),
        "wv": leaf(normal(ks[2], (d, hkv, dh), dtype), "embed", "kv_heads", "head"),
        "wo": leaf(normal(ks[3], (h, dh, d), dtype), "q_heads", "head", "embed"),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = leaf(zeros((h, dh), dtype), "q_heads", "head")
        p["bk"] = leaf(zeros((hkv, dh), dtype), "kv_heads", "head")
        p["bv"] = leaf(zeros((hkv, dh), dtype), "kv_heads", "head")
    if cfg.qk_norm and not cross:
        p["q_scale"] = leaf(ones((dh,), jnp.float32), "head")
        p["k_scale"] = leaf(ones((dh,), jnp.float32), "head")
    return p


def _head_rmsnorm(x: jax.Array, scale: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + 1e-6)
    return (xf * scale).astype(x.dtype)


def _project_qkv(p, cfg, x, positions, apply_rope: bool = True):
    q = jnp.einsum("btd,dhk->bhtk", x, p["wq"])
    k = jnp.einsum("btd,dhk->bhtk", x, p["wk"])
    v = jnp.einsum("btd,dhk->bhtk", x, p["wv"])
    if "bq" in p:
        q = q + p["bq"][None, :, None, :]
        k = k + p["bk"][None, :, None, :]
        v = v + p["bv"][None, :, None, :]
    if "q_scale" in p:
        q = _head_rmsnorm(q, p["q_scale"])
        k = _head_rmsnorm(k, p["k_scale"])
    if apply_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _blockwise_attention(q, k, v, *, causal, window, q_offset, softcap, chunk):
    """Online-softmax attention, chunked over KV (pure jnp, differentiable).

    Memory O(Tq * chunk) per head instead of O(Tq * Tk): the 32k cells
    and the remat policy rely on this.  Mirrors ``kernels/flash_attention``
    (which serves the non-differentiable TPU serving path).
    """
    B, Hq, Tq, D = q.shape
    Hkv, Tk = k.shape[1], k.shape[2]
    group = Hq // Hkv
    pad_k = (-Tk) % chunk
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    n_chunks = k.shape[2] // chunk
    qf = (q.astype(jnp.float32) * (D ** -0.5)).reshape(B, Hkv, group, Tq, D)
    kc = k.reshape(B, Hkv, n_chunks, chunk, D)
    vc = v.reshape(B, Hkv, n_chunks, chunk, D)
    qpos = q_offset + jnp.arange(Tq)

    def step(carry, inp):
        m, l, acc = carry
        kj, vj, j = inp
        kj = kj.astype(jnp.float32)
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kj)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        kpos = j * chunk + jnp.arange(chunk)
        mask = kpos[None, :] < Tk
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kpos[None, :] > qpos[:, None] - window
        s = jnp.where(mask[None, None, None], s, -1e30)
        m_new = jnp.maximum(m, s.max(-1))
        pexp = jnp.exp(s - m_new[..., None])
        pexp = jnp.where(mask[None, None, None], pexp, 0.0)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + pexp.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bhkd->bhgqd", pexp, vj.astype(jnp.float32)
        )
        return (m_new, l, acc), None

    m0 = jnp.full((B, Hkv, group, Tq), -1e30, jnp.float32)
    l0 = jnp.zeros((B, Hkv, group, Tq), jnp.float32)
    a0 = jnp.zeros((B, Hkv, group, Tq, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step,
        (m0, l0, a0),
        (kc.transpose(2, 0, 1, 3, 4), vc.transpose(2, 0, 1, 3, 4),
         jnp.arange(n_chunks)),
    )
    l = jnp.where(l == 0.0, 1.0, l)
    out = (acc / l[..., None]).reshape(B, Hq, Tq, D)
    return out.astype(q.dtype)


def attention_core(q, k, v, *, causal, window, q_offset, softcap,
                   kv_positions: Optional[jax.Array] = None,
                   q_positions: Optional[jax.Array] = None):
    """Dispatch dense / blockwise / cache attention.

    ``kv_positions``: absolute positions of cache slots for decode
    (entries < 0 are empty slots).  When given, masking uses positions
    (``q_positions``) rather than indices.
    """
    Tk = k.shape[2]
    if kv_positions is not None:
        # decode path: dense scores against the cache (Tq is tiny).
        # KV operands stay in the cache dtype with f32 accumulation
        # (preferred_element_type) — materializing an f32 copy of a
        # multi-GiB cache would double decode HBM (observed as temp
        # blow-up in the dry-run memory analysis).
        B, Hq, Tq, D = q.shape
        Hkv = k.shape[1]
        group = Hq // Hkv
        qf = (q.astype(k.dtype) * (D ** -0.5)).reshape(B, Hkv, group, Tq, D)
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, k,
                       preferred_element_type=jnp.float32)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        qpos = q_positions
        mask = kv_positions[None, :] >= 0
        if causal:
            mask = mask & (kv_positions[None, :] <= qpos[:, None])
        if window is not None:
            mask = mask & (kv_positions[None, :] > qpos[:, None] - window)
        s = jnp.where(mask[None, None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(k.dtype), v,
                         preferred_element_type=jnp.float32)
        return out.reshape(B, Hq, Tq, D).astype(q.dtype)
    if Tk > BLOCKWISE_KV_THRESHOLD:
        return _blockwise_attention(
            q, k, v, causal=causal, window=window, q_offset=q_offset,
            softcap=softcap, chunk=BLOCKWISE_CHUNK,
        )
    return kops._attention_ref(q, k, v, causal, window, q_offset, softcap)


def apply_attention(
    p: Dict,
    cfg: ModelConfig,
    x: jax.Array,
    positions: jax.Array,
    *,
    kind: str = "attn",              # attn | local | swa
    causal: bool = True,
    cache: Optional[Dict] = None,    # {"k","v","pos"}; decode/prefill KV cache
    cache_index: Optional[jax.Array] = None,  # slot to write new kv at
) -> Tuple[jax.Array, Optional[Dict]]:
    window = cfg.window if kind in ("local", "swa") else None
    is_decode = cache is not None and x.shape[1] == 1
    q, k, v = _project_qkv(p, cfg, x, positions)
    q = constrain(q, "batch", "heads_act", None, None)
    k = constrain(k, "batch", "kv_act", None, None)
    v = constrain(v, "batch", "kv_act", None, None)

    if is_decode and _use_shard_decode():
        from repro.distributed import axes as _AX
        from repro.distributed.decode_attn import sharded_decode_attention
        out, new_cache = sharded_decode_attention(
            _AX.current_mesh(), q, cache, k, v, positions,
            causal=causal, window=window, softcap=cfg.softcap,
        )
        y = jnp.einsum("bhtk,hkd->btd", out, p["wo"])
        return y, new_cache

    new_cache = None
    kv_positions = None
    if cache is not None:
        cache_len = cache["k"].shape[2]
        Tq = k.shape[2]
        if Tq >= cache_len:
            # Prefill longer than a window-limited cache: only the last
            # ``cache_len`` positions survive.  Slot invariant is
            # slot = pos % cache_len, so the window is rolled into place.
            kw = k[:, :, -cache_len:].astype(cache["k"].dtype)
            vw = v[:, :, -cache_len:].astype(cache["v"].dtype)
            pw = positions[-cache_len:]
            shift = pw[0] % cache_len
            ck = jnp.roll(kw, shift, axis=2)
            cv = jnp.roll(vw, shift, axis=2)
            cpos = jnp.roll(pw, shift)
        else:
            # Fits: contiguous write at slot = pos % cache_len (decode
            # steps and from-zero prefills never wrap).
            slot = cache_index if cache_index is not None else positions[0] % cache_len
            ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                              (0, 0, slot, 0))
            cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                              (0, 0, slot, 0))
            cpos = jax.lax.dynamic_update_slice(cache["pos"], positions, (slot,))
        new_cache = {"k": ck, "v": cv, "pos": cpos}
        if is_decode:
            # decode: attend over the cache (positions mask empty slots)
            k, v, kv_positions = ck, cv, cpos
    out = attention_core(
        q, k, v, causal=causal, window=window, q_offset=0,
        softcap=cfg.softcap, kv_positions=kv_positions, q_positions=positions,
    )
    y = jnp.einsum("bhtk,hkd->btd", out, p["wo"])
    return y, new_cache


def apply_cross_attention(
    p: Dict, cfg: ModelConfig, x: jax.Array, memory_kv: Tuple[jax.Array, jax.Array]
) -> jax.Array:
    """Decoder cross-attention over precomputed encoder K/V."""
    q = jnp.einsum("btd,dhk->bhtk", x, p["wq"])
    k, v = memory_kv
    out = attention_core(q, k, v, causal=False, window=None, q_offset=0, softcap=None)
    return jnp.einsum("bhtk,hkd->btd", out, p["wo"])


def cross_attention_memory(p: Dict, cfg: ModelConfig, enc_out: jax.Array):
    k = jnp.einsum("btd,dhk->bhtk", enc_out, p["wk"])
    v = jnp.einsum("btd,dhk->bhtk", enc_out, p["wv"])
    return (k, v)


def _use_shard_decode() -> bool:
    from repro.distributed import axes as _AX

    rules = _AX.current_rules()
    mesh = _AX.current_mesh()
    return bool(rules and rules.get("__shard_decode__")
                and mesh is not None and "model" in mesh.axis_names)


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> Dict:
    hkv, dh = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": zeros((batch, hkv, max_len, dh), dtype),
        "v": zeros((batch, hkv, max_len, dh), dtype),
        "pos": -jnp.ones((max_len,), jnp.int32),
    }
