"""Recurrent token mixers: RG-LRU (recurrentgemma), mLSTM + sLSTM (xLSTM).

All three are pure functions with an explicit state dict, so the same
code serves training (scan over the full sequence), prefill (same, but
returning the final state) and decode (T=1 step with carried state) —
which is what makes the ``long_500k`` cells O(1)-state for these
families.

TPU adaptation: the RG-LRU diagonal recurrence lowers to
``kernels.ops.linear_scan`` (chunked-sequential Pallas kernel on TPU,
associative scan on CPU).  The mLSTM matrix memory uses the chunked
GLA-style formulation — per-chunk parallel MXU work + a tiny cross-chunk
state scan — rather than a per-token loop.  The sLSTM's hidden-to-gate
recurrence is inherently sequential and stays a ``lax.scan``.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.models.config import ModelConfig
from repro.models.param_util import leaf, normal, ones, zeros

# ---------------------------------------------------------------------------
# temporal conv (shared by RG-LRU and mLSTM blocks)
# ---------------------------------------------------------------------------


def _causal_conv(x: jax.Array, w: jax.Array, state: Optional[jax.Array]):
    """Depthwise causal conv. x: (B,T,D); w: (W,D); state: (B,W-1,D)."""
    W = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    xx = jnp.concatenate([state, x], axis=1)            # (B, T+W-1, D)
    out = sum(xx[:, i : i + x.shape[1], :] * w[i] for i in range(W))
    new_state = xx[:, -(W - 1):, :] if W > 1 else state
    return out, new_state


# ---------------------------------------------------------------------------
# RG-LRU block (Griffin / recurrentgemma)
# ---------------------------------------------------------------------------


def init_rglru(rng, cfg: ModelConfig, dtype) -> Dict:
    d, r, w = cfg.d_model, cfg.rnn_width, cfg.conv_width
    ks = jax.random.split(rng, 7)
    return {
        "wx": leaf(normal(ks[0], (d, r), dtype), "embed", "rnn"),
        "wy": leaf(normal(ks[1], (d, r), dtype), "embed", "rnn"),
        "conv": leaf(normal(ks[2], (w, r), dtype, scale=0.1), "conv", "rnn"),
        "w_a": leaf(normal(ks[3], (r, r), dtype), "rnn", "rnn_gate"),
        "w_i": leaf(normal(ks[4], (r, r), dtype), "rnn", "rnn_gate"),
        # Λ init so that a = exp(-8 softplus(Λ) r) starts near 0.9..0.999
        "lam": leaf((jnp.log(jnp.expm1(jnp.linspace(0.001, 0.1, r))) / 1.0)
                    .astype(jnp.float32), "rnn"),
        "wo": leaf(normal(ks[5], (r, d), dtype), "rnn", "embed"),
    }


def apply_rglru(
    p: Dict, cfg: ModelConfig, x: jax.Array, state: Optional[Dict] = None
) -> Tuple[jax.Array, Optional[Dict]]:
    """x: (B,T,D) -> (y, new_state). state={"h": (B,R), "conv": (B,W-1,R)}."""
    xb = jnp.einsum("btd,dr->btr", x, p["wx"])
    yb = jnp.einsum("btd,dr->btr", x, p["wy"])          # gate branch
    conv_state = state["conv"] if state is not None else None
    xb, new_conv = _causal_conv(xb, p["conv"], conv_state)

    xf = xb.astype(jnp.float32)
    r_gate = jax.nn.sigmoid(jnp.einsum("btr,rg->btg", xf, p["w_a"].astype(jnp.float32)))
    i_gate = jax.nn.sigmoid(jnp.einsum("btr,rg->btg", xf, p["w_i"].astype(jnp.float32)))
    log_a = -8.0 * jax.nn.softplus(p["lam"]) * r_gate   # (B,T,R)
    a = jnp.exp(log_a)
    gated_x = xf * i_gate
    # input normalization: sqrt(1 - a^2) (Griffin eq. 4)
    scaled_x = gated_x * jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6))

    h0 = state["h"].astype(jnp.float32) if state is not None else None
    if x.shape[1] == 1 and h0 is not None:
        h = (a[:, 0] * h0 + scaled_x[:, 0])[:, None]    # decode: one step
    else:
        if h0 is not None:
            scaled_x = scaled_x.at[:, 0].add(a[:, 0] * h0)
        h = kops.linear_scan(a, scaled_x)
    new_state = {"h": h[:, -1], "conv": new_conv}
    y = h.astype(x.dtype) * jax.nn.gelu(yb)
    return jnp.einsum("btr,rd->btd", y, p["wo"]), new_state


def init_rglru_state(cfg: ModelConfig, batch: int, dtype) -> Dict:
    r, w = cfg.rnn_width, cfg.conv_width
    return {"h": zeros((batch, r), jnp.float32), "conv": zeros((batch, w - 1, r), dtype)}


# ---------------------------------------------------------------------------
# mLSTM block (xLSTM): matrix memory, chunked-parallel
# ---------------------------------------------------------------------------


def init_mlstm(rng, cfg: ModelConfig, dtype) -> Dict:
    d, r, h = cfg.d_model, cfg.rnn_width, cfg.n_heads
    dh = r // h
    ks = jax.random.split(rng, 9)
    return {
        "w_up": leaf(normal(ks[0], (d, 2 * r), dtype), "embed", "rnn_up"),
        "conv": leaf(normal(ks[1], (cfg.conv_width, r), dtype, scale=0.1), "conv", "rnn"),
        "wq": leaf(normal(ks[2], (r, h, dh), dtype), "rnn", "q_heads", "head"),
        "wk": leaf(normal(ks[3], (r, h, dh), dtype), "rnn", "q_heads", "head"),
        "wv": leaf(normal(ks[4], (r, h, dh), dtype), "rnn", "q_heads", "head"),
        "w_if": leaf(normal(ks[5], (r, 2 * h), jnp.float32), "rnn", "gates"),
        "b_if": leaf(jnp.concatenate([zeros((h,), jnp.float32),
                                      3.0 * ones((h,), jnp.float32)]), "gates"),
        "o_norm": leaf(ones((h, dh), jnp.float32), "q_heads", "head"),
        "w_down": leaf(normal(ks[6], (r, d), dtype), "rnn", "embed"),
    }


def _mlstm_chunk_scan(q, k, v, log_f, log_i, C0, n0, m0, chunk: int):
    """Chunked mLSTM. q,k,v: (B,H,T,Dh); log_f/log_i: (B,H,T).

    Stabilized exponential gating (xLSTM eq. 19-27) evaluated chunkwise:
    within a chunk all pairwise decay factors are formed as
    ``exp(F_t - F_s + i_s - m)`` MXU-style; across chunks the matrix
    state C (B,H,Dh,Dh) carries.
    """
    B, H, T, Dh = q.shape
    pad = (-T) % chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        log_f = jnp.pad(log_f, ((0, 0), (0, 0), (0, pad)))
        log_i = jnp.pad(log_i, ((0, 0), (0, 0), (0, pad)), constant_values=-1e30)
    Tp = q.shape[2]
    nc = Tp // chunk
    qc = q.reshape(B, H, nc, chunk, Dh).transpose(2, 0, 1, 3, 4)
    kc = k.reshape(B, H, nc, chunk, Dh).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(B, H, nc, chunk, Dh).transpose(2, 0, 1, 3, 4)
    fc = log_f.reshape(B, H, nc, chunk).transpose(2, 0, 1, 3)
    ic = log_i.reshape(B, H, nc, chunk).transpose(2, 0, 1, 3)

    def step(carry, inp):
        C, n, m = carry                      # (B,H,Dh,Dh), (B,H,Dh), (B,H)
        qj, kj, vj, fj, ij = inp
        F = jnp.cumsum(fj, axis=-1)          # (B,H,c) cumulative log-forget
        Ftot = F[..., -1]
        # stabilizer for this chunk
        a_log = F - fj + ij                  # contribution position s: decay to end handled below
        # intra-chunk pair decay: D[t,s] = F_t - F_s + i_s  (s<=t)
        Dmat = F[..., :, None] - F[..., None, :] + ij[..., None, :]
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        Dmat = jnp.where(tri, Dmat, -jnp.inf)
        m_intra = jnp.max(Dmat, axis=-1)                       # (B,H,c)
        m_inter = F + m[..., None]                             # carry path
        m_new_t = jnp.maximum(m_intra, m_inter)                # (B,H,c)
        # intra contribution
        w = jnp.exp(Dmat - m_new_t[..., None])                 # (B,H,c,c)
        s = jnp.einsum("bhtd,bhsd->bhts", qj, kj)              # scores
        h_intra = jnp.einsum("bhts,bhsd->bhtd", w * s, vj)
        l_intra = jnp.einsum("bhts,bhsd->bhtd", w, kj)         # for normalizer
        n_intra = jnp.einsum("bhtd,bhtd->bht", qj, l_intra)
        # inter contribution (state from previous chunks)
        scale = jnp.exp(m_inter - m_new_t)                     # (B,H,c)
        h_inter = jnp.einsum("bhtd,bhde->bhte", qj, C) * scale[..., None]
        n_inter = jnp.einsum("bhtd,bhd->bht", qj, n) * scale
        denom = jnp.maximum(jnp.abs(n_intra + n_inter), jnp.exp(-m_new_t))
        h = (h_intra + h_inter) / denom[..., None]
        # -- state update to end of chunk --
        m_end = jnp.maximum(Ftot + m, jnp.max(a_log + (Ftot[..., None] - F), axis=-1))
        # decay of each in-chunk position to chunk end:
        dec = jnp.exp(ij + Ftot[..., None] - F - m_end[..., None])  # (B,H,c)
        C_new = C * jnp.exp(Ftot + m - m_end)[..., None, None] + jnp.einsum(
            "bhs,bhsd,bhse->bhde", dec, kj, vj
        )
        n_new = n * jnp.exp(Ftot + m - m_end)[..., None] + jnp.einsum(
            "bhs,bhsd->bhd", dec, kj
        )
        return (C_new, n_new, m_end), h

    (C, n, m), hs = jax.lax.scan(step, (C0, n0, m0), (qc, kc, vc, fc, ic))
    h = hs.transpose(1, 2, 0, 3, 4).reshape(B, H, Tp, Dh)[:, :, :T]
    return h, (C, n, m)


def apply_mlstm(
    p: Dict, cfg: ModelConfig, x: jax.Array, state: Optional[Dict] = None,
    chunk: int = 256,
) -> Tuple[jax.Array, Optional[Dict]]:
    B, T, _ = x.shape
    r, H = cfg.rnn_width, cfg.n_heads
    dh = r // H
    up = jnp.einsum("btd,du->btu", x, p["w_up"])
    xi, z = jnp.split(up, 2, axis=-1)
    conv_state = state["conv"] if state is not None else None
    xi, new_conv = _causal_conv(xi, p["conv"], conv_state)
    xi_act = jax.nn.silu(xi)
    q = jnp.einsum("btr,rhk->bhtk", xi_act, p["wq"]) * (dh ** -0.5)
    k = jnp.einsum("btr,rhk->bhtk", xi_act, p["wk"])
    v = jnp.einsum("btr,rhk->bhtk", xi_act, p["wv"])
    gates = jnp.einsum("btr,rg->btg", xi.astype(jnp.float32), p["w_if"]) + p["b_if"]
    log_i, log_f = jnp.split(gates, 2, axis=-1)            # (B,T,H)
    log_f = jax.nn.log_sigmoid(log_f).transpose(0, 2, 1)   # (B,H,T)
    log_i = log_i.transpose(0, 2, 1)                       # exp input gate (log-space)

    if state is not None:
        C0, n0, m0 = state["C"], state["n"], state["m"]
    else:
        C0 = jnp.zeros((B, H, dh, dh), jnp.float32)
        n0 = jnp.zeros((B, H, dh), jnp.float32)
        m0 = jnp.full((B, H), -1e30, jnp.float32)
    h, (C, n, m) = _mlstm_chunk_scan(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
        log_f, log_i, C0, n0, m0, chunk=min(chunk, max(T, 1)),
    )
    h = h * p["o_norm"][None, :, None, :]
    h = h.transpose(0, 2, 1, 3).reshape(B, T, r).astype(x.dtype)
    y = h * jax.nn.silu(z)
    new_state = {"C": C, "n": n, "m": m, "conv": new_conv}
    return jnp.einsum("btr,rd->btd", y, p["w_down"]), new_state


def init_mlstm_state(cfg: ModelConfig, batch: int, dtype) -> Dict:
    r, H, w = cfg.rnn_width, cfg.n_heads, cfg.conv_width
    dh = r // H
    return {
        "C": zeros((batch, H, dh, dh), jnp.float32),
        "n": zeros((batch, H, dh), jnp.float32),
        "m": jnp.full((batch, H), -1e30, jnp.float32),
        "conv": zeros((batch, w - 1, r), dtype),
    }


# ---------------------------------------------------------------------------
# sLSTM block (xLSTM): scalar memory, sequential scan
# ---------------------------------------------------------------------------


def init_slstm(rng, cfg: ModelConfig, dtype) -> Dict:
    d, r = cfg.d_model, cfg.rnn_width
    ks = jax.random.split(rng, 4)
    return {
        "w_in": leaf(normal(ks[0], (d, 4 * r), dtype), "embed", "rnn_gates"),
        "r_rec": leaf(normal(ks[1], (r, 4 * r), dtype, scale=0.01), "rnn", "rnn_gates"),
        "b": leaf(zeros((4 * r,), jnp.float32), "rnn_gates"),
        "w_out": leaf(normal(ks[2], (r, d), dtype), "rnn", "embed"),
    }


def apply_slstm(
    p: Dict, cfg: ModelConfig, x: jax.Array, state: Optional[Dict] = None
) -> Tuple[jax.Array, Optional[Dict]]:
    """Sequential sLSTM with exponential gating + stabilizer (xLSTM §2.1)."""
    B, T, _ = x.shape
    r = cfg.rnn_width
    pre = jnp.einsum("btd,dg->btg", x, p["w_in"]).astype(jnp.float32)
    if state is None:
        state = init_slstm_state(cfg, B, x.dtype)
    c0, n0, h0, m0 = (state[k] for k in ("c", "n", "h", "m"))
    rrec = p["r_rec"].astype(jnp.float32)

    def step(carry, pre_t):
        c, n, h, m = carry
        g = pre_t + h @ rrec + p["b"]
        zi, zf, zz, zo = jnp.split(g, 4, axis=-1)
        log_i = zi
        log_f = jax.nn.log_sigmoid(zf)
        m_new = jnp.maximum(log_f + m, log_i)
        i = jnp.exp(log_i - m_new)
        f = jnp.exp(log_f + m - m_new)
        z = jnp.tanh(zz)
        o = jax.nn.sigmoid(zo)
        c_new = f * c + i * z
        n_new = f * n + i
        h_new = o * c_new / jnp.maximum(jnp.abs(n_new), 1.0)
        return (c_new, n_new, h_new, m_new), h_new

    (c, n, h, m), hs = jax.lax.scan(step, (c0, n0, h0, m0), pre.transpose(1, 0, 2))
    y = hs.transpose(1, 0, 2).astype(x.dtype)
    new_state = {"c": c, "n": n, "h": h, "m": m}
    return jnp.einsum("btr,rd->btd", y, p["w_out"]), new_state


def init_slstm_state(cfg: ModelConfig, batch: int, dtype) -> Dict:
    r = cfg.rnn_width
    return {
        "c": zeros((batch, r), jnp.float32),
        "n": zeros((batch, r), jnp.float32),
        "h": zeros((batch, r), jnp.float32),
        "m": jnp.full((batch, r), -1e30, jnp.float32),
    }
