"""Parameter-tree utilities.

Init functions build trees whose leaves are ``(array, logical_axes)``
pairs; :func:`split_tree` separates them into a value tree (what the
optimizer sees) and an axes tree (what the partitioner sees).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


def leaf(array: jax.Array, *axes) -> Tuple[jax.Array, Tuple]:
    assert array.ndim == len(axes), (array.shape, axes)
    return (array, tuple(axes))


def is_leaf(x) -> bool:
    return isinstance(x, tuple) and len(x) == 2 and isinstance(x[1], tuple)


def split_tree(tree) -> Tuple[Any, Any]:
    """((array, axes) leaves) -> (params, axes) twin trees."""
    params = jax.tree.map(lambda l: l[0], tree, is_leaf=is_leaf)
    axes = jax.tree.map(lambda l: l[1], tree, is_leaf=is_leaf)
    return params, axes


def normal(rng, shape, dtype, scale: float = 0.02):
    return (jax.random.normal(rng, shape, jnp.float32) * scale).astype(dtype)


def zeros(shape, dtype):
    return jnp.zeros(shape, dtype)


def ones(shape, dtype):
    return jnp.ones(shape, dtype)


def stack_trees(trees):
    """Stack a list of identically-structured param trees along axis 0
    (layer-scan stacking); logical axes gain a leading "layers"."""
    if len(trees) == 1:
        stacked = jax.tree.map(
            lambda l: (l[0][None], ("layers",) + l[1]), trees[0], is_leaf=is_leaf
        )
        return stacked
    out = jax.tree.map(
        lambda *ls: (jnp.stack([l[0] for l in ls]), ("layers",) + ls[0][1]),
        *trees,
        is_leaf=is_leaf,
    )
    return out
