"""Model facade: uniform API over decoder-only and encoder-decoder archs.

``build_model(cfg)`` returns a :class:`Model` whose methods are pure
functions of (params, batch) — the train/serve steps, the dry-run and
the smoke tests all drive models exclusively through this interface.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import encdec as ED
from repro.models import lm as LM
from repro.models.config import ModelConfig
from repro.models.param_util import split_tree


@dataclass
class Model:
    cfg: ModelConfig
    init: Callable          # rng -> (params, axes)
    loss_fn: Callable       # (params, batch, remat_policy) -> (loss, metrics)
    init_cache: Callable    # (batch, max_len) -> cache
    prefill: Callable       # (params, batch, cache) -> (logits, cache[, extras])
    decode_step: Callable   # (params, token, pos, cache[, extras]) -> (logits, cache)

    def abstract(self, rng=None) -> Tuple[Any, Any]:
        """(abstract_params, axes) without materializing any array.

        The axes tree is static Python data, captured via a side channel
        while ``eval_shape`` traces the init (no allocation happens).
        """
        rng = jax.random.PRNGKey(0) if rng is None else rng
        captured: Dict[str, Any] = {}

        def traced(r):
            params, axes = self.init(r)
            captured["axes"] = axes
            return params

        abstract_params = jax.eval_shape(traced, rng)
        return abstract_params, captured["axes"]


def build_model(cfg: ModelConfig) -> Model:
    if cfg.arch_kind == "decoder":
        def init(rng):
            return split_tree(LM.init_lm(rng, cfg))

        def loss_fn(params, batch, remat_policy="none"):
            return LM.lm_loss(params, cfg, batch, remat_policy)

        def init_cache(batch, max_len):
            return LM.init_lm_cache(cfg, batch, max_len)

        def prefill(params, batch, cache):
            return LM.lm_prefill(params, cfg, batch, cache)

        def decode_step(params, token, pos, cache):
            return LM.lm_decode_step(params, cfg, token, pos, cache)

    elif cfg.arch_kind == "encdec":
        def init(rng):
            return split_tree(ED.init_encdec(rng, cfg))

        def loss_fn(params, batch, remat_policy="none"):
            return ED.encdec_loss(params, cfg, batch, remat_policy)

        def init_cache(batch, max_len):
            return ED.init_encdec_cache(cfg, batch, max_len)

        def prefill(params, batch, cache):
            return ED.encdec_prefill(params, cfg, batch, cache)

        def decode_step(params, token, pos, cache, memories=None):
            return ED.encdec_decode_step(params, cfg, token, pos, cache, memories)

    else:
        raise ValueError(cfg.arch_kind)

    return Model(cfg=cfg, init=init, loss_fn=loss_fn, init_cache=init_cache,
                 prefill=prefill, decode_step=decode_step)
