"""Decoder-only LM assembly: embed -> pattern blocks -> norm -> logits.

Layers follow ``cfg.block_pattern`` cycled over ``cfg.n_layers``; whole
pattern groups are stacked and driven by ``lax.scan`` (compact HLO for
80-layer models; activation-checkpointing wraps the group body), with
any remainder layers unrolled.

Three entry points per model:

* ``loss_fn``    — next-token CE (+ MoE aux, + z-loss) for train_4k;
* ``prefill``    — full-sequence forward that fills the KV/state caches
                   (prefill_32k);
* ``decode_step``— one token against the caches (decode_32k/long_500k).

VLM (internvl2): ``batch["vision_embeds"]`` (stub ViT output) replaces
the embeddings of the first ``n_frontend_tokens`` positions.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed import constrain
from repro.models import layers as L
from repro.models import moe as M
from repro.models import recurrent as R
from repro.models.config import ModelConfig
from repro.models.param_util import leaf, normal, split_tree, stack_trees

ATTN_KINDS = ("attn", "local", "swa")


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# block init / apply
# ---------------------------------------------------------------------------


def init_block(rng, cfg: ModelConfig, kind: str) -> Dict:
    dt = _dtype(cfg)
    ks = jax.random.split(rng, 3)
    p: Dict = {"norm1": L.init_norm(cfg, dt)}
    if kind in ATTN_KINDS:
        p["mixer"] = L.init_attention(ks[0], cfg, dt)
    elif kind == "rglru":
        p["mixer"] = R.init_rglru(ks[0], cfg, dt)
    elif kind == "mlstm":
        p["mixer"] = R.init_mlstm(ks[0], cfg, dt)
    elif kind == "slstm":
        p["mixer"] = R.init_slstm(ks[0], cfg, dt)
    else:
        raise ValueError(kind)
    if cfg.d_ff > 0 and kind not in ("mlstm", "slstm"):
        p["norm2"] = L.init_norm(cfg, dt)
        p["ffn"] = M.init_moe(ks[1], cfg, dt) if cfg.moe else L.init_mlp(ks[1], cfg, dt)
    return p


def apply_block(
    p: Dict,
    cfg: ModelConfig,
    kind: str,
    x: jax.Array,
    positions: jax.Array,
    cache: Optional[Dict],
):
    """Returns (x, new_cache, aux_loss)."""
    h = L.apply_norm(p["norm1"], cfg, x)
    if kind in ATTN_KINDS:
        y, new_cache = L.apply_attention(
            p["mixer"], cfg, h, positions, kind=kind, cache=cache
        )
    elif kind == "rglru":
        y, new_cache = R.apply_rglru(p["mixer"], cfg, h, cache)
    elif kind == "mlstm":
        y, new_cache = R.apply_mlstm(p["mixer"], cfg, h, cache)
    elif kind == "slstm":
        y, new_cache = R.apply_slstm(p["mixer"], cfg, h, cache)
    else:
        raise ValueError(kind)
    x = x + y
    aux = jnp.zeros((), jnp.float32)
    if "ffn" in p:
        h2 = L.apply_norm(p["norm2"], cfg, x)
        if cfg.moe:
            y2, aux = M.apply_moe(p["ffn"], cfg, h2)
        else:
            y2 = L.apply_mlp(p["ffn"], cfg, h2)
        x = x + y2
    x = constrain(x, "batch", None, "embed_act")
    return x, new_cache, aux


def init_cache_entry(cfg: ModelConfig, kind: str, batch: int, max_len: int):
    dt = _dtype(cfg)
    if kind in ATTN_KINDS:
        length = max_len if kind == "attn" or cfg.window is None else min(max_len, cfg.window)
        return L.init_kv_cache(cfg, batch, length, dt)
    if kind == "rglru":
        return R.init_rglru_state(cfg, batch, dt)
    if kind == "mlstm":
        return R.init_mlstm_state(cfg, batch, dt)
    if kind == "slstm":
        return R.init_slstm_state(cfg, batch, dt)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# whole-model params
# ---------------------------------------------------------------------------


def _pattern_layout(cfg: ModelConfig) -> Tuple[int, Tuple[str, ...]]:
    """(n_groups, remainder_kinds)."""
    P = len(cfg.block_pattern)
    return cfg.n_layers // P, tuple(
        cfg.block_pattern[i % P] for i in range(cfg.n_layers - cfg.n_layers % P, cfg.n_layers)
    )


def init_lm(rng, cfg: ModelConfig):
    """Returns a tree with (array, axes) leaves; split with split_tree."""
    dt = _dtype(cfg)
    n_groups, rest = _pattern_layout(cfg)
    ks = iter(jax.random.split(rng, 4 + cfg.n_layers))
    tree: Dict = {
        "embed": {"table": leaf(normal(next(ks), (cfg.vocab_size, cfg.d_model), dt),
                                "vocab", "embed")},
        "final_norm": L.init_norm(cfg, dt),
    }
    if not cfg.tie_embeddings:
        tree["lm_head"] = {"w": leaf(normal(next(ks), (cfg.d_model, cfg.vocab_size), dt),
                                     "embed", "vocab")}
    groups = []
    if n_groups > 0:
        for p_idx, kind in enumerate(cfg.block_pattern):
            per_group = [init_block(next(ks), cfg, kind) for _ in range(n_groups)]
            groups.append(stack_trees(per_group))
    tree["groups"] = groups
    tree["rest"] = [init_block(next(ks), cfg, kind) for kind in rest]
    return tree


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------


def _embed(params, cfg: ModelConfig, batch: Dict) -> jax.Array:
    tokens = batch["tokens"]
    x = params["embed"]["table"][tokens]
    if cfg.frontend is not None and "vision_embeds" in batch:
        fe = batch["vision_embeds"].astype(x.dtype)
        n = fe.shape[1]
        x = jnp.concatenate([fe, x[:, n:]], axis=1)
    return constrain(x, "batch", None, "embed_act")


def _logits(params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    x = L.apply_norm(params["final_norm"], cfg, x)
    if cfg.tie_embeddings:
        w = params["embed"]["table"].T
    else:
        w = params["lm_head"]["w"]
    logits = jnp.einsum("btd,dv->btv", x, w)
    return constrain(logits, "batch", None, "vocab_act")


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "full":
        return jax.checkpoint(fn)
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    raise ValueError(policy)


def apply_stack_train(params, cfg: ModelConfig, x, positions, remat_policy="none"):
    """Training/prefill-style pass without caches. Returns (x, aux)."""
    n_groups, rest = _pattern_layout(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    if n_groups > 0:
        def group_body(x, group_params):
            aux = jnp.zeros((), jnp.float32)
            for p_idx, kind in enumerate(cfg.block_pattern):
                x, _, a = apply_block(group_params[p_idx], cfg, kind, x, positions, None)
                aux = aux + a
            return x, aux

        body = _remat(group_body, remat_policy)
        x, auxs = jax.lax.scan(lambda c, xs: body(c, xs), x, tuple(params["groups"]))
        aux_total = aux_total + auxs.sum()
    for p_rest, kind in zip(params["rest"], _pattern_layout(cfg)[1]):
        x, _, a = apply_block(p_rest, cfg, kind, x, positions, None)
        aux_total = aux_total + a
    return x, aux_total


def apply_stack_cached(params, cfg: ModelConfig, x, positions, cache):
    """Prefill/decode pass threading caches. Returns (x, new_cache)."""
    n_groups, rest_kinds = _pattern_layout(cfg)
    new_cache: Dict = {"groups": [], "rest": []}
    if n_groups > 0:
        def group_body(x, xs):
            group_params, group_cache = xs
            new_entries = []
            for p_idx, kind in enumerate(cfg.block_pattern):
                x, nc, _ = apply_block(
                    group_params[p_idx], cfg, kind, x, positions, group_cache[p_idx]
                )
                new_entries.append(nc)
            return x, tuple(new_entries)

        x, new_group_cache = jax.lax.scan(
            group_body, x, (tuple(params["groups"]), tuple(cache["groups"]))
        )
        new_cache["groups"] = list(new_group_cache)
    for p_rest, kind, c_rest in zip(params["rest"], rest_kinds, cache["rest"]):
        x, nc, _ = apply_block(p_rest, cfg, kind, x, positions, c_rest)
        new_cache["rest"].append(nc)
    return x, new_cache


def init_lm_cache(cfg: ModelConfig, batch: int, max_len: int):
    n_groups, rest_kinds = _pattern_layout(cfg)
    groups = []
    if n_groups > 0:
        for kind in cfg.block_pattern:
            entries = [init_cache_entry(cfg, kind, batch, max_len) for _ in range(n_groups)]
            groups.append(jax.tree.map(lambda *ls: jnp.stack(ls), *entries)
                          if n_groups > 1 else jax.tree.map(lambda l: l[None], entries[0]))
    rest = [init_cache_entry(cfg, kind, batch, max_len) for kind in rest_kinds]
    return {"groups": groups, "rest": rest}


# ---------------------------------------------------------------------------
# public heads
# ---------------------------------------------------------------------------


def lm_loss(params, cfg: ModelConfig, batch: Dict, remat_policy="none"):
    """Next-token CE over ``labels`` (mask: labels < 0). Returns (loss, metrics)."""
    x = _embed(params, cfg, batch)
    T = x.shape[1]
    positions = jnp.arange(T)
    x, aux = apply_stack_train(params, cfg, x, positions, remat_policy)
    logits = _logits(params, cfg, x).astype(jnp.float32)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    lbl = jnp.maximum(labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, lbl[..., None], axis=-1)[..., 0]
    ce = (lse - gold) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = ce.sum() / denom
    zloss = 1e-4 * ((lse * mask) ** 2).sum() / denom
    total = loss + zloss + 1e-2 * aux
    return total, {"ce": loss, "zloss": zloss, "aux": aux, "tokens": denom}


def lm_prefill(params, cfg: ModelConfig, batch: Dict, cache):
    """Forward the prompt, filling caches; returns (last_logits, cache)."""
    x = _embed(params, cfg, batch)
    T = x.shape[1]
    positions = jnp.arange(T)
    x, cache = apply_stack_cached(params, cfg, x, positions, cache)
    logits = _logits(params, cfg, x[:, -1:, :])
    return logits[:, 0], cache


def lm_decode_step(params, cfg: ModelConfig, token: jax.Array, pos: jax.Array, cache):
    """One decode step. token: (B,) int32; pos: () int32 absolute position."""
    x = params["embed"]["table"][token][:, None, :]
    positions = jnp.full((1,), pos, jnp.int32)
    x, cache = apply_stack_cached(params, cfg, x, positions, cache)
    logits = _logits(params, cfg, x)
    return logits[:, 0], cache
