"""Mixture-of-Experts FFN (olmoe-1b-7b, granite-moe-1b-a400m).

GShard/Switch-style capacity-based top-k routing with einsum dispatch —
the TPU-native formulation: dispatch/combine are dense one-hot einsums
(MXU work, no scatter), expert compute is a batched GEMM with the expert
axis shardable over the mesh ("expert parallelism"); XLA lowers the
sharded dispatch to all-to-alls.  Compute scales with ``top_k`` and the
capacity factor, not with ``n_experts`` — HLO FLOPs stay proportional to
*active* parameters, which the §Roofline MODEL_FLOPS/HLO_FLOPs ratio
checks.

Tokens overflowing an expert's capacity are dropped (standard GShard
behaviour); the auxiliary load-balancing loss keeps overflow rare.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.distributed import constrain
from repro.models.config import ModelConfig
from repro.models.param_util import leaf, normal


def init_moe(rng, cfg: ModelConfig, dtype) -> Dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(rng, 4)
    return {
        "router": leaf(normal(ks[0], (d, e), jnp.float32), "embed", "experts"),
        "wi": leaf(normal(ks[1], (e, d, f), dtype), "experts", "embed", "mlp"),
        "wg": leaf(normal(ks[2], (e, d, f), dtype), "experts", "embed", "mlp"),
        "wo": leaf(normal(ks[3], (e, f, d), dtype), "experts", "mlp", "embed"),
    }


def apply_moe(p: Dict, cfg: ModelConfig, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: (B, T, D) -> (out, aux_loss)."""
    B0, T0, D = x.shape
    if cfg.moe_group is not None and T0 > cfg.moe_group and T0 % cfg.moe_group == 0:
        # re-group tokens: dispatch cost drops from O(T^2) to O(T*group)
        g = cfg.moe_group
        x = x.reshape(B0 * (T0 // g), g, D)
    B, T, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    capacity = max(1, int(cfg.capacity_factor * K * T / E))

    logits = jnp.einsum("btd,de->bte", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)

    # -- top-k choice per token ------------------------------------------------
    gate_vals, expert_idx = jax.lax.top_k(probs, K)          # (B,T,K)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    # -- capacity assignment (GShard): position of each (token, choice)
    # within its expert's buffer, computed with a cumulative sum over the
    # flattened token axis, independently per batch group.
    onehot = jax.nn.one_hot(expert_idx, E, dtype=jnp.float32)  # (B,T,K,E)
    # priority: choice k=0 of every token first, then k=1, ... (GShard)
    flat = onehot.transpose(0, 2, 1, 3).reshape(B, K * T, E)   # (B, K*T, E)
    pos_in_expert = jnp.cumsum(flat, axis=1) - flat            # (B, K*T, E)
    pos_in_expert = (pos_in_expert * flat).sum(-1)             # (B, K*T)
    fits = pos_in_expert < capacity
    flat = flat * fits[..., None]
    pos_oh = jax.nn.one_hot(pos_in_expert, capacity, dtype=jnp.float32)  # (B,K*T,C)
    dispatch = jnp.einsum("bse,bsc->bsec", flat, pos_oh)       # (B,K*T,E,C)
    dispatch = dispatch.reshape(B, K, T, E, capacity).transpose(0, 2, 1, 3, 4)
    dispatch = dispatch.sum(2)                                 # (B,T,E,C)
    combine = dispatch * jnp.einsum(
        "btke,btk->bte", onehot, gate_vals
    )[..., None]                                               # (B,T,E,C)

    # -- expert compute ---------------------------------------------------------
    xin = jnp.einsum("btec,btd->becd", dispatch.astype(x.dtype), x)   # (B,E,C,D)
    xin = constrain(xin, "batch", "experts_act", None, None)
    h = jnp.einsum("becd,edf->becf", xin, p["wi"])
    g = jax.nn.silu(jnp.einsum("becd,edf->becf", xin, p["wg"]))
    h = h * g
    eout = jnp.einsum("becf,efd->becd", h, p["wo"])             # (B,E,C,D)
    eout = constrain(eout, "batch", "experts_act", None, None)
    out = jnp.einsum("btec,becd->btd", combine.astype(x.dtype), eout)

    # -- auxiliary load-balance loss (Switch eq. 4) -------------------------------
    me = probs.mean(axis=(0, 1))                                # (E,)
    ce = onehot.sum(2).mean(axis=(0, 1))                        # fraction routed
    aux = E * jnp.sum(me * ce / K)
    if out.shape[0] != B0:
        out = out.reshape(B0, T0, D)
    return out, aux.astype(jnp.float32)
