"""Architecture configuration schema.

One :class:`ModelConfig` instance per assigned architecture lives in
``repro.configs.<id>``; reduced copies (via :meth:`ModelConfig.reduced`)
drive the CPU smoke tests.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | hybrid | moe | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: Optional[int] = None     # default: d_model // n_heads

    # -- attention flavour ---------------------------------------------------
    window: Optional[int] = None     # sliding-window size (SWA / local attn)
    qk_norm: bool = False            # per-head RMSNorm on q,k (qwen3)
    qkv_bias: bool = False           # bias on qkv projections (qwen1.5)
    rope_theta: float = 10_000.0
    softcap: Optional[float] = None

    # -- norms / mlp ----------------------------------------------------------
    norm_kind: str = "rmsnorm"       # rmsnorm | layernorm | nonparam_ln
    mlp_kind: str = "swiglu"         # swiglu | geglu | gelu
    tie_embeddings: bool = False

    # -- MoE -------------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # routing-group size: dispatch/combine einsums cost O(group * E * C)
    # per token with C ∝ group, i.e. quadratic in the group — None groups
    # per batch row (group = seq_len, the naive GShard layout); the perf
    # pass re-groups to a few hundred tokens (see EXPERIMENTS.md §Perf).
    moe_group: Optional[int] = None

    # -- layer pattern (cycled; heterogeneous for hybrid/ssm) -------------------
    # entries: "attn" | "local" | "swa" | "rglru" | "mlstm" | "slstm"
    block_pattern: Tuple[str, ...] = ("attn",)
    d_rnn: Optional[int] = None      # RG-LRU / xLSTM state width
    conv_width: int = 4              # temporal conv in recurrent blocks

    # -- topology ----------------------------------------------------------------
    arch_kind: str = "decoder"       # decoder | encdec
    n_enc_layers: int = 0

    # -- modality frontend (STUB: precomputed embeddings via input_specs) --------
    frontend: Optional[str] = None   # None | "vision_stub" | "audio_stub"
    n_frontend_tokens: int = 0       # patches / frames prepended to the sequence

    dtype: str = "bfloat16"

    # ------------------------------------------------------------------ derived
    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else self.d_model // self.n_heads

    @property
    def rnn_width(self) -> int:
        return self.d_rnn if self.d_rnn is not None else self.d_model

    @property
    def moe(self) -> bool:
        return self.n_experts > 0

    @property
    def attention_free(self) -> bool:
        return all(k in ("rglru", "mlstm", "slstm") for k in self.block_pattern)

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch decode at 500k context (no full-attention layer)?"""
        return all(k != "attn" for k in self.block_pattern)

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, dh = self.d_model, self.head_dim
        emb = self.vocab_size * d
        per_layer = {}
        attn = d * (self.n_heads * dh) + 2 * d * (self.n_kv_heads * dh) + (self.n_heads * dh) * d
        if self.mlp_kind in ("swiglu", "geglu"):
            mlp = 3 * d * self.d_ff
        else:
            mlp = 2 * d * self.d_ff
        if self.moe:
            mlp = self.n_experts * (3 * d * self.d_ff) + d * self.n_experts
        rnn = self.rnn_width
        rec = 2 * d * rnn + rnn * d + self.conv_width * rnn + 3 * rnn  # griffin-ish
        mls = 2 * d * 2 * rnn + 2 * rnn * d + (3 + 3) * rnn            # mlstm-ish
        total = emb
        n_stacks = (1 if self.arch_kind == "decoder" else 2)
        pattern = self.block_pattern
        for i in range(self.n_layers):
            kind = pattern[i % len(pattern)]
            if kind in ("attn", "local", "swa"):
                total += attn + (mlp if self.d_ff > 0 else 0)
            elif kind == "rglru":
                total += rec + (mlp if self.d_ff > 0 else 0)
            else:
                total += mls
        if self.arch_kind == "encdec":
            # encoder stack + cross attention in decoder
            total += self.n_enc_layers * (attn + mlp)
            total += self.n_layers * attn  # cross-attn
        if not self.tie_embeddings:
            total += self.vocab_size * d
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of n_experts)."""
        if not self.moe:
            return self.param_count()
        d = self.d_model
        full = self.param_count()
        moe_all = self.n_layers * self.n_experts * 3 * d * self.d_ff
        moe_active = self.n_layers * self.top_k * 3 * d * self.d_ff
        return full - moe_all + moe_active

    def reduced(self, **overrides) -> "ModelConfig":
        """Tiny same-family copy for CPU smoke tests."""
        shrink = dict(
            n_layers=min(self.n_layers, len(self.block_pattern) + 1),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            d_head=16,
            d_ff=96 if self.d_ff > 0 else 0,
            vocab_size=257,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            window=min(self.window, 8) if self.window else None,
            d_rnn=64 if self.d_rnn else None,
            n_enc_layers=min(self.n_enc_layers, 2),
            n_frontend_tokens=min(self.n_frontend_tokens, 4),
            dtype="float32",
        )
        shrink.update(overrides)
        return dataclasses.replace(self, **shrink)
