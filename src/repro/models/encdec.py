"""Encoder-decoder assembly (seamless-m4t-large-v2 backbone).

Bidirectional encoder over stub frame embeddings (the multimodal
frontend provides precomputed embeddings via ``input_specs`` — paper
scope is the transformer backbone), causal decoder with per-layer
cross-attention.  Decode shapes lower the *decoder* step against a
fixed encoder memory.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed import constrain
from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.param_util import leaf, normal, stack_trees

# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------


def _init_enc_block(rng, cfg: ModelConfig, dt) -> Dict:
    ks = jax.random.split(rng, 2)
    return {
        "norm1": L.init_norm(cfg, dt),
        "attn": L.init_attention(ks[0], cfg, dt),
        "norm2": L.init_norm(cfg, dt),
        "mlp": L.init_mlp(ks[1], cfg, dt),
    }


def _init_dec_block(rng, cfg: ModelConfig, dt) -> Dict:
    ks = jax.random.split(rng, 3)
    return {
        "norm1": L.init_norm(cfg, dt),
        "self": L.init_attention(ks[0], cfg, dt),
        "norm_x": L.init_norm(cfg, dt),
        "cross": L.init_attention(ks[1], cfg, dt, cross=True),
        "norm2": L.init_norm(cfg, dt),
        "mlp": L.init_mlp(ks[2], cfg, dt),
    }


def _apply_enc_block(p, cfg, x, positions):
    h = L.apply_norm(p["norm1"], cfg, x)
    y, _ = L.apply_attention(p["attn"], cfg, h, positions, causal=False)
    x = x + y
    h = L.apply_norm(p["norm2"], cfg, x)
    x = x + L.apply_mlp(p["mlp"], cfg, h)
    return constrain(x, "batch", None, "embed_act")


def _apply_dec_block(p, cfg, x, positions, memory_kv, cache):
    h = L.apply_norm(p["norm1"], cfg, x)
    y, new_cache = L.apply_attention(p["self"], cfg, h, positions, cache=cache)
    x = x + y
    h = L.apply_norm(p["norm_x"], cfg, x)
    x = x + L.apply_cross_attention(p["cross"], cfg, h, memory_kv)
    h = L.apply_norm(p["norm2"], cfg, x)
    x = x + L.apply_mlp(p["mlp"], cfg, h)
    return constrain(x, "batch", None, "embed_act"), new_cache


# ---------------------------------------------------------------------------
# whole model
# ---------------------------------------------------------------------------


def init_encdec(rng, cfg: ModelConfig):
    dt = jnp.dtype(cfg.dtype)
    n_enc, n_dec = cfg.n_enc_layers, cfg.n_layers
    ks = iter(jax.random.split(rng, n_enc + n_dec + 8))
    tree: Dict = {
        "embed": {"table": leaf(normal(next(ks), (cfg.vocab_size, cfg.d_model), dt),
                                "vocab", "embed")},
        "enc_blocks": stack_trees([_init_enc_block(next(ks), cfg, dt) for _ in range(n_enc)]),
        "enc_norm": L.init_norm(cfg, dt),
        "dec_blocks": stack_trees([_init_dec_block(next(ks), cfg, dt) for _ in range(n_dec)]),
        "final_norm": L.init_norm(cfg, dt),
        "lm_head": {"w": leaf(normal(next(ks), (cfg.d_model, cfg.vocab_size), dt),
                              "embed", "vocab")},
    }
    return tree


def _remat(fn, policy):
    if policy == "none":
        return fn
    if policy == "full":
        return jax.checkpoint(fn)
    return jax.checkpoint(
        fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
    )


def encode(params, cfg: ModelConfig, enc_embeds: jax.Array, remat_policy="none"):
    """enc_embeds: (B, S_enc, D) stub frontend output."""
    x = enc_embeds.astype(jnp.dtype(cfg.dtype))
    x = constrain(x, "batch", None, "embed_act")
    positions = jnp.arange(x.shape[1])
    body = _remat(lambda c, p: (_apply_enc_block(p, cfg, c, positions), None),
                  remat_policy)
    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return L.apply_norm(params["enc_norm"], cfg, x)


def cross_memories(params, cfg: ModelConfig, enc_out: jax.Array):
    """Precompute per-layer cross-attention K/V from encoder output."""
    def per_layer(_, p):
        return None, L.cross_attention_memory(p["cross"], cfg, enc_out)

    _, kv = jax.lax.scan(per_layer, None, params["dec_blocks"])
    return kv  # leaves have leading n_dec axis


def decode_train(params, cfg: ModelConfig, tokens, enc_out, remat_policy="none"):
    x = params["embed"]["table"][tokens]
    positions = jnp.arange(x.shape[1])

    def body(c, xs):
        p = xs
        mem = L.cross_attention_memory(p["cross"], cfg, enc_out)
        out, _ = _apply_dec_block(p, cfg, c, positions, mem, None)
        return out, None

    x, _ = jax.lax.scan(_remat(body, remat_policy), x, params["dec_blocks"])
    x = L.apply_norm(params["final_norm"], cfg, x)
    return jnp.einsum("btd,dv->btv", x, params["lm_head"]["w"])


def encdec_loss(params, cfg: ModelConfig, batch: Dict, remat_policy="none"):
    """batch: enc_embeds (B,S,D), tokens (B,T), labels (B,T)."""
    enc_out = encode(params, cfg, batch["enc_embeds"], remat_policy)
    logits = decode_train(params, cfg, batch["tokens"], enc_out, remat_policy)
    logits = constrain(logits, "batch", None, "vocab_act").astype(jnp.float32)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    lbl = jnp.maximum(labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, lbl[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = ((lse - gold) * mask).sum() / denom
    zloss = 1e-4 * ((lse * mask) ** 2).sum() / denom
    return loss + zloss, {"ce": loss, "zloss": zloss, "tokens": denom}


def init_encdec_cache(cfg: ModelConfig, batch: int, max_len: int):
    dt = jnp.dtype(cfg.dtype)
    entries = [L.init_kv_cache(cfg, batch, max_len, dt) for _ in range(cfg.n_layers)]
    return jax.tree.map(lambda *ls: jnp.stack(ls), *entries)


def encdec_prefill(params, cfg: ModelConfig, batch: Dict, cache):
    """Encode + run decoder prompt; returns (last_logits, cache, memories)."""
    enc_out = encode(params, cfg, batch["enc_embeds"])
    memories = cross_memories(params, cfg, enc_out)
    x = params["embed"]["table"][batch["tokens"]]
    positions = jnp.arange(x.shape[1])

    def body(c, xs):
        p, mem, entry = xs
        out, nc = _apply_dec_block(p, cfg, c, positions, mem, entry)
        return out, nc

    x, new_cache = jax.lax.scan(body, x, (params["dec_blocks"], memories, cache))
    x = L.apply_norm(params["final_norm"], cfg, x[:, -1:, :])
    return jnp.einsum("btd,dv->btv", x, params["lm_head"]["w"])[:, 0], new_cache, memories


def encdec_decode_step(params, cfg: ModelConfig, token, pos, cache, memories):
    x = params["embed"]["table"][token][:, None, :]
    positions = jnp.full((1,), pos, jnp.int32)

    def body(c, xs):
        p, mem, entry = xs
        out, nc = _apply_dec_block(p, cfg, c, positions, mem, entry)
        return out, nc

    x, new_cache = jax.lax.scan(body, x, (params["dec_blocks"], memories, cache))
    x = L.apply_norm(params["final_norm"], cfg, x)
    return jnp.einsum("btd,dv->btv", x, params["lm_head"]["w"])[:, 0], new_cache
