"""Model zoo: every assigned architecture as a composable JAX module."""

from repro.models.config import ModelConfig
from repro.models.zoo import build_model

__all__ = ["ModelConfig", "build_model"]
