"""BlobSeer-backed checkpointing."""

from repro.checkpoint.blobckpt import BlobCheckpointer, CheckpointStats

__all__ = ["BlobCheckpointer", "CheckpointStats"]
