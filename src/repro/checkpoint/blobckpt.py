"""Versioned, incremental, branchable checkpoints over BlobSeer.

This is the paper's technique deployed as the framework's fault-
tolerance substrate:

* the training state pytree is laid out in one blob, every leaf aligned
  to page boundaries;
* each save WRITEs only the *changed page ranges* (detected with the
  ``page_digest``/``delta_mask`` kernels), so unchanged pages — frozen
  embeddings, cold optimizer slots, the entire model when only the data
  cursor moved — are physically shared between checkpoints via the
  segment tree's copy-on-write weaving (paper §4.3 "efficient use of
  storage space").  All dirty runs of one save ride a single
  ``BlobClient.write_many`` batch: one version per run as before, but
  one version-manager assignment round trip and one batched completion
  for the whole save (the scale-out write plane);
* commit protocol: data pages -> manifest (layout + step + digests +
  pipeline cursor) -> a one-page *commit pointer* holding the manifest
  write's snapshot version.  A restore resolves the pointer and reads
  manifest + leaves **at that version** — BlobSeer snapshots are
  immutable, so a reader can GET_RECENT at any moment (mid-save
  included) and always reconstruct a fully consistent checkpoint, while
  later saves proceed concurrently on higher versions;
* BRANCH forks a checkpoint lineage in O(1) bytes for ablations /
  fine-tunes (examples/branch_experiments.py);
* the delta scan's page digests are passed straight through
  ``write_many(..., digests=...)`` as the dedup-handshake input, so a
  deployment with content-addressed dedup matches equal pages (branch
  twins, re-written checkpoints) without hashing anything twice.

Blob traffic is plain numpy/bytes on the host side: device arrays are
pulled once per leaf with ``jax.device_get`` and all dirty runs of a
save ride one batched ``write_many`` (a real multi-host deployment
would hand each host its own leaf shards; the interface is per-leaf so
that change is local).
"""

from __future__ import annotations

import json
import zlib
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.blob import BlobClient
from repro.core.version_manager import RetiredVersion, VersionUnpublished
from repro.kernels import ops as kops


@dataclass
class CheckpointStats:
    version: int
    step: int
    total_bytes: int
    written_bytes: int
    pages_total: int
    pages_written: int

    @property
    def sharing_fraction(self) -> float:
        return 1.0 - (self.pages_written / max(self.pages_total, 1))


def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path
        )
        out.append((key, leaf))
    out.sort(key=lambda kv: kv[0])
    return out


class BlobCheckpointer:
    def __init__(
        self,
        client: BlobClient,
        blob_id: Optional[str] = None,
        *,
        psize: int = 256 * 1024,
        header_pages: int = 64,
    ) -> None:
        self.client = client
        if blob_id is None:
            blob_id = client.create(psize=psize)
        self.blob_id = blob_id
        self.psize = client.vm.psize_of(blob_id)
        self.header_bytes = header_pages * self.psize
        # header layout: [commit pointer page][manifest region]
        self.manifest_off = self.psize
        self._digests: Dict[str, np.ndarray] = {}   # path -> (n_pages, 2) u32
        self._layout: Dict[str, Tuple[int, int]] = {}  # path -> (offset, nbytes)
        # rolling GC pin on the latest commit's manifest snapshot: the
        # commit pointer dereferences an *older* version than the commit
        # write itself, which a keep-last retention window cannot see
        self._manifest_lease: Optional[str] = None

    # ------------------------------------------------------------------- save
    def save(self, state, step: int, extra: Optional[Dict] = None) -> CheckpointStats:
        """Write an incremental checkpoint; returns sharing stats."""
        leaves = _flatten_with_paths(state)
        psz = self.psize

        # -- layout: leaf offsets page-aligned after the header region --
        offset = self.header_bytes
        layout: Dict[str, Tuple[int, int]] = {}
        arrays: Dict[str, np.ndarray] = {}
        for path, leaf in leaves:
            arr = np.asarray(jax.device_get(leaf))
            arrays[path] = arr
            nbytes = max(arr.nbytes, 1)
            layout[path] = (offset, nbytes)
            offset += -(-nbytes // psz) * psz
        total = offset
        layout_changed = layout != self._layout

        # BlobSeer WRITE forbids holes (offset <= size of the previous
        # snapshot): on first save, commit a zero header so subsequent
        # page-aligned leaf writes extend the blob contiguously.
        recent = self.client.get_recent(self.blob_id)
        cur_size = self.client.get_size(self.blob_id, recent) if recent else 0
        if cur_size < self.header_bytes:
            self.client.write(self.blob_id, b"\0" * self.header_bytes, 0)

        written_bytes = 0
        pages_written = 0
        pages_total = (total - self.header_bytes) // psz
        manifest_leaves = []
        new_digests: Dict[str, np.ndarray] = {}
        # dirty page runs across ALL leaves are collected and written as
        # one write_many batch: one version per run (same snapshots as
        # one write() per run), but the whole save pays a single
        # version-manager assignment round trip and a single batched
        # completion — the scale-out write plane under the checkpointer
        dirty_writes: List[Tuple[bytes, int]] = []
        # per run, the delta scan's page fingerprints ride along into
        # write_many as the dedup-handshake input — the content-hash
        # index matches on exactly these digests, nothing hashes twice
        dirty_digests: List[List[Tuple[int, int]]] = []
        for path, leaf in leaves:
            arr = arrays[path]
            off, nbytes = layout[path]
            raw = arr.tobytes()
            padded = raw + b"\0" * ((-len(raw)) % 4)
            dg = np.asarray(kops.page_digest(
                jnp.asarray(np.frombuffer(padded, dtype=np.uint8)), page_bytes=psz,
            ))
            new_digests[path] = dg
            old = self._digests.get(path)
            if layout_changed or old is None or old.shape != dg.shape:
                dirty = np.ones(dg.shape[0], dtype=bool)
            else:
                dirty = np.asarray(kops.delta_mask(
                    jax.numpy.asarray(dg), jax.numpy.asarray(old)
                ))
            # write contiguous dirty page runs, zero-padded to full pages:
            # page-aligned writes are BlobSeer's fast path (no boundary
            # merging) and keep blob growth contiguous
            n_pages = dg.shape[0]
            i = 0
            while i < n_pages:
                if not dirty[i]:
                    i += 1
                    continue
                j = i
                while j < n_pages and dirty[j]:
                    j += 1
                lo = i * psz
                chunk = raw[lo : j * psz]
                pad = (j - i) * psz - len(chunk)
                if pad:
                    chunk = chunk + b"\0" * pad
                dirty_writes.append((chunk, off + lo))
                dirty_digests.append(
                    [(int(dg[k, 0]), int(dg[k, 1])) for k in range(i, j)])
                written_bytes += len(chunk)
                pages_written += j - i
                i = j
            manifest_leaves.append({
                "path": path,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "offset": off,
                "nbytes": nbytes,
            })

        if dirty_writes:
            self.client.write_many(self.blob_id, dirty_writes,
                                   digests=dirty_digests)

        manifest = {
            "format": 1,
            "step": step,
            "total_bytes": total,
            "leaves": manifest_leaves,
            "extra": extra or {},
            "digests": {p: d.tobytes().hex() for p, d in new_digests.items()},
        }
        payload = zlib.compress(json.dumps(manifest).encode())
        record = len(payload).to_bytes(8, "little") + payload
        if len(record) > self.header_bytes - self.manifest_off:
            raise ValueError(
                f"manifest ({len(record)}B) exceeds header region "
                f"({self.header_bytes - self.manifest_off}B); raise header_pages"
            )
        # commit protocol: manifest, then the commit pointer naming the
        # manifest write's snapshot version (restores read AT that version)
        vm_version = self.client.write(self.blob_id, record, self.manifest_off)
        self.client.sync(self.blob_id, vm_version)
        # roll the GC pin forward NOW, while the manifest snapshot is
        # still the newest published version (always kept): pinning only
        # after the commit write would leave a window where a retention
        # GC round retires the manifest of the just-committed checkpoint
        lease = self.client.pin(self.blob_id, vm_version)
        try:
            commit = vm_version.to_bytes(8, "little") + b"\1"
            vc = self.client.write(self.blob_id, commit, 0)
            self.client.sync(self.blob_id, vc)
        except BaseException:
            # failed commit: release the just-taken pin or it leaks an
            # untimed lease that excludes this snapshot from GC forever
            try:
                self.client.unpin(lease)
            except Exception:
                pass  # best effort (e.g. wire down); save() still fails
            raise
        if self._manifest_lease is not None:
            self.client.unpin(self._manifest_lease)
        self._manifest_lease = lease
        self._digests = new_digests
        self._layout = layout
        written_bytes += len(record) + len(commit)
        return CheckpointStats(
            version=vc, step=step, total_bytes=total,
            written_bytes=written_bytes, pages_total=pages_total,
            pages_written=pages_written,
        )

    # ---------------------------------------------------------------- restore
    def read_manifest(self, version: Optional[int] = None) -> Tuple[Dict, int]:
        """(manifest, resolved_version). Leaf reads must use the latter.

        ``version`` may be any snapshot (default: most recent published);
        the commit pointer stored at that snapshot names the manifest
        write's version, and manifest + leaves are read there — immutable
        snapshots make this consistent no matter what later saves did.
        """
        at = version if version is not None else self.client.get_recent(self.blob_id)
        if at == 0:
            raise FileNotFoundError("no checkpoint published yet")
        head = self.client.read(self.blob_id, at, 0, 9)
        if head[8] != 1:
            raise FileNotFoundError("no checkpoint committed yet")
        vm = int.from_bytes(head[:8], "little")
        head = self.client.read(self.blob_id, vm, self.manifest_off, 8)
        n = int.from_bytes(head, "little")
        raw = self.client.read(self.blob_id, vm, self.manifest_off + 8, n)
        manifest = json.loads(zlib.decompress(raw))
        return manifest, vm

    def restore(self, like, version: Optional[int] = None,
                with_manifest: bool = False):
        """Rebuild a state pytree shaped ``like`` from a checkpoint.

        ``like`` may contain arrays or ShapeDtypeStructs; restored leaves
        are plain numpy (callers ``device_put`` with their shardings).

        The commit-pointer snapshot and the resolved manifest snapshot
        are both pinned before their reads, so a concurrent GC round
        (retention-driven snapshot retirement) cannot sweep the
        checkpoint out from under the manifest or leaf reads.  If GC
        retires the snapshot before the pin lands, the pin raises a
        typed ``RetiredVersion`` and the caller can retry at a newer
        commit.
        """
        at = version if version is not None else self.client.get_recent(self.blob_id)
        outer = self.client.pin(self.blob_id, at) if at > 0 else None
        try:
            manifest, version = self.read_manifest(at)
            lease = self.client.pin(self.blob_id, version)
        finally:
            if outer is not None:
                self.client.unpin(outer)
        try:
            by_path = {l["path"]: l for l in manifest["leaves"]}
            flat = jax.tree_util.tree_flatten_with_path(like)
            leaves = []
            for path, leaf in flat[0]:
                key = "/".join(str(p.key) if hasattr(p, "key") else str(p.idx)
                               for p in path)
                rec = by_path.get(key)
                if rec is None:
                    raise KeyError(f"checkpoint v{version} missing leaf {key}")
                raw = self.client.read(self.blob_id, version, rec["offset"], rec["nbytes"])
                arr = np.frombuffer(raw, dtype=np.dtype(rec["dtype"])).reshape(rec["shape"])
                leaves.append(arr)
            tree = jax.tree_util.tree_unflatten(flat[1], leaves)
        finally:
            self.client.unpin(lease)
        if with_manifest:
            return tree, manifest
        return tree

    def load_digest_cache(self, version: Optional[int] = None) -> None:
        """Resume delta-detection after a trainer restart."""
        manifest, _ = self.read_manifest(version)
        self._digests = {
            p: np.frombuffer(bytes.fromhex(h), dtype=np.uint32).reshape(-1, 2)
            for p, h in manifest.get("digests", {}).items()
        }
        self._layout = {
            l["path"]: (l["offset"], l["nbytes"]) for l in manifest["leaves"]
        }

    # ----------------------------------------------------------------- branch
    def branch(self, version: Optional[int] = None) -> "BlobCheckpointer":
        """Fork the lineage at a commit version (default: most recent)."""
        if version is None:
            version = self.client.get_recent(self.blob_id)
        bid = self.client.branch(self.blob_id, version)
        child = BlobCheckpointer(self.client, bid,
                                 header_pages=self.header_bytes // self.psize)
        child.load_digest_cache(version)
        return child

    def steps(self) -> List[Tuple[int, int]]:
        """(version, step) of every complete checkpoint in the lineage."""
        out = []
        recent = self.client.get_recent(self.blob_id)
        seen = set()
        v = recent
        while v > 0:
            try:
                manifest, _ = self.read_manifest(v)
            except (FileNotFoundError, VersionUnpublished, RetiredVersion):
                # Typed end-of-history ONLY: no checkpoint published or
                # committed at v (read_manifest's FileNotFoundError), a
                # never-assigned version, or one GC already retired.
                # Anything else — a downed endpoint, a wire error, real
                # corruption — must propagate: swallowing it here used
                # to silently truncate the listing to whatever prefix
                # happened to be reachable, and callers pruned/restored
                # against that lie.
                break
            key = manifest["step"]
            if key not in seen:
                out.append((v, key))
                seen.add(key)
            # jump to before this checkpoint's writes: heuristic walk
            v -= 1
            if len(out) > 10_000:
                break
        return sorted(out)
