"""Byte-level tokenizer for the runnable examples.

Vocab: 256 byte values + BOS/EOS/PAD.  Enough to train the e2e example
end to end without external assets; the pipeline is tokenizer-agnostic
(it moves int32 token streams).
"""

from __future__ import annotations

import numpy as np

PAD, BOS, EOS = 256, 257, 258
VOCAB_SIZE = 259


class ByteTokenizer:
    vocab_size = VOCAB_SIZE
    pad, bos, eos = PAD, BOS, EOS

    def encode(self, text: str, add_special: bool = True) -> np.ndarray:
        ids = np.frombuffer(text.encode("utf-8", errors="replace"), dtype=np.uint8)
        ids = ids.astype(np.int32)
        if add_special:
            ids = np.concatenate([[BOS], ids, [EOS]]).astype(np.int32)
        return ids

    def decode(self, ids) -> str:
        ids = [int(i) for i in ids if int(i) < 256]
        return bytes(ids).decode("utf-8", errors="replace")
