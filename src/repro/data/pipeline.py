"""Training-data pipeline over a BlobSeer blob — the paper's own usage
scenario (§2.2), applied to tokens instead of pictures:

* ingestion processes APPEND tokenized documents to a corpus blob
  concurrently (multiple writers, no synchronization — the paper's
  headline property);
* training readers pin a *published* snapshot version and read disjoint
  ranges of it ("a set of workers READ disjoint parts of the blob"),
  while ingestion keeps appending to later versions;
* the reader cursor (version, offset) is tiny and lives inside the
  checkpoint manifest, so a restarted job resumes bit-identically.

The stream is raw little-endian int32 tokens; documents are delimited
in-band by the tokenizer's EOS.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

from repro.core.blob import BlobClient

_ITEM = 4  # bytes per int32 token


class CorpusWriter:
    """Appends tokenized documents to the corpus blob."""

    def __init__(self, client: BlobClient, blob_id: Optional[str] = None,
                 psize: int = 64 * 1024) -> None:
        self.client = client
        self.blob_id = blob_id if blob_id is not None else client.create(psize=psize)

    def append_tokens(self, tokens: np.ndarray) -> int:
        """Append an int32 token array; returns the published-when version."""
        arr = np.ascontiguousarray(tokens, dtype=np.int32)
        return self.client.append(self.blob_id, arr.tobytes())

    def n_tokens(self, version: Optional[int] = None) -> int:
        if version is None:
            version = self.client.get_recent(self.blob_id)
        if version == 0:
            return 0
        return self.client.get_size(self.blob_id, version) // _ITEM


@dataclass
class ReaderState:
    version: int      # pinned snapshot
    position: int     # next token index for THIS shard
    shard: int
    n_shards: int


class ShardedReader:
    """Deterministic next-token batches from a pinned snapshot.

    Shard ``i`` of ``n`` owns token indices ``[i*W, (i+1)*W)`` then
    ``[i*W + n*W, ...)`` etc. with window ``W = batch*(seq+1)`` — disjoint
    ranges per shard, exactly the paper's concurrent-readers pattern.
    When the pinned snapshot is exhausted the reader re-pins the most
    recent published version (data may have grown since) or wraps.
    """

    def __init__(
        self,
        client: BlobClient,
        blob_id: str,
        batch: int,
        seq_len: int,
        shard: int = 0,
        n_shards: int = 1,
        state: Optional[Dict] = None,
    ) -> None:
        self.client = client
        self.blob_id = blob_id
        self.batch = batch
        self.seq_len = seq_len
        if state is not None:
            self.state = ReaderState(**state)
        else:
            version = client.get_recent(blob_id)
            self.state = ReaderState(version=version, position=shard * self._window(),
                                     shard=shard, n_shards=n_shards)

    def _window(self) -> int:
        return self.batch * (self.seq_len + 1)

    def state_dict(self) -> Dict:
        return dict(version=self.state.version, position=self.state.position,
                    shard=self.state.shard, n_shards=self.state.n_shards)

    def _snapshot_tokens(self) -> int:
        if self.state.version == 0:
            return 0
        return self.client.get_size(self.blob_id, self.state.version) // _ITEM

    def repin(self) -> None:
        """Advance to the latest published snapshot (ingestion caught up)."""
        v = self.client.get_recent(self.blob_id)
        if v > self.state.version:
            self.state.version = v

    def next_batch(self) -> Tuple[np.ndarray, np.ndarray]:
        """(tokens, labels) both (batch, seq_len) int32. Deterministic."""
        W = self._window()
        total = self._snapshot_tokens()
        if self.state.position + W > total:
            self.repin()
            total = self._snapshot_tokens()
            if self.state.position + W > total:
                # wrap: restart this shard's walk over the snapshot
                self.state.position = self.state.shard * W
                if self.state.position + W > total:
                    raise RuntimeError(
                        f"corpus too small: need {W} tokens/shard, have {total}"
                    )
        raw = self.client.read(
            self.blob_id, self.state.version, self.state.position * _ITEM, W * _ITEM
        )
        flat = np.frombuffer(raw, dtype=np.int32).reshape(self.batch, self.seq_len + 1)
        self.state.position += W * self.state.n_shards
        return flat[:, :-1].copy(), flat[:, 1:].copy()

    def __iter__(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        while True:
            yield self.next_batch()
