"""BlobSeer-backed data pipeline."""

from repro.data.pipeline import CorpusWriter, ShardedReader
from repro.data.tokenizer import ByteTokenizer

__all__ = ["CorpusWriter", "ShardedReader", "ByteTokenizer"]
