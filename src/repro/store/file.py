"""File-backed page store.

One file per page under a spool directory — the layout a real deployment
would use for NVMe spill.  Pages are immutable, so writes use
write-to-temp + rename for crash atomicity (a torn page write is never
visible, matching the paper's never-overwrite invariant).
"""

from __future__ import annotations

import os
import threading
from typing import Iterator, Optional


class FilePageStore:
    """``fsync`` policy mirrors the VM WAL's: ``"never"`` (default —
    rename-atomic but a power cut may lose the page) or ``"always"``
    (fsync the file before the rename and the directory after it, so a
    renamed page is durable, not just atomic)."""

    FSYNC_POLICIES = ("never", "always")

    def __init__(self, root: str, fsync: str = "never") -> None:
        if fsync not in self.FSYNC_POLICIES:
            raise ValueError(
                f"fsync policy {fsync!r} not in {self.FSYNC_POLICIES}")
        self.root = root
        self.fsync = fsync
        os.makedirs(root, exist_ok=True)
        self._lock = threading.Lock()

    def _path(self, pid: str) -> str:
        # two-level fanout so directories stay small at scale
        return os.path.join(self.root, pid[-2:], pid)

    def put(self, pid: str, payload: bytes) -> None:
        path = self._path(pid)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        if os.path.exists(path):
            return  # immutable: identical by pid-uniqueness
        tmp = path + ".tmp"
        try:
            with open(tmp, "wb") as f:
                f.write(payload)
                if self.fsync == "always":
                    f.flush()
                    os.fsync(f.fileno())
            os.replace(tmp, path)
        except BaseException:
            # never leak the temp file: a failed write must leave the
            # spool exactly as it was
            try:
                os.remove(tmp)
            except OSError:
                pass
            raise
        if self.fsync == "always":
            # the rename itself is only durable once the directory is
            dfd = os.open(os.path.dirname(path), os.O_RDONLY)
            try:
                os.fsync(dfd)
            finally:
                os.close(dfd)

    def get(self, pid: str) -> Optional[bytes]:
        try:
            with open(self._path(pid), "rb") as f:
                return f.read()
        except FileNotFoundError:
            return None

    def has(self, pid: str) -> bool:
        return os.path.exists(self._path(pid))

    def delete(self, pid: str) -> None:
        try:
            os.remove(self._path(pid))
        except FileNotFoundError:
            pass

    def __len__(self) -> int:
        n = 0
        for _, _, files in os.walk(self.root):
            n += sum(1 for f in files if not f.endswith(".tmp"))
        return n

    def iter_pids(self) -> Iterator[str]:
        for _, _, files in os.walk(self.root):
            for f in files:
                if not f.endswith(".tmp"):
                    yield f

    def total_bytes(self) -> int:
        total = 0
        for d, _, files in os.walk(self.root):
            for f in files:
                if not f.endswith(".tmp"):
                    total += os.path.getsize(os.path.join(d, f))
        return total
