"""Physical page stores backing data providers."""

from repro.store.memory import MemoryPageStore
from repro.store.file import FilePageStore

__all__ = ["MemoryPageStore", "FilePageStore"]
