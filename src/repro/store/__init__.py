"""Physical page stores backing data providers."""

from repro.store.memory import MemoryPageStore
from repro.store.file import FilePageStore
from repro.store.s3 import S3PageStore

__all__ = ["MemoryPageStore", "FilePageStore", "S3PageStore"]
