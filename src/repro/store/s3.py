"""S3-class object store for the cold tier.

The shape mirrors a bucket/prefix blob backend fronted by a local cache
(the ``zodb-s3blobs`` pattern ROADMAP item 1 names): pages live under
``s3://<bucket>/<prefix>/<pid>``, every operation is a billable request,
and reads are slow-but-durable capacity — the deployment's shared
:class:`~repro.core.cache.PageCache` absorbs repeat reads exactly as it
does for hot providers, so only the first touch of a demoted page pays
the cold path.

The implementation is an in-memory dict (this repo simulates the wire;
latency/bandwidth are charged by ``transport.Wire`` at the provider
endpoint like every other backend).  What distinguishes it from
:class:`~repro.store.memory.MemoryPageStore` is the request-counter
ledger (``op_counts``) — the billing surface a real S3 backend meters —
and key layout.  It satisfies the same page-store interface every
provider backend does: ``put/get/has/delete/iter_pids/__len__/
total_bytes``.
"""

from __future__ import annotations

import threading
from typing import Dict, Iterator, Optional


class S3PageStore:
    def __init__(self, bucket: str, prefix: str = "pages") -> None:
        self.bucket = bucket
        self.prefix = prefix.strip("/")
        self._objects: Dict[str, bytes] = {}
        self._lock = threading.Lock()
        self.op_counts: Dict[str, int] = {
            "put": 0, "get": 0, "head": 0, "delete": 0, "list": 0,
        }

    def _key(self, pid: str) -> str:
        return f"{self.prefix}/{pid}"

    def url(self, pid: str) -> str:
        return f"s3://{self.bucket}/{self._key(pid)}"

    def put(self, pid: str, payload: bytes) -> None:
        with self._lock:
            self.op_counts["put"] += 1
            key = self._key(pid)
            # object stores are last-writer-wins; immutability comes from
            # pid uniqueness upstream, so a re-put must match
            prev = self._objects.get(key)
            if prev is not None and prev != payload:
                raise ValueError(
                    f"page {pid} re-stored with different content")
            self._objects[key] = payload

    def get(self, pid: str) -> Optional[bytes]:
        with self._lock:
            self.op_counts["get"] += 1
            return self._objects.get(self._key(pid))

    def has(self, pid: str) -> bool:
        with self._lock:
            self.op_counts["head"] += 1
            return self._key(pid) in self._objects

    def delete(self, pid: str) -> None:
        with self._lock:
            self.op_counts["delete"] += 1
            self._objects.pop(self._key(pid), None)

    def __len__(self) -> int:
        with self._lock:
            return len(self._objects)

    def iter_pids(self) -> Iterator[str]:
        with self._lock:
            self.op_counts["list"] += 1
            skip = len(self.prefix) + 1
            return iter([k[skip:] for k in self._objects])

    def total_bytes(self) -> int:
        with self._lock:
            return sum(len(v) for v in self._objects.values())
