"""In-RAM page store (default for providers).

Pages are immutable once stored (BlobSeer never overwrites a page), so a
plain dict with a lock is enough; readers take no lock after the
reference is fetched.
"""

from __future__ import annotations

import threading
from typing import Dict, Iterator, Optional


class MemoryPageStore:
    def __init__(self) -> None:
        self._pages: Dict[str, bytes] = {}
        self._lock = threading.Lock()

    def put(self, pid: str, payload: bytes) -> None:
        with self._lock:
            # Page ids are globally unique; a duplicate put is a replica
            # re-send and must carry identical content.
            prev = self._pages.get(pid)
            if prev is not None and prev is not payload and prev != payload:
                raise ValueError(f"page {pid} re-stored with different content")
            self._pages[pid] = payload

    def get(self, pid: str) -> Optional[bytes]:
        with self._lock:
            return self._pages.get(pid)

    def has(self, pid: str) -> bool:
        with self._lock:
            return pid in self._pages

    def delete(self, pid: str) -> None:
        with self._lock:
            self._pages.pop(pid, None)

    def __len__(self) -> int:
        with self._lock:
            return len(self._pages)

    def iter_pids(self) -> Iterator[str]:
        with self._lock:
            return iter(list(self._pages.keys()))

    def total_bytes(self) -> int:
        with self._lock:
            return sum(len(v) for v in self._pages.values())
