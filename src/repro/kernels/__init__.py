"""Pallas TPU kernels for the framework's compute hot-spots.

* ``page_digest`` / ``delta_mask`` — the BlobSeer incremental-checkpoint
  scan (digest device-resident pages, emit changed-page bitmap);
* ``flash_attention`` — blockwise online-softmax GQA attention
  (prefill/decode serving path);
* ``linear_scan`` — chunked diagonal linear recurrence (RG-LRU / xLSTM).

Use ``repro.kernels.ops`` (backend dispatch); ``repro.kernels.ref``
holds the pure-jnp oracles; ``repro.kernels.hostdigest`` is the
numpy-only digest twin the core write path may import without dragging
jax in (submodules load lazily for the same reason).
"""

import importlib

__all__ = ["ops", "ref", "hostdigest"]


def __getattr__(name):  # PEP 562: lazy submodule access
    if name in __all__:
        return importlib.import_module(f"repro.kernels.{name}")
    raise AttributeError(f"module 'repro.kernels' has no attribute {name!r}")
