"""Pallas TPU kernels for the framework's compute hot-spots.

* ``page_digest`` / ``delta_mask`` — the BlobSeer incremental-checkpoint
  scan (digest device-resident pages, emit changed-page bitmap);
* ``flash_attention`` — blockwise online-softmax GQA attention
  (prefill/decode serving path);
* ``linear_scan`` — chunked diagonal linear recurrence (RG-LRU / xLSTM).

Use ``repro.kernels.ops`` (backend dispatch); ``repro.kernels.ref``
holds the pure-jnp oracles.
"""

from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
