"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``ref_*`` function is the semantic ground truth: kernels are tested
against these over shape/dtype sweeps (see ``tests/test_kernels.py``),
and they double as the CPU execution path in ``ops.py``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# Digest constants and host weight table live in ``hostdigest`` (numpy-only,
# shared with the dedup handshake); re-exported here for the kernels.
from repro.kernels.hostdigest import (  # noqa: F401  (re-export)
    DIGEST_MULTS,
    DIGEST_SALT,
    digest_weights,
)

U32 = jnp.uint32


def ref_page_digest(pages_u32: jax.Array) -> jax.Array:
    """Per-page polynomial digest.

    ``pages_u32``: (n_pages, n_words) uint32.  Returns (n_pages, 2) u32:
    ``digest[p, m] = sum_i (x[p,i] + SALT) * A_m^(n_words-1-i) mod 2^32``.
    Order-sensitive (polynomial in A), so page content permutations
    change the digest; two independent moduli give a 64-bit fingerprint
    for copy-on-write delta detection in the checkpoint layer.
    """
    n_words = pages_u32.shape[-1]
    w = jnp.asarray(digest_weights(n_words))  # (2, W)
    x = pages_u32.astype(U32) + U32(DIGEST_SALT)
    # u32 multiply-accumulate wraps mod 2^32 exactly like the kernel
    return (x[:, None, :] * w[None, :, :]).sum(axis=-1, dtype=U32)


def ref_delta_mask(new_digest: jax.Array, old_digest: jax.Array) -> jax.Array:
    """(n_pages,) bool — True where the page content changed."""
    return jnp.any(new_digest != old_digest, axis=-1)


def ref_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
    softcap: float | None = None,
) -> jax.Array:
    """Reference GQA attention.

    q: (B, Hq, Tq, D);  k, v: (B, Hkv, Tk, D);  Hq % Hkv == 0.
    ``q_offset``: absolute position of q[...,-Tq,:] start (decode: Tk-1).
    ``window``: sliding-window size (key positions > window behind the
    query are masked), per Mistral/RecurrentGemma local attention.
    """
    B, Hq, Tq, D = q.shape
    Hkv, Tk = k.shape[1], k.shape[2]
    group = Hq // Hkv
    qf = q.astype(jnp.float32) * (D ** -0.5)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    qg = qf.reshape(B, Hkv, group, Tq, D)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kf)
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    qpos = q_offset + jnp.arange(Tq)[:, None]
    kpos = jnp.arange(Tk)[None, :]
    mask = jnp.ones((Tq, Tk), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, vf)
    return out.reshape(B, Hq, Tq, D).astype(q.dtype)


def ref_linear_scan(a: jax.Array, x: jax.Array, h0: jax.Array | None = None) -> jax.Array:
    """Diagonal linear recurrence ``h_t = a_t * h_{t-1} + x_t``.

    a, x: (B, T, D).  Returns h: (B, T, D).  This is the core of the
    RG-LRU (Griffin) and diagonal-state xLSTM paths.  Implemented with
    an associative scan (Blelloch), the standard JAX formulation.
    """
    if h0 is not None:
        x = x.at[:, 0].add(a[:, 0] * h0)

    def combine(c1, c2):
        a1, x1 = c1
        a2, x2 = c2
        return a1 * a2, a2 * x1 + x2

    _, h = jax.lax.associative_scan(combine, (a, x), axis=1)
    return h
