"""Public kernel API with backend dispatch.

Callers use these wrappers, never the kernels directly:

* on TPU the Pallas kernels run compiled;
* on CPU (this container) the pure-jnp references run under jit, and the
  Pallas kernels can be forced through the interpreter with
  ``REPRO_PALLAS=interpret`` (the kernel-vs-oracle test path).

Every wrapper normalizes shapes/dtypes so the Pallas and reference paths
see bit-identical inputs — the correctness contract the tests assert.
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.delta_mask import delta_mask_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.linear_scan import linear_scan_pallas
from repro.kernels.page_digest import page_digest_pallas

DIGEST_BLOCK_WORDS = 512


def _backend() -> str:
    return jax.default_backend()


def use_pallas() -> bool:
    mode = os.environ.get("REPRO_PALLAS", "auto")
    if mode == "off":
        return False
    if mode in ("on", "interpret"):
        return True
    return _backend() == "tpu"


def _interpret() -> bool:
    if os.environ.get("REPRO_PALLAS") == "interpret":
        return True
    return _backend() != "tpu"


# ---------------------------------------------------------------------------
# digest / delta
# ---------------------------------------------------------------------------


def as_page_words(data: jax.Array, page_bytes: int) -> jax.Array:
    """Reinterpret a flat array as (n_pages, words) u32, zero-padded.

    The canonical digest domain: bytes are padded to a whole number of
    ``page_bytes`` pages and each page to a multiple of
    ``DIGEST_BLOCK_WORDS`` 32-bit words, identically for both backends.
    """
    assert page_bytes % 4 == 0
    flat = data.reshape(-1)
    as_bytes = jax.lax.bitcast_convert_type(flat, jnp.uint8).reshape(-1)
    pad = (-as_bytes.shape[0]) % page_bytes
    if pad:
        as_bytes = jnp.pad(as_bytes, (0, pad))
    n_pages = as_bytes.shape[0] // page_bytes
    words = jax.lax.bitcast_convert_type(
        as_bytes.reshape(n_pages, page_bytes // 4, 4), jnp.uint32
    )
    word_pad = (-words.shape[1]) % DIGEST_BLOCK_WORDS
    if word_pad:
        words = jnp.pad(words, ((0, 0), (0, word_pad)))
    return words


@functools.partial(jax.jit, static_argnames=("page_bytes",))
def _page_digest_ref(data: jax.Array, page_bytes: int) -> jax.Array:
    return _ref.ref_page_digest(as_page_words(data, page_bytes))


def page_digest(data: jax.Array, page_bytes: int = 64 * 1024) -> jax.Array:
    """Digest device-resident data as (n_pages, 2) u32 fingerprints."""
    if use_pallas():
        words = as_page_words(data, page_bytes)
        return page_digest_pallas(
            words, block_w=DIGEST_BLOCK_WORDS, interpret=_interpret()
        )
    return _page_digest_ref(data, page_bytes)


@jax.jit
def _delta_mask_ref(new_digest: jax.Array, old_digest: jax.Array) -> jax.Array:
    return _ref.ref_delta_mask(new_digest, old_digest)


def delta_mask(new_digest: jax.Array, old_digest: jax.Array) -> jax.Array:
    """(n,) bool — pages whose digest changed since the last checkpoint."""
    if use_pallas():
        return delta_mask_pallas(new_digest, old_digest, interpret=_interpret()) != 0
    return _delta_mask_ref(new_digest, old_digest)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "q_offset", "softcap")
)
def _attention_ref(q, k, v, causal, window, q_offset, softcap):
    return _ref.ref_attention(
        q, k, v, causal=causal, window=window, q_offset=q_offset, softcap=softcap
    )


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
    softcap: float | None = None,
) -> jax.Array:
    """GQA attention; Pallas on TPU, reference elsewhere (differentiable)."""
    if use_pallas():
        return flash_attention_pallas(
            q, k, v, causal=causal, window=window, q_offset=q_offset,
            softcap=softcap, interpret=_interpret(),
        )
    return _attention_ref(q, k, v, causal, window, q_offset, softcap)


# ---------------------------------------------------------------------------
# linear scan
# ---------------------------------------------------------------------------


@jax.jit
def _linear_scan_ref(a, x):
    return _ref.ref_linear_scan(a, x)


def linear_scan(a: jax.Array, x: jax.Array) -> jax.Array:
    """h_t = a_t * h_{t-1} + x_t over (B, T, D)."""
    if use_pallas():
        return linear_scan_pallas(a, x, interpret=_interpret())
    return _linear_scan_ref(a, x)
