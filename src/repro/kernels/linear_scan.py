"""Pallas TPU kernel: chunked diagonal linear recurrence.

``h_t = a_t * h_{t-1} + x_t`` over (batch, time, hidden) — the inner
loop of the RG-LRU (recurrentgemma) and the diagonal-state xLSTM path,
and the state-update of every ``long_500k`` decode cell.

TPU adaptation: a GPU implementation leans on warp-parallel Blelloch
scans; on TPU the natural schedule is *chunked sequential*: the time
axis becomes the innermost sequential grid dimension, the carried state
``h`` lives in VMEM scratch, and each grid step processes a
``(batch_tile, chunk, hidden_tile)`` block with a short in-register
``fori_loop`` over the chunk.  Batch and hidden tile the sublane/lane
axes, so every elementwise op is a full-vreg VPU op.  Arithmetic
intensity is ~2 flops / 12 bytes: memory-bound by construction, the
kernel exists to keep the scan at HBM bandwidth instead of paying an
XLA while-loop's per-step overhead.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(a_ref, x_ref, o_ref, h_ref, *, chunk):
    """Grid: (batch_tiles, hidden_tiles, time_chunks); time sequential."""
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    def step(i, h):
        h = a_ref[:, i, :] * h + x_ref[:, i, :]
        o_ref[:, i, :] = h
        return h

    h_ref[...] = jax.lax.fori_loop(0, chunk, step, h_ref[...])


@functools.partial(
    jax.jit, static_argnames=("batch_tile", "hidden_tile", "chunk", "interpret")
)
def linear_scan_pallas(
    a: jax.Array,
    x: jax.Array,
    *,
    batch_tile: int = 8,
    hidden_tile: int = 128,
    chunk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """a, x: (B, T, D) -> h: (B, T, D) with h_t = a_t h_{t-1} + x_t."""
    assert a.shape == x.shape and a.ndim == 3
    B, T, D = a.shape
    batch_tile = min(batch_tile, B)
    hidden_tile = min(hidden_tile, D)
    chunk = min(chunk, T)
    pad_b = (-B) % batch_tile
    pad_t = (-T) % chunk
    pad_d = (-D) % hidden_tile
    if pad_b or pad_t or pad_d:
        # zero-pad: a=0 resets the padded state, x=0 keeps outputs zero;
        # padding the *tail* of time never pollutes real steps.
        a = jnp.pad(a, ((0, pad_b), (0, pad_t), (0, pad_d)))
        x = jnp.pad(x, ((0, pad_b), (0, pad_t), (0, pad_d)))
    Bp, Tp, Dp = a.shape
    grid = (Bp // batch_tile, Dp // hidden_tile, Tp // chunk)
    out = pl.pallas_call(
        functools.partial(_scan_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((batch_tile, chunk, hidden_tile), lambda b, d, t: (b, t, d)),
            pl.BlockSpec((batch_tile, chunk, hidden_tile), lambda b, d, t: (b, t, d)),
        ],
        out_specs=pl.BlockSpec((batch_tile, chunk, hidden_tile), lambda b, d, t: (b, t, d)),
        out_shape=jax.ShapeDtypeStruct((Bp, Tp, Dp), a.dtype),
        scratch_shapes=[pltpu.VMEM((batch_tile, hidden_tile), a.dtype)],
        interpret=interpret,
    )(a, x)
    return out[:B, :T, :D]
