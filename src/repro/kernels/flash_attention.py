"""Pallas TPU kernel: blockwise online-softmax (flash) attention forward.

The serving hot-spot for every attention-bearing assigned architecture
(prefill_32k / decode_32k shapes).  GQA-aware: query heads are grouped
over shared KV heads; causal and sliding-window (local) masks supported,
which covers qwen3/qwen1.5/olmo/olmoe/granite (causal), danube3
(SWA 4096) and recurrentgemma (local 2048).

TPU adaptation (vs. the CUDA flash-attention formulation):

* blocks are (block_q, head_dim) x (block_k, head_dim) MXU tiles with
  head_dim padded to a lane multiple (128);
* the KV axis is the innermost sequential grid dimension; running max
  ``m``, normalizer ``l`` and the output accumulator live in VMEM
  scratch across KV steps (no atomics / warp shuffles — the sequential
  grid is the TPU-native way to express the online softmax);
* with a sliding window, KV blocks wholly outside the window are
  skipped via ``pl.when`` so local attention costs O(T * window).

Forward only: training uses the blockwise-jnp reference (differentiable
under XLA); serving uses this kernel on TPU.  ``ops.flash_attention``
dispatches.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, scale, block_q, block_k, causal, window, q_offset, softcap, kv_len,
):
    """Grid: (batch*q_heads, num_q_blocks, num_k_blocks); k sequential."""
    iq, ik = pl.program_id(1), pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qpos = q_offset + iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    # Cheap block-level skip: is any (q, k) pair in this block live?
    live = jnp.asarray(ik * block_k < kv_len)  # padded tail blocks are dead
    if causal:
        first_q = q_offset + iq * block_q
        last_q = first_q + block_q - 1
        first_k = ik * block_k
        live = jnp.logical_and(live, first_k <= last_q)
    if window is not None:
        last_k = ik * block_k + block_k - 1
        first_q = q_offset + iq * block_q
        live = jnp.logical_and(live, last_k > first_q - window)

    @pl.when(live)
    def _step():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, d)
        k = k_ref[0].astype(jnp.float32)                  # (bk, d)
        v = v_ref[0].astype(jnp.float32)                  # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )                                                  # (bq, bk)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        mask = kpos < kv_len                               # mask padded keys
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                                # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                             # (bq, bk)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)                    # (bq, 1)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ik == pl.num_programs(2) - 1)
    def _flush():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)  # fully masked rows -> zeros
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_offset", "softcap",
                     "block_q", "block_k", "interpret"),
)
def flash_attention_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
    softcap: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """GQA flash attention. q: (B,Hq,Tq,D); k,v: (B,Hkv,Tk,D)."""
    B, Hq, Tq, D = q.shape
    Hkv, Tk = k.shape[1], k.shape[2]
    assert Hq % Hkv == 0, "query heads must group over kv heads"
    group = Hq // Hkv
    scale = D ** -0.5

    block_q = min(block_q, Tq)
    block_k = min(block_k, Tk)
    pad_q = (-Tq) % block_q
    pad_k = (-Tk) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        # padded keys are masked inside the kernel via kv_len
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    Tqp, Tkp = q.shape[2], k.shape[2]

    qf = q.reshape(B * Hq, Tqp, D)
    kf = k.reshape(B * Hkv, Tkp, D)
    vf = v.reshape(B * Hkv, Tkp, D)

    grid = (B * Hq, Tqp // block_q, Tkp // block_k)

    kernel = functools.partial(
        _attn_kernel,
        scale=scale, block_q=block_q, block_k=block_k,
        causal=causal, window=window, q_offset=q_offset, softcap=softcap,
        kv_len=Tk,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j, g=group: (b // g, j, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j, g=group: (b // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Tqp, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    out = out.reshape(B, Hq, Tqp, D)
    if pad_q:
        out = out[:, :, :Tq]
    return out
