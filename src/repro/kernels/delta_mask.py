"""Pallas TPU kernel: changed-page bitmap from digest comparison.

Second half of the incremental-checkpoint hot path: compare this step's
page digests against the previous checkpoint's and emit a 0/1 mask (as
uint32 — TPU vregs have no packed bool) plus, on the host side of
``ops.py``, the changed-page count used to size the WRITE.

Trivially bandwidth-bound; exists as a kernel so the whole
digest->delta pipeline stays on-device with one fused dispatch each.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

U32 = jnp.uint32


def _delta_kernel(new_ref, old_ref, o_ref):
    neq = (new_ref[...] != old_ref[...]).any(axis=1)
    o_ref[...] = neq.astype(U32)[:, None]


@functools.partial(jax.jit, static_argnames=("page_tile", "interpret"))
def delta_mask_pallas(
    new_digest: jax.Array,
    old_digest: jax.Array,
    *,
    page_tile: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """(n,2),(n,2) u32 -> (n,) u32 0/1 changed mask via Pallas."""
    n = new_digest.shape[0]
    pad = (-n) % page_tile
    if pad:
        new_digest = jnp.pad(new_digest, ((0, pad), (0, 0)))
        old_digest = jnp.pad(old_digest, ((0, pad), (0, 0)))
    P = new_digest.shape[0]
    out = pl.pallas_call(
        _delta_kernel,
        grid=(P // page_tile,),
        in_specs=[
            pl.BlockSpec((page_tile, 2), lambda i: (i, 0)),
            pl.BlockSpec((page_tile, 2), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((page_tile, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((P, 1), U32),
        interpret=interpret,
    )(new_digest, old_digest)
    return out[:n, 0]
