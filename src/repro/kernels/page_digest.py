"""Pallas TPU kernel: per-page polynomial digest.

The checkpoint layer fingerprints every page of device-resident training
state to detect copy-on-write deltas (only changed pages are re-written
to BlobSeer providers), and the same fingerprints feed the dedup
handshake: ``blobckpt`` passes them to ``BlobClient.write_many`` so the
content-hash index can match equal pages without re-hashing.  At
multi-TB state sizes this scan must run at HBM bandwidth on the chip,
not on the host — hence a TPU kernel.  Off-TPU callers with plain bytes
use the numpy twin ``hostdigest.host_page_digest`` (same constants,
same padding, bit-identical results).

Math (same as ``ref.ref_page_digest``): for each page ``p`` and each of
two independent odd multipliers ``A_m``::

    digest[p, m] = sum_i (x[p, i] + SALT) * A_m^(W-1-i)   (mod 2^32)

evaluated blockwise Horner-style over word-blocks of size ``block_w``::

    acc <- acc * A_m^block_w + poly_block(acc_block)

TPU adaptation notes:

* uint32 VPU arithmetic wraps mod 2^32 natively — no emulation needed;
* pages tile the sublane axis (8) and words the lane axis (128), so a
  (page_tile, block_w) = (8, 512) block is four perfectly aligned
  (8, 128) vregs;
* the word-block axis is the innermost (sequential) grid dimension; the
  running accumulator lives in VMEM scratch and is multiplied by the
  per-block constant ``A^block_w`` each step — a classic reduction
  pipeline, bandwidth-bound by design (arithmetic intensity ~2 flops
  per 4 bytes).
"""

from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import DIGEST_MULTS, DIGEST_SALT, digest_weights

U32 = jnp.uint32


def _block_mults(block_w: int) -> tuple[int, int]:
    """``A_m^block_w mod 2^32`` for both multipliers."""
    out = []
    for mult in DIGEST_MULTS:
        acc = 1
        for _ in range(block_w):
            acc = (acc * mult) & 0xFFFFFFFF
        out.append(acc)
    return tuple(out)


def _digest_kernel(x_ref, w_ref, o_ref, acc_ref, *, block_mults):
    """Grid: (page_tiles, word_blocks); word_blocks is sequential."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...] + U32(DIGEST_SALT)          # (PT, BW)
    w = w_ref[...]                              # (2, BW)
    # poly over this block for both multipliers: (PT, 2)
    poly0 = (x * w[0][None, :]).sum(axis=1, dtype=U32)
    poly1 = (x * w[1][None, :]).sum(axis=1, dtype=U32)
    carry0 = acc_ref[:, 0] * U32(block_mults[0]) + poly0
    carry1 = acc_ref[:, 1] * U32(block_mults[1]) + poly1
    acc_ref[...] = jnp.stack([carry0, carry1], axis=1)

    @pl.when(j == pl.num_programs(1) - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("page_tile", "block_w", "interpret"))
def page_digest_pallas(
    pages_u32: jax.Array,
    *,
    page_tile: int = 8,
    block_w: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """(n_pages, n_words) u32 -> (n_pages, 2) u32 digests via Pallas."""
    n_pages, n_words = pages_u32.shape
    pad_p = (-n_pages) % page_tile
    pad_w = (-n_words) % block_w
    if pad_p or pad_w:
        pages_u32 = jnp.pad(pages_u32, ((0, pad_p), (0, pad_w)))
    P, W = pages_u32.shape
    # Per-block polynomial weights are identical for every block
    # (A^(BW-1-i)); the cross-block shift is the scalar A^BW in scratch.
    w_block = jnp.asarray(digest_weights(block_w))  # (2, BW)
    grid = (P // page_tile, W // block_w)
    out = pl.pallas_call(
        functools.partial(_digest_kernel, block_mults=_block_mults(block_w)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((page_tile, block_w), lambda i, j: (i, j)),
            pl.BlockSpec((2, block_w), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((page_tile, 2), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((P, 2), U32),
        scratch_shapes=[pltpu.VMEM((page_tile, 2), U32)],
        interpret=interpret,
    )(pages_u32, w_block)
    return out[:n_pages]
