"""Host-side (numpy-only) page digest — the dedup handshake fallback.

The write path fingerprints pages before shipping them so the dedup
index can match equal-content pages already stored by someone else
(see ``core/dedup_index.py``).  On TPU the checkpoint layer computes
digests with the ``page_digest`` Pallas kernel and passes them through
``BlobClient.write_many(..., digests=...)`` — no double hashing.  Plain
blob writers (scenario clients, the data pipeline) have raw ``bytes``
buffers and no device in the loop, so they need the same fingerprint
computed on the host without touching jax at all.  That is this module.

The math and padding are bit-identical to the kernel path:

* bytes are zero-padded to a whole number of ``page_bytes`` pages and
  each page to a multiple of ``DIGEST_BLOCK_WORDS`` 32-bit words
  (mirroring ``ops.as_page_words``);
* ``digest[m] = sum_i (x_i + SALT) * A_m^(W-1-i)  mod 2^32`` for two
  independent odd multipliers ``A_m`` (mirroring
  ``ref.ref_page_digest``); the accumulation runs in uint64 — since
  2^32 divides 2^64, wraparound mod 2^64 preserves the mod-2^32 result.

``ref.py`` and the Pallas kernel import the constants from here so all
three implementations share one definition.
"""

from __future__ import annotations

import functools
from typing import Tuple

import numpy as np

# Digest constants: two independent odd multipliers (Knuth & xxHash primes)
# and an additive salt so zero pages don't hash to zero.
DIGEST_MULTS = (2654435761, 2246822519)
DIGEST_SALT = 0x9E3779B9

# Must match ``ops.DIGEST_BLOCK_WORDS`` (kept literal here so this module
# never imports jax-touching code).
DIGEST_BLOCK_WORDS = 512


def digest_weights(n_words: int) -> np.ndarray:
    """Polynomial weights ``A_m^(n_words-1-i) mod 2^32`` as (2, n_words) u32."""
    out = np.empty((2, n_words), dtype=np.uint32)
    for m, mult in enumerate(DIGEST_MULTS):
        w = np.empty(n_words, dtype=np.uint64)
        acc = np.uint64(1)
        for i in range(n_words - 1, -1, -1):
            w[i] = acc
            acc = (acc * np.uint64(mult)) & np.uint64(0xFFFFFFFF)
        out[m] = w.astype(np.uint32)
    return out


@functools.lru_cache(maxsize=8)
def _weights_u64(n_words: int) -> np.ndarray:
    return digest_weights(n_words).astype(np.uint64)


def _padded_words(payload: bytes, page_bytes: int) -> np.ndarray:
    """One page of ``payload`` as padded u32 words (``as_page_words`` domain)."""
    assert page_bytes % 4 == 0
    assert len(payload) <= page_bytes
    n_words = page_bytes // 4
    n_words += (-n_words) % DIGEST_BLOCK_WORDS
    buf = np.zeros(n_words * 4, dtype=np.uint8)
    buf[: len(payload)] = np.frombuffer(payload, dtype=np.uint8)
    return buf.view("<u4")


def host_page_digest(payload: bytes, page_bytes: int) -> Tuple[int, int]:
    """Fingerprint one page's payload as two ints, kernel-compatible.

    ``payload`` may be shorter than ``page_bytes`` (tail page); it is
    zero-padded exactly like the device path pads, so a host digest and
    a kernel digest of the same logical page always agree.
    """
    x = _padded_words(payload, page_bytes).astype(np.uint64) + np.uint64(DIGEST_SALT)
    w = _weights_u64(x.shape[0])
    with np.errstate(over="ignore"):
        d = (x[None, :] * w).sum(axis=1) & np.uint64(0xFFFFFFFF)
    return int(d[0]), int(d[1])
