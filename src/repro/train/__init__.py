"""Training: optimizer, train step, schedules."""

from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update, opt_state_axes
from repro.train.step import TrainStepBuilder

__all__ = [
    "AdamWConfig",
    "adamw_init",
    "adamw_update",
    "opt_state_axes",
    "TrainStepBuilder",
]
