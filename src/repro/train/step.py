"""Train/serve step builders: the programs the dry-run lowers.

``TrainStepBuilder`` binds (model, mesh, rules, optimizer) into jittable
steps with full in/out shardings:

* ``train_step(state, batch)``   — fwd + bwd + AdamW, grad accumulation
  via microbatch scan when ``accum > 1`` (compute/communication overlap
  falls out: XLA overlaps the per-microbatch reduce-scatters with the
  next microbatch's compute);
* ``prefill_step(params, batch, cache)``;
* ``decode_step(params, token, pos, cache)``.

All steps run under ``mesh`` with logical rules active, so the
``constrain`` annotations in model code take effect.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed import axes as AX
from repro.distributed import partitioning as PT
from repro.models.zoo import Model
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update, opt_state_axes


class TrainStepBuilder:
    def __init__(
        self,
        model: Model,
        mesh: Mesh,
        strategy: str = "tp",
        opt: Optional[AdamWConfig] = None,
        remat_policy: str = "full",
        accum: int = 1,
        zero2: bool = False,
    ) -> None:
        """``zero2``: under an fsdp strategy, gather parameters ONCE per
        step (outside the microbatch scan) instead of per microbatch, and
        reduce-scatter each microbatch's grads into an fsdp-sharded fp32
        accumulator — ZeRO-2-style.  Collective volume drops from
        ~3·accum·P to ~P + accum·P at the cost of keeping the gathered
        (TP-sharded) parameters resident for the step."""
        self.model = model
        self.mesh = mesh
        self.strategy = strategy
        self.rules = PT.get_rules(strategy)
        self.opt = opt or AdamWConfig()
        self.remat_policy = remat_policy
        self.accum = accum
        self.zero2 = zero2 and "fsdp" in strategy

    # ----------------------------------------------------------------- helpers
    def _activate(self):
        AX.set_logical_rules(self.rules, self.mesh)

    def param_shardings(self, abstract_params, axes_tree):
        return PT.shardings_for_tree(self.mesh, self.rules, abstract_params, axes_tree)

    def state_shardings(self, abstract_params, axes_tree):
        p_shard = self.param_shardings(abstract_params, axes_tree)
        return {
            "params": p_shard,
            "opt": {
                "mu": p_shard,
                "nu": p_shard,
                "master": p_shard,
                "count": NamedSharding(self.mesh, P()),
            },
            "step": NamedSharding(self.mesh, P()),
        }

    def batch_shardings(self, batch_tree):
        ax = PT.batch_axes_for(batch_tree)
        return PT.shardings_for_tree(self.mesh, self.rules, batch_tree, ax)

    def cache_shardings(self, cache_tree):
        ax = PT.cache_axes_for(cache_tree)
        return PT.shardings_for_tree(self.mesh, self.rules, cache_tree, ax)

    def memories_shardings(self, mem_tree):
        ax = PT.memories_axes_for(mem_tree)
        return PT.shardings_for_tree(self.mesh, self.rules, mem_tree, ax)

    # -------------------------------------------------------------- train step
    def init_state(self, rng) -> Dict[str, Any]:
        params, _ = self.model.init(rng)
        return {"params": params, "opt": adamw_init(params), "step": jnp.zeros((), jnp.int32)}

    def train_step_fn(self, gathered_sh=None, grad_sh=None):
        model, opt_cfg, remat, accum = self.model, self.opt, self.remat_policy, self.accum
        zero2 = self.zero2 and gathered_sh is not None

        def loss_fn(params, batch):
            loss, metrics = model.loss_fn(params, batch, remat)
            return loss, metrics

        def step(state, batch):
            self._activate()
            params = state["params"]
            if zero2:
                # one all-gather per STEP: constrain to the TP-only
                # sharding outside the microbatch scan
                params_use = jax.lax.with_sharding_constraint(params, gathered_sh)
            else:
                params_use = params
            if accum <= 1:
                (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                    params_use, batch
                )
                if zero2:
                    grads = jax.lax.with_sharding_constraint(grads, grad_sh)
            else:
                # microbatch scan: batch leaves are (accum*b, ...) and are
                # resliced per microstep; grads accumulate in fp32 (under
                # zero2 the accumulator is fsdp-sharded, so each micro-
                # batch's grads reduce-scatter into it).
                def micro(carry, mb):
                    acc = carry
                    (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                        params_use, mb
                    )
                    if zero2:
                        g = jax.lax.with_sharding_constraint(g, grad_sh)
                    acc = jax.tree.map(
                        lambda a, gg: a + gg.astype(jnp.float32) / accum, acc, g
                    )
                    return acc, (l, m)

                zeros = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params
                )
                if zero2:
                    zeros = jax.lax.with_sharding_constraint(zeros, grad_sh)
                mbs = jax.tree.map(
                    lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]),
                    batch,
                )
                grads, (losses, metricses) = jax.lax.scan(micro, zeros, mbs)
                loss = losses.mean()
                metrics = jax.tree.map(lambda m: m.mean(0), metricses)
            new_params, new_opt, stats = adamw_update(opt_cfg, grads, state["opt"], params)
            new_state = {"params": new_params, "opt": new_opt,
                         "step": state["step"] + 1}
            metrics = dict(metrics, loss=loss, **stats)
            return new_state, metrics

        return step

    def jit_train_step(self, abstract_params, axes_tree, abstract_batch):
        state_sh = self.state_shardings(abstract_params, axes_tree)
        batch_sh = self.batch_shardings(abstract_batch)
        gathered_sh = grad_sh = None
        if self.zero2:
            tp_rules = dict(self.rules)
            tp_rules["embed"] = None      # gather the fsdp dim, keep TP
            gathered_sh = PT.shardings_for_tree(
                self.mesh, tp_rules, abstract_params, axes_tree)
            grad_sh = state_sh["params"]
        return jax.jit(
            self.train_step_fn(gathered_sh=gathered_sh, grad_sh=grad_sh),
            in_shardings=(state_sh, batch_sh),
            out_shardings=(state_sh, None),
            donate_argnums=(0,),
        )

    # -------------------------------------------------------------- serve steps
    def prefill_step_fn(self):
        model = self.model

        def step(params, batch, cache):
            self._activate()
            return model.prefill(params, batch, cache)

        return step

    def decode_step_fn(self):
        model = self.model

        def step(params, token, pos, cache, *extras):
            self._activate()
            return model.decode_step(params, token, pos, cache, *extras)

        return step
