"""AdamW with fp32 master weights, built on plain pytrees.

Model parameters are stored in the compute dtype (bf16 at scale); the
optimizer keeps fp32 first/second moments plus an fp32 master copy so
repeated bf16 round-trips don't stall convergence.  All three optimizer
trees shard exactly like their parameter (ZeRO: under ``tp_fsdp`` rules
the optimizer state is fully sharded over the DP axis).

Global-norm clipping and warmup-cosine scheduling included — everything
the e2e example and the train_step need, with no external deps.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def adamw_init(params) -> Dict[str, Any]:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros32, params),
        "nu": jax.tree.map(zeros32, params),
        # copy=True: with fp32 params, astype would alias the parameter
        # buffer and break donation in the jitted step
        "master": jax.tree.map(lambda p: jnp.array(p, jnp.float32, copy=True), params),
        "count": jnp.zeros((), jnp.int32),
    }


def opt_state_axes(param_axes) -> Dict[str, Any]:
    """Optimizer state shards exactly like its parameter."""
    return {
        "mu": param_axes,
        "nu": param_axes,
        "master": param_axes,
        "count": (),
    }


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(
    cfg: AdamWConfig, grads, opt_state, params
) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    """Returns (new_params, new_opt_state, stats)."""
    count = opt_state["count"] + 1
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
    lr = schedule(cfg, count)
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    def upd(g, mu, nu, master):
        g = g.astype(jnp.float32)
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        mhat = mu / b1c
        nhat = nu / b2c
        step = mhat / (jnp.sqrt(nhat) + cfg.eps) + cfg.weight_decay * master
        master = master - lr * step
        return mu, nu, master

    flat_g, treedef = jax.tree.flatten(grads)
    flat_mu = treedef.flatten_up_to(opt_state["mu"])
    flat_nu = treedef.flatten_up_to(opt_state["nu"])
    flat_ma = treedef.flatten_up_to(opt_state["master"])
    out = [upd(g, m, n, ma) for g, m, n, ma in zip(flat_g, flat_mu, flat_nu, flat_ma)]
    new_mu = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_nu = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_ma = jax.tree.unflatten(treedef, [o[2] for o in out])
    new_params = jax.tree.map(lambda ma, p: ma.astype(p.dtype), new_ma, params)
    new_state = {"mu": new_mu, "nu": new_nu, "master": new_ma, "count": count}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
