"""Simulated transport + wire-cost accounting.

The paper evaluates BlobSeer on Grid'5000 (1 Gbit/s intra-cluster
Ethernet, measured 117.5 MB/s TCP, 0.1 ms latency).  This container is a
single CPU core, so we cannot measure real network throughput.  Instead,
every remote interaction goes through a :class:`Wire`, which

* optionally injects *real* latency (``sleep_scale > 0``) so that
  concurrency tests exercise true interleavings, and
* always accounts *simulated* wire time per endpoint
  (``latency + bytes / bandwidth``), so benchmarks can report derived
  Grid'5000-equivalent bandwidth figures next to raw wall-clock numbers.

Per-endpoint serialization is modelled with one lock per endpoint: two
clients hitting the same provider serialize there, exactly the conflict
the paper says the provider-manager placement strategy must minimize
(§4.3 "data access serialization is only necessary when the same
provider is contacted at the same time by different clients").

Under a virtual :class:`~repro.core.sim.Simulator` clock the queueing
model is promoted from accounting to *actual scheduling*: the issuing
task sleeps (in virtual time) until its request's completion instant
``max(now, endpoint_busy_until) + cost``, so endpoint contention shapes
the schedule exactly as it shaped the derived makespans before.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence

from repro.core.sim import Clock, WallClock


GRID5000_BANDWIDTH = 117.5e6  # bytes/s, measured TCP figure from the paper
GRID5000_LATENCY = 0.1e-3     # seconds

# Wire-cost model of the GC sweep verbs (beyond paper; the paper never
# reclaims space).  A delete carries only an identifier, no payload:
# the per-item cost of a batched `MetadataDHT.delete_many` /
# `DataProvider.delete_pages` is one key/page-id plus framing, and the
# whole batch pays a single latency charge via `transfer_batch`.
DELETE_NODE_KEY_BYTES = 40  # one metadata-node key in a batched delete
DELETE_PAGE_CMD_BYTES = 24  # one page-id in a batched page delete
LIST_PAGE_ENTRY_BYTES = 28  # one (page id, stored-at) entry in an inventory

# Wire-cost model of the version-manager control plane.  Singleton verbs
# (GET_RECENT, SYNC, a lone assign, ...) each pay one latency charge plus
# VM_CTRL_MSG_BYTES.  The batched writer verbs of the scale-out write
# plane — `VersionManager.assign_versions_many` and
# `metadata_complete_many` — pay ONE latency charge for the whole batch
# plus a per-item framing cost below, which is what lets an appender
# swarm amortize version-manager round trips the way `get_many`
# amortized metadata reads.
VM_CTRL_MSG_BYTES = 96      # one singleton control-plane verb
VM_ASSIGN_REQ_BYTES = 128   # one request inside assign_versions_many
VM_COMPLETE_CMD_BYTES = 48  # one command inside metadata_complete_many

# Wire-cost model of the HA control plane (replicated lineage shards).
# Every journal record a shard leader commits is streamed to its F
# followers: all of one verb's records ride ONE fire-and-forget
# `transfer_batch` per follower, per record below.  Publication acks
# barrier on the stream's completion instant (per-endpoint FIFO makes
# that cover every earlier record too), so replication adds bandwidth
# but no blocking round trip to the assign path.  Failover pays one
# blocking promotion handshake to the follower being promoted.
VM_WAL_REC_BYTES = 112     # one replicated journal record in a stream batch
VM_WAL_PROMOTE_BYTES = 64  # the lease-takeover promotion handshake RPC

# Wire-cost model of the subscription plane (watch/notify version
# leases).  Watch registration/renewal/cancel are singleton control
# verbs on the lineage leader.  Notification fan-out is the inverted
# primitive: at publication time the leader coalesces every watcher's
# pending gap into ONE entry and ships all entries bound for the same
# inbox endpoint as ONE fire-and-forget `transfer_batch` — a burst of K
# publications to W watchers costs O(K x endpoints-with-watchers)
# round trips, never O(W).  Push-based page-cache invalidation rides
# the same shape: one batched fire-and-forget send per retire intent.
VM_WATCH_REQ_BYTES = 64      # one watch/unwatch/renew control verb
WATCH_NOTIFY_EVT_BYTES = 32  # one coalesced per-watcher entry in a notify batch
CACHE_INVAL_EVT_BYTES = 24   # one page-id entry in a push-invalidation batch

# Wire-cost model of the dedup index (``core/dedup_index.py``).  The
# lookup is the one blocking control round trip the handshake adds per
# write burst: all of a burst's digests ride ONE `transfer_batch`, per
# item below.  Registrations and plain decrements are fire-and-forget
# (they never gate the writer); GC's release batch is blocking because
# the sweeper needs the refcount verdicts back.
DEDUP_LOOKUP_REQ_BYTES = 24    # one (digest64, length) probe in lookup_and_acquire
DEDUP_REGISTER_REQ_BYTES = 48  # one (digest64, page descriptor) in register
DEDUP_RELEASE_REQ_BYTES = 24   # one reference-drop command in release/unreference
DEDUP_REFRESH_REQ_BYTES = 56   # one (page_id, new provider tuple) in refresh_providers

# Wire-cost model of the durability plane (``core/durability.py``).
# A scrub round asks each provider to re-digest its stored pages in
# place (one batched round trip, one probe entry per page — bytes stay
# on the provider), and consults the provider manager's relocation
# overlay when a descriptor's replica list is exhausted (repair and
# lifecycle demotion move bytes without rewriting published metadata).
SCRUB_PROBE_BYTES = 24     # one per-page verify entry in a scrub batch
PM_LOCATE_REQ_BYTES = 40   # one relocation-overlay lookup at the manager

# Wire-cost model of the elastic-membership plane (hash-ring join/drain,
# ``core/membership.py``).  A migration copy pays the full page payload
# through the ordinary provider put/get path; these constants price only
# the *control* framing around it — the per-page move command, the
# per-key metadata handoff, and the ring-membership announcement a
# join/drain broadcasts — so the rebalance gate (moved bytes vs the
# theoretical minimum) accounts for real protocol overhead instead of
# pretending coordination is free.
MIGRATE_PAGE_CMD_BYTES = 48   # one page/shard move command in a migration batch
MIGRATE_META_KEY_BYTES = 48   # one DHT key handoff command in an arc transfer
RING_ANNOUNCE_BYTES = 96      # one join/drain membership announcement
WIDEN_CMD_BYTES = 48          # one replica-widening command (flash-crowd)


@dataclass
class WireStats:
    """Cumulative per-endpoint wire accounting."""

    bytes_in: int = 0
    bytes_out: int = 0
    requests: int = 0
    sim_busy_until: float = 0.0  # simulated clock: when this endpoint frees up


class EndpointDown(RuntimeError):
    """Raised when a failed endpoint is contacted (failure injection)."""


@dataclass
class Wire:
    """Shared wire model for one deployment.

    ``sleep_scale``  multiply injected real sleeps (0 = don't sleep; tests
                     that need true interleaving set a small value).
    """

    bandwidth: float = GRID5000_BANDWIDTH
    latency: float = GRID5000_LATENCY
    sleep_scale: float = 0.0
    clock: Clock = field(default_factory=WallClock)

    _stats: Dict[str, WireStats] = field(default_factory=dict)
    _locks: Dict[str, threading.Lock] = field(default_factory=dict)
    _down: Dict[str, bool] = field(default_factory=dict)
    _slow: Dict[str, float] = field(default_factory=dict)  # straggler factor
    _global: threading.Lock = field(default_factory=threading.Lock)
    _sim_clock: float = 0.0
    _round_trips: int = 0
    _local_hits: int = 0       # requests served from a local cache, no RPC
    _local_hit_bytes: int = 0  # bytes those hits kept off the wire

    # -- endpoint registry ---------------------------------------------------
    def _ep(self, endpoint: str) -> WireStats:
        with self._global:
            if endpoint not in self._stats:
                self._stats[endpoint] = WireStats()
                self._locks[endpoint] = threading.Lock()
            return self._stats[endpoint]

    def lock(self, endpoint: str) -> threading.Lock:
        self._ep(endpoint)
        return self._locks[endpoint]

    # -- failure / straggler injection ----------------------------------------
    def set_down(self, endpoint: str, down: bool = True) -> None:
        self._ep(endpoint)
        self._down[endpoint] = down

    def is_down(self, endpoint: str) -> bool:
        return self._down.get(endpoint, False)

    def set_straggler(self, endpoint: str, factor: float) -> None:
        """Make an endpoint ``factor`` x slower (simulated + injected)."""
        self._ep(endpoint)
        self._slow[endpoint] = factor

    # -- the actual transfer ---------------------------------------------------
    def transfer(
        self, endpoint: str, nbytes: int, *, inbound: bool,
        peer: Optional[str] = None, async_peer: bool = False,
        fire_and_forget: bool = False,
    ) -> float:
        """Account one request moving ``nbytes`` to/from ``endpoint``.

        ``peer`` is the other side of the connection (usually the
        client); its NIC is charged wire time too, which is what makes a
        single appender's bandwidth top out near the measured per-link
        figure, as in the paper's Fig 2(a).

        ``async_peer`` models the paper's "for all ... in parallel"
        loops: with many RPCs in flight, the peer's NIC is occupied by
        the *bytes* only — per-request latency overlaps across requests
        and is paid by the remote endpoint, not the issuing NIC.

        ``fire_and_forget`` models a request the issuer does not wait
        for (cache prefetch): the endpoint queue, byte counters and
        round-trip count are charged exactly as usual, but the issuing
        task is **never blocked** — not in virtual time, not by
        ``sleep_scale``.  The completion instant is still recorded in
        the endpoint's ``sim_busy_until``, which is how the cache learns
        when the prefetched bytes "arrive".

        Returns the completion instant ``done_at`` (simulated-clock
        coordinates).  Raises :class:`EndpointDown` on failed endpoints.

        Under a virtual clock a non-fire-and-forget issuing task
        *blocks in virtual time* until ``done_at`` — the per-endpoint
        queue stops being mere accounting and becomes the schedule.
        """
        if self._down.get(endpoint, False):
            raise EndpointDown(endpoint)
        st = self._ep(endpoint)
        factor = self._slow.get(endpoint, 1.0)
        cost = (self.latency + nbytes / self.bandwidth) * factor
        virtual = self.clock.is_virtual
        base = self.clock.now() if virtual else self._sim_clock
        with self._locks[endpoint]:
            st.requests += 1
            if inbound:
                st.bytes_in += nbytes
            else:
                st.bytes_out += nbytes
            # Endpoint serialization in simulated time: requests queue.
            with self._global:
                self._round_trips += 1
                start = max(base, st.sim_busy_until)
                st.sim_busy_until = start + cost
        done_at = start + cost
        if peer is not None:
            peer_cost = (nbytes / self.bandwidth) if async_peer else cost
            pst = self._ep(peer)
            with self._locks[peer]:
                pst.requests += 1
                if inbound:
                    pst.bytes_out += nbytes
                else:
                    pst.bytes_in += nbytes
                with self._global:
                    start = max(base, pst.sim_busy_until)
                    pst.sim_busy_until = start + peer_cost
        if not fire_and_forget:
            if virtual:
                self.clock.sleep_until(done_at)
            elif self.sleep_scale > 0.0:
                self.clock.sleep(cost * self.sleep_scale)
        return done_at

    def transfer_batch(
        self, endpoint: str, sizes: Sequence[int], *, inbound: bool,
        peer: Optional[str] = None, async_peer: bool = True,
        fire_and_forget: bool = False,
    ) -> float:
        """Account ONE batched request carrying ``len(sizes)`` items.

        The whole batch pays a single latency charge plus the summed
        bytes — the accounting ``MetadataDHT.put_many`` pioneered, now a
        first-class primitive shared by the batched read plane
        (``get_many``, ``fetch_pages``).  Counts as one round trip.
        Returns the batch's completion instant (see :meth:`transfer`).
        """
        return self.transfer(
            endpoint, sum(sizes), inbound=inbound, peer=peer,
            async_peer=async_peer, fire_and_forget=fire_and_forget,
        )

    # -- simulated clock -------------------------------------------------------
    def advance_clock(self, seconds: float) -> None:
        with self._global:
            self._sim_clock += seconds

    def sim_span(self) -> float:
        """Simulated makespan: latest endpoint-free time."""
        with self._global:
            busy = [s.sim_busy_until for s in self._stats.values()]
            return max(busy) if busy else 0.0

    def stats(self, endpoint: str) -> WireStats:
        return self._ep(endpoint)

    def total_bytes(self) -> int:
        with self._global:
            return sum(s.bytes_in + s.bytes_out for s in self._stats.values())

    def total_round_trips(self) -> int:
        """RPCs issued so far (a batched transfer counts once)."""
        with self._global:
            return self._round_trips

    # -- cache-hit vs RPC accounting -------------------------------------------
    def note_local_hit(self, nbytes: int) -> None:
        """Account a request served from a local cache: zero round trips,
        zero wire time — ``nbytes`` records what an RPC *would* have
        moved, so benchmarks can report bytes kept off the wire next to
        ``total_bytes()``.  Never touches endpoint queues or the clock."""
        with self._global:
            self._local_hits += 1
            self._local_hit_bytes += nbytes

    def total_local_hits(self) -> int:
        with self._global:
            return self._local_hits

    def total_local_hit_bytes(self) -> int:
        """Bytes served from local caches instead of the wire."""
        with self._global:
            return self._local_hit_bytes

    def reset_accounting(self) -> None:
        with self._global:
            for s in self._stats.values():
                s.bytes_in = s.bytes_out = s.requests = 0
                s.sim_busy_until = 0.0
            self._sim_clock = 0.0
            self._round_trips = 0
            self._local_hits = 0
            self._local_hit_bytes = 0
