"""Content-hash page index: equal-content pages stored and shipped once.

The segment tree already shares *unchanged subtrees* between versions;
this index extends copy-on-write sharing to *equal-content* pages that
arrive through different paths — adjacent checkpoint steps, forked
fine-tune lineages, re-striped appends.  It lives beside the DHT as its
own endpoint and maps a 64-bit page fingerprint (two independent 32-bit
polynomial digests, see ``kernels/hostdigest.py``) plus the payload
length to the descriptor of the first stored copy, with a reference
count.

Write-path handshake (``BlobClient._store_planned``):

1. the client digests every full page of a burst (device kernel for
   checkpoints, host twin otherwise) and probes the index with ONE
   batched ``lookup_and_acquire`` RPC — the single blocking control
   round trip dedup adds per burst;
2. hits bump the refcount and reuse the existing descriptor — those
   pages never ship bytes;
3. misses are stored normally, then ``register``-ed fire-and-forget
   (refcount 1 = the storer's own descriptor reference).

Refcount lifecycle invariant: **refcount == number of outstanding
page-descriptor references**.  Every acquisition (a ``register`` by the
storer, a hit by a reuser) is matched by exactly one release — either
an ``unreference`` when a re-striped append abandons its optimistic
pages, or a GC ``release_many`` when the referencing version is swept
(idempotent per ``(blob, version, rel)`` so sweep retries never
double-decrement).  The sweep deletes a page's bytes only at refcount
zero AND after mark-phase liveness lapsed; a positive refcount after
release means another version still holds the page and the sweeper
finalizes without deleting.  Refcount zero alone is NOT sufficient:
copy-on-write subtree sharing keeps pages live with no pd reference at
all, so zero-refcount entries of still-live pages stay indexed and
matchable (a later lookup resurrects them to refcount 1 — that is what
keeps a restarted checkpointer's re-digested pages deduplicating)
until the mark path claims them through ``claim_dead``.  The index is
volatile (rebuilt empty on restore): mark-based liveness remains a
sufficient correctness backstop on its own, refcounts only ever *defer*
deletion, never cause one.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.core.transport import (
    DEDUP_LOOKUP_REQ_BYTES,
    DEDUP_REFRESH_REQ_BYTES,
    DEDUP_REGISTER_REQ_BYTES,
    DEDUP_RELEASE_REQ_BYTES,
    Wire,
)

# (digest0, digest1, payload_length) — length disambiguates tail pages
# whose zero-padding makes their digest equal a longer page's.
DigestKey = Tuple[int, int, int]
# (blob_id, version, rel) — one pd slot of one version; the idempotency
# unit for GC releases.
RefKey = Tuple[str, int, int]


@dataclass
class _Entry:
    page_id: str
    providers: Tuple[str, ...]
    length: int
    refcount: int


class DedupIndex:
    """Digest → page-descriptor index with wire-accounted verbs."""

    ENDPOINT = "dedup-idx"

    def __init__(self, wire: Wire) -> None:
        self.wire = wire
        self._lock = threading.Lock()
        self._by_digest: Dict[DigestKey, _Entry] = {}
        self._by_pid: Dict[str, DigestKey] = {}
        self._released: Set[RefKey] = set()
        # True once any page was ever registered; GC consults this to
        # skip release/guard RPCs entirely for dedup-free workloads so
        # their wire schedules stay byte-identical to the non-dedup
        # write plane.
        self.ever_registered = False
        self._counters: Dict[str, int] = {}
        self.reset_rpc_counters()

    # ----------------------------------------------------------- write path
    def lookup_and_acquire(
        self, wants: Sequence[DigestKey], peer: Optional[str] = None
    ) -> List[Optional[Tuple[str, Tuple[str, ...], int]]]:
        """Probe ``wants`` in ONE batched RPC; hits bump the refcount.

        Returns, aligned with ``wants``, the reusable descriptor
        ``(page_id, providers, length)`` or ``None`` per digest.  The
        bump happens inside the probe so a concurrent sweep can never
        observe the page unreferenced between match and use.
        """
        if not wants:
            return []
        self.wire.transfer_batch(
            self.ENDPOINT,
            [DEDUP_LOOKUP_REQ_BYTES] * len(wants),
            inbound=True,
            peer=peer,
        )
        out: List[Optional[Tuple[str, Tuple[str, ...], int]]] = []
        with self._lock:
            self._counters["lookup_rounds"] += 1
            self._counters["lookup_keys"] += len(wants)
            for key in wants:
                ent = self._by_digest.get(key)
                if ent is None:
                    out.append(None)
                else:
                    ent.refcount += 1
                    self._counters["hits"] += 1
                    self._counters["hit_bytes"] += ent.length
                    out.append((ent.page_id, ent.providers, ent.length))
        return out

    def register(
        self,
        items: Sequence[Tuple[DigestKey, str, Tuple[str, ...], int]],
        peer: Optional[str] = None,
    ) -> None:
        """Index freshly stored pages, fire-and-forget (never gates the
        writer).  Refcount starts at 1: the storer's own pd reference.
        If two writers raced the same content, first registration wins
        and the loser's copy stays unindexed (its own pd still owns it;
        GC's mark path collects it normally)."""
        if not items:
            return
        self.wire.transfer_batch(
            self.ENDPOINT,
            [DEDUP_REGISTER_REQ_BYTES] * len(items),
            inbound=True,
            peer=peer,
            fire_and_forget=True,
        )
        with self._lock:
            self._counters["register_rounds"] += 1
            self.ever_registered = True
            for key, pid, provs, length in items:
                if key in self._by_digest or pid in self._by_pid:
                    continue
                self._by_digest[key] = _Entry(pid, tuple(provs), length, 1)
                self._by_pid[pid] = key
                self._counters["registered"] += 1

    def refresh_providers(
        self,
        updates: Sequence[Tuple[str, Tuple[str, ...]]],
        peer: Optional[str] = None,
    ) -> int:
        """Batched provider-refresh: the repair plane's stale-descriptor
        fix.  ``updates`` holds ``(page_id, new_provider_tuple)`` pairs
        for pages whose bytes repair (or lifecycle demotion) moved; the
        entry's frozen ``providers`` tuple is replaced so later dedup
        hits hand out descriptors pointing at live endpoints instead of
        the dead one.  Fire-and-forget (repair never gates on the
        index; a reader holding a not-yet-refreshed descriptor still
        recovers through the provider manager's relocation overlay).
        Returns the number of entries actually updated.
        """
        if not updates:
            return 0
        self.wire.transfer_batch(
            self.ENDPOINT,
            [DEDUP_REFRESH_REQ_BYTES] * len(updates),
            inbound=True,
            peer=peer,
            fire_and_forget=True,
        )
        n = 0
        with self._lock:
            self._counters["refresh_rounds"] += 1
            for pid, provs in updates:
                key = self._by_pid.get(pid)
                if key is None:
                    continue
                self._by_digest[key].providers = tuple(provs)
                self._counters["refreshed"] += 1
                n += 1
        return n

    def unreference(
        self, page_ids: Sequence[str], peer: Optional[str] = None
    ) -> None:
        """Drop plain references (no version attached) — the re-striped
        append abandoning its optimistic pages.  Fire-and-forget; a
        refcount reaching zero only unindexes the entry (the bytes, if
        any were stored, become orphans for the inventory pass)."""
        if not page_ids:
            return
        self.wire.transfer_batch(
            self.ENDPOINT,
            [DEDUP_RELEASE_REQ_BYTES] * len(page_ids),
            inbound=True,
            peer=peer,
            fire_and_forget=True,
        )
        with self._lock:
            self._counters["release_rounds"] += 1
            for pid in page_ids:
                self._release_pid(pid, unindex_at_zero=True)

    def _release_pid(self, pid: str, *, unindex_at_zero: bool) -> Optional[int]:
        """Decrement under the lock.  Returns the new refcount, or None
        if the pid is not indexed.  ``unindex_at_zero`` is the plain
        client-side release (abandoned pages become orphans); the GC
        path keeps zero-refcount entries so :meth:`release_many` can
        rule on liveness first."""
        key = self._by_pid.get(pid)
        if key is None:
            return None
        ent = self._by_digest[key]
        ent.refcount -= 1
        self._counters["released"] += 1
        if unindex_at_zero and ent.refcount <= 0:
            del self._by_digest[key]
            del self._by_pid[pid]
            self._counters["dropped"] += 1
        return ent.refcount

    def _unindex(self, pid: str) -> None:
        key = self._by_pid.pop(pid, None)
        if key is not None:
            del self._by_digest[key]
            self._counters["dropped"] += 1

    # ------------------------------------------------------------------- GC
    def release_many(
        self,
        refs: Sequence[Tuple[RefKey, str]],
        live: Set[str],
        peer: Optional[str] = None,
    ) -> Tuple[Set[str], Set[str]]:
        """Release swept versions' page references; ONE blocking batch
        (the sweeper needs the refcount verdicts back).

        ``refs``: ``((blob, version, rel), page_id)`` per pd slot;
        idempotent per ref-key, so a sweep retried after a failed
        delete can never double-decrement.  All decrements apply first,
        then per-page verdicts are computed on the final refcount:

        * ``keep``  — refcount still positive: another version holds
          the page; the sweeper must NOT delete, and needs no deferral.
        * ``drop``  — refcount hit zero and the page is not pinned live
          by a kept version's subtree: the entry is removed under the
          lock (no later lookup can resurrect it) and the bytes are
          safe to delete now.

        A page whose refcount reached zero but that IS still live stays
        *indexed at refcount zero*: pd refcounts only count the
        versions that created/acquired the page, while copy-on-write
        subtree sharing keeps pages live with no pd reference at all —
        exactly the pages a restarted checkpointer re-digests, so their
        entries must stay matchable (a hit resurrects the entry to
        refcount 1).  Liveness-driven deletion of those entries belongs
        to the mark path, which must claim them through
        :meth:`claim_dead` first.  Pages in neither returned set fall
        through to the caller's mark-based path.
        """
        if not refs:
            return set(), set()
        self.wire.transfer_batch(
            self.ENDPOINT,
            [DEDUP_RELEASE_REQ_BYTES] * len(refs),
            inbound=True,
            peer=peer,
        )
        keep: Set[str] = set()
        drop: Set[str] = set()
        with self._lock:
            self._counters["release_rounds"] += 1
            touched: Dict[str, int] = {}
            for refkey, pid in refs:
                if refkey in self._released:
                    continue
                self._released.add(refkey)
                rc = self._release_pid(pid, unindex_at_zero=False)
                if rc is not None:
                    touched[pid] = rc
            for pid, rc in touched.items():
                if rc > 0:
                    keep.add(pid)
                elif pid not in live:
                    self._unindex(pid)
                    drop.add(pid)
                # rc == 0 and live: entry stays, matchable at rc 0; the
                # mark path defers the version until liveness lapses.
        return keep, drop

    def claim_dead(
        self, page_ids: Sequence[str], peer: Optional[str] = None
    ) -> Tuple[Set[str], Set[str]]:
        """Atomically claim mark-dead pages for deletion.

        Between a sweep's mark phase and its delete RPCs other tasks
        run (the virtual clock yields at every blocking transfer), so a
        zero-refcount entry the mark found dead may be *resurrected* by
        a concurrent lookup before the delete lands.  The sweeper
        therefore claims each candidate under the index lock first:

        * entry at refcount 0 (or missing) — claimed: removed from the
          index, no future lookup can hand it out, delete is safe;
        * entry at refcount > 0 — ``resurrected``: a writer acquired
          the page after the mark; the sweeper must skip the delete
          (the new holder's own release will retire the bytes later).

        Local decision on sweep-side state — rides the delete round it
        gates, so no wire charge of its own.
        """
        claimed: Set[str] = set()
        resurrected: Set[str] = set()
        with self._lock:
            for pid in page_ids:
                key = self._by_pid.get(pid)
                if key is None:
                    claimed.add(pid)
                elif self._by_digest[key].refcount > 0:
                    resurrected.add(pid)
                else:
                    self._unindex(pid)
                    claimed.add(pid)
        return claimed, resurrected

    def orphan_guard(
        self, page_ids: Sequence[str], peer: Optional[str] = None
    ) -> Set[str]:
        """Reconcile the orphan inventory against the index; returns the
        page-ids to KEEP.  An orphan candidate (stored but in no
        journaled pd) with refcount >= 2 has a hitter beyond its storer
        — typically a writer that acquired the page but has not
        published its descriptor yet — so its bytes must survive.  At
        refcount <= 1 the only reference is the storer's own, which the
        inventory just proved stale: the entry is dropped and the
        delete proceeds."""
        if not page_ids:
            return set()
        self.wire.transfer_batch(
            self.ENDPOINT,
            [DEDUP_LOOKUP_REQ_BYTES] * len(page_ids),
            inbound=True,
            peer=peer,
        )
        kept: Set[str] = set()
        with self._lock:
            self._counters["guard_rounds"] += 1
            for pid in page_ids:
                key = self._by_pid.get(pid)
                if key is None:
                    continue
                if self._by_digest[key].refcount >= 2:
                    kept.add(pid)
                else:
                    del self._by_digest[key]
                    del self._by_pid[pid]
                    self._counters["dropped"] += 1
        return kept

    def forget_pages(self, page_ids: Iterable[str]) -> None:
        """Unconditional local unindex, invoked by the provider manager
        alongside every page-delete RPC (no wire charge of its own — it
        rides the delete round).  Belt to the refcount braces: no index
        entry can outlive its bytes, so a later digest match can never
        resurrect a deleted page."""
        with self._lock:
            for pid in page_ids:
                key = self._by_pid.pop(pid, None)
                if key is not None:
                    del self._by_digest[key]
                    self._counters["dropped"] += 1

    # ------------------------------------------------------------ inspection
    def refcount(self, page_id: str) -> int:
        """Current refcount of an indexed page (0 if unindexed)."""
        with self._lock:
            key = self._by_pid.get(page_id)
            return self._by_digest[key].refcount if key is not None else 0

    def indexed_pages(self) -> Dict[str, int]:
        """Snapshot ``{page_id: refcount}`` — oracle hook for tests."""
        with self._lock:
            return {pid: self._by_digest[key].refcount
                    for pid, key in self._by_pid.items()}

    def rpc_counters(self) -> Dict[str, int]:
        with self._lock:
            return dict(self._counters)

    def reset_rpc_counters(self) -> None:
        with self._lock:
            self._counters = {
                "lookup_rounds": 0,
                "lookup_keys": 0,
                "hits": 0,
                "hit_bytes": 0,
                "register_rounds": 0,
                "registered": 0,
                "release_rounds": 0,
                "released": 0,
                "guard_rounds": 0,
                "dropped": 0,
                "refresh_rounds": 0,
                "refreshed": 0,
            }
