"""Elastic membership: online provider join/drain and flash-crowd relief.

The paper's deployments are static — "we deploy ... on the other
nodes" (§5) fixes the fleet before the first write.  This module makes
the data-provider fleet elastic on top of the consistent-hash ring
(:class:`~repro.core.placement.HashRing`, wired into
``ProviderManager``):

* **Join** (:func:`join_provider` + :func:`build_join_plan`): the new
  member enters the ring immediately (new pages place onto it from the
  next allocation), then receives exactly the already-stored pages
  whose ring owner set it now contains — nothing else moves, so the
  transfer volume stays at the consistent-hash minimum (~pages/n).

* **Drain** (:func:`start_drain` / :func:`finish_drain`): the member
  leaves the placement pool at once (``ProviderManager.mark_draining``)
  but keeps serving reads; every live copy it holds is pushed to that
  page's next ring owner, a final straggler sweep re-lists the store,
  and only then does the member deregister (``finish_drain`` marks it
  ``_departed`` so later GC sweeps know its copies died clean) — zero
  failed ops end to end.

Both directions run as **budget-capped rounds**
(:func:`migration_round` / :func:`run_migration`) concurrently with
client reads and writes: the old holder serves a page until its move
lands, and the per-page "configuration pointer flip" is the relocation
overlay entry (``ProviderManager.record_relocation``) the read path
already consults — the ARES fragmented-transfer scheme
(arXiv:2201.13292) applied to the data plane, where descriptors rather
than the ring route reads.  Every move is wire-accounted (payload read
+ payload write + ``MIGRATE_PAGE_CMD_BYTES`` framing) and refreshes the
dedup index so content-hash hits never hand out a drained endpoint.

:func:`mitigate_flash_crowd` is the load-side twin: when the per-page
read tallies (``ProviderManager.read_tallies``) show a hot page, its
replica set widens onto the next ring owners
(``ProviderManager.widen_page``) so the replica load balancer can
spread the crowd.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.placement import (
    logical_pid,
    page_codec,
    shard_id,
    split_shard,
    stable_hash,
)
from repro.core.transport import MIGRATE_PAGE_CMD_BYTES, EndpointDown

#: Default per-round byte budget: a handful of 64 KiB pages per round,
#: so rebalancing converges over rounds instead of bursting and
#: starving client traffic (mirrors durability.DEFAULT_SCRUB_BUDGET).
DEFAULT_MIGRATION_BUDGET = 8 * 1024 * 1024


@dataclass(frozen=True)
class Move:
    """One planned page-copy transfer.

    ``phys`` is the store id that moves (the logical page id for
    replicated pages, a positional ``.sN`` shard id for EC pages);
    ``src`` the holder that loses the copy once it lands (``""`` when
    the move only widens the holder set); ``read_from`` the holders the
    payload may be read out of, busiest-last; ``new_holders`` the full
    holder tuple recorded in the relocation overlay after the move.
    """

    logical: str
    phys: str
    src: str
    dst: str
    read_from: Tuple[str, ...]
    new_holders: Tuple[str, ...]


def _plan_order(svc, move: Move) -> Tuple[int, str]:
    """Deterministic plan order: ring position first (arcs transfer in
    ring order, like the DHT's), physical id as tie-break."""
    return stable_hash(svc.pm.place_key(move.logical)), move.phys


def _holders(svc, phys: str, journaled: Sequence[str]) -> List[str]:
    overlay = svc.pm.relocated(phys)
    return list(overlay) if overlay else list(dict.fromkeys(journaled))


def _cold_pids(svc) -> set:
    return {p.pid for p in svc.pm.all_providers()
            if getattr(p, "tier", "hot") == "cold"}


def _shard_holder(svc, lpid: str, j: int,
                  journaled: Sequence[str]) -> Optional[str]:
    sid = shard_id(lpid, j)
    overlay = svc.pm.relocated(sid)
    if overlay:
        return overlay[0]
    return journaled[j] if j < len(journaled) else None


def build_join_plan(svc, joining: str) -> List[Move]:
    """Every already-stored live page the ring now assigns to
    ``joining``: exactly the consistent-hash minimum transfer set."""
    cold = _cold_pids(svc)
    moves: List[Move] = []
    for lpid, (_blob, provs, _length) in sorted(
            svc.vm.page_locations().items()):
        codec = page_codec(lpid)
        width = len(dict.fromkeys(provs)) if codec is None else sum(codec)
        desired = svc.pm.ring_owners(svc.pm.place_key(lpid), width)
        if joining not in desired:
            continue
        if codec is None:
            holders = _holders(svc, lpid, provs)
            if joining in holders or set(holders) & cold:
                continue  # already landed / lifecycle owns cold pages
            lost = [h for h in holders if h not in desired]
            src = lost[0] if lost else ""
            new_holders = tuple(h for h in holders if h != src) + (joining,)
            moves.append(Move(lpid, lpid, src, joining,
                              tuple(holders), new_holders))
        else:
            j = desired.index(joining)
            holder = _shard_holder(svc, lpid, j, provs)
            if holder is None or holder == joining or holder in cold:
                continue
            moves.append(Move(lpid, shard_id(lpid, j), holder, joining,
                              (holder,), (joining,)))
    moves.sort(key=lambda m: _plan_order(svc, m))
    return moves


def build_drain_plan(svc, draining: str) -> List[Move]:
    """Every live copy the draining member still holds, paired with the
    ring owner that takes it over.  ``mark_draining`` must already have
    run — the ring no longer offers the draining member, so
    ``ring_owners`` resolves each page's next home directly."""
    inventory = svc.vm.page_locations()
    prov = svc.pm.get(draining)
    listing = prov.list_pages(peer="migrator")
    moves: List[Move] = []
    for phys, _at in sorted(listing):
        lpid = logical_pid(phys)
        rec = inventory.get(lpid)
        if rec is None:
            continue  # garbage pending sweep: dies with the member
        _blob, provs, _length = rec
        codec = page_codec(lpid)
        width = len(dict.fromkeys(provs)) if codec is None else sum(codec)
        desired = svc.pm.ring_owners(svc.pm.place_key(lpid), width)
        if codec is None:
            holders = _holders(svc, lpid, provs)
            if draining not in holders:
                continue  # an overlay move already superseded this copy
            keep = [h for h in holders if h != draining]
            dst = next((d for d in desired if d not in keep), None)
            if dst is None:
                pool = sorted(
                    (p for p in svc.pm.placement_pool()
                     if p.pid not in keep),
                    key=lambda p: (p.page_count(), p.pid))
                dst = pool[0].pid if pool else None
            if dst is None:
                continue  # nowhere to go; straggler sweep retries
            moves.append(Move(lpid, phys, draining, dst,
                              tuple(holders), tuple(keep) + (dst,)))
        else:
            split = split_shard(phys)
            if split is None:
                continue
            j = split[1]
            if _shard_holder(svc, lpid, j, provs) != draining:
                continue
            exclude = {h for jj in range(width)
                       for h in (_shard_holder(svc, lpid, jj, provs),)
                       if h is not None and jj != j}
            dst = next((d for d in desired if d not in exclude), None)
            if dst is None:
                continue
            moves.append(Move(lpid, phys, draining, dst,
                              (draining,), (dst,)))
    moves.sort(key=lambda m: _plan_order(svc, m))
    return moves


def migration_round(
    svc,
    plan: List[Move],
    *,
    budget_bytes: int = DEFAULT_MIGRATION_BUDGET,
    peer: str = "migrator",
) -> Dict[str, int]:
    """Execute moves off the front of ``plan`` (mutated in place) until
    the byte budget is spent.

    Each move: read the payload from a live holder (the old owner keeps
    serving clients throughout), write it to the new owner with
    ``MIGRATE_PAGE_CMD_BYTES`` framing, flip the page's configuration
    pointer (``record_relocation``), then delete the superseded copy.
    A move whose holders are all unreachable is deferred to the back of
    the plan.  At least one move executes per round even when it alone
    exceeds the budget, so progress is guaranteed.  Returns round
    stats; ``plan`` empty means the transfer phase is complete.
    """
    stats = {"moves": 0, "bytes": 0, "payload_bytes": 0, "deferred": 0,
             "remaining": 0}
    spent = 0
    deferred: List[Move] = []
    refreshed: List[Tuple[str, Tuple[str, ...]]] = []
    while plan:
        move = plan[0]
        payload = None
        for holder in move.read_from:
            try:
                payload = svc.pm.get(holder).get_page(move.phys, peer=peer)
                break
            except (EndpointDown, KeyError):
                continue
        if payload is None:
            plan.pop(0)
            deferred.append(move)
            stats["deferred"] += 1
            continue
        cost = 2 * len(payload) + MIGRATE_PAGE_CMD_BYTES
        if spent and spent + cost > budget_bytes:
            break
        plan.pop(0)
        try:
            dst = svc.pm.get(move.dst)
            svc.wire.transfer(move.dst, MIGRATE_PAGE_CMD_BYTES,
                              inbound=True, peer=peer, async_peer=True)
            if not dst.has_page(move.phys):
                dst.put_pages([(move.phys, payload)], peer=peer)
        except (EndpointDown, KeyError):
            deferred.append(move)
            stats["deferred"] += 1
            continue
        svc.pm.record_relocation(move.phys, move.new_holders)
        if move.src:
            try:
                svc.pm.get(move.src).delete_pages([move.phys], peer=peer)
            except (EndpointDown, KeyError):
                pass  # descriptor still lists src; GC sweeps it later
        if move.phys == move.logical:
            refreshed.append((move.logical, move.new_holders))
        spent += cost
        stats["moves"] += 1
        stats["bytes"] += cost
        stats["payload_bytes"] += len(payload)
        svc.pm.note_migration(1, cost, payload_bytes=len(payload))
    plan.extend(deferred)
    stats["remaining"] = len(plan)
    if refreshed and getattr(svc.dedup_index, "ever_registered", False):
        svc.dedup_index.refresh_providers(
            list(dict.fromkeys(refreshed)), peer=peer)
    return stats


def run_migration(
    svc,
    plan: List[Move],
    *,
    budget_bytes: int = DEFAULT_MIGRATION_BUDGET,
    round_sleep: float = 0.0,
    max_rounds: int = 10_000,
    peer: str = "migrator",
) -> Dict[str, int]:
    """Drive :func:`migration_round` until the plan drains (or only
    unreachable-holder moves remain).  ``round_sleep`` yields simulated
    time between rounds so client traffic interleaves with the
    transfer."""
    total = {"moves": 0, "bytes": 0, "payload_bytes": 0, "rounds": 0,
             "deferred": 0}
    for _ in range(max_rounds):
        if not plan:
            break
        stats = migration_round(svc, plan, budget_bytes=budget_bytes,
                                peer=peer)
        total["rounds"] += 1
        total["moves"] += stats["moves"]
        total["bytes"] += stats["bytes"]
        total["payload_bytes"] += stats["payload_bytes"]
        if stats["moves"] == 0 and stats["remaining"]:
            # every remaining move is deferred (holders unreachable);
            # leave them for a later call rather than spinning
            total["deferred"] = stats["remaining"]
            break
        if round_sleep and plan:
            svc.clock.sleep(round_sleep)
    return total


# --------------------------------------------------------------- orchestration
def join_provider(svc, pid: str, tier: str = "hot") -> List[Move]:
    """Register a new member and return its rebalance plan (run it with
    :func:`run_migration`).  The member starts taking *new* pages the
    moment this returns; the plan moves the already-stored pages the
    ring now assigns to it."""
    svc.add_provider(pid, tier=tier)
    svc.pm.announce_join(pid)
    if tier != "hot" or svc.pm.ring is None:
        return []  # cold members take no ring placement, nothing to move
    return build_join_plan(svc, pid)


def start_drain(svc, pid: str) -> List[Move]:
    """Take ``pid`` out of placement (it keeps serving reads) and
    return the transfer-out plan.  Refused when the remaining hot fleet
    could no longer hold ``replication`` distinct copies — the same
    floor the metadata ring enforces on ``begin_drain``."""
    prov = svc.pm.get(pid)   # KeyError for unknown members, like the DHT
    if getattr(prov, "tier", "hot") == "hot":
        hot = [p.pid for p in svc.pm.all_providers()
               if getattr(p, "tier", "hot") == "hot"
               and p.pid not in svc.pm._draining]
        remaining = len([h for h in hot if h != pid])
        if remaining < svc.pm.replication:
            raise RuntimeError(
                f"draining {pid} would leave {remaining} hot providers, "
                f"fewer than the {svc.pm.replication}-way replication "
                f"floor")
    svc.pm.mark_draining(pid)
    return build_drain_plan(svc, pid)


def finish_drain(svc, pid: str, *, peer: str = "migrator",
                 max_sweeps: int = 16) -> int:
    """Straggler sweep + deregistration: re-plan until the member holds
    no live copy (writes that raced the main transfer), then mark it
    departed.  Returns the number of straggler moves."""
    stragglers = 0
    for _ in range(max_sweeps):
        plan = build_drain_plan(svc, pid)
        if not plan:
            svc.pm.finish_drain(pid)
            return stragglers
        done = run_migration(svc, plan, peer=peer)
        stragglers += done["moves"]
        if done["moves"] == 0:
            break
    raise RuntimeError(
        f"drain of {pid} cannot complete: live copies remain with no "
        f"reachable source or destination")


def drain_provider(svc, pid: str, *,
                   budget_bytes: int = DEFAULT_MIGRATION_BUDGET,
                   round_sleep: float = 0.0,
                   peer: str = "migrator") -> Dict[str, int]:
    """Full drain in one call: plan, budgeted transfer, straggler
    sweep, deregister."""
    plan = start_drain(svc, pid)
    total = run_migration(svc, plan, budget_bytes=budget_bytes,
                          round_sleep=round_sleep, peer=peer)
    total["stragglers"] = finish_drain(svc, pid, peer=peer)
    return total


# ----------------------------------------------------------------- flash crowd
def mitigate_flash_crowd(
    svc,
    *,
    threshold: int = 32,
    extra: int = 1,
    blob_id: Optional[str] = None,
    peer: str = "balancer",
) -> List[Tuple[str, Tuple[str, ...]]]:
    """Widen the replica set of every page whose served-read tally
    crossed ``threshold`` (see ``ProviderManager.hot_pages``) onto its
    next ``extra`` ring owners.  ``blob_id`` scopes the pass to one
    blob's pages.  Returns ``(page_id, new_holders)`` per widened page.
    Call periodically (scenario/monitor cadence); the tallies reset so
    each interval's crowd is judged on its own."""
    hot = svc.pm.hot_pages(threshold)
    if not hot:
        return []
    inventory = svc.vm.page_locations()
    widened: List[Tuple[str, Tuple[str, ...]]] = []
    for lpid, _count in hot:
        rec = inventory.get(lpid)
        if rec is None or page_codec(lpid) is not None:
            continue  # EC pages already spread shard load k+m wide
        if blob_id is not None and rec[0] != blob_id:
            continue
        holders = _holders(svc, lpid, rec[1])
        got = svc.pm.widen_page(lpid, holders, extra=extra, peer=peer)
        if got:
            widened.append((lpid, got))
    if widened:
        # widened copies are real holders: refresh the dedup index so a
        # later content hit hands out the full (spread) replica set,
        # not the pre-crowd tuple (same fix as the migration path)
        svc.dedup_index.refresh_providers(list(widened), peer=peer)
    svc.pm.reset_read_tallies()
    return widened
