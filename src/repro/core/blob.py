"""Blob client: the paper's user-facing primitives.

CREATE / READ (Alg 1) / WRITE (Alg 2) / APPEND / GET_RECENT / GET_SIZE /
SYNC / BRANCH, against a deployment of {version manager, metadata DHT,
provider manager}.

Concurrency properties (paper §4.3) preserved:

* data pages are written with **no synchronization** between clients —
  every update creates new pages;
* metadata is built without locking: border nodes of concurrent
  unpublished updates are resolved from the version-manager-supplied
  registry info, everything else by descending a published tree;
* the only serialization points are the version-manager critical
  section (short, and per *lineage* — unrelated blobs never contend)
  and same-endpoint contention.

The write path is pipelined (see docs/write-path.md): page stores go
out as per-endpoint batches that overlap assignment, border prefetch
and metadata puts; the border set is prefetched as one level-batched
cohort; bursts (:meth:`BlobClient.append_many` /
:meth:`BlobClient.write_many`) amortize the version-manager round
trips through the batched writer verbs.

Unaligned ranges (the paper's "slightly more complex" §3 case) are fully
supported: a boundary page whose range is partially overwritten becomes
a *new* page whose content merges the previous snapshot's bytes with the
update's bytes.  Only this case ever waits on another writer (the
previous version's metadata must be complete to read the old content).
"""

from __future__ import annotations

import itertools
import threading
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core import segment_tree as st
from repro.core.cache import NodeCache
from repro.core.dedup_index import DedupIndex
from repro.core.dht import MetadataDHT
from repro.core.pages import UpdateExtent, fresh_page_id, pages_spanned
from repro.core.provider import ProviderManager
from repro.core.transport import Wire
from repro.core.version_manager import (
    AssignInfo,
    VersionManager,
    owner_fn_for_lineage,
)
from repro.kernels.hostdigest import host_page_digest

# Backwards-compatible alias: the node cache grew up and moved to
# repro.core.cache (shared with the page cache and the accounting
# layer); existing imports keep working.
_NodeCache = NodeCache

_client_ids = itertools.count()
_client_ids_lock = threading.Lock()


class ReadError(RuntimeError):
    """A READ failed validation: unpublished version or out-of-bounds
    range.  (Retired snapshots raise the typed
    :class:`~repro.core.version_manager.RetiredVersion` instead.)"""


class WatchInbox:
    """A client's notification inbox: the delivery end of the
    subscription plane (see docs/watch.md).

    The version manager pushes coalesced publication events here as
    fire-and-forget wire batches addressed to ``self.endpoint``; the
    inbox queues them per watch lease and wakes blocked
    :meth:`wait_for` callers.  Under a virtual clock an event becomes
    *visible* only at its wire arrival instant (``ready_at``), so the
    push plane never beats the wire.

    The inbox also enforces the delivery contract locally: per lease it
    keeps a monotone watermark and drops anything at or below it — a
    failover re-flush (the promoted leader re-covering the un-journaled
    tail of deliveries) is deduplicated here, which is what makes
    "no gap" and "no duplicate" compose.  One inbox (one wire endpoint)
    can carry any number of leases: notify cost scales with endpoints,
    not leases.
    """

    def __init__(self, wire: Wire, name: str) -> None:
        self.wire = wire
        self.endpoint = f"inbox-{name}"
        self._clock = wire.clock
        self._cond = self._clock.condition()
        # per-lease pending events, each (version, ready_at); both
        # components are monotone within a queue
        self._queues: Dict[str, List[Tuple[int, float]]] = {}
        self._last: Dict[str, int] = {}      # newest version ever accepted
        self._consumed: Dict[str, int] = {}  # newest version drained by poll
        self._closed: set = set()
        self.delivered = 0            # versions accepted
        self.duplicates_dropped = 0   # re-deliveries the watermark caught

    def track(self, watch_id: str, from_version: int) -> None:
        """Open local state for a lease.  Catch-up deliveries may land
        *before* this (the manager flushes inside ``watch()``), so the
        watermark only ever moves up."""
        with self._cond:
            self._queues.setdefault(watch_id, [])
            self._last[watch_id] = max(self._last.get(watch_id, -1),
                                       from_version)
            self._consumed.setdefault(watch_id, from_version)
            self._closed.discard(watch_id)

    def forget(self, watch_id: str) -> None:
        """Drop a lease's queue and refuse its future deliveries
        (client-side half of ``unwatch``)."""
        with self._cond:
            self._queues.pop(watch_id, None)
            self._closed.add(watch_id)
            self._cond.notify_all()

    def deliver(self, entries: Sequence[Tuple[str, str, Tuple[int, ...]]],
                ready_at: float = 0.0) -> None:
        """Receive one notify batch: ``(watch_id, blob_id, versions)``
        entries.  Called by the version manager (possibly under its
        shard lock — this lock is leaf-level and never blocks)."""
        if not self._clock.is_virtual:
            ready_at = 0.0
        with self._cond:
            for wid, _blob_id, versions in entries:
                if wid in self._closed:
                    continue
                q = self._queues.setdefault(wid, [])
                last = self._last.get(wid, -1)
                for v in versions:
                    if v <= last:
                        self.duplicates_dropped += 1
                        continue
                    q.append((v, ready_at))
                    last = v
                    self.delivered += 1
                self._last[wid] = last
            self._cond.notify_all()

    def poll(self, watch_id: str) -> List[int]:
        """Drain and return the lease's arrived versions (ascending).
        Events still in flight on the wire (``ready_at`` in the future)
        stay queued."""
        now = self._clock.now()
        with self._cond:
            q = self._queues.get(watch_id)
            if not q:
                return []
            i = 0
            while i < len(q) and q[i][1] <= now:
                i += 1
            out = [v for v, _ in q[:i]]
            del q[:i]
            if out:
                self._consumed[watch_id] = max(
                    self._consumed.get(watch_id, -1), out[-1])
            return out

    def wait_for(self, watch_id: str, version: int,
                 timeout: Optional[float] = None) -> None:
        """Block (through the deployment clock) until a version
        ``>= version`` has arrived on the lease — delivered by push, or
        already drained by an earlier :meth:`poll`.  Raises
        ``TimeoutError`` on the deadline."""
        deadline = (None if timeout is None
                    else self._clock.now() + timeout)
        with self._cond:
            while True:
                now = self._clock.now()
                if self._consumed.get(watch_id, -1) >= version:
                    return
                q = self._queues.get(watch_id, ())
                arrival = None
                for v, at in q:
                    if v >= version:
                        arrival = at
                        break
                if arrival is not None and arrival <= now:
                    return
                # next wake: the event's wire arrival or the deadline
                wake = arrival
                if deadline is not None and (wake is None or deadline < wake):
                    wake = deadline
                if wake is not None and wake <= now:
                    raise TimeoutError(
                        f"wait_for {watch_id} v{version}")
                self._cond.wait(None if wake is None else wake - now)


class BlobClient:
    """One client process (paper §3.1: 'Clients may create blobs and
    read, write and append data to them')."""

    def __init__(
        self,
        vm: VersionManager,
        dht: MetadataDHT,
        pm: ProviderManager,
        wire: Wire,
        name: Optional[str] = None,
        io_workers: int = 0,
        prefetch_pages: int = 0,
        dedup_index: Optional["DedupIndex"] = None,
        dedup: bool = False,
    ) -> None:
        """``prefetch_pages``: how many sibling pages past a read's range
        to pull into the shared page cache on the same batched fetch
        (0 = off).  Sequential readers hide the next read's data-plane
        latency this way; the descriptors come from widening the same
        segment-tree descent the read already pays for.

        ``io_workers`` is accepted for backward compatibility and is a
        no-op: the thread-pool fan-out it once enabled is subsumed by
        the batched write plane (``ProviderManager.store_pages`` groups
        all page stores per endpoint into single round trips and
        pipelines them under a virtual clock), which models the paper's
        'in parallel' loops without real threads.

        ``dedup_index``: the deployment's content-hash page index (see
        :mod:`repro.core.dedup_index`); ``dedup`` sets this client's
        default for the batched write verbs' two-phase handshake (each
        call may override with its own ``dedup=`` keyword)."""
        self.vm = vm
        self.dht = NodeCache(dht)
        self.pm = pm
        self.wire = wire
        self.prefetch_pages = max(0, prefetch_pages)
        self.dedup_index = dedup_index
        self.dedup_default = bool(dedup) and dedup_index is not None
        if name is None:
            with _client_ids_lock:
                name = f"client-{next(_client_ids):04d}"
        self.name = name
        del io_workers  # no-op, see docstring
        self._lineage_cache: Dict[str, Tuple[Tuple[str, int], ...]] = {}
        # per-client request sequence: idempotency keys for assign verbs
        # (a re-driven request after a VM leader failover returns its
        # already-journaled version instead of double-assigning)
        self._req_seq = itertools.count(1)
        # notification inbox, created lazily on first watch (one wire
        # endpoint per client, any number of leases on it)
        self._watch_inbox: Optional[WatchInbox] = None

    def _assign_key(self) -> str:
        return f"{self.name}/{next(self._req_seq)}"

    # ------------------------------------------------------------- small utils
    def _await(self, barrier: float) -> None:
        """Sleep (in virtual time) to a pipelined store barrier.

        Fire-and-forget page stores / metadata puts return their
        completion instants; the writer must not signal
        ``metadata_complete`` before the latest of them — a snapshot
        may never publish before its bytes have arrived.  No-op on the
        wall backend (those transfers block inline).
        """
        clock = self.wire.clock
        if barrier > 0.0 and clock.is_virtual and barrier > clock.now():
            clock.sleep_until(barrier)

    def _owner_fn(self, blob_id: str):
        chain = self._lineage_cache.get(blob_id)
        if chain is None:
            chain = self.vm.lineage(blob_id)
            self._lineage_cache[blob_id] = chain
        return owner_fn_for_lineage(chain)

    # ---------------------------------------------------------------- CREATE
    def create(self, psize: int = 64 * 1024) -> str:
        """CREATE: a new empty blob (snapshot 0, size 0); returns its id."""
        return self.vm.create(psize, client=self.name)

    # ------------------------------------------------------------------ READ
    def read(self, blob_id: str, version: int, offset: int, size: int) -> bytes:
        """Algorithm 1. Fails if ``version`` unpublished or range OOB;
        raises :class:`~repro.core.version_manager.RetiredVersion` for
        snapshots retired by GC.

        The read holds a version-manager *read lease* for its duration:
        GC's sweep barrier drains leases on versions being retired
        before deleting anything, so an in-flight read never races its
        pages away.  Reads of kept versions are never blocked.
        """
        if not self.vm.is_published(blob_id, version):
            raise ReadError(f"{blob_id} v{version} not published")
        total, root_pages = self.vm.enter_read(blob_id, version, client=self.name)
        try:
            if offset < 0 or size < 0 or offset + size > total:
                raise ReadError(
                    f"range ({offset},{size}) out of bounds for v{version} (size {total})"
                )
            if size == 0:
                return b""
            psize = self.vm.psize_of(blob_id)
            p0, p1 = pages_spanned(offset, size, psize)
            # Sibling-page prefetch: widen the descent past p1 so the
            # NEXT sequential read's pages ride this read's batched
            # waves into the shared page cache.  The extra leaves cost
            # keys on the same level-synchronous rounds, not extra
            # latency waves.  Pointless without a cache to land in —
            # the widening is skipped then (no metadata-plane waste).
            p1_want = p1
            pc = self.pm.page_cache
            if self.prefetch_pages > 0 and pc is not None and pc.enabled:
                p1_want = min(p1 + self.prefetch_pages,
                              -(-total // psize))
            pd = st.read_meta(
                self.dht, self._owner_fn(blob_id), version,
                root_pages, p0, p1_want,
                peer=self.name,
            )
            return self._fetch_ranges(pd, offset, size, psize,
                                      prefetch_beyond=p1_want > p1)
        finally:
            self.vm.exit_read(blob_id, version, client=self.name)

    def _fetch_ranges(
        self,
        pd: Sequence[st.PageDescriptor],
        offset: int,
        size: int,
        psize: int,
        prefetch_beyond: bool = False,
    ) -> bytes:
        """Fetch the bytes of ``[offset, offset+size)`` from page replicas.

        All page reads go out as one ``fetch_pages`` call, which groups
        them per provider endpoint (one batched round trip each) instead
        of paying per-page latency — the data-plane mirror of the
        level-batched metadata descent.

        When the shared page cache is enabled, requests are normalized
        to *whole pages* and sliced locally, so the cache is
        page-granular: overlapping sub-range reads of one page share a
        single entry (no budget double-charging), and a prefetched page
        serves any later read of it — aligned or not.  The standard
        page-cache tradeoff applies: a small cold read moves its whole
        page over the wire once (psize bytes) to make every later read
        of that page free — workloads of tiny *non-repeating* random
        reads should run with ``page_cache_bytes=0``, which restores
        exact sub-range fetches (no extra bytes on the wire).
        With ``prefetch_beyond``, descriptors past the requested range
        (widened descent) become best-effort whole-page prefetches.
        """
        pc = self.pm.page_cache
        whole_pages = prefetch_beyond or (pc is not None and pc.enabled)
        buf = bytearray(size)
        requests: List[Tuple[Sequence[str], str, int, int]] = []
        prefetch: List[Tuple[Sequence[str], str, int, int]] = []
        spans: List[Tuple[int, int, int]] = []  # (lo, hi, chunk offset)
        for d in pd:
            page_start = d.page_index * psize
            lo = max(offset, page_start)
            hi = min(offset + size, page_start + d.length)
            if hi <= lo:
                if prefetch_beyond:
                    prefetch.append((d.providers, d.page_id, 0, d.length))
                continue
            if whole_pages:
                requests.append((d.providers, d.page_id, 0, d.length))
                spans.append((lo, hi, lo - page_start))
            else:
                requests.append((d.providers, d.page_id,
                                 lo - page_start, hi - lo))
                spans.append((lo, hi, 0))
        chunks = self.pm.fetch_pages(requests, peer=self.name,
                                     prefetch=prefetch)
        for (lo, hi, skip), chunk in zip(spans, chunks):
            buf[lo - offset : hi - offset] = chunk[skip : skip + (hi - lo)]
        return bytes(buf)

    # ------------------------------------------------------------- WRITE/APPEND
    def write(self, blob_id: str, buf: bytes, offset: int) -> int:
        """Algorithm 2 (+ unaligned boundary handling). Returns vw."""
        return self._update(blob_id, buf, offset=offset)

    def append(self, blob_id: str, buf: bytes) -> int:
        """APPEND: offset is assigned by the version manager."""
        return self._update(blob_id, buf, offset=None)

    def _update(self, blob_id: str, buf: bytes, offset: Optional[int]) -> int:
        """The four-phase pipelined write path (see docs/write-path.md).

        Phase 1 stores every fully covered page *before* version
        assignment (no synchronization; under a virtual clock the
        per-endpoint store batches go out fire-and-forget, so they
        overlap everything that follows).  Phase 2 is the version
        manager's short critical section.  Phase 3 stores boundary
        pages (the only phase that can wait on another writer).  Phase
        4 prefetches the whole border set in one level-batched cohort,
        weaves the metadata (Algorithm 4), then — after sleeping to the
        store barrier — publishes.
        """
        if len(buf) == 0:
            raise ValueError("empty update")
        psize = self.vm.psize_of(blob_id)
        size = len(buf)
        stored: Dict[int, Tuple[str, Tuple[str, ...], int]] = {}  # rel_page -> (pid, provs, length)

        # -- phase 1: store what we can BEFORE version assignment (no sync) --
        # WRITE knows its offset: every page fully covered by the range can
        # go out now.  APPEND optimistically assumes a page-aligned offset
        # (always true in the paper); if assignment reveals an unaligned
        # offset we re-stripe below.
        presumed_offset = offset if offset is not None else 0  # append: relative
        p0_pre, _ = pages_spanned(presumed_offset, size, psize)
        barrier = self._store_full_pages(buf, presumed_offset, psize,
                                         p0_pre, stored, blob_id=blob_id)
        pd_wire = tuple(
            (pid, rel, provs, ln) for rel, (pid, provs, ln) in sorted(stored.items())
        )

        # -- phase 2: version assignment (the only global serialization) --
        info = self.vm.assign_version(
            blob_id, offset, size, client=self.name, pd=pd_wire,
            key=self._assign_key(),
        )
        vw, off = info.version, info.offset

        if offset is None and off % psize != 0:
            # Optimistic append striping assumed an aligned offset (always
            # true in the paper's aligned world); restripe at the real one.
            # The optimistically stored pages become orphans (reclaimed by
            # the GC inventory pass).
            stored.clear()
            barrier = max(barrier, self._store_full_pages(
                buf, off, psize, info.p0, stored, blob_id=blob_id))

        # -- phase 3: boundary pages (merge with snapshot vw-1 content) --
        stored_boundary, b3 = self._store_boundary_pages(
            blob_id, buf, off, size, psize, info, stored
        )
        barrier = max(barrier, b3)

        pd_final = tuple(
            (pid, rel, provs, ln) for rel, (pid, provs, ln) in sorted(stored.items())
        )
        if stored_boundary or pd_final != pd_wire:
            self.vm.register_pd(blob_id, vw, pd_final, client=self.name)

        # -- phase 4: weave metadata (Algorithm 4), then publish --
        self._build_and_complete(blob_id, info, pd_final, store_barrier=barrier)
        return vw

    # ------------------------------------------------------- batched updates
    def append_many(self, blob_id: str, bufs: Sequence[bytes],
                    *,
                    digests: Optional[Sequence[Sequence[Tuple[int, int]]]] = None,
                    dedup: Optional[bool] = None) -> List[int]:
        """APPEND a burst of buffers in one batched write-plane pass.

        Semantically identical to ``[self.append(blob_id, b) for b in
        bufs]`` — one snapshot version per buffer, published in order —
        but the whole burst pays ONE ``assign_versions_many`` and ONE
        ``metadata_complete_many`` control round trip, and every
        buffer's page stores share the same per-endpoint batched waves.
        Intra-burst boundary merges (unaligned appends) are resolved
        from the burst's own buffers locally; only the first buffer can
        ever wait on a pre-burst writer.  Returns the assigned versions
        in buffer order.

        ``dedup``/``digests``: see :meth:`write_many`.
        """
        return self._update_many(blob_id, [(buf, None) for buf in bufs],
                                 digests=digests, dedup=dedup)

    def write_many(self, blob_id: str,
                   items: Sequence[Tuple[bytes, int]],
                   *,
                   digests: Optional[Sequence[Sequence[Tuple[int, int]]]] = None,
                   dedup: Optional[bool] = None) -> List[int]:
        """WRITE a batch of ``(buf, offset)`` updates in one pass.

        One snapshot version per item, assigned and published in list
        order, with the version-manager round trips amortized across
        the batch exactly like :meth:`append_many` (the checkpoint
        layer uses this for its dirty-page runs).  Offsets are
        validated against the batch's own running size — item *k* may
        extend the blob and item *k+1* may write into the extension.

        ``dedup`` (default: the client's ``dedup`` constructor flag)
        enables the two-phase dedup handshake on the burst's full
        pages: digests go to the content-hash index in one batched
        lookup, matched pages reuse the indexed descriptor and ship no
        bytes.  ``dedup=False`` is byte-for-byte the plain write plane.
        ``digests`` optionally supplies the fingerprints — item *k*'s
        entry lists ``(d0, d1)`` per *fully covered* page in page
        order, as computed by the ``page_digest`` kernel (the
        checkpoint layer passes its delta-scan digests through so
        nothing is hashed twice); without it the host twin
        ``hostdigest.host_page_digest`` fills in.
        """
        return self._update_many(blob_id, [(buf, off) for buf, off in items],
                                 digests=digests, dedup=dedup)

    def _update_many(self, blob_id: str,
                     items: Sequence[Tuple[bytes, Optional[int]]],
                     digests: Optional[Sequence[Sequence[Tuple[int, int]]]] = None,
                     dedup: Optional[bool] = None) -> List[int]:
        items = list(items)
        if not items:
            return []
        if any(len(buf) == 0 for buf, _off in items):
            raise ValueError("empty update")
        is_append = items[0][1] is None
        if any((off is None) != is_append for _buf, off in items):
            raise ValueError("mixed append/write batch (split it)")
        psize = self.vm.psize_of(blob_id)
        use_dedup = (self.dedup_default if dedup is None else bool(dedup)) \
            and self.dedup_index is not None
        if digests is not None and len(digests) != len(items):
            raise ValueError("digests must align with items")
        # Page-ids this burst acquired from / registered with the dedup
        # index; released if a re-stripe abandons the optimistic pages.
        acquired: List[str] = []
        stored: List[Dict[int, Tuple[str, Tuple[str, ...], int]]] = [
            {} for _ in items
        ]

        # -- phase 1: optimistic pre-store of every fully covered page --
        # Appends presume a page-aligned burst base (cumulative offsets
        # from 0); writes know their offsets exactly.
        cursor = 0
        plans: List[Tuple[int, List[Tuple[int, bytes]]]] = []
        for idx, (buf, off) in enumerate(items):
            p_off = cursor if is_append else off
            if is_append:
                cursor += len(buf)
            p0_pre, _ = pages_spanned(p_off, len(buf), psize)
            plans.append((idx, self._plan_full_pages(buf, p_off, psize, p0_pre)))
        barrier = self._store_planned(
            plans, stored, psize=psize, digests=digests,
            use_dedup=use_dedup, acquired=acquired, blob_id=blob_id)
        pd_wire = [
            tuple((pid, rel, provs, ln)
                  for rel, (pid, provs, ln) in sorted(s.items()))
            for s in stored
        ]

        # -- phase 2: ONE batched version assignment for the burst --
        infos = self.vm.assign_versions_many(
            [(blob_id, None if is_append else off, len(buf), pd_wire[idx])
             for idx, (buf, off) in enumerate(items)],
            client=self.name,
            keys=[self._assign_key() for _ in items],
        )

        if is_append and infos[0].offset % psize != 0:
            # Phase-2 re-stripe: the burst's presumed page-aligned base
            # was wrong — restripe every buffer at its real offset (the
            # page *phase* of all presumed offsets was off by the same
            # amount, so the whole burst restripes together).  Abandoned
            # optimistic pages become orphans (reclaimed by the GC
            # inventory pass) and their dedup references are dropped;
            # the re-striped pages carry new content phases, so any
            # caller-supplied digests no longer apply (the host twin
            # re-fingerprints).
            if use_dedup and acquired:
                self.dedup_index.unreference(acquired, peer=self.name)
                acquired = []
            plans = []
            for idx, (buf, _off) in enumerate(items):
                stored[idx].clear()
                plans.append((idx, self._plan_full_pages(
                    buf, infos[idx].offset, psize, infos[idx].p0)))
            barrier = max(barrier, self._store_planned(
                plans, stored, psize=psize, use_dedup=use_dedup,
                acquired=acquired, blob_id=blob_id))

        # -- phase 3: boundary pages, intra-batch merges resolved locally --
        prebatch_size = infos[0].prev_size
        prebatch_version = infos[0].version - 1

        def make_old_read(idx: int) -> Callable[[int, int], bytes]:
            def old_read(a: int, b: int) -> bytes:
                # Content of snapshot v_{idx}-1 over [a, b): pre-batch
                # bytes below the batch's starting size (the only remote
                # part — and the only wait, on the pre-batch writer),
                # overlaid with every earlier buffer in the batch (their
                # versions are exactly the snapshots between the batch
                # base and v_idx).
                out = bytearray(b - a)
                lo_remote = min(b, prebatch_size)
                if a < lo_remote and prebatch_version > 0:
                    self.vm.wait_metadata(blob_id, prebatch_version)
                    out[0:lo_remote - a] = self._read_unpublished(
                        blob_id, prebatch_version, a, lo_remote - a,
                        infos[idx])
                for j in range(idx):
                    jbuf = items[j][0]
                    joff = infos[j].offset
                    lo, hi = max(a, joff), min(b, joff + len(jbuf))
                    if hi > lo:
                        out[lo - a:hi - a] = jbuf[lo - joff:hi - joff]
                return bytes(out)
            return old_read

        versions: List[int] = []
        for idx, (buf, _off) in enumerate(items):
            info = infos[idx]
            stored_boundary, b3 = self._store_boundary_pages(
                blob_id, buf, info.offset, len(buf), psize, info,
                stored[idx], old_read=make_old_read(idx),
            )
            barrier = max(barrier, b3)
            pd_final = tuple(
                (pid, rel, provs, ln)
                for rel, (pid, provs, ln) in sorted(stored[idx].items())
            )
            if stored_boundary or pd_final != pd_wire[idx]:
                self.vm.register_pd(blob_id, info.version, pd_final,
                                    client=self.name)

            # -- phase 4a: weave each update's metadata (border ranges of
            # concurrent batch members resolve locally from AssignInfo) --
            self._build_and_complete(blob_id, info, pd_final, complete=False)
            versions.append(info.version)

        # -- phase 4b: store barrier, then ONE batched completion --
        self._await(barrier)
        self.vm.metadata_complete_many(
            [(blob_id, v) for v in versions], client=self.name)
        return versions

    # ------------------------------------------------------- update internals
    def _plan_full_pages(
        self, buf: bytes, off: int, psize: int, p0: int,
    ) -> List[Tuple[int, bytes]]:
        """``(rel_page, payload)`` for every page fully covered by the
        byte range ``[off, off+len(buf))`` (boundary pages are phase 3's
        job).  ``p0`` is the update's first touched page."""
        full_lo = -(-off // psize)                 # first fully covered page
        full_hi = (off + len(buf)) // psize        # one past last fully covered
        return [
            (k - p0, buf[k * psize - off:(k + 1) * psize - off])
            for k in range(full_lo, full_hi)
        ]

    def _store_planned(
        self,
        plans: Sequence[Tuple[int, List[Tuple[int, bytes]]]],
        stored: List[Dict[int, Tuple[str, Tuple[str, ...], int]]],
        *,
        psize: Optional[int] = None,
        digests: Optional[Sequence[Sequence[Tuple[int, int]]]] = None,
        use_dedup: bool = False,
        acquired: Optional[List[str]] = None,
        blob_id: Optional[str] = None,
    ) -> float:
        """Store many updates' planned pages in one grouped, pipelined
        ``store_pages`` call; returns the store barrier instant.

        With ``use_dedup`` the two-phase handshake runs first: one
        batched ``lookup_and_acquire`` over every planned page's
        fingerprint (caller-supplied ``digests`` where given, host
        digest otherwise — planned pages are always full ``psize``
        pages, so the two are interchangeable); hits reuse the indexed
        descriptor and ship no bytes, misses store normally and are
        then registered fire-and-forget.  Acquired/registered page-ids
        are appended to ``acquired`` so a re-stripe can drop them.
        """
        flat = [(idx, rel, payload)
                for idx, plan in plans for rel, payload in plan]
        if not flat:
            return 0.0

        if use_dedup:
            wants: List[Tuple[int, int, int]] = []
            for idx, plan in plans:
                dlist = digests[idx] if digests is not None else None
                if dlist is not None and len(dlist) != len(plan):
                    raise ValueError(
                        f"item {idx}: {len(dlist)} digests for "
                        f"{len(plan)} fully covered pages")
                for k, (_rel, payload) in enumerate(plan):
                    if dlist is not None:
                        d0, d1 = int(dlist[k][0]), int(dlist[k][1])
                    else:
                        d0, d1 = host_page_digest(payload, psize)
                    wants.append((d0, d1, len(payload)))
            matches = self.dedup_index.lookup_and_acquire(
                wants, peer=self.name)
            misses: List[int] = []
            for j, ((idx, rel, _payload), hit) in enumerate(zip(flat, matches)):
                if hit is None:
                    misses.append(j)
                else:
                    pid, provs, length = hit
                    stored[idx][rel] = (pid, tuple(provs), length)
                    if acquired is not None:
                        acquired.append(pid)
            if not misses:
                return 0.0
            keep_keys = [wants[j] for j in misses]
            flat = [flat[j] for j in misses]
        else:
            keep_keys = None

        # Per-blob placement: the policy picks the provider-group shape
        # and tags new page ids so their layout is self-describing
        # ("pg-...-ec6+2" pages fan into shards on the read path).
        policy = self.pm.policy_for(blob_id)
        page_ids = [fresh_page_id(tag=policy.tag) for _ in flat]
        groups = self.pm.allocate(len(flat), blob_id=blob_id,
                                  page_ids=page_ids)
        puts = [(groups[i], page_ids[i], payload)
                for i, (_idx, _rel, payload) in enumerate(flat)]
        locations, done_at = self.pm.store_pages(puts, peer=self.name)
        for (idx, rel, payload), (_g, pid, _p), provs in zip(flat, puts,
                                                             locations):
            stored[idx][rel] = (pid, tuple(provs), len(payload))
        if keep_keys is not None:
            reg = [(key, pid, tuple(provs), len(payload))
                   for key, (_g, pid, payload), provs
                   in zip(keep_keys, puts, locations)]
            self.dedup_index.register(reg, peer=self.name)
            if acquired is not None:
                acquired.extend(pid for _key, pid, _provs, _ln in reg)
        return done_at

    def _store_full_pages(
        self,
        buf: bytes,
        off: int,
        psize: int,
        p0: int,
        stored: Dict[int, Tuple[str, Tuple[str, ...], int]],
        blob_id: Optional[str] = None,
    ) -> float:
        """Store every fully covered page of one update (phase 1);
        returns the pipelined store barrier (0.0 on the wall backend)."""
        return self._store_planned(
            [(0, self._plan_full_pages(buf, off, psize, p0))], [stored],
            blob_id=blob_id)

    def _store_boundary_pages(
        self,
        blob_id: str,
        buf: bytes,
        off: int,
        size: int,
        psize: int,
        info: AssignInfo,
        stored: Dict[int, Tuple[str, Tuple[str, ...], int]],
        old_read: Optional[Callable[[int, int], bytes]] = None,
    ) -> Tuple[bool, float]:
        """Create merged pages for partially covered boundary pages.

        Returns ``(stored_any, barrier)``.  ``old_read(a, b)`` supplies
        the previous snapshot's bytes over ``[a, b)``; the default reads
        snapshot ``vw-1`` through the DHT after ``wait_metadata`` — the
        "only boundary pages ever wait on vw-1" contract: this is the
        single point in the write path that can block on another
        writer, and it blocks only when the boundary page actually
        needs bytes the update does not overwrite.  Batched updates
        pass an ``old_read`` that serves intra-batch ranges from the
        batch's own buffers (no wait at all).
        """
        vw = info.version
        end = off + size
        boundary: List[int] = []
        if off % psize != 0:
            boundary.append(off // psize)
        if end % psize != 0 and end // psize not in boundary:
            boundary.append(end // psize)
        if not boundary:
            return False, 0.0

        old_size = info.prev_size
        if old_read is None:
            def old_read(a: int, b: int) -> bytes:
                # merging needs snapshot vw-1 content: the one wait
                if vw - 1 > 0:
                    self.vm.wait_metadata(blob_id, vw - 1)
                    return self._read_unpublished(blob_id, vw - 1, a, b - a,
                                                  info)
                return b"\0" * (b - a)

        puts: List[Tuple[Sequence, str, bytes]] = []
        metas: List[Tuple[int, int]] = []
        policy = self.pm.policy_for(blob_id)
        for k in boundary:
            page_start = k * psize
            page_end_new = min((k + 1) * psize, info.new_size)
            length = page_end_new - page_start
            page = bytearray(length)
            # old content of this page from snapshot vw-1, fetched only
            # when some byte of it survives the overlay (a boundary page
            # whose old bytes are all overwritten never waits)
            old_hi = min(old_size, page_end_new)
            needs_old = (page_start < off and old_size > page_start) or \
                        (end < old_hi)
            if needs_old and old_hi > page_start:
                old = old_read(page_start, old_hi)
                page[0:len(old)] = old
            # overlay the new bytes
            lo = max(off, page_start)
            hi = min(end, page_end_new)
            page[lo - page_start:hi - page_start] = buf[lo - off:hi - off]
            bpid = fresh_page_id(tag=policy.tag)
            puts.append((self.pm.allocate(1, blob_id=blob_id,
                                          page_ids=[bpid])[0],
                         bpid, bytes(page)))
            metas.append((k, length))
        locations, done_at = self.pm.store_pages(puts, peer=self.name)
        for (_g, pid, _payload), provs, (k, length) in zip(puts, locations,
                                                           metas):
            stored[k - info.p0] = (pid, tuple(provs), length)
        return True, done_at

    def _read_unpublished(
        self, blob_id: str, version: int, offset: int, size: int, info: AssignInfo
    ) -> bytes:
        """Read from a snapshot whose metadata is complete but possibly
        not yet published (boundary merge against vw-1)."""
        psize = self.vm.psize_of(blob_id)
        rec = self.vm.update_log(blob_id, version)
        p0, p1 = pages_spanned(offset, size, psize)
        pd = st.read_meta(
            self.dht, self._owner_fn(blob_id), version, rec.root_pages, p0, p1,
            peer=self.name,
        )
        return self._fetch_ranges(pd, offset, size, psize)

    def _build_and_complete(self, blob_id: str, info: AssignInfo, pd_final,
                            store_barrier: float = 0.0,
                            complete: bool = True) -> None:
        """Phase 4: prefetch the border set, weave, publish.

        The :class:`AssignInfo` carries the full border context, so the
        entire border set (``st.border_ranges``) is resolved upfront as
        ONE level-batched ``resolve_many`` cohort — BUILD_META's
        per-level lookups then hit the resolver cache and the weave
        itself issues only its ``put_many`` node writes.  The writer
        sleeps to ``store_barrier`` (pipelined page stores) before
        signalling completion; with ``complete=False`` the caller
        batches the completion itself (``metadata_complete_many``).
        """
        leaves = [
            st.PageDescriptor(info.p0 + rel, pid, tuple(provs), ln)
            for (pid, rel, provs, ln) in pd_final
        ]
        border = st.BorderResolver(
            self.dht, self._owner_fn(blob_id), info.recent_updates,
            info.vp, info.vp_root_pages, peer=self.name,
        )
        border.prefetch(st.border_ranges(
            UpdateExtent(info.p0, info.p1, info.root_pages)))
        st.build_meta(
            self.dht, self._owner_fn(blob_id), info.version, info.root_pages,
            leaves, border, peer=self.name,
        )
        self._await(store_barrier)
        if complete:
            self.vm.metadata_complete(blob_id, info.version, client=self.name)

    # ------------------------------------------------- recovery (beyond paper)
    def rebuild_metadata(self, blob_id: str, version: int) -> None:
        """Replay BUILD_META for a writer that died after assignment.

        Page descriptors come from the version manager's WAL; the
        construction is deterministic, so replaying alongside a slow (not
        actually dead) writer is safe — both produce identical nodes and
        the DHT treats identical re-puts as replica re-sends.
        """
        info = self.vm.assign_info_for_recovery(blob_id, version)
        rec = self.vm.update_log(blob_id, version)
        if not rec.pd:
            raise RuntimeError(
                f"cannot recover {blob_id} v{version}: no page descriptors journaled"
            )
        self._build_and_complete(blob_id, info, rec.pd)

    # ------------------------------------------------------------- passthrough
    def get_recent(self, blob_id: str) -> int:
        """GET_RECENT: a recently published, still-live snapshot version
        (0 for an empty blob; retired versions are never handed out)."""
        return self.vm.get_recent(blob_id, client=self.name)

    def get_size(self, blob_id: str, version: int) -> int:
        """GET_SIZE of a published snapshot; raises
        :class:`~repro.core.version_manager.VersionUnpublished` /
        :class:`~repro.core.version_manager.RetiredVersion` otherwise."""
        return self.vm.get_size(blob_id, version, client=self.name)

    def sync(self, blob_id: str, version: int, timeout: Optional[float] = None) -> None:
        """Block (through the deployment clock) until ``version`` is
        published — read-your-writes for a writer that kept its vw."""
        self.vm.sync(blob_id, version, timeout=timeout, client=self.name)

    def branch(self, blob_id: str, version: int) -> str:
        """BRANCH: fork a new blob whose snapshots ``<= version`` are
        shared with the parent (zero copying — the paper's cheap
        branching); returns the new blob id."""
        bid = self.vm.branch(blob_id, version, client=self.name)
        self._lineage_cache.pop(bid, None)
        return bid

    # ----------------------------------------------------- GC: pins, retention
    def pin(self, blob_id: str, version: int, ttl: Optional[float] = None) -> str:
        """Pin a published snapshot against GC; returns the lease id.

        A pinned version is kept (and fully readable) across GC rounds
        until :meth:`unpin` or until the lease's clock-based ``ttl``
        expires — the checkpoint layer pins what it restores from.
        """
        return self.vm.pin(blob_id, version, client=self.name, ttl=ttl)

    def unpin(self, lease_id: str) -> None:
        """Release a pin lease taken with :meth:`pin` (idempotent)."""
        self.vm.unpin(lease_id, client=self.name)

    def set_retention(self, blob_id: str, keep_last: int) -> None:
        """Keep the newest ``keep_last`` published snapshots at GC time
        (plus pins, branch roots and in-flight anchors); 0 = keep all."""
        self.vm.set_retention(blob_id, keep_last, client=self.name)

    # -------------------------------------------- subscriptions: watch/notify
    @property
    def inbox(self) -> WatchInbox:
        """This client's notification inbox (created and registered
        with the version manager on first use)."""
        if self._watch_inbox is None:
            self._watch_inbox = WatchInbox(self.wire, self.name)
            self.vm.register_inbox(self._watch_inbox)
        return self._watch_inbox

    def watch(self, blob_id: str, from_version: int = 0,
              ttl: Optional[float] = None) -> str:
        """Lease a push subscription on ``blob_id``: publications past
        ``from_version`` are delivered to this client's :attr:`inbox`
        (already-published versions catch up immediately).  ``ttl``
        arms a renewable clock-based expiry (``None`` = until
        :meth:`unwatch`).  Returns the lease id — hand it to
        :meth:`poll_notifications` / ``inbox.wait_for``."""
        inbox = self.inbox
        wid = self.vm.watch(blob_id, from_version, endpoint=inbox.endpoint,
                            client=self.name, ttl=ttl)
        inbox.track(wid, from_version)
        return wid

    def unwatch(self, watch_id: str) -> None:
        """Cancel a watch lease (idempotent); nothing is delivered to
        it afterward."""
        self.vm.unwatch(watch_id, client=self.name)
        if self._watch_inbox is not None:
            self._watch_inbox.forget(watch_id)

    def renew_watch(self, watch_id: str, ttl: Optional[float]) -> None:
        """Extend a watch lease's expiry (``None`` = make permanent)."""
        self.vm.renew_watch(watch_id, ttl, client=self.name)

    def poll_notifications(self, watch_id: str) -> List[int]:
        """Drain the lease's arrived version notifications (ascending,
        monotone across calls, no duplicates)."""
        return self.inbox.poll(watch_id)

    def wait_for_version(self, blob_id: str, version: int,
                         timeout: Optional[float] = None) -> int:
        """Block until ``blob_id``'s snapshot ``version`` is published,
        by subscription instead of SYNC polling: takes a temporary
        watch from ``version - 1``, waits for the push, and releases
        the lease.  Returns ``version``; raises ``TimeoutError`` on the
        deadline."""
        wid = self.watch(blob_id, from_version=max(0, version - 1))
        try:
            self.inbox.wait_for(wid, version, timeout=timeout)
        finally:
            self.unwatch(wid)
        return version
