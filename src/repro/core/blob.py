"""Blob client: the paper's user-facing primitives.

CREATE / READ (Alg 1) / WRITE (Alg 2) / APPEND / GET_RECENT / GET_SIZE /
SYNC / BRANCH, against a deployment of {version manager, metadata DHT,
provider manager}.

Concurrency properties (paper §4.3) preserved:

* data pages are written with **no synchronization** between clients —
  every update creates new pages;
* metadata is built without locking: border nodes of concurrent
  unpublished updates are resolved from the version-manager-supplied
  registry info, everything else by descending a published tree;
* the only serialization points are the version-manager critical
  section (short) and same-endpoint contention.

Unaligned ranges (the paper's "slightly more complex" §3 case) are fully
supported: a boundary page whose range is partially overwritten becomes
a *new* page whose content merges the previous snapshot's bytes with the
update's bytes.  Only this case ever waits on another writer (the
previous version's metadata must be complete to read the old content).
"""

from __future__ import annotations

import itertools
import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import segment_tree as st
from repro.core.cache import NodeCache
from repro.core.dht import MetadataDHT
from repro.core.pages import fresh_page_id, pages_spanned
from repro.core.provider import ProviderManager
from repro.core.transport import Wire
from repro.core.version_manager import (
    AssignInfo,
    VersionManager,
    owner_fn_for_lineage,
)

# Backwards-compatible alias: the node cache grew up and moved to
# repro.core.cache (shared with the page cache and the accounting
# layer); existing imports keep working.
_NodeCache = NodeCache

_client_ids = itertools.count()
_client_ids_lock = threading.Lock()


class ReadError(RuntimeError):
    """A READ failed validation: unpublished version or out-of-bounds
    range.  (Retired snapshots raise the typed
    :class:`~repro.core.version_manager.RetiredVersion` instead.)"""


class BlobClient:
    """One client process (paper §3.1: 'Clients may create blobs and
    read, write and append data to them')."""

    def __init__(
        self,
        vm: VersionManager,
        dht: MetadataDHT,
        pm: ProviderManager,
        wire: Wire,
        name: Optional[str] = None,
        io_workers: int = 0,
        prefetch_pages: int = 0,
    ) -> None:
        """``prefetch_pages``: how many sibling pages past a read's range
        to pull into the shared page cache on the same batched fetch
        (0 = off).  Sequential readers hide the next read's data-plane
        latency this way; the descriptors come from widening the same
        segment-tree descent the read already pays for."""
        self.vm = vm
        self.dht = NodeCache(dht)
        self.pm = pm
        self.wire = wire
        self.prefetch_pages = max(0, prefetch_pages)
        if name is None:
            with _client_ids_lock:
                name = f"client-{next(_client_ids):04d}"
        self.name = name
        self._pool = ThreadPoolExecutor(max_workers=io_workers) if io_workers > 0 else None
        self._lineage_cache: Dict[str, Tuple[Tuple[str, int], ...]] = {}

    # ------------------------------------------------------------- small utils
    def _parallel(self, fn, items: Sequence) -> List:
        """'for all ... in parallel do' loops of Algorithms 1 and 2.

        Under a virtual clock the loop is always serial: pool threads
        are not simulated tasks, and the batched wire paths already
        collapse per-item latency — the simulation models parallel
        fan-out through `transfer_batch`, not real threads.
        """
        if self._pool is None or len(items) <= 1 or self.wire.clock.is_virtual:
            return [fn(x) for x in items]
        return list(self._pool.map(fn, items))

    def _owner_fn(self, blob_id: str):
        chain = self._lineage_cache.get(blob_id)
        if chain is None:
            chain = self.vm.lineage(blob_id)
            self._lineage_cache[blob_id] = chain
        return owner_fn_for_lineage(chain)

    # ---------------------------------------------------------------- CREATE
    def create(self, psize: int = 64 * 1024) -> str:
        """CREATE: a new empty blob (snapshot 0, size 0); returns its id."""
        return self.vm.create(psize, client=self.name)

    # ------------------------------------------------------------------ READ
    def read(self, blob_id: str, version: int, offset: int, size: int) -> bytes:
        """Algorithm 1. Fails if ``version`` unpublished or range OOB;
        raises :class:`~repro.core.version_manager.RetiredVersion` for
        snapshots retired by GC.

        The read holds a version-manager *read lease* for its duration:
        GC's sweep barrier drains leases on versions being retired
        before deleting anything, so an in-flight read never races its
        pages away.  Reads of kept versions are never blocked.
        """
        if not self.vm.is_published(blob_id, version):
            raise ReadError(f"{blob_id} v{version} not published")
        total, root_pages = self.vm.enter_read(blob_id, version, client=self.name)
        try:
            if offset < 0 or size < 0 or offset + size > total:
                raise ReadError(
                    f"range ({offset},{size}) out of bounds for v{version} (size {total})"
                )
            if size == 0:
                return b""
            psize = self.vm.psize_of(blob_id)
            p0, p1 = pages_spanned(offset, size, psize)
            # Sibling-page prefetch: widen the descent past p1 so the
            # NEXT sequential read's pages ride this read's batched
            # waves into the shared page cache.  The extra leaves cost
            # keys on the same level-synchronous rounds, not extra
            # latency waves.  Pointless without a cache to land in —
            # the widening is skipped then (no metadata-plane waste).
            p1_want = p1
            pc = self.pm.page_cache
            if self.prefetch_pages > 0 and pc is not None and pc.enabled:
                p1_want = min(p1 + self.prefetch_pages,
                              -(-total // psize))
            pd = st.read_meta(
                self.dht, self._owner_fn(blob_id), version,
                root_pages, p0, p1_want,
                peer=self.name,
            )
            return self._fetch_ranges(pd, offset, size, psize,
                                      prefetch_beyond=p1_want > p1)
        finally:
            self.vm.exit_read(blob_id, version, client=self.name)

    def _fetch_ranges(
        self,
        pd: Sequence[st.PageDescriptor],
        offset: int,
        size: int,
        psize: int,
        prefetch_beyond: bool = False,
    ) -> bytes:
        """Fetch the bytes of ``[offset, offset+size)`` from page replicas.

        All page reads go out as one ``fetch_pages`` call, which groups
        them per provider endpoint (one batched round trip each) instead
        of paying per-page latency — the data-plane mirror of the
        level-batched metadata descent.

        When the shared page cache is enabled, requests are normalized
        to *whole pages* and sliced locally, so the cache is
        page-granular: overlapping sub-range reads of one page share a
        single entry (no budget double-charging), and a prefetched page
        serves any later read of it — aligned or not.  The standard
        page-cache tradeoff applies: a small cold read moves its whole
        page over the wire once (psize bytes) to make every later read
        of that page free — workloads of tiny *non-repeating* random
        reads should run with ``page_cache_bytes=0``, which restores
        exact sub-range fetches (no extra bytes on the wire).
        With ``prefetch_beyond``, descriptors past the requested range
        (widened descent) become best-effort whole-page prefetches.
        """
        pc = self.pm.page_cache
        whole_pages = prefetch_beyond or (pc is not None and pc.enabled)
        buf = bytearray(size)
        requests: List[Tuple[Sequence[str], str, int, int]] = []
        prefetch: List[Tuple[Sequence[str], str, int, int]] = []
        spans: List[Tuple[int, int, int]] = []  # (lo, hi, chunk offset)
        for d in pd:
            page_start = d.page_index * psize
            lo = max(offset, page_start)
            hi = min(offset + size, page_start + d.length)
            if hi <= lo:
                if prefetch_beyond:
                    prefetch.append((d.providers, d.page_id, 0, d.length))
                continue
            if whole_pages:
                requests.append((d.providers, d.page_id, 0, d.length))
                spans.append((lo, hi, lo - page_start))
            else:
                requests.append((d.providers, d.page_id,
                                 lo - page_start, hi - lo))
                spans.append((lo, hi, 0))
        chunks = self.pm.fetch_pages(requests, peer=self.name,
                                     prefetch=prefetch)
        for (lo, hi, skip), chunk in zip(spans, chunks):
            buf[lo - offset : hi - offset] = chunk[skip : skip + (hi - lo)]
        return bytes(buf)

    # ------------------------------------------------------------- WRITE/APPEND
    def write(self, blob_id: str, buf: bytes, offset: int) -> int:
        """Algorithm 2 (+ unaligned boundary handling). Returns vw."""
        return self._update(blob_id, buf, offset=offset)

    def append(self, blob_id: str, buf: bytes) -> int:
        """APPEND: offset is assigned by the version manager."""
        return self._update(blob_id, buf, offset=None)

    def _update(self, blob_id: str, buf: bytes, offset: Optional[int]) -> int:
        if len(buf) == 0:
            raise ValueError("empty update")
        psize = self.vm.psize_of(blob_id)
        size = len(buf)
        stored: Dict[int, Tuple[str, Tuple[str, ...], int]] = {}  # rel_page -> (pid, provs, length)

        # -- phase 1: store what we can BEFORE version assignment (no sync) --
        # WRITE knows its offset: every page fully covered by the range can
        # go out now.  APPEND optimistically assumes a page-aligned offset
        # (always true in the paper); if assignment reveals an unaligned
        # offset we re-stripe below.
        presumed_offset = offset if offset is not None else 0  # append: relative
        p0_pre, _ = pages_spanned(presumed_offset, size, psize)
        full_lo = -(-presumed_offset // psize)                      # first fully covered page
        full_hi = (presumed_offset + size) // psize                 # one past last fully covered
        self._store_full_pages(
            buf, presumed_offset, psize, range(full_lo, full_hi), p0_pre, stored
        )
        pd_wire = tuple(
            (pid, rel, provs, ln) for rel, (pid, provs, ln) in sorted(stored.items())
        )

        # -- phase 2: version assignment (the only global serialization) --
        info = self.vm.assign_version(
            blob_id, offset, size, client=self.name, pd=pd_wire
        )
        vw, off = info.version, info.offset

        if offset is None and off % psize != 0:
            # Optimistic append striping assumed an aligned offset (always
            # true in the paper's aligned world); restripe at the real one.
            stored.clear()
            full_lo = -(-off // psize)
            full_hi = (off + size) // psize
            self._store_full_pages(buf, off, psize, range(full_lo, full_hi), info.p0, stored)

        # -- phase 3: boundary pages (merge with snapshot vw-1 content) --
        stored_boundary = self._store_boundary_pages(
            blob_id, buf, off, size, psize, info, stored
        )

        pd_final = tuple(
            (pid, rel, provs, ln) for rel, (pid, provs, ln) in sorted(stored.items())
        )
        if stored_boundary or pd_final != pd_wire:
            self.vm.register_pd(blob_id, vw, pd_final, client=self.name)

        # -- phase 4: weave metadata (Algorithm 4), then publish --
        self._build_and_complete(blob_id, info, pd_final)
        return vw

    # ------------------------------------------------------- update internals
    def _store_full_pages(
        self,
        buf: bytes,
        off: int,
        psize: int,
        page_range,
        p0: int,
        stored: Dict[int, Tuple[str, Tuple[str, ...], int]],
    ) -> None:
        pages = list(page_range)
        if not pages:
            return
        groups = self.pm.allocate(len(pages))

        def put(i_k):
            i, k = i_k
            payload = buf[k * psize - off : (k + 1) * psize - off]
            pid = fresh_page_id()
            provs = self.pm.store_page(groups[i], pid, payload, peer=self.name)
            stored[k - p0] = (pid, tuple(provs), len(payload))

        self._parallel(put, list(enumerate(pages)))

    def _store_boundary_pages(
        self,
        blob_id: str,
        buf: bytes,
        off: int,
        size: int,
        psize: int,
        info: AssignInfo,
        stored: Dict[int, Tuple[str, Tuple[str, ...], int]],
    ) -> bool:
        """Create merged pages for partially covered boundary pages.

        Returns True if any page was stored here.  Only this path ever
        waits on the previous writer (its metadata must be complete so
        the old content is readable) — full-page updates never block.
        """
        vw = info.version
        end = off + size
        boundary: List[int] = []
        if off % psize != 0:
            boundary.append(off // psize)
        if end % psize != 0 and end // psize not in boundary:
            boundary.append(end // psize)
        if not boundary:
            return False

        old_size = info.prev_size
        if any((k * psize < off and old_size > k * psize) or (end < min(old_size, (k + 1) * psize))
               for k in boundary):
            # merging needs snapshot vw-1 content
            if vw - 1 > 0:
                self.vm.wait_metadata(blob_id, vw - 1)

        for k in boundary:
            page_start = k * psize
            page_end_new = min((k + 1) * psize, info.new_size)
            length = page_end_new - page_start
            page = bytearray(length)
            # old content of this page from snapshot vw-1 (if any)
            old_hi = min(old_size, page_end_new)
            if old_hi > page_start and vw - 1 > 0:
                old = self._read_unpublished(blob_id, vw - 1, page_start, old_hi - page_start,
                                             info)
                page[0 : len(old)] = old
            # overlay the new bytes
            lo = max(off, page_start)
            hi = min(end, page_end_new)
            page[lo - page_start : hi - page_start] = buf[lo - off : hi - off]
            pid = fresh_page_id()
            group = self.pm.allocate(1)[0]
            provs = self.pm.store_page(group, pid, bytes(page), peer=self.name)
            stored[k - info.p0] = (pid, tuple(provs), length)
        return True

    def _read_unpublished(
        self, blob_id: str, version: int, offset: int, size: int, info: AssignInfo
    ) -> bytes:
        """Read from a snapshot whose metadata is complete but possibly
        not yet published (boundary merge against vw-1)."""
        psize = self.vm.psize_of(blob_id)
        rec = self.vm.update_log(blob_id, version)
        p0, p1 = pages_spanned(offset, size, psize)
        pd = st.read_meta(
            self.dht, self._owner_fn(blob_id), version, rec.root_pages, p0, p1,
            peer=self.name,
        )
        return self._fetch_ranges(pd, offset, size, psize)

    def _build_and_complete(self, blob_id: str, info: AssignInfo, pd_final) -> None:
        leaves = [
            st.PageDescriptor(info.p0 + rel, pid, tuple(provs), ln)
            for (pid, rel, provs, ln) in pd_final
        ]
        border = st.BorderResolver(
            self.dht, self._owner_fn(blob_id), info.recent_updates,
            info.vp, info.vp_root_pages, peer=self.name,
        )
        st.build_meta(
            self.dht, self._owner_fn(blob_id), info.version, info.root_pages,
            leaves, border, peer=self.name,
        )
        self.vm.metadata_complete(blob_id, info.version, client=self.name)

    # ------------------------------------------------- recovery (beyond paper)
    def rebuild_metadata(self, blob_id: str, version: int) -> None:
        """Replay BUILD_META for a writer that died after assignment.

        Page descriptors come from the version manager's WAL; the
        construction is deterministic, so replaying alongside a slow (not
        actually dead) writer is safe — both produce identical nodes and
        the DHT treats identical re-puts as replica re-sends.
        """
        info = self.vm.assign_info_for_recovery(blob_id, version)
        rec = self.vm.update_log(blob_id, version)
        if not rec.pd:
            raise RuntimeError(
                f"cannot recover {blob_id} v{version}: no page descriptors journaled"
            )
        self._build_and_complete(blob_id, info, rec.pd)

    # ------------------------------------------------------------- passthrough
    def get_recent(self, blob_id: str) -> int:
        """GET_RECENT: a recently published, still-live snapshot version
        (0 for an empty blob; retired versions are never handed out)."""
        return self.vm.get_recent(blob_id, client=self.name)

    def get_size(self, blob_id: str, version: int) -> int:
        """GET_SIZE of a published snapshot; raises
        :class:`~repro.core.version_manager.VersionUnpublished` /
        :class:`~repro.core.version_manager.RetiredVersion` otherwise."""
        return self.vm.get_size(blob_id, version, client=self.name)

    def sync(self, blob_id: str, version: int, timeout: Optional[float] = None) -> None:
        """Block (through the deployment clock) until ``version`` is
        published — read-your-writes for a writer that kept its vw."""
        self.vm.sync(blob_id, version, timeout=timeout, client=self.name)

    def branch(self, blob_id: str, version: int) -> str:
        """BRANCH: fork a new blob whose snapshots ``<= version`` are
        shared with the parent (zero copying — the paper's cheap
        branching); returns the new blob id."""
        bid = self.vm.branch(blob_id, version, client=self.name)
        self._lineage_cache.pop(bid, None)
        return bid

    # ----------------------------------------------------- GC: pins, retention
    def pin(self, blob_id: str, version: int, ttl: Optional[float] = None) -> str:
        """Pin a published snapshot against GC; returns the lease id.

        A pinned version is kept (and fully readable) across GC rounds
        until :meth:`unpin` or until the lease's clock-based ``ttl``
        expires — the checkpoint layer pins what it restores from.
        """
        return self.vm.pin(blob_id, version, client=self.name, ttl=ttl)

    def unpin(self, lease_id: str) -> None:
        """Release a pin lease taken with :meth:`pin` (idempotent)."""
        self.vm.unpin(lease_id, client=self.name)

    def set_retention(self, blob_id: str, keep_last: int) -> None:
        """Keep the newest ``keep_last`` published snapshots at GC time
        (plus pins, branch roots and in-flight anchors); 0 = keep all."""
        self.vm.set_retention(blob_id, keep_last, client=self.name)
