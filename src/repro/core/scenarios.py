"""Scenario library for the deterministic concurrency harness.

Reproduces the paper's §5 experiments — N concurrent readers of one
blob, N concurrent appenders, N writers to disjoint ranges, and a mixed
read/write workload — as client programs scheduled by
:class:`~repro.core.sim.Simulator` in virtual time.  Hundreds of
simulated clients run in milliseconds of wall time, every interleaving
is replayable from the seed, and aggregate throughput falls out of the
virtual makespan (the same per-endpoint wire model the benchmarks
always used for derived bandwidth, now actually driving the schedule).

Writing a new scenario::

    def my_scenario(env: ScenarioEnv) -> None:          # setup (driver
        env.blob = env.client("setup").create(...)      # thread — free)

    def my_program(env: ScenarioEnv, i: int):
        def prog():                                     # one client task
            c = env.client(f"c{i:03d}")
            ...                                         # blocking calls
            return {"ops": ..., "bytes": ...}           # charge virtual time
        return prog

    SCENARIOS["mine"] = Scenario("mine", "...", my_scenario, my_program)

then ``run_scenario("mine", n_clients=256, seed=1)``.  Programs must
return ``{"ops": int, "bytes": int}``; the runner aggregates those into
the throughput figures.  Failure injection: pass
``failures=[(virtual_time, target), ...]`` and the runner spawns a
chaos task that downs each target at its scheduled virtual instant.  A
plain target names a data provider; ``"vm-leader:<idx>"`` downs the
replicated version-manager leader of the ``idx``-th setup blob's
lineage (resolved at fire time), exercising the lease-based failover;
``"corrupt:<provider>"`` silently flips bytes of one stored page
*behind the provider's back* (bitrot injection — the recorded digest
stays intact, so only a scrub's ``verify_pages`` probe can tell).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.blob import BlobClient
from repro.core.service import BlobSeerService
from repro.core.sim import Simulator
from repro.core.transport import EndpointDown, Wire
from repro.core.version_manager import RetiredVersion


@dataclass
class ScenarioEnv:
    """Everything a scenario's setup and client programs can touch."""

    sim: Simulator
    svc: BlobSeerService
    n_clients: int
    psize: int
    chunk_pages: int
    ops_per_client: int
    blob: str = ""
    state: Dict[str, object] = field(default_factory=dict)

    @property
    def chunk(self) -> int:
        return self.chunk_pages * self.psize

    def client(self, name: str) -> BlobClient:
        return self.svc.client(name)


@dataclass(frozen=True)
class Scenario:
    """One experiment: driver-thread setup + per-client program.

    ``env_defaults`` are deployment kwargs the scenario pins unless the
    caller overrides them explicitly — the §5 paper reproductions pin
    ``page_cache_bytes=0`` because their clients model *distinct*
    nodes that share nothing (a shared in-process page cache would
    serve their repeat reads locally and fake the provider contention
    those figures measure); the beyond-paper cache/GC scenarios keep
    the production default (shared cache on).
    """

    name: str
    doc: str
    setup: Callable[[ScenarioEnv], None]
    program: Callable[[ScenarioEnv, int], Callable[[], dict]]
    env_defaults: Dict[str, object] = field(default_factory=dict)


@dataclass
class ScenarioResult:
    scenario: str
    n_clients: int
    seed: int
    ops: int
    bytes_moved: int
    makespan: float            # virtual seconds
    aggregate_mbps: float      # simulated aggregate throughput
    wall_seconds: float        # real time the simulation took
    events: int                # scheduler dispatches
    rpc: Dict[str, int]
    trace_digest: str
    client_results: Dict[str, object]
    errors: Dict[str, str]

    def row(self) -> str:
        return (
            f"{self.scenario},n={self.n_clients},seed={self.seed},"
            f"agg={self.aggregate_mbps:.1f}MBps,"
            f"makespan={self.makespan * 1e3:.2f}ms,"
            f"rpc_rounds={self.rpc.get('wire_round_trips', 0)},"
            f"wall={self.wall_seconds:.2f}s,trace={self.trace_digest[:12]}"
        )


# ---------------------------------------------------------------------------
# The four §5 experiments
# ---------------------------------------------------------------------------


def _setup_preloaded(env: ScenarioEnv) -> None:
    """One blob preloaded so every client has a disjoint chunk to read."""
    c = env.client("setup")
    env.blob = c.create(psize=env.psize)
    payload = b"\xcd" * env.chunk
    for _ in range(max(1, env.n_clients)):
        c.append(env.blob, payload)
    env.state["version"] = c.get_recent(env.blob)


def _reader_program(env: ScenarioEnv, i: int):
    """Fig 2(b): N readers concurrently read distinct chunks of one blob."""

    def prog() -> dict:
        c = env.client(f"r{i:03d}")
        v = env.state["version"]
        size = c.get_size(env.blob, v)
        done = 0
        for k in range(env.ops_per_client):
            off = ((i + k * env.n_clients) * env.chunk) % max(
                size - env.chunk, 1
            )
            data = c.read(env.blob, v, off, env.chunk)
            assert len(data) == env.chunk
            done += 1
        return {"ops": done, "bytes": done * env.chunk}

    return prog


def _setup_empty(env: ScenarioEnv) -> None:
    env.blob = env.client("setup").create(psize=env.psize)


def _appender_program(env: ScenarioEnv, i: int):
    """Fig 2(a)/3: N appenders; total order is asserted by the tests."""

    def prog() -> dict:
        c = env.client(f"a{i:03d}")
        versions: List[int] = []
        payload = bytes([i % 251 + 1]) * env.chunk
        for _ in range(env.ops_per_client):
            versions.append(c.append(env.blob, payload))
        return {"ops": len(versions), "bytes": len(versions) * env.chunk,
                "versions": versions}

    return prog


def _writer_program(env: ScenarioEnv, i: int):
    """§5 "concurrent writes": each client overwrites its own disjoint
    range of the preloaded blob, so final content is schedule-independent."""

    def prog() -> dict:
        c = env.client(f"w{i:03d}")
        payload = bytes([i % 251 + 1]) * env.chunk
        versions: List[int] = []
        for _ in range(env.ops_per_client):
            versions.append(c.write(env.blob, payload, i * env.chunk))
        return {"ops": len(versions), "bytes": len(versions) * env.chunk,
                "versions": versions}

    return prog


def _mixed_program(env: ScenarioEnv, i: int):
    """R/W workload: even clients read the most recent published
    snapshot while odd clients keep appending."""
    if i % 2 == 1:
        return _appender_program(env, i)

    def prog() -> dict:
        c = env.client(f"r{i:03d}")
        done = bytes_read = 0
        for _ in range(env.ops_per_client):
            v = c.get_recent(env.blob)
            if v == 0:
                # nothing published yet: wait (in virtual time) for the
                # first append instead of spinning on GET_RECENT
                c.sync(env.blob, 1, timeout=600.0)
                v = c.get_recent(env.blob)
            size = c.get_size(env.blob, v)
            take = min(env.chunk, size)
            data = c.read(env.blob, v, size - take, take)
            done += 1
            bytes_read += len(data)
        return {"ops": done, "bytes": bytes_read}

    return prog


def _setup_multi_blob(env: ScenarioEnv) -> None:
    """Several independent blobs (one lineage shard each): the ingest
    swarm spreads bursts over them, so version-manager contention is
    per lineage, never global."""
    c = env.client("setup")
    n_blobs = max(2, min(8, env.n_clients // 8 or 2))
    env.state["blobs"] = [c.create(psize=env.psize) for _ in range(n_blobs)]


BURST = 4  # appends per append_many burst in the append_burst scenario


def _append_burst_program(env: ScenarioEnv, i: int):
    """Multi-blob ingest: each client APPENDs bursts of ``BURST`` chunks
    via ``append_many``, cycling over the deployment's blobs.  One
    burst pays one ``assign_versions_many`` + one
    ``metadata_complete_many`` control round trip — the write-plane
    amortization ``bench_append`` gates on — and bursts to different
    blobs publish on independent lineage shards."""

    def prog() -> dict:
        blobs = env.state["blobs"]
        c = env.client(f"b{i:03d}")
        payload = bytes([i % 251 + 1]) * env.chunk
        versions: List[int] = []
        for k in range(env.ops_per_client):
            bid = blobs[(i + k) % len(blobs)]
            versions.extend(c.append_many(bid, [payload] * BURST))
        return {"ops": len(versions), "bytes": len(versions) * env.chunk,
                "versions": versions}

    return prog


def _setup_vm_failover(env: ScenarioEnv) -> None:
    """Multi-lineage burst fixture for the HA control plane: each blob
    roots its own lineage with a replicated leader endpoint
    (``vm-<blob>``), and clients are *pinned* to one blob each so
    per-lineage effects (the killed leader's lineage vs the untouched
    ones) are attributable in the wire stats."""
    c = env.client("setup")
    n_blobs = max(2, min(4, env.n_clients // 4 or 2))
    env.state["blobs"] = [c.create(psize=env.psize) for _ in range(n_blobs)]


def _vm_failover_program(env: ScenarioEnv, i: int):
    """Append bursts pinned per lineage, recording each burst's virtual
    latency.  With ``failures=[(t, 'vm-leader:0')]`` the leader of the
    first blob's lineage dies mid-run: its writers wait out the lease,
    promote a follower and re-drive — the burst still completes and no
    published version is lost (``bench_failover`` gates this)."""

    def prog() -> dict:
        blobs = env.state["blobs"]
        bid = blobs[i % len(blobs)]
        c = env.client(f"f{i:03d}")
        clock = env.svc.clock
        payload = bytes([i % 251 + 1]) * env.chunk
        versions: List[int] = []
        lats: List[float] = []
        for _ in range(env.ops_per_client):
            t0 = clock.now()
            versions.extend(c.append_many(bid, [payload] * BURST))
            lats.append(clock.now() - t0)
        return {"ops": len(versions), "bytes": len(versions) * env.chunk,
                "versions": versions, "lineage": i % len(blobs),
                "burst_latencies": lats}

    return prog


def _setup_hot_set(env: ScenarioEnv) -> None:
    """Small preloaded blob every reader hammers: the shared page cache
    and single-flight de-duplication are what keep the providers idle."""
    c = env.client("setup")
    env.blob = c.create(psize=env.psize)
    hot_chunks = max(4, min(16, env.n_clients // 4))
    payload = b"\xe7" * env.chunk
    for _ in range(hot_chunks):
        c.append(env.blob, payload)
    env.state["version"] = c.get_recent(env.blob)
    env.state["hot_chunks"] = hot_chunks


def _hot_set_program(env: ScenarioEnv, i: int):
    """N readers over a hot set much smaller than N * ops: reader *i*
    starts at chunk ``i % hot`` and walks the set sequentially, so the
    same pages are wanted by many clients at once (single-flight) and
    again later (cache hits).  Deterministic; no RNG."""

    def prog() -> dict:
        c = env.client(f"h{i:03d}")
        v = env.state["version"]
        hot = env.state["hot_chunks"]
        done = 0
        for k in range(env.ops_per_client):
            off = ((i + k) % hot) * env.chunk
            data = c.read(env.blob, v, off, env.chunk)
            assert len(data) == env.chunk
            done += 1
        return {"ops": done, "bytes": done * env.chunk}

    return prog


def _setup_gc_mixed(env: ScenarioEnv) -> None:
    """Preloaded blob with a keep-last retention window: GC rounds run
    *inside* the concurrent phase, racing readers and appenders."""
    c = env.client("setup")
    env.blob = c.create(psize=env.psize)
    payload = b"\xab" * env.chunk
    for _ in range(4):
        c.append(env.blob, payload)
    c.set_retention(env.blob, keep_last=4)
    env.state["version"] = c.get_recent(env.blob)


def _gc_mixed_program(env: ScenarioEnv, i: int):
    """GC-while-active: client 0 runs GC epochs, odd clients append,
    even clients read a pinned snapshot plus the most recent one.

    Reads of pinned (kept) versions must NEVER fail — that is the
    epoch/mark safety property.  Reads of the recency pointer may race
    past the retention window and get the typed ``RetiredVersion``;
    those are counted and retried, never crashes.
    """
    if i == 0:

        def gc_prog() -> dict:
            from repro.core.gc import collect_garbage

            clock = env.svc.clock
            rounds = swept_pages = retired = 0
            for _ in range(max(4, env.ops_per_client)):
                clock.sleep(0.02)
                try:
                    # orphan inventory off: it is a slow-cadence job (600s
                    # grace) and would ship every provider's full listing
                    # each 0.02s round for nothing
                    stats = collect_garbage(env.svc, client=f"gc{i:03d}",
                                            orphan_grace=None)
                except EndpointDown:
                    continue  # a downed endpoint aborts the round; retried
                rounds += 1
                swept_pages += stats["swept_pages"]
                retired += stats["retired_versions"]
            return {"ops": rounds, "bytes": 0, "swept_pages": swept_pages,
                    "retired_versions": retired}

        return gc_prog

    if i % 2 == 1:

        def writer_prog() -> dict:
            # alternate append/overwrite: overwrites orphan the previous
            # copies of their pages, so the sweep has bytes to reclaim
            c = env.client(f"a{i:03d}")
            payload = bytes([i % 251 + 1]) * env.chunk
            versions: List[int] = []
            for k in range(env.ops_per_client):
                if k % 2 == 0:
                    versions.append(c.append(env.blob, payload))
                else:
                    versions.append(c.write(env.blob, payload, 0))
            return {"ops": len(versions), "bytes": len(versions) * env.chunk,
                    "versions": versions}

        return writer_prog

    def reader_prog() -> dict:
        c = env.client(f"r{i:03d}")
        v_pin = env.state["version"]
        lease = c.pin(env.blob, v_pin)
        pinned_size = c.get_size(env.blob, v_pin)
        done = bytes_read = pinned_failures = retired_retries = 0
        try:
            for _ in range(env.ops_per_client):
                try:
                    data = c.read(env.blob, v_pin, 0,
                                  min(env.chunk, pinned_size))
                    bytes_read += len(data)
                except Exception:  # noqa: BLE001 - any failure is a bug
                    pinned_failures += 1
                v = c.get_recent(env.blob)
                try:
                    size = c.get_size(env.blob, v)
                    take = min(env.chunk, size)
                    data = c.read(env.blob, v, size - take, take)
                    bytes_read += len(data)
                except RetiredVersion:
                    retired_retries += 1  # recency raced the GC window: allowed
                done += 1
        finally:
            c.unpin(lease)
        return {"ops": done, "bytes": bytes_read,
                "pinned_failures": pinned_failures,
                "retired_retries": retired_retries}

    return reader_prog


def _setup_train_serve(env: ScenarioEnv) -> None:
    """Blob-backed train/serve loop fixture (the integrated e2e workload).

    Driver-thread setup (free in virtual time): a token corpus the
    trainers will stream through ``data/pipeline.py`` shards, a model
    state sized ``TS_MODEL_PAGES`` pages, and its step-0 checkpoint
    committed through ``blobckpt`` — whose delta-scan digests feed the
    dedup handshake during the measured phase.  Lazy imports keep the
    scenario library's module surface jax-free for every other
    scenario.
    """
    import numpy as np

    from repro.checkpoint.blobckpt import BlobCheckpointer
    from repro.data.pipeline import CorpusWriter

    cfg = {
        "model_pages": env.state.get("model_pages", 256),
        "dirty_pages": env.state.get("dirty_pages", 32),
        "steps": max(2, env.ops_per_client),
        "header_pages": 4,
        "batch": 2,
        "seq_len": 127,
    }
    env.state["cfg"] = cfg

    corpus_client = env.client("corpus-setup")
    writer = CorpusWriter(corpus_client, psize=env.psize)
    words = env.psize // 4
    rng = np.random.default_rng(1234)
    writer.append_tokens(
        rng.integers(0, 50_000, size=4 * words, dtype=np.int32))
    env.state["corpus"] = writer.blob_id

    # model state: one flat int32 leaf, TS_MODEL_PAGES pages of psize
    w = np.zeros(cfg["model_pages"] * words, dtype=np.int32)
    w[::words] = np.arange(cfg["model_pages"])
    env.state["model"] = {"w": w}

    ckpt = BlobCheckpointer(env.client("ckpt-writer"), psize=env.psize,
                            header_pages=cfg["header_pages"])
    ckpt.save(env.state["model"], step=0)
    env.client("retention-setup").set_retention(ckpt.blob_id, keep_last=6)
    env.state["ckpt"] = ckpt
    env.state["ckpt_blob"] = ckpt.blob_id


def _train_serve_program(env: ScenarioEnv, i: int):
    """Roles: client 0 is the training checkpointer (the measured one),
    client 1 runs GC rounds, even clients serve reads of recent
    checkpoints through the shared page cache, odd clients are trainers
    streaming disjoint corpus shards.

    The checkpointer's result carries the bytes-on-wire ledger the
    ``bench_e2e`` gate asserts: per steady step it dirties exactly
    ``dirty_pages`` pages with step-unique content (the honest delta —
    never dedupable), then re-saves the full state from a fresh
    checkpointer with no digest cache (restart: every page *looks*
    dirty, the content-hash index absorbs all of it), then branches and
    saves a one-page mutation (fork: shared pages by refcount, not
    copy).
    """
    cfg = env.state["cfg"]

    def _provider_in_bytes() -> int:
        return sum(env.svc.wire.stats(p.pid).bytes_in
                   for p in env.svc.pm.all_providers())

    if i == 0:

        def ckpt_prog() -> dict:
            import numpy as np

            from repro.checkpoint.blobckpt import BlobCheckpointer

            clock = env.svc.clock
            ckpt = env.state["ckpt"]
            model = env.state["model"]
            w = model["w"]
            words = env.psize // 4
            per_step_wire: List[int] = []
            payload_bytes = 0
            for step in range(1, cfg["steps"] + 1):
                clock.sleep(0.05)
                for j in range(cfg["dirty_pages"]):
                    p = (step * 7 + j * 5) % cfg["model_pages"]
                    w[p * words + 1] = step * 100_000 + p
                before = _provider_in_bytes()
                stats = ckpt.save(model, step=step)
                per_step_wire.append(_provider_in_bytes() - before)
                payload_bytes += stats.written_bytes
            # restart: fresh checkpointer, no digest cache — all pages
            # scan dirty; with dedup on, the handshake ships none of them
            clock.sleep(0.05)
            ck2 = BlobCheckpointer(env.client("ckpt-restart"),
                                   blob_id=ckpt.blob_id, psize=env.psize,
                                   header_pages=cfg["header_pages"])
            before = _provider_in_bytes()
            s_restart = ck2.save(model, step=cfg["steps"] + 1)
            restart_wire = _provider_in_bytes() - before
            # branch + one-page mutation: shared pages stay shared
            clock.sleep(0.05)
            child = ck2.branch()
            w[1] = -1
            pages_before = env.svc.storage_report()["pages"]
            before = _provider_in_bytes()
            s_branch = child.save(model, step=cfg["steps"] + 2)
            branch_wire = _provider_in_bytes() - before
            branch_pages_added = (env.svc.storage_report()["pages"]
                                  - pages_before)
            return {
                "ops": cfg["steps"] + 2,
                "bytes": payload_bytes,
                "per_step_wire": per_step_wire,
                "restart_wire": restart_wire,
                "restart_pages_scanned": s_restart.pages_written,
                "branch_wire": branch_wire,
                "branch_pages_added": branch_pages_added,
                "branch_pages_written": s_branch.pages_written,
                "model_bytes": cfg["model_pages"] * env.psize,
                "dirty_frac": cfg["dirty_pages"] / cfg["model_pages"],
            }

        return ckpt_prog

    if i == 1:

        def gc_prog() -> dict:
            from repro.core.gc import collect_garbage

            clock = env.svc.clock
            rounds = swept = 0
            for _ in range(cfg["steps"] + 2):
                clock.sleep(0.07)
                try:
                    stats = collect_garbage(env.svc, client=f"gc{i:03d}",
                                            orphan_grace=None)
                except EndpointDown:
                    continue
                rounds += 1
                swept += stats["swept_pages"]
            return {"ops": rounds, "bytes": 0, "swept_pages": swept}

        return gc_prog

    if i % 2 == 0:

        def serve_prog() -> dict:
            from repro.checkpoint.blobckpt import BlobCheckpointer

            c = env.client(f"serve{i:03d}")
            reader = BlobCheckpointer(c, blob_id=env.state["ckpt_blob"],
                                      psize=env.psize,
                                      header_pages=cfg["header_pages"])
            clock = env.svc.clock
            done = bytes_read = retired_retries = 0
            for k in range(cfg["steps"]):
                clock.sleep(0.03 + 0.001 * i)
                try:
                    manifest, mv = reader.read_manifest()
                    leaf = manifest["leaves"][0]
                    off = leaf["offset"] + ((i + k) % cfg["model_pages"]) \
                        * env.psize
                    data = c.read(env.state["ckpt_blob"], mv, off, env.psize)
                    bytes_read += len(data)
                    done += 1
                except RetiredVersion:
                    retired_retries += 1  # raced the retention window: retry
            return {"ops": done, "bytes": bytes_read,
                    "retired_retries": retired_retries}

        return serve_prog

    def trainer_prog() -> dict:
        from repro.data.pipeline import ShardedReader

        c = env.client(f"train{i:03d}")
        n_shards = max(1, (env.n_clients - 1) // 2)
        shard = (i - 3) // 2 % n_shards
        reader = ShardedReader(c, env.state["corpus"], batch=cfg["batch"],
                               seq_len=cfg["seq_len"], shard=shard,
                               n_shards=n_shards)
        clock = env.svc.clock
        done = bytes_read = 0
        for _ in range(cfg["steps"]):
            xs, ys = reader.next_batch()
            bytes_read += xs.nbytes + ys.nbytes
            done += 1
            clock.sleep(0.04)
        return {"ops": done, "bytes": bytes_read}

    return trainer_prog


def _setup_durability(env: ScenarioEnv) -> None:
    """Durability-tier fixture: an erasure-coded blob (``ec:6+2``) and a
    3-way replicated twin, both preloaded with distinct per-chunk
    content.  The runner's ``failures`` list then kills providers (and
    injects bitrot via ``corrupt:<prov>``) mid-run; the scrub client
    repairs while readers keep verifying both blobs."""
    c = env.client("setup")
    ec_blob = c.create(psize=env.psize)
    env.svc.set_blob_placement(ec_blob, "ec:6+2")
    rep_blob = c.create(psize=env.psize)
    env.svc.set_blob_placement(rep_blob, "rep:3")
    chunks = max(2, min(8, env.n_clients))
    for blob in (ec_blob, rep_blob):
        for k in range(chunks):
            c.append(blob, bytes([(k % 251) + 1]) * env.chunk)
    env.state["blobs"] = [ec_blob, rep_blob]
    env.state["versions"] = {b: c.get_recent(b) for b in (ec_blob, rep_blob)}
    env.state["chunks"] = chunks
    env.state.setdefault("scrub_budget", 2 * 1024 * 1024)


def _durability_program(env: ScenarioEnv, i: int):
    """Client 0 is the scrub plane (budget-capped repair rounds on the
    virtual clock); everyone else reads both blobs throughout the chaos
    window and counts failed reads — the availability figure
    ``bench_durability`` gates on (EC must mask the loss of any ``m``
    shard providers with ZERO failed reads)."""
    if i == 0:

        def scrub_prog() -> dict:
            clock = env.svc.clock
            budget = env.state["scrub_budget"]
            rounds = repaired = corrupt = deferred = 0
            max_round_bytes = 0
            lost: set = set()
            for _ in range(max(8, env.ops_per_client * 4)):
                clock.sleep(0.02)
                try:
                    stats = env.svc.scrub(budget_bytes=budget,
                                          peer=f"scrub{i:03d}")
                except EndpointDown:
                    continue  # a probe raced a kill; retried next round
                rounds += 1
                repaired += stats["repaired_pages"]
                corrupt += stats["corrupt_copies"]
                deferred += stats["deferred_pages"]
                max_round_bytes = max(max_round_bytes,
                                      stats["repair_bytes"])
                lost.update(stats["losses"])
            # verification round: all damage the chaos injected must be
            # gone by now (anything this round still finds is residual)
            final = env.svc.scrub(budget_bytes=budget, peer=f"scrub{i:03d}")
            return {"ops": rounds, "bytes": 0,
                    "repaired_pages": repaired,
                    "corrupt_found": corrupt,
                    "deferred": deferred,
                    "max_round_repair_bytes": max_round_bytes,
                    "lost": sorted(lost),
                    "final_damaged": final["damaged_pages"],
                    "final_losses": list(final["losses"])}

        return scrub_prog

    def reader_prog() -> dict:
        c = env.client(f"d{i:03d}")
        blobs = env.state["blobs"]
        versions = env.state["versions"]
        chunks = env.state["chunks"]
        clock = env.svc.clock
        done = bytes_read = 0
        failed = [0] * len(blobs)
        for k in range(env.ops_per_client * 2):
            clock.sleep(0.01)
            which = (i + k) % len(blobs)
            bid = blobs[which]
            off = ((i + k) % chunks) * env.chunk
            try:
                data = c.read(bid, versions[bid], off, env.chunk)
                assert len(data) == env.chunk
                bytes_read += len(data)
            except EndpointDown:
                failed[which] += 1
            done += 1
        return {"ops": done, "bytes": bytes_read,
                "failed_reads": sum(failed),
                "failed_reads_ec": failed[0],
                "failed_reads_rep": failed[1]}

    return reader_prog


N_WATCH_WRITERS = 8  # writer clients in the watchers scenarios; the rest
#                      are gateway clients multiplexing many watch leases


def _setup_watchers(env: ScenarioEnv) -> None:
    """Subscription-plane fixture: ``N_WATCH_WRITERS`` blobs (one pinned
    writer each) and ``state["watchers"]`` simulated subscribers spread
    round-robin over the *gateway* clients.  Each gateway holds many
    watch leases on ONE shared inbox endpoint, so the notify fan-out is
    bounded by gateways (endpoints-with-watchers), never by the watcher
    count — the O(K x endpoints) property ``bench_watch`` gates on."""
    if env.n_clients <= N_WATCH_WRITERS:
        raise ValueError(
            f"watchers scenario needs > {N_WATCH_WRITERS} clients "
            f"({N_WATCH_WRITERS} writers + at least one gateway)")
    c = env.client("setup")
    blobs = [c.create(psize=env.psize) for _ in range(N_WATCH_WRITERS)]
    env.state["blobs"] = blobs
    env.state["final"] = env.ops_per_client * BURST
    total = int(env.state.get("watchers", 64))
    n_gateways = env.n_clients - N_WATCH_WRITERS
    gateways: List[Tuple[BlobClient, List[Tuple[str, str]]]] = []
    for g in range(n_gateways):
        client = env.client(f"gw{g:03d}")
        leases: List[Tuple[str, str]] = []
        for w in range(g, total, n_gateways):
            bid = blobs[w % len(blobs)]
            leases.append((client.watch(bid, from_version=0), bid))
        gateways.append((client, leases))
    env.state["gateways"] = gateways


def _watcher_program(env: ScenarioEnv, i: int):
    """Writers (``i < N_WATCH_WRITERS``) publish append bursts to their
    pinned blob; gateways block on their inboxes until every lease has
    been pushed the final version, then drain the delivered streams."""
    blobs = env.state["blobs"]
    final = env.state["final"]

    if i < N_WATCH_WRITERS:

        def writer_prog() -> dict:
            bid = blobs[i]
            c = env.client(f"wr{i:03d}")
            payload = bytes([i % 251 + 1]) * env.chunk
            versions: List[int] = []
            for _ in range(env.ops_per_client):
                versions.extend(c.append_many(bid, [payload] * BURST))
            return {"ops": len(versions), "bytes": len(versions) * env.chunk,
                    "versions": versions}

        return writer_prog

    def gateway_prog() -> dict:
        client, leases = env.state["gateways"][i - N_WATCH_WRITERS]
        delivered: Dict[str, List[int]] = {}
        for wid, _bid in leases:
            client.inbox.wait_for(wid, final, timeout=600.0)
            delivered[wid] = client.poll_notifications(wid)
        return {"ops": sum(len(vs) for vs in delivered.values()),
                "bytes": 0, "delivered": delivered}

    return gateway_prog


def _setup_watchers_poll(env: ScenarioEnv) -> None:
    """Poll-twin fixture: same blobs/writers/watcher spread as
    ``watchers``, but NO leases — gateways learn of publications by
    polling ``get_recent`` per simulated watcher, the control-plane
    cost the subscription plane exists to remove."""
    if env.n_clients <= N_WATCH_WRITERS:
        raise ValueError(
            f"watchers_poll scenario needs > {N_WATCH_WRITERS} clients "
            f"({N_WATCH_WRITERS} writers + at least one gateway)")
    c = env.client("setup")
    blobs = [c.create(psize=env.psize) for _ in range(N_WATCH_WRITERS)]
    env.state["blobs"] = blobs
    env.state["final"] = env.ops_per_client * BURST
    total = int(env.state.get("watchers", 64))
    n_gateways = env.n_clients - N_WATCH_WRITERS
    env.state["poll_sets"] = [
        [blobs[w % len(blobs)] for w in range(g, total, n_gateways)]
        for g in range(n_gateways)
    ]


def _poll_watcher_program(env: ScenarioEnv, i: int):
    """Identical writers; each gateway polls ``get_recent`` for every
    simulated watcher it fronts until all have observed the final
    version — one RPC per watcher per round, O(W) on the control plane
    (the figure the notify path beats by >= 10x)."""
    if i < N_WATCH_WRITERS:
        return _watcher_program(env, i)

    def poll_prog() -> dict:
        targets = env.state["poll_sets"][i - N_WATCH_WRITERS]
        final = env.state["final"]
        interval = float(env.state.get("poll_interval", 0.05))
        c = env.client(f"pg{i:03d}")
        clock = env.svc.clock
        last = [0] * len(targets)
        delivered: List[List[int]] = [[] for _ in targets]
        poll_rpcs = 0
        while any(lv < final for lv in last):
            for w, bid in enumerate(targets):
                if last[w] >= final:
                    continue
                v = c.get_recent(bid)
                poll_rpcs += 1
                if v > last[w]:
                    delivered[w].extend(range(last[w] + 1, v + 1))
                    last[w] = v
            if any(lv < final for lv in last):
                clock.sleep(interval)
        return {"ops": sum(len(vs) for vs in delivered),
                "bytes": 0, "poll_rpcs": poll_rpcs,
                "delivered": {str(w): vs for w, vs in enumerate(delivered)}}

    return poll_prog


# ---------------------------------------------------------------------------
# Elastic membership scenarios
# ---------------------------------------------------------------------------


def _setup_membership(env: ScenarioEnv) -> None:
    """Preloaded blob for the membership scenarios; ``state['blobs']``
    is set so index-based chaos targets resolve."""
    _setup_preloaded(env)
    env.state["blobs"] = [env.blob]


def _rolling_restart_program(env: ScenarioEnv, i: int):
    """Client 0 is the operator rolling the fleet: each cycled provider
    is drained (transfer-out concurrent with the readers, zero failed
    ops), deregistered, then rejoined as a fresh empty member that
    receives its owed pages back via budgeted migration.  Everyone else
    reads the preloaded blob throughout; ``failed_reads`` must stay 0 —
    the old owner serves every page until its move lands."""
    if i == 0:

        def operator_prog() -> dict:
            clock = env.svc.clock
            sleep = float(env.state.get("migration_sleep", 0.005))
            hot = sorted(p.pid for p in env.svc.pm.all_providers()
                         if getattr(p, "tier", "hot") == "hot")
            n_cycles = int(env.state.get(
                "restart_cycles", min(3, max(1, len(hot) - 2))))
            cycled = 0
            moves = 0
            for pid in hot[:n_cycles]:
                clock.sleep(0.01)
                stats = env.svc.drain_provider(pid, round_sleep=sleep)
                moves += stats["moves"] + stats["stragglers"]
                clock.sleep(0.01)
                plan = env.svc.join_provider(pid)
                back = env.svc.run_migration(plan, round_sleep=sleep)
                moves += back["moves"]
                cycled += 1
            return {"ops": cycled, "bytes": 0, "cycled": cycled,
                    "migration_moves": moves}

        return operator_prog

    def reader_prog() -> dict:
        c = env.client(f"r{i:03d}")
        v = env.state["version"]
        size = c.get_size(env.blob, v)
        clock = env.svc.clock
        done = bytes_read = failed = 0
        for k in range(env.ops_per_client * 2):
            clock.sleep(0.008)
            off = ((i + k * env.n_clients) * env.chunk) % max(
                size - env.chunk, 1)
            try:
                data = c.read(env.blob, v, off, env.chunk)
                assert len(data) == env.chunk
                bytes_read += len(data)
            except EndpointDown:
                failed += 1
            done += 1
        return {"ops": done, "bytes": bytes_read, "failed_reads": failed}

    return reader_prog


def _scale_out_program(env: ScenarioEnv, i: int):
    """Client 0 joins fresh providers mid-run and streams them their
    owed pages while odd clients keep appending (new pages place onto
    the joined members from their first allocation) and even clients
    keep reading the preloaded snapshot — zero failed ops both ways."""
    if i == 0:

        def operator_prog() -> dict:
            clock = env.svc.clock
            sleep = float(env.state.get("migration_sleep", 0.005))
            n_new = int(env.state.get("scale_out_by", 2))
            joined = []
            moves = 0
            for j in range(n_new):
                clock.sleep(0.02)
                pid = f"prov-join-{j:02d}"
                plan = env.svc.join_provider(pid)
                stats = env.svc.run_migration(plan, round_sleep=sleep)
                moves += stats["moves"]
                joined.append(pid)
            return {"ops": len(joined), "bytes": 0, "joined": joined,
                    "migration_moves": moves}

        return operator_prog

    if i % 2 == 1:

        def appender_prog() -> dict:
            c = env.client(f"a{i:03d}")
            clock = env.svc.clock
            payload = bytes([i % 251 + 1]) * env.chunk
            versions: List[int] = []
            for _ in range(env.ops_per_client):
                clock.sleep(0.006)
                versions.append(c.append(env.blob, payload))
            return {"ops": len(versions), "bytes": len(versions) * env.chunk,
                    "versions": versions}

        return appender_prog

    def reader_prog() -> dict:
        c = env.client(f"r{i:03d}")
        v = env.state["version"]
        size = c.get_size(env.blob, v)
        clock = env.svc.clock
        done = bytes_read = failed = 0
        for k in range(env.ops_per_client):
            clock.sleep(0.009)
            off = ((i + k * env.n_clients) * env.chunk) % max(
                size - env.chunk, 1)
            try:
                data = c.read(env.blob, v, off, env.chunk)
                bytes_read += len(data)
            except EndpointDown:
                failed += 1
            done += 1
        return {"ops": done, "bytes": bytes_read, "failed_reads": failed}

    return reader_prog


def _setup_flash_crowd(env: ScenarioEnv) -> None:
    """A small preloaded blob whose FIRST chunk every client hammers —
    the flash crowd.  ``state['flashcrowd_mitigate']`` (default on)
    lets the benchmark run a no-mitigation twin for the load contrast;
    the shared page cache is pinned off because the crowd models
    distinct client nodes hitting the providers directly."""
    c = env.client("setup")
    env.blob = c.create(psize=env.psize)
    for k in range(4):
        c.append(env.blob, bytes([(k % 251) + 1]) * env.chunk)
    env.state["version"] = c.get_recent(env.blob)
    env.state["blobs"] = [env.blob]
    env.state.setdefault("flashcrowd_mitigate", True)
    env.state.setdefault("flashcrowd_threshold", 16)
    env.state.setdefault("flashcrowd_extra", 2)


def _flash_crowd_program(env: ScenarioEnv, i: int):
    """Client 0 is the load balancer: it samples the served-read
    tallies every interval and widens any hot page onto its next ring
    owners (``mitigate_flash_crowd``); the crowd keeps re-reading the
    same first chunk.  The balancer's result carries the final
    per-provider served-read load — the distribution ``bench_ring``
    gates on (mitigated max-load must flatten vs the twin)."""
    if i == 0:

        def balancer_prog() -> dict:
            clock = env.svc.clock
            mitigate = bool(env.state.get("flashcrowd_mitigate", True))
            rounds = widened = 0
            for _ in range(max(6, env.ops_per_client * 2)):
                clock.sleep(0.01)
                rounds += 1
                if mitigate:
                    widened += len(env.svc.mitigate_flash_crowd(
                        threshold=int(env.state["flashcrowd_threshold"]),
                        extra=int(env.state["flashcrowd_extra"]),
                        blob_id=env.blob))
            return {"ops": rounds, "bytes": 0, "widened_pages": widened,
                    "read_load": dict(env.svc.pm.read_load())}

        return balancer_prog

    def crowd_prog() -> dict:
        c = env.client(f"c{i:03d}")
        v = env.state["version"]
        clock = env.svc.clock
        done = bytes_read = failed = 0
        for _ in range(env.ops_per_client * 2):
            clock.sleep(0.004)
            try:
                data = c.read(env.blob, v, 0, env.chunk)
                assert len(data) == env.chunk
                bytes_read += len(data)
            except EndpointDown:
                failed += 1
            done += 1
        return {"ops": done, "bytes": bytes_read, "failed_reads": failed}

    return crowd_prog


SCENARIOS: Dict[str, Scenario] = {
    "readers": Scenario(
        "readers",
        "N concurrent readers of one blob, disjoint chunks (paper Fig 2b)",
        _setup_preloaded, _reader_program,
        env_defaults={"page_cache_bytes": 0},
    ),
    "appenders": Scenario(
        "appenders",
        "N concurrent appenders to one blob (paper Fig 2a/3)",
        _setup_empty, _appender_program,
        env_defaults={"page_cache_bytes": 0},
    ),
    "writers": Scenario(
        "writers",
        "N concurrent writers to disjoint ranges (paper Fig 4)",
        _setup_preloaded, _writer_program,
        env_defaults={"page_cache_bytes": 0},
    ),
    "mixed": Scenario(
        "mixed",
        "N/2 readers of recent snapshots + N/2 appenders (paper §5 R/W)",
        _setup_preloaded, _mixed_program,
        env_defaults={"page_cache_bytes": 0},
    ),
    "append_burst": Scenario(
        "append_burst",
        "N clients ingesting multi-blob append bursts through the "
        "batched writer verbs (scale-out write plane)",
        _setup_multi_blob, _append_burst_program,
        env_defaults={"page_cache_bytes": 0},
    ),
    "hot_set": Scenario(
        "hot_set",
        "N readers hammering a small hot set of one blob (page-cache "
        "hits + single-flight de-duplication carry the load)",
        _setup_hot_set, _hot_set_program,
    ),
    "gc_mixed": Scenario(
        "gc_mixed",
        "GC epochs racing a mixed pinned-reader/appender workload "
        "(distributed mark/sweep while clients are active)",
        _setup_gc_mixed, _gc_mixed_program,
    ),
    "vm_failover": Scenario(
        "vm_failover",
        "Clients pinned per lineage driving append bursts while a VM "
        "lineage leader dies mid-run (HA control plane: lease failover, "
        "journal re-drive, untouched lineages unaffected)",
        _setup_vm_failover, _vm_failover_program,
        env_defaults={"page_cache_bytes": 0, "vm_replication": 2,
                      "vm_lease_ttl": 0.05},
    ),
    "durability": Scenario(
        "durability",
        "Self-healing tier under chaos: an ec:6+2 blob and a rep:3 twin "
        "read continuously while providers die and bitrot is injected; "
        "a budget-capped scrub plane detects and repairs everything "
        "(erasure decode on read masks the losses meanwhile)",
        _setup_durability, _durability_program,
        env_defaults={"verify_digests": True},
    ),
    "watchers": Scenario(
        "watchers",
        "Subscription plane at scale: thousands of watch leases "
        "multiplexed over gateway inboxes while pinned writers publish "
        "append bursts; notify fan-out is per endpoint, not per watcher",
        _setup_watchers, _watcher_program,
        env_defaults={"page_cache_bytes": 0, "vm_replication": 2,
                      "vm_lease_ttl": 0.05},
    ),
    "watchers_poll": Scenario(
        "watchers_poll",
        "Poll twin of the watchers scenario: the same watcher spread "
        "learns of publications by polling get_recent per watcher — the "
        "O(W) control-plane baseline the notify path is gated against",
        _setup_watchers_poll, _poll_watcher_program,
        env_defaults={"page_cache_bytes": 0, "vm_replication": 2,
                      "vm_lease_ttl": 0.05},
    ),
    "rolling_restart": Scenario(
        "rolling_restart",
        "Operator rolls the provider fleet: drain -> deregister -> "
        "rejoin each member in turn while readers stay on the blob; "
        "budget-capped migration keeps every op succeeding (elastic "
        "membership)",
        _setup_membership, _rolling_restart_program,
        env_defaults={"page_cache_bytes": 0},
    ),
    "scale_out": Scenario(
        "scale_out",
        "Fresh providers join mid-run and receive exactly their owed "
        "key ranges while appenders and readers keep running (online "
        "consistent-hash rebalance)",
        _setup_membership, _scale_out_program,
        env_defaults={"page_cache_bytes": 0},
    ),
    "flash_crowd": Scenario(
        "flash_crowd",
        "Every client hammers one chunk; a load balancer samples read "
        "tallies and widens the hot pages onto their next ring owners "
        "(load-aware replica widening vs the unmitigated twin)",
        _setup_flash_crowd, _flash_crowd_program,
        env_defaults={"page_cache_bytes": 0},
    ),
    "train_serve": Scenario(
        "train_serve",
        "Integrated train/serve loop: trainers stream corpus shards, the "
        "checkpointer commits deltas through the dedup handshake, a "
        "serving tier reads recent checkpoints via the page cache, GC "
        "races everyone (virtual clock, deterministic)",
        _setup_train_serve, _train_serve_program,
        env_defaults={"dedup": True},
    ),
}


# ---------------------------------------------------------------------------
# Failure injection
# ---------------------------------------------------------------------------


def parse_failure_target(target: str) -> Tuple[str, object]:
    """Parse a chaos target spec into ``(kind, arg)``.

    ``"vm-leader:<idx>"`` -> ``("vm-leader", idx)`` — down the replicated
    version-manager leader of the idx-th setup blob's lineage;
    ``"corrupt:<provider>"`` -> ``("corrupt", provider)`` — flip bytes of
    that provider's first stored page behind its back;
    ``"join:<provider>"`` -> ``("join", provider)`` — an elastic-membership
    event: the named provider joins the ring and receives its owed pages
    via budgeted migration rounds; ``"drain:<provider>"`` ->
    ``("drain", provider)`` — the named provider transfers out and
    deregisters with zero failed ops; ``"flashcrowd:<idx>"`` ->
    ``("flashcrowd", idx)`` — run one flash-crowd mitigation pass scoped
    to the idx-th setup blob (widen its hot pages onto their next ring
    owners); any other non-empty string -> ``("kill", target)`` — a data
    provider to down.  Malformed specs raise ``ValueError`` (so
    ``run_scenario`` rejects them up front, before any virtual time has
    elapsed).
    """
    if not target:
        raise ValueError("empty failure target")
    if target.startswith("vm-leader:"):
        raw = target.split(":", 1)[1]
        try:
            idx = int(raw)
        except ValueError:
            raise ValueError(
                f"vm-leader index must be an integer, got {raw!r}"
            ) from None
        if idx < 0:
            raise ValueError(f"vm-leader index must be >= 0, got {idx}")
        return "vm-leader", idx
    if target.startswith("corrupt:"):
        prov = target.split(":", 1)[1]
        if not prov:
            raise ValueError("corrupt target names no provider")
        return "corrupt", prov
    if target.startswith("join:"):
        prov = target.split(":", 1)[1]
        if not prov:
            raise ValueError("join target names no provider")
        return "join", prov
    if target.startswith("drain:"):
        prov = target.split(":", 1)[1]
        if not prov:
            raise ValueError("drain target names no provider")
        return "drain", prov
    if target.startswith("flashcrowd:"):
        raw = target.split(":", 1)[1]
        try:
            idx = int(raw)
        except ValueError:
            raise ValueError(
                f"flashcrowd index must be an integer, got {raw!r}"
            ) from None
        if idx < 0:
            raise ValueError(f"flashcrowd index must be >= 0, got {idx}")
        return "flashcrowd", idx
    return "kill", target


def apply_failure_target(svc: BlobSeerService, state: Dict[str, object],
                         target: str) -> str:
    """Fire one parsed chaos target against a live deployment.

    Targets resolve at fire time: "vm-leader:<idx>" downs the
    replicated VM leader of the idx-th setup blob's lineage (HA
    failover path); "corrupt:<prov>" flips bytes of that provider's
    first stored page behind its back (bitrot — the digest recorded at
    put time is left alone, so only a scrub probe can detect it);
    anything else is a data provider to kill.  Returns the endpoint (or
    spec) that was hit.
    """
    kind, arg = parse_failure_target(target)
    if kind == "vm-leader":
        blobs = state.get("blobs")
        if not blobs:
            raise ValueError(
                "vm-leader target needs setup blobs in env.state['blobs']")
        if arg >= len(blobs):  # type: ignore[operator]
            raise ValueError(
                f"vm-leader index {arg} out of range "
                f"(setup created {len(blobs)} blobs)")  # type: ignore[arg-type]
        return svc.kill_vm_leader(blobs[arg])  # type: ignore[index]
    if kind == "corrupt":
        prov = svc.pm.get(arg)
        victims = sorted(prov.store.iter_pids())
        if victims:
            vic = victims[0]
            payload = prov.store.get(vic)
            # mutate the raw store, NOT through delete_pages /
            # put_pages — silent corruption leaves bookkeeping
            # (digests, timestamps) untouched
            prov.store.delete(vic)
            prov.store.put(vic, bytes([payload[0] ^ 0xFF]) + payload[1:])
        return target
    if kind == "join":
        # elastic scale-out mid-run: the member starts taking new pages
        # at once; its owed already-stored pages stream over in budgeted
        # rounds that yield virtual time to the surrounding clients
        plan = svc.join_provider(arg)  # type: ignore[arg-type]
        svc.run_migration(
            plan, round_sleep=float(state.get("migration_sleep", 0.005)))
        return target
    if kind == "drain":
        svc.drain_provider(
            arg,  # type: ignore[arg-type]
            round_sleep=float(state.get("migration_sleep", 0.005)))
        return target
    if kind == "flashcrowd":
        blobs = state.get("blobs")
        if not blobs:
            raise ValueError(
                "flashcrowd target needs setup blobs in env.state['blobs']")
        if arg >= len(blobs):  # type: ignore[operator]
            raise ValueError(
                f"flashcrowd index {arg} out of range "
                f"(setup created {len(blobs)} blobs)")  # type: ignore[arg-type]
        svc.mitigate_flash_crowd(
            threshold=int(state.get("flashcrowd_threshold", 32)),
            extra=int(state.get("flashcrowd_extra", 1)),
            blob_id=blobs[arg])  # type: ignore[index]
        return target
    svc.kill_provider(arg)
    return target


# ---------------------------------------------------------------------------
# Runner
# ---------------------------------------------------------------------------


def build_env(
    n_clients: int,
    *,
    seed: int = 0,
    n_providers: int = 16,
    n_meta_shards: int = 8,
    psize: int = 64 * 1024,
    chunk_pages: int = 4,
    ops_per_client: int = 2,
    record_trace: bool = False,
    scenario: Optional[str] = None,
    **svc_kwargs,
) -> ScenarioEnv:
    """A simulated deployment + env, ready for spawn/run.

    Pass ``scenario`` to apply that scenario's ``env_defaults`` (e.g.
    the §5 reproductions pin ``page_cache_bytes=0``); explicit
    ``svc_kwargs`` still win.  Prebuilding an env without naming the
    scenario skips those pins — deliberate only when you want to study
    a scenario under non-default deployment settings.
    """
    if scenario is not None:
        svc_kwargs = {**SCENARIOS[scenario].env_defaults, **svc_kwargs}
    sim = Simulator(seed=seed, record_trace=record_trace)
    svc = BlobSeerService(
        n_providers=n_providers, n_meta_shards=n_meta_shards,
        wire=Wire(clock=sim), **svc_kwargs,
    )
    return ScenarioEnv(
        sim=sim, svc=svc, n_clients=n_clients, psize=psize,
        chunk_pages=chunk_pages, ops_per_client=ops_per_client,
    )


def run_scenario(
    scenario: str,
    n_clients: int,
    *,
    seed: int = 0,
    failures: Sequence[Tuple[float, str]] = (),
    raise_errors: bool = True,
    env: Optional[ScenarioEnv] = None,
    **env_kwargs,
) -> ScenarioResult:
    """Run one §5 scenario at ``n_clients`` simulated clients.

    Setup happens on the driver thread (free in virtual time); counters
    and wire accounting are then zeroed so makespan/throughput measure
    only the concurrent phase.  ``failures`` downs endpoints at
    scheduled virtual instants via a chaos task.
    """
    spec = SCENARIOS[scenario]
    if env is None:
        env = build_env(n_clients, seed=seed, scenario=scenario,
                        **env_kwargs)
    sim, svc = env.sim, env.svc
    spec.setup(env)
    svc.reset_rpc_counters()

    for i in range(n_clients):
        sim.spawn(spec.program(env, i), name=f"{scenario}-{i:03d}")
    for t, target in failures:
        parse_failure_target(target)  # reject malformed specs up front
        def chaos(target=target):
            # Targets resolve at FIRE time (see apply_failure_target):
            # the leader a "vm-leader:<idx>" spec downs is whoever holds
            # the lineage lease at that virtual instant.
            killed = apply_failure_target(svc, env.state, target)
            return {"ops": 0, "bytes": 0, "killed": killed}
        sim.spawn_at(t, chaos, name=f"chaos-{target}")

    t0 = time.perf_counter()
    sim.run(raise_errors=raise_errors)
    wall = time.perf_counter() - t0

    client_results = sim.results()
    errors = {k: repr(v) for k, v in sim.errors().items()}
    ops = sum(r.get("ops", 0) for r in client_results.values()
              if isinstance(r, dict))
    moved = sum(r.get("bytes", 0) for r in client_results.values()
                if isinstance(r, dict))
    makespan = max(sim.now(), svc.wire.sim_span())
    return ScenarioResult(
        scenario=scenario, n_clients=n_clients, seed=seed, ops=ops,
        bytes_moved=moved, makespan=makespan,
        aggregate_mbps=moved / max(makespan, 1e-12) / 1e6,
        wall_seconds=wall, events=sim.events_dispatched,
        rpc=svc.rpc_report(), trace_digest=sim.trace_digest(),
        client_results=client_results, errors=errors,
    )
