"""Metadata DHT.

Paper §4.1/§5: tree nodes are stored on metadata providers "in a
distributed way, using a simple DHT".  The paper's "simple static
distribution scheme" is replaced by a consistent-hash ring
(:class:`~repro.core.placement.HashRing`): a key's home shards are its
``replication`` distinct ring owners, so placement stays a pure
function of (ring membership, key) while shards can now join and drain
*online*.  Keys are immutable once written (new metadata is always
*created*, never updated — the paper's key design choice), which is
what makes lock-free concurrent access safe.

Reconfiguration follows the Fragmented-ARES playbook (arXiv:2201.13292):
while a join/drain is in flight the ring keeps BOTH configurations and
a per-range configuration pointer — the merged arc set of the old and
new rings.  Writes land on the union of both configurations' owners
(idempotent re-puts are permitted, so a racing writer can never lose a
key to a mid-flight range transfer); reads race the same union.  A
budgeted migration round copies each arc's keys to their new owners and
then flips that arc's pointer; once every arc has flipped, a completion
sweep re-verifies every key against the final ring, deletes the copies
on shards that no longer own them, and (for a drain) deregisters the
now-empty shard — zero failed ops throughout.

Beyond-paper: optional R-way replication of each key across distinct
ring owners (the paper lists volatility/failure support as future
work), plus replica racing on reads for straggler mitigation.
"""

from __future__ import annotations

import threading
from typing import Dict, Hashable, List, Optional, Set, Tuple

from repro.core.placement import HashRing
from repro.core.placement import stable_hash as _ring_hash
from repro.core.transport import (
    DELETE_NODE_KEY_BYTES,
    MIGRATE_META_KEY_BYTES,
    RING_ANNOUNCE_BYTES,
    EndpointDown,
    Wire,
)


class MetadataShard:
    """One metadata provider endpoint."""

    def __init__(self, shard_id: str, wire: Wire) -> None:
        self.shard_id = shard_id
        self.wire = wire
        self._kv: Dict[Hashable, object] = {}
        self._lock = threading.Lock()

    def put(self, key: Hashable, value: object, nbytes: int, peer: Optional[str] = None) -> None:
        self.wire.transfer(self.shard_id, nbytes, inbound=True, peer=peer)
        self.put_local(key, value)

    def put_local(self, key: Hashable, value: object) -> None:
        with self._lock:
            existing = self._kv.get(key)
            # Immutability invariant: a key is written at most once
            # (concurrent writers never produce the same (version, range)).
            # Replica re-sends of an identical node are permitted.
            if existing is not None and existing != value:
                raise ValueError(f"DHT key {key!r} rewritten with different value")
            self._kv[key] = value

    def get(self, key: Hashable, nbytes: int, peer: Optional[str] = None) -> Optional[object]:
        self.wire.transfer(self.shard_id, nbytes, inbound=False, peer=peer)
        return self.get_local(key)

    def get_local(self, key: Hashable) -> Optional[object]:
        with self._lock:
            return self._kv.get(key)

    def delete_local(self, key: Hashable) -> bool:
        """Remove a key (GC sweep). Immutability only ever applies while
        a key exists: retired keys are deleted, never rewritten."""
        with self._lock:
            return self._kv.pop(key, None) is not None

    def keys(self) -> List[Hashable]:
        """Snapshot of the shard's stored keys (migration planning)."""
        with self._lock:
            return list(self._kv)

    def __len__(self) -> int:
        with self._lock:
            return len(self._kv)


class MetadataDHT:
    """Static-distribution DHT over ``n_shards`` metadata providers."""

    def __init__(
        self,
        wire: Wire,
        n_shards: int,
        *,
        replication: int = 1,
        node_nbytes: int = 64,
    ) -> None:
        self.wire = wire
        self.replication = max(1, min(replication, n_shards))
        self.node_nbytes = node_nbytes  # wire-cost estimate per tree node
        self.shards: List[MetadataShard] = [
            MetadataShard(f"meta-{i:04d}", wire) for i in range(n_shards)
        ]
        self._by_id: Dict[str, MetadataShard] = {
            s.shard_id: s for s in self.shards}
        self.ring = HashRing(self._by_id)
        # ARES-style reconfiguration state: while a join/drain is in
        # flight, ``_old_ring`` holds the previous configuration,
        # ``_arcs`` the merged per-range pointer boundaries, and
        # ``_flipped`` the arcs already transferred to the new ring.
        self._old_ring: Optional[HashRing] = None
        self._arcs: List[int] = []
        self._flipped: Set[int] = set()
        self._draining_shard: Optional[str] = None
        self._ctr_lock = threading.Lock()
        self._counters: Dict[str, int] = {
            "get_keys": 0,        # logical keys requested
            "get_keys_cached": 0,  # keys served by client node caches, no RPC
            "get_rounds": 0,      # client-visible batched waves (get/get_many calls loop)
            "get_shard_rpcs": 0,  # per-shard round trips actually issued
            "put_keys": 0,
            "put_shard_rpcs": 0,
            "delete_keys": 0,        # logical keys swept
            "delete_shard_rpcs": 0,  # batched per-shard delete round trips
            "migrate_keys": 0,       # key copies moved by ring rebalance
            "migrate_shard_rpcs": 0,  # batched per-shard migration round trips
            "arcs_flipped": 0,       # per-range configuration-pointer flips
        }

    def _count(self, **deltas: int) -> None:
        with self._ctr_lock:
            for k, d in deltas.items():
                self._counters[k] += d

    def rpc_counters(self) -> Dict[str, int]:
        with self._ctr_lock:
            return dict(self._counters)

    def reset_rpc_counters(self) -> None:
        with self._ctr_lock:
            for k in self._counters:
                self._counters[k] = 0

    def note_cache_hits(self, n: int) -> None:
        """Client node caches report their hits here, so one
        ``rpc_counters()`` read shows cache-hit vs RPC accounting for
        the whole metadata plane (``get_keys`` = keys that DID cross
        the wire path, ``get_keys_cached`` = keys that did not)."""
        self._count(get_keys_cached=n)

    # -- key placement: consistent-hash ring, R distinct owners -----------------
    @staticmethod
    def key_pos(key: Hashable) -> int:
        """Ring position of a metadata key (stable across runs — keys
        are tuples of deterministic components, never raw page ids)."""
        return _ring_hash(repr(key))

    def _home_shards(self, key: Hashable) -> List[MetadataShard]:
        """The shards serving ``key`` right now.

        Steady state: the key's ``replication`` distinct ring owners.
        Mid-reconfiguration, the per-range configuration pointer
        decides: a flipped arc routes to the new ring alone; an
        unflipped arc routes to the UNION of old and new owners — puts
        land on both configurations (idempotent re-puts make that safe)
        and reads race both, so no interleaving of writers with the
        range transfer can lose or miss a key.
        """
        pos = self.key_pos(key)
        if self._old_ring is None:
            ids = self.ring.owners_at(pos, self.replication)
        else:
            new = self.ring.owners_at(pos, self.replication)
            if HashRing.arc_index(self._arcs, pos) in self._flipped:
                ids = new
            else:
                old = self._old_ring.owners_at(pos, self.replication)
                ids = list(dict.fromkeys(old + new))
        return [self._by_id[i] for i in ids]

    # -- elastic membership ------------------------------------------------------
    @property
    def reconfiguring(self) -> bool:
        return self._old_ring is not None

    def _begin_reconfig(self, old_nodes: Set[str]) -> None:
        self.wire.transfer(self.shards[0].shard_id, RING_ANNOUNCE_BYTES,
                           inbound=True, async_peer=True)
        self._old_ring = HashRing(old_nodes)
        self._arcs = HashRing.merged_arcs(self._old_ring, self.ring)
        self._flipped = set()

    def begin_join(self, shard_id: str) -> MetadataShard:
        """A metadata shard joins the ring; its owed key ranges arrive
        via subsequent :meth:`migration_round` calls (ARES: transfer
        the fragment set, then flip each range's pointer)."""
        if self._old_ring is not None:
            raise RuntimeError("a ring reconfiguration is already in flight")
        if shard_id in self._by_id:
            raise ValueError(f"shard {shard_id} already registered")
        old_nodes = self.ring.nodes()
        shard = MetadataShard(shard_id, self.wire)
        self.shards.append(shard)
        self._by_id[shard_id] = shard
        self.ring.add(shard_id)
        self._begin_reconfig(old_nodes)
        return shard

    def begin_drain(self, shard_id: str) -> None:
        """Start draining a shard: it leaves the new configuration at
        once (new writes stop targeting it beyond the transfer window)
        but keeps serving its arcs until they flip; the completion sweep
        deregisters it empty."""
        if self._old_ring is not None:
            raise RuntimeError("a ring reconfiguration is already in flight")
        if shard_id not in self._by_id:
            raise KeyError(f"unknown shard {shard_id}")
        if len(self.shards) - 1 < self.replication:
            raise RuntimeError(
                f"draining {shard_id} would leave fewer shards than "
                f"replication={self.replication}")
        old_nodes = self.ring.nodes()
        self.ring.remove(shard_id)
        self._draining_shard = shard_id
        self._begin_reconfig(old_nodes)

    def migration_round(self, budget_bytes: int) -> Dict[str, int]:
        """One budgeted migration round of the in-flight reconfiguration.

        Scans the old configuration's shards once, buckets keys by
        merged arc, copies each unflipped arc's keys to their new-ring
        owners (one batched round trip per destination shard), and
        flips the arc's configuration pointer.  Arcs are processed in
        ring order and the round stops when the byte budget is spent —
        migration runs *concurrently* with client traffic, never as a
        stop-the-world pass.  When every arc has flipped, a completion
        sweep deletes stale copies from shards that no longer own their
        keys and deregisters a drained shard.  Returns round stats with
        ``done=1`` once the reconfiguration is fully complete.
        """
        stats = {"arcs_flipped": 0, "keys_moved": 0, "bytes_moved": 0,
                 "done": 0}
        if self._old_ring is None:
            stats["done"] = 1
            return stats
        per_key = self.node_nbytes + MIGRATE_META_KEY_BYTES
        # one scan, bucketed by arc (keys seen on any old-config shard)
        by_arc: Dict[int, Dict[Hashable, MetadataShard]] = {}
        for shard in self.shards:
            for key in shard.keys():
                arc = HashRing.arc_index(self._arcs, self.key_pos(key))
                if arc in self._flipped:
                    continue
                by_arc.setdefault(arc, {}).setdefault(key, shard)
        spent = 0
        for arc in range(len(self._arcs)):
            if arc in self._flipped:
                continue
            moves: Dict[MetadataShard, List[Hashable]] = {}
            for key, holder in sorted(
                    by_arc.get(arc, {}).items(),
                    key=lambda kv: (self.key_pos(kv[0]), repr(kv[0]))):
                for dst_id in self.ring.owners_at(
                        self.key_pos(key), self.replication):
                    dst = self._by_id[dst_id]
                    if dst.get_local(key) is None:
                        moves.setdefault(dst, []).append(key)
            cost = per_key * sum(len(ks) for ks in moves.values())
            if moves and spent and spent + cost > budget_bytes:
                break  # budget spent; later arcs wait for the next round
                # (a round always flips at least one non-empty arc, so an
                # arc larger than the budget still makes progress)
            for dst in sorted(moves, key=lambda s: s.shard_id):
                batch = moves[dst]
                self.wire.transfer_batch(
                    dst.shard_id, [per_key] * len(batch), inbound=True,
                    async_peer=True,
                    fire_and_forget=self.wire.clock.is_virtual)
                for key in batch:
                    dst.put_local(key, by_arc[arc][key].get_local(key))
                self._count(migrate_keys=len(batch), migrate_shard_rpcs=1)
            spent += cost
            self._flipped.add(arc)
            self._count(arcs_flipped=1)
            stats["arcs_flipped"] += 1
            stats["keys_moved"] += sum(len(ks) for ks in moves.values())
            stats["bytes_moved"] += cost
        if len(self._flipped) >= len(self._arcs):
            stats["bytes_moved"] += self._complete_reconfig()
            stats["done"] = 1
        return stats

    def _complete_reconfig(self) -> int:
        """Completion sweep: re-verify every key against the final ring
        (catches a writer that raced an arc flip), delete copies from
        shards that no longer own them, deregister a drained shard."""
        moved_bytes = 0
        per_key = self.node_nbytes + MIGRATE_META_KEY_BYTES
        for shard in list(self.shards):
            stale: List[Hashable] = []
            for key in shard.keys():
                owner_ids = self.ring.owners_at(
                    self.key_pos(key), self.replication)
                if shard.shard_id in owner_ids:
                    continue
                # safety net for raced writes: make sure every final
                # owner holds the key before this copy goes away
                for dst_id in owner_ids:
                    dst = self._by_id[dst_id]
                    if dst.get_local(key) is None:
                        self.wire.transfer(
                            dst.shard_id, per_key, inbound=True,
                            async_peer=True,
                            fire_and_forget=self.wire.clock.is_virtual)
                        dst.put_local(key, shard.get_local(key))
                        moved_bytes += per_key
                        self._count(migrate_keys=1, migrate_shard_rpcs=1)
                stale.append(key)
            if stale:
                self.wire.transfer_batch(
                    shard.shard_id, [DELETE_NODE_KEY_BYTES] * len(stale),
                    inbound=True, async_peer=True,
                    fire_and_forget=self.wire.clock.is_virtual)
                for key in stale:
                    shard.delete_local(key)
        if self._draining_shard is not None:
            gone = self._by_id.pop(self._draining_shard)
            self.shards.remove(gone)
            self._draining_shard = None
        self._old_ring = None
        self._arcs = []
        self._flipped = set()
        return moved_bytes

    def put(self, key: Hashable, value: object, peer: Optional[str] = None) -> None:
        errs = []
        ok = 0
        self._count(put_keys=1)
        for shard in self._home_shards(key):
            try:
                shard.put(key, value, self.node_nbytes, peer=peer)
                ok += 1
                self._count(put_shard_rpcs=1)
            except EndpointDown as e:
                errs.append(e)
        if ok == 0:
            raise EndpointDown(f"all metadata replicas down for {key!r}: {errs}")

    def put_many(self, items, peer: Optional[str] = None) -> float:
        """Batched put: one wire round-trip per (shard, batch).

        BUILD_META writes all of an update's tree nodes "in parallel"
        (paper Alg 4 l.34); batching them per home shard collapses the
        per-node latency on the writer's NIC into one per shard — a
        measurable append-bandwidth win at small page sizes (§Perf).
        Under a **virtual clock** the per-shard batches are issued
        fire-and-forget and the call sleeps once to the *latest* batch
        completion, so writes to distinct shards overlap in simulated
        time instead of serializing on the issuing task — the paper's
        "in parallel" made literal.  The blocking contract is
        unchanged: when ``put_many`` returns, every batch's transfer
        has completed.  Returns that completion instant (0.0 on the
        wall backend).  Storage semantics are unchanged (same keys,
        same shards, same immutability check).
        """
        by_shard: Dict[MetadataShard, list] = {}
        n_items = 0
        for key, value in items:
            n_items += 1
            for shard in self._home_shards(key):
                by_shard.setdefault(shard, []).append((key, value))
        self._count(put_keys=n_items)
        virtual = self.wire.clock.is_virtual
        failures = 0
        done_at = 0.0
        for shard, batch in by_shard.items():
            try:
                d = self.wire.transfer_batch(shard.shard_id,
                                             [self.node_nbytes] * len(batch),
                                             inbound=True, peer=peer,
                                             async_peer=True,
                                             fire_and_forget=virtual)
                self._count(put_shard_rpcs=1)
                done_at = max(done_at, d if virtual else 0.0)
                for key, value in batch:
                    shard.put_local(key, value)
            except EndpointDown:
                failures += 1
        if failures == len(by_shard) and by_shard:
            raise EndpointDown("all metadata shards down for batched put")
        if virtual and done_at > self.wire.clock.now():
            # the blocking contract: return only once the last batch
            # has arrived (overlapped, not serialized)
            self.wire.clock.sleep_until(done_at)
        return done_at

    def get(self, key: Hashable, peer: Optional[str] = None) -> Optional[object]:
        homes = self._home_shards(key)
        # replica racing: least-busy replica first (shard-id tie-break
        # keeps replays deterministic when queue depths are equal)
        homes.sort(key=lambda s: (self.wire.stats(s.shard_id).sim_busy_until,
                                  s.shard_id))
        last: Optional[Exception] = None
        reachable = False
        self._count(get_keys=1, get_rounds=1)
        for shard in homes:
            try:
                value = shard.get(key, self.node_nbytes, peer=peer)
                self._count(get_shard_rpcs=1)
                reachable = True
                if value is not None:
                    return value
                # A None miss on one replica may be the hole a partial
                # put left behind; keep trying the remaining replicas
                # before concluding the key is absent.
            except EndpointDown as e:
                last = e
        if reachable:
            return None
        raise EndpointDown(f"all metadata replicas down for {key!r}: {last}")

    def get_many(
        self, keys, peer: Optional[str] = None
    ) -> Dict[Hashable, Optional[object]]:
        """Batched get: group keys per home shard, one round trip per shard.

        The read-side mirror of :meth:`put_many`: READ_META descends a
        whole tree *level* at a time, so the per-node latency collapses
        into one batched round trip per (level, shard).  Per-key replica
        failover matches :meth:`get` exactly — a downed shard or a
        replication hole sends just the affected keys to their next
        replica (another batched wave), and ``EndpointDown`` is raised
        only when every replica of a key is unreachable.
        """
        # key -> ordered replica shards still to try (least busy first)
        pending: Dict[Hashable, List[MetadataShard]] = {}
        for key in dict.fromkeys(keys):
            homes = self._home_shards(key)
            homes.sort(key=lambda s: (self.wire.stats(s.shard_id).sim_busy_until,
                                      s.shard_id))
            pending[key] = homes
        out: Dict[Hashable, Optional[object]] = {}
        reachable_miss = set()  # keys a live shard answered None for
        self._count(get_keys=len(pending))
        while pending:
            self._count(get_rounds=1)
            by_shard: Dict[MetadataShard, List[Hashable]] = {}
            for key, homes in pending.items():
                by_shard.setdefault(homes[0], []).append(key)
            nxt: Dict[Hashable, List[MetadataShard]] = {}
            for shard, batch in by_shard.items():
                try:
                    self.wire.transfer_batch(shard.shard_id,
                                             [self.node_nbytes] * len(batch),
                                             inbound=False, peer=peer,
                                             async_peer=True)
                    self._count(get_shard_rpcs=1)
                except EndpointDown as e:
                    for key in batch:
                        rest = pending[key][1:]
                        if rest:
                            nxt[key] = rest
                        elif key in reachable_miss:
                            out[key] = None
                        else:
                            raise EndpointDown(
                                f"all metadata replicas down for {key!r}: {e}"
                            )
                    continue
                for key in batch:
                    value = shard.get_local(key)
                    if value is not None:
                        out[key] = value
                        continue
                    reachable_miss.add(key)
                    rest = pending[key][1:]
                    if rest:
                        nxt[key] = rest  # hole fallthrough, as in get()
                    else:
                        out[key] = None
            pending = nxt
        return out

    def delete_many(
        self, keys, peer: Optional[str] = None
    ) -> Tuple[int, List[Hashable]]:
        """Batched delete (GC sweep): one round trip per touched shard.

        Every replica of every key is contacted; all commands bound for
        one shard collapse into a single ``transfer_batch`` carrying
        ``DELETE_NODE_KEY_BYTES`` per key (a delete moves identifiers,
        not node payloads).  Returns ``(n_deleted, failed_keys)`` where
        ``failed_keys`` lists keys with at least one unreachable replica
        — the sweep retries those in a later round (deletes are
        idempotent), so a downed shard never silently leaks its keys.
        """
        by_shard: Dict[MetadataShard, List[Hashable]] = {}
        n_keys = 0
        for key in dict.fromkeys(keys):
            n_keys += 1
            for shard in self._home_shards(key):
                by_shard.setdefault(shard, []).append(key)
        self._count(delete_keys=n_keys)
        removed: Dict[Hashable, bool] = {}
        failed_set: Dict[Hashable, bool] = {}
        for shard, batch in by_shard.items():
            try:
                self.wire.transfer_batch(shard.shard_id,
                                         [DELETE_NODE_KEY_BYTES] * len(batch),
                                         inbound=True, peer=peer,
                                         async_peer=True)
                self._count(delete_shard_rpcs=1)
                for key in batch:
                    if shard.delete_local(key):
                        removed[key] = True
            except EndpointDown:
                for key in batch:
                    failed_set[key] = True
        deleted = sum(1 for k in removed if k not in failed_set)
        return deleted, list(failed_set)

    # -- introspection -----------------------------------------------------------
    def total_keys(self) -> int:
        return sum(len(s) for s in self.shards)

    def shard_loads(self) -> List[Tuple[str, int]]:
        return [(s.shard_id, len(s)) for s in self.shards]
