"""BlobSeer core: the paper's contribution.

Versioned, page-striped blob storage with distributed segment-tree
metadata over a DHT, total-order snapshot publication, and cheap
branching — per Nicolae, Antoniu & Bougé (DAMAP 2009).
"""

from repro.core.blob import BlobClient, ReadError
from repro.core.service import BlobSeerService
from repro.core.sim import Clock, SimDeadlock, Simulator, WallClock
from repro.core.transport import Wire, EndpointDown
from repro.core.version_manager import (
    RetiredVersion,
    VersionManager,
    VersionUnpublished,
    WriteBeyondEnd,
)

__all__ = [
    "BlobClient",
    "BlobSeerService",
    "Clock",
    "EndpointDown",
    "ReadError",
    "RetiredVersion",
    "SimDeadlock",
    "Simulator",
    "VersionManager",
    "VersionUnpublished",
    "WallClock",
    "Wire",
    "WriteBeyondEnd",
]


def collect_garbage(svc, keep=None, **kwargs):
    """Distributed snapshot-retirement GC (see repro.core.gc)."""
    from repro.core.gc import collect_garbage as _gc

    return _gc(svc, keep, **kwargs)
