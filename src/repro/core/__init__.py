"""BlobSeer core: the paper's contribution, grown toward production.

Versioned, page-striped blob storage with distributed segment-tree
metadata over a DHT, total-order snapshot publication, and cheap
branching — per Nicolae, Antoniu & Bougé (2009) — plus the
beyond-paper subsystems this repo has added on top: a batched
metadata/data request plane, a deterministic virtual-time concurrency
harness (:class:`Simulator`), concurrent-safe distributed GC with
typed :class:`RetiredVersion` answers, and an immutability-aware
read-path cache hierarchy (:class:`NodeCache`/:class:`PageCache`).
See ARCHITECTURE.md for the deep dives and README.md for the map.
"""

from repro.core.blob import BlobClient, ReadError
from repro.core.cache import NodeCache, PageCache
from repro.core.dedup_index import DedupIndex
from repro.core.service import BlobSeerService
from repro.core.sim import Clock, SimDeadlock, Simulator, WallClock
from repro.core.transport import Wire, EndpointDown
from repro.core.version_manager import (
    LineageShard,
    RetiredVersion,
    VersionManager,
    VersionUnpublished,
    WriteBeyondEnd,
)

__all__ = [
    "BlobClient",
    "BlobSeerService",
    "Clock",
    "DedupIndex",
    "EndpointDown",
    "LineageShard",
    "NodeCache",
    "PageCache",
    "ReadError",
    "RetiredVersion",
    "SimDeadlock",
    "Simulator",
    "VersionManager",
    "VersionUnpublished",
    "WallClock",
    "Wire",
    "WriteBeyondEnd",
]


def collect_garbage(svc, keep=None, **kwargs):
    """Distributed snapshot-retirement GC (see repro.core.gc)."""
    from repro.core.gc import collect_garbage as _gc

    return _gc(svc, keep, **kwargs)
