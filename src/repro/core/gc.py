"""Snapshot retirement: mark-and-sweep page GC (beyond paper).

The paper's copy-on-write versioning never frees pages ("versioning
efficiency ... reasonably acceptable overhead of storage space"); a
production deployment must retire old checkpoints.  Because metadata is
immutable and pages are content-addressed by unique ids, GC is a pure
mark-and-sweep over the segment trees of the snapshots to KEEP:

1. mark: walk READ_META over the full range of every kept snapshot of
   every blob (branches walk their lineage), collecting live page ids;
2. sweep: delete unreferenced pages from providers.

Metadata tree nodes of retired versions are swept by key prefix.
Safe concurrently with readers of kept versions (their pages are
marked); callers must quiesce readers of versions being retired —
the version manager's published watermark makes "still referenced"
checks trivial for the checkpoint layer (it retires only versions
below every client's pin).
"""

from __future__ import annotations

from typing import Dict, Iterable, List, Set, Tuple

from repro.core import segment_tree as st
from repro.core.pages import node_children
from repro.core.service import BlobSeerService


def live_page_ids(
    svc: BlobSeerService, keep: Dict[str, Iterable[int]]
) -> Tuple[Set[str], Set[Tuple]]:
    """(live page ids, live metadata node keys) for kept snapshots."""
    client = svc.client("gc")
    pages: Set[str] = set()
    node_keys: Set[Tuple] = set()
    for blob_id, versions in keep.items():
        owner_of = client._owner_fn(blob_id)
        for v in versions:
            if v == 0:
                continue
            rec = svc.vm.update_log(blob_id, v)
            # walk the whole tree, remembering every visited node key
            stack = [(v, 0, rec.root_pages)]
            while stack:
                nv, off, size = stack.pop()
                key = (owner_of(nv), nv, off, size)
                if key in node_keys:
                    continue
                node = client.dht.get(key)
                if node is None:
                    continue
                node_keys.add(key)
                if isinstance(node, st.LeafNode):
                    pages.add(node.page_id)
                    continue
                (lo, ls), (ro, rs) = node_children(off, size)
                if node.vl is not None:
                    stack.append((node.vl, lo, ls))
                if node.vr is not None:
                    stack.append((node.vr, ro, rs))
    return pages, node_keys


def collect_garbage(
    svc: BlobSeerService, keep: Dict[str, Iterable[int]]
) -> Dict[str, int]:
    """Retire every page/metadata node not reachable from ``keep``.

    ``keep`` maps blob id -> iterable of snapshot versions to preserve
    (across branches, list each blob explicitly).  Returns sweep stats.
    """
    live_pages, live_nodes = live_page_ids(svc, keep)
    swept_pages = 0
    for prov in svc.pm.all_providers():
        for pid in list(prov.store.iter_pids()):
            if pid not in live_pages:
                prov.store.delete(pid)
                swept_pages += 1
    swept_nodes = 0
    for shard in svc.dht.shards:
        with shard._lock:
            dead = [k for k in shard._kv if k not in live_nodes]
            for k in dead:
                del shard._kv[k]
            swept_nodes += len(dead)
    return {
        "live_pages": len(live_pages),
        "swept_pages": swept_pages,
        "live_nodes": len(live_nodes),
        "swept_nodes": swept_nodes,
    }
