"""Distributed snapshot-retirement GC (beyond paper).

The paper's copy-on-write versioning never frees space ("versioning
efficiency ... reasonably acceptable overhead of storage space"); a
production deployment must retire old snapshots **without stopping
readers or writers**.  GC here is a distributed protocol that runs
entirely through the RPC plane — every mark fetch and every sweep
delete crosses the :class:`~repro.core.transport.Wire` and shows up in
``service.rpc_report()`` — and is safe concurrently with live clients:

1. **plan** (version manager, one control RPC per blob): atomically
   compute the retirement set from the retention policy (keep-last-K),
   pin leases, branch roots and in-flight writers' border anchors; mark
   retire-*intent* and journal it to the WAL.  From this instant
   readers/pinners/branchers of a retired version get a typed
   :class:`~repro.core.version_manager.RetiredVersion`.  With the
   sharded write plane every keep rule is an intra-lineage fact
   (branches share their ancestor's shard), so each blob's plan runs
   under its own lineage lock and scans only that lineage — a GC round
   never stalls writers of unrelated blobs.
2. **drain** (epoch barrier): wait until every read lease opened on a
   retired version *before* the intent has been released.  Reads of
   kept versions are never blocked — their safety comes from marking.
3. **mark**: walk the segment trees of every kept snapshot (all blobs,
   so branch lineages are covered) *level-synchronously* with batched
   ``get_many`` — at most ``depth + 1`` latency waves per tree, cost
   proportional to the live set, not to history length.
4. **sweep**: the candidate set of a retired version is derived with no
   I/O at all — its created tree nodes from the deterministic tree
   shape (``iter_created_nodes``) and its pages from the journaled page
   descriptors.  Candidates not reachable from any kept snapshot are
   deleted with batched wire verbs: ``MetadataDHT.delete_many`` (one
   round trip per touched shard) and ``ProviderManager.delete_pages``
   (one per touched endpoint).  Deletes are idempotent; versions whose
   deletes all succeeded are finalized in the WAL, the rest are
   re-swept next round.  When content-addressed dedup is in play the
   sweep first releases the retired versions' page references through
   the :class:`~repro.core.dedup_index.DedupIndex` — bytes are deleted
   only at refcount zero, so an equal-content page shared by another
   lineage survives its co-owners' retirement (see ``_sweep``).

Why concurrent readers/writers are safe:

* a reader of a kept version only touches nodes/pages reachable from a
  kept root — all marked live, never deleted;
* a reader of a retired version is either rejected at ``enter_read``
  (typed error) or drained before the first delete goes out;
* a writer's border descent anchors on a published version the version
  manager keeps alive while the update is in flight (``vp`` anchors),
  and the nodes it creates carry a version number newer than anything
  retired — never sweep candidates.

Cache coherence: the read-path page cache (``core/cache.py``) is
evicted twice per round — at retire-*intent* (the ``gc_epoch`` bump
fires the version manager's GC listeners with the retired versions'
page ids) and again inside ``ProviderManager.delete_pages`` before the
first delete RPC, which also dooms in-flight fetches of the doomed
pages.  A cached page therefore never outlives its sweep; GC itself
never reads through a cache (``mark_live`` walks ``svc.dht`` raw).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.core import segment_tree as st
from repro.core.pages import UpdateExtent, iter_created_nodes, node_children
from repro.core.placement import logical_pid
from repro.core.transport import EndpointDown
from repro.core.version_manager import VersionUnpublished, owner_fn_for_lineage


def mark_live(
    svc, peer: Optional[str] = None
) -> Tuple[Set[Tuple], Set[str], int, int]:
    """Batched mark phase: walk every kept snapshot's tree.

    Returns ``(live_node_keys, live_page_ids, rounds, keys_fetched)``.
    The walk is level-synchronous across *all* roots of *all* blobs at
    once: each wave fetches the whole frontier with one ``get_many``
    (one batched round trip per touched shard), so the entire mark
    costs at most ``max tree depth + 1`` latency waves.  Shared
    subtrees are visited once (the frontier is deduplicated on node
    keys), which is what makes the cost proportional to the live set.
    """
    owner_of: Dict[str, object] = {}
    frontier: Dict[Tuple, str] = {}  # node key -> root blob id (for owner fn)
    for blob_id, roots in sorted(svc.vm.mark_roots().items()):
        owner_of[blob_id] = owner_fn_for_lineage(svc.vm.lineage(blob_id))
        for version, root_pages in roots:
            key = (owner_of[blob_id](version), version, 0, root_pages)
            frontier.setdefault(key, blob_id)

    live_nodes: Set[Tuple] = set()
    live_pages: Set[str] = set()
    rounds = keys_fetched = 0
    while frontier:
        keys = sorted(frontier)
        nodes = svc.dht.get_many(keys, peer=peer)
        rounds += 1
        keys_fetched += len(keys)
        nxt: Dict[Tuple, str] = {}
        for key in keys:
            blob_id = frontier[key]
            node = nodes.get(key)
            if node is None:
                raise st.MetadataMissing(f"mark walk: missing node {key!r}")
            live_nodes.add(key)
            if isinstance(node, st.LeafNode):
                live_pages.add(node.page_id)
                continue
            _owner, _v, off, size = key
            (lo, ls), (ro, rs) = node_children(off, size)
            for child_v, c_off, c_size in ((node.vl, lo, ls), (node.vr, ro, rs)):
                if child_v is None:
                    continue
                ckey = (owner_of[blob_id](child_v), child_v, c_off, c_size)
                if ckey not in live_nodes:
                    nxt.setdefault(ckey, blob_id)
        frontier = nxt
    return live_nodes, live_pages, rounds, keys_fetched


def _sweep(
    svc,
    pending: Dict[str, List],
    live_nodes: Set[Tuple],
    live_pages: Set[str],
    peer: Optional[str],
    finalize: bool = True,
) -> Dict[str, int]:
    """Batched sweep of ``pending`` (blob id -> retired UpdateRecords).

    Candidate nodes/pages come from pure page math and the journaled
    page descriptors; everything not marked live is deleted through the
    wire, grouped per shard / per endpoint across all blobs at once.

    Page locations are the assign-time replica lists (leaf nodes are
    immutable, so nothing fresher exists).  A version with a replica on
    a dead/deregistered endpoint stays *pending* and is retried every
    round — deliberately: finalizing it would leak the replica if the
    endpoint comes back, and the retry costs one batched RPC attempt
    per downed endpoint per round.

    Dedup awareness: when the deployment's content-hash index has ever
    registered a page, every pending version's pd references are first
    released through it in ONE batched ``release_many`` (idempotent per
    ``(blob, version, rel)``).  A page whose refcount stays positive is
    still held by another version — not deleted, and *not* a reason to
    defer this version; a page whose refcount reached zero and is not
    pinned live is deleted now.  Everything else (unindexed pages,
    zero-but-live) falls through to the pre-dedup mark-based logic, so
    refcounts only ever *defer* deletions, never cause one the mark
    phase would forbid.
    """
    idx = getattr(svc, "dedup_index", None)
    use_idx = idx is not None and idx.ever_registered
    keep_pids: Set[str] = set()
    drop_pids: Set[str] = set()
    if use_idx:
        refs = [((blob_id, rec.version, rel), pid)
                for blob_id, recs in sorted(pending.items())
                for rec in recs
                for pid, rel, _provs, _length in rec.pd]
        if refs:
            keep_pids, drop_pids = idx.release_many(
                refs, live_pages, peer=peer)

    dead_nodes: List[Tuple] = []
    dead_pages: List[Tuple[Tuple[str, ...], str]] = []
    page_bytes: Dict[str, int] = {}
    node_version: Dict[Tuple, Tuple[str, int]] = {}
    page_version: Dict[str, Tuple[str, int]] = {}
    # versions with candidates still reachable from a *kept* snapshot:
    # those items become garbage only when their keeper retires, so the
    # version must stay pending (never finalize) until everything it
    # created is confirmed dead and deleted — otherwise shared pages
    # would leak forever once the version left sweep_pending
    has_live: Set[Tuple[str, int]] = set()
    for blob_id, recs in sorted(pending.items()):
        for rec in recs:
            ext = UpdateExtent(rec.p0, rec.p1, rec.root_pages)
            for off, size in iter_created_nodes(ext):
                key = (blob_id, rec.version, off, size)
                if key in live_nodes:
                    has_live.add((blob_id, rec.version))
                else:
                    dead_nodes.append(key)
                    node_version[key] = (blob_id, rec.version)
            for pid, _rel, provs, length in rec.pd:
                if pid in keep_pids:
                    # refcount still positive: another version's pd holds
                    # the page — this version is done with it
                    continue
                if pid in drop_pids:
                    if pid not in page_version:
                        dead_pages.append((tuple(provs), pid))
                        page_bytes[pid] = length
                        page_version[pid] = (blob_id, rec.version)
                    continue
                if pid in live_pages:
                    has_live.add((blob_id, rec.version))
                elif pid not in page_version:
                    if use_idx:
                        # mark-dead but possibly resurrected: a lookup
                        # may have re-acquired the page since the mark
                        # (zero-refcount entries stay matchable) — claim
                        # it under the index lock or leave it alone
                        _claimed, resurrected = idx.claim_dead((pid,))
                        if resurrected:
                            continue  # new holder's release owns deletion
                    dead_pages.append((tuple(provs), pid))
                    page_bytes[pid] = length
                    page_version[pid] = (blob_id, rec.version)

    swept_nodes, failed_keys = (
        svc.dht.delete_many(dead_nodes, peer=peer) if dead_nodes else (0, [])
    )
    freed_pages, freed_bytes, missed = (
        svc.pm.delete_pages(dead_pages, peer=peer) if dead_pages else (0, 0, [])
    )

    # Finalize only versions whose every candidate is dead AND whose
    # every delete was acknowledged; the rest stay pending and are
    # re-examined next round (deletes are idempotent, and still-live
    # candidates cost no RPC — they are just rechecked against the next
    # mark's live set).
    incomplete: Set[Tuple[str, int]] = set(has_live)
    for key in failed_keys:
        incomplete.add(node_version[key])
    for pid in missed:
        incomplete.add(page_version[pid])
    if finalize:
        for blob_id, recs in sorted(pending.items()):
            done = [rec.version for rec in recs
                    if (blob_id, rec.version) not in incomplete]
            svc.vm.finalize_sweep(blob_id, done, client=peer)
    else:
        # restore-time resweep: a version finalized pre-crash whose
        # re-deletes failed (or whose candidates restore made reachable
        # again) must leave the finalized set — ordinary rounds only
        # look at retired - swept, so without this the resurrected
        # nodes/pages would leak until the next restart's resweep.
        for blob_id, recs in sorted(pending.items()):
            redo = [rec.version for rec in recs
                    if (blob_id, rec.version) in incomplete]
            svc.vm.unfinalize_sweep(blob_id, redo, client=peer)

    return {
        "swept_nodes": swept_nodes,
        "swept_pages": freed_pages,
        "reclaimed_bytes": freed_bytes,
        "failed_deletes": len(failed_keys) + len(missed),
        "deferred_versions": len(has_live),
    }


def collect_orphans(
    svc, grace: float, peer: Optional[str] = None
) -> Dict[str, int]:
    """Reclaim pages no assigned update has ever journaled.

    A writer stores pages *before* version assignment (the paper's
    lock-free data path); if it restripes an optimistic append or dies
    before ``assign_version``, those pages are referenced by nothing —
    no version, no WAL record — and the pd-derived sweep can never see
    them.  This pass asks every alive provider for a wire-accounted
    inventory (one batched round trip each) and deletes listed pages
    that are not journaled anywhere and are older than ``grace`` on the
    deployment clock.  The grace window is what makes it safe against
    in-flight writers between ``store_page`` and ``assign_version``.

    With dedup deployed, the inventory also reconciles the content-hash
    index: doomed pages are run through ``orphan_guard`` first — a page
    some in-flight writer has acquired (refcount ≥ 2) survives, a page
    whose only reference is its storer's now-provably-stale one is
    unindexed and deleted.
    """
    referenced = svc.vm.all_page_ids()
    now = svc.wire.clock.now()
    doomed: List[Tuple[Tuple[str, ...], str]] = []
    for prov in svc.pm.alive_providers():
        try:
            listing = prov.list_pages(peer=peer)
        except EndpointDown:
            continue
        # Providers list *physical* ids: an EC shard ("...-ec6+2.s3") is
        # referenced iff its logical page is journaled, so membership is
        # checked on the logical id (plain pages map to themselves).
        doomed.extend(((prov.pid,), pid) for pid, stored_at in listing
                      if logical_pid(pid) not in referenced
                      and now - stored_at >= grace)
    idx = getattr(svc, "dedup_index", None)
    if doomed and idx is not None and idx.ever_registered:
        kept = idx.orphan_guard([logical_pid(pid) for _provs, pid in doomed],
                                peer=peer)
        if kept:
            doomed = [(provs, pid) for provs, pid in doomed
                      if logical_pid(pid) not in kept]
    if not doomed:
        return {"orphan_pages": 0, "orphan_bytes": 0}
    # delete through the provider manager so the sweep counters in
    # rpc_report() account for orphan reclamation too; a page missed
    # because its endpoint just went down is simply retried by the next
    # round's inventory (it is still unreferenced)
    freed_pages, freed_bytes, _missed = svc.pm.delete_pages(doomed, peer=peer)
    return {"orphan_pages": freed_pages, "orphan_bytes": freed_bytes}


def collect_garbage(
    svc,
    keep: Optional[Dict[str, Iterable[int]]] = None,
    *,
    client: str = "gc",
    orphan_grace: Optional[float] = 600.0,
) -> Dict[str, int]:
    """One GC round over the whole deployment; safe with live clients.

    ``keep`` (optional) maps blob id -> versions to keep *explicitly*:
    for those blobs every other published version is retired (pins,
    branch roots, in-flight anchors and the newest published snapshot
    are still kept on top).  Blobs not listed follow their retention
    policy (``set_retention``; no policy = keep everything).

    ``orphan_grace`` additionally reclaims never-journaled pages older
    than the grace window (see :func:`collect_orphans`); ``None``
    disables the inventory pass.

    Every mark/sweep operation crosses the wire — zero direct shard or
    provider-store mutations — and the whole round is deterministic
    under the simulated clock.  Returns round statistics.
    """
    keep = keep or {}
    vm = svc.vm
    retired_now = 0
    kept_total = 0
    for blob_id in vm.known_blobs():
        kept_v, newly = vm.plan_retirement(
            blob_id,
            keep_extra=keep.get(blob_id),
            explicit=blob_id in keep,
            client=client,
        )
        kept_total += len(kept_v)
        retired_now += len(newly)
        if newly:
            vm.wait_reads_drained(blob_id, newly)

    pending = {
        blob_id: recs
        for blob_id in vm.known_blobs()
        if (recs := vm.sweep_pending(blob_id))
    }

    live_nodes, live_pages, mark_rounds, mark_keys = mark_live(svc, peer=client)
    stats = _sweep(svc, pending, live_nodes, live_pages, peer=client)
    if orphan_grace is not None:
        stats.update(collect_orphans(svc, orphan_grace, peer=client))
    else:
        stats.update({"orphan_pages": 0, "orphan_bytes": 0})
    stats.update({
        "live_nodes": len(live_nodes),
        "live_pages": len(live_pages),
        "kept_versions": kept_total,
        "retired_versions": retired_now,
        "mark_rounds": mark_rounds,
        "mark_keys": mark_keys,
        "sweep_versions": sum(len(r) for r in pending.values()),
    })
    return stats


def resweep_after_restore(svc, client: str = "gc-restore") -> Dict[str, int]:
    """Re-apply retirement after a cold restart.

    ``BlobSeerService.restore`` rebuilds metadata for *every* completed
    update — retired ones included, because rebuilding snapshot ``v``
    descends ``v-1``'s just-rebuilt tree.  This pass then re-deletes
    everything the pre-crash sweeps had reclaimed (the WAL's ``retire``
    records are authoritative), so a swept version never comes back:
    its reads still answer ``RetiredVersion`` and its dead nodes/pages
    are removed again.  Idempotent, wire-accounted, same code path as a
    live sweep.  Versions whose re-deletes report failures are
    *un-finalized* (journaled), so ordinary live rounds keep retrying
    them instead of leaking until the next restart.
    """
    vm = svc.vm
    pending: Dict[str, List] = {}
    for blob_id in vm.known_blobs():
        retired = vm.retired_versions(blob_id)
        if not retired:
            continue
        recs = []
        for v in sorted(retired):
            try:
                recs.append(vm.update_log(blob_id, v))
            except VersionUnpublished:
                # retire record without an assign record: skip.  ONLY
                # this typed answer means "never assigned" — any other
                # exception here is real corruption and must propagate
                continue
        if recs:
            pending[blob_id] = recs
    if not pending:
        return {"swept_nodes": 0, "swept_pages": 0, "reclaimed_bytes": 0,
                "failed_deletes": 0}
    live_nodes, live_pages, _rounds, _keys = mark_live(svc, peer=client)
    return _sweep(svc, pending, live_nodes, live_pages, peer=client,
                  finalize=False)
