"""The version manager (paper §3.1, §4.2, §4.3).

"The version manager is the key actor of the system.  It registers
update requests (APPEND and WRITE), assigning snapshot version numbers,
and eventually publishes these updates, guaranteeing total ordering and
atomicity."

Responsibilities implemented here, faithfully:

* assign strictly increasing snapshot versions per blob; APPEND offsets
  are the size of the previous snapshot (assigned, possibly unpublished);
* keep the in-flight registry of assigned-but-unpublished updates and
  hand each new writer (a) the ranges of every update between the last
  published snapshot and its own version — the *partial border set*
  information of §4.2 — and (b) a recently published snapshot version to
  resolve the rest of its border nodes;
* publish versions **in order** once their metadata is complete, so a
  reader can never observe snapshot ``v`` without snapshots ``< v``
  being fully resolvable (atomicity in the sense of [9]);
* serve GET_RECENT / GET_SIZE / SYNC.

Beyond-paper (the paper defers failure handling):

* every version assignment is journaled to a write-ahead log together
  with the update's page descriptors (pages are already durably stored
  at assignment time), so a crashed writer's metadata can be rebuilt
  deterministically by any recovery agent (`find_stalled` +
  ``BlobClient.rebuild_metadata``) instead of stalling the publication
  pipeline forever;
* the version manager itself recovers its full state from the WAL.
"""

from __future__ import annotations

import itertools
import json
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.pages import pages_spanned, root_pages_for
from repro.core.sim import Clock, WallClock
from repro.core.transport import Wire

VMGR_ENDPOINT = "vmgr"
_CTRL_MSG_BYTES = 96  # wire-cost estimate of one control-plane RPC


class BlobUnknown(KeyError):
    pass


class VersionUnpublished(RuntimeError):
    pass


class WriteBeyondEnd(ValueError):
    """WRITE offset larger than the size of the previous snapshot."""


@dataclass
class UpdateRecord:
    version: int
    offset: int            # bytes
    size: int              # bytes written
    new_blob_size: int     # bytes: size of this snapshot
    root_pages: int
    p0: int                # page extent of the update
    p1: int
    is_append: bool
    client: str
    pd: Tuple = ()         # ((pid, rel_page_index, providers, length), ...)
    complete: bool = False
    assigned_at: float = field(default_factory=time.monotonic)


@dataclass
class BlobRecord:
    blob_id: str
    psize: int
    parent: Optional[Tuple[str, int]] = None  # (parent blob id, branch version)
    base_version: int = 0                     # versions <= base live in the parent
    updates: Dict[int, UpdateRecord] = field(default_factory=dict)
    last_assigned: int = 0
    published: int = 0


class VersionManager:
    def __init__(self, wire: Optional[Wire] = None, wal_path: Optional[str] = None,
                 clock: Optional[Clock] = None) -> None:
        self.wire = wire
        if clock is None:
            clock = wire.clock if wire is not None else WallClock()
        self._clock = clock
        self._blobs: Dict[str, BlobRecord] = {}
        self._lock = threading.RLock()
        # SYNC / publication waits block through the clock: real
        # threading.Condition on the wall backend, virtual-time waits
        # under a Simulator.
        self._cond = clock.condition(self._lock)
        self._ids = itertools.count(1)
        self._wal: List[dict] = []
        self._wal_path = wal_path
        self._wal_file = open(wal_path, "a") if wal_path else None

    # ------------------------------------------------------------------ utils
    def _charge(self, client: Optional[str]) -> None:
        if self.wire is not None:
            self.wire.transfer(VMGR_ENDPOINT, _CTRL_MSG_BYTES, inbound=True, peer=client)

    def _journal(self, rec: dict) -> None:
        self._wal.append(rec)
        if self._wal_file is not None:
            self._wal_file.write(json.dumps(rec) + "\n")
            self._wal_file.flush()

    def _blob(self, blob_id: str) -> BlobRecord:
        try:
            return self._blobs[blob_id]
        except KeyError:
            raise BlobUnknown(blob_id)

    def _record(self, blob_id: str, version: int) -> Optional[UpdateRecord]:
        """Update record for ``version``, walking branch lineage."""
        b = self._blob(blob_id)
        while version <= b.base_version and b.parent is not None:
            b = self._blob(b.parent[0])
        return b.updates.get(version)

    def owner_of(self, blob_id: str, version: int) -> str:
        """Blob id owning the tree nodes of ``version`` (branch lineage)."""
        b = self._blob(blob_id)
        while version <= b.base_version and b.parent is not None:
            b = self._blob(b.parent[0])
        return b.blob_id

    def lineage(self, blob_id: str) -> Tuple[Tuple[str, int], ...]:
        """Branch chain as ((blob_id, base_version), ...) youngest first.

        Version ``v`` is owned by the first entry with ``v > base``.
        Clients cache this; it only ever grows by BRANCH.
        """
        with self._lock:
            chain: List[Tuple[str, int]] = []
            b = self._blob(blob_id)
            while True:
                chain.append((b.blob_id, b.base_version))
                if b.parent is None:
                    break
                b = self._blob(b.parent[0])
            return tuple(chain)

    def _size_of(self, blob_id: str, version: int) -> int:
        if version == 0:
            return 0
        rec = self._record(blob_id, version)
        if rec is None:
            raise VersionUnpublished(f"{blob_id} v{version} not assigned")
        return rec.new_blob_size

    def _root_pages_of(self, blob_id: str, version: int) -> int:
        if version == 0:
            return 0
        rec = self._record(blob_id, version)
        if rec is None:
            raise VersionUnpublished(f"{blob_id} v{version} not assigned")
        return rec.root_pages

    # ------------------------------------------------------------- public API
    def create(self, psize: int, client: Optional[str] = None) -> str:
        """CREATE: new empty blob, snapshot 0 (size 0)."""
        self._charge(client)
        with self._lock:
            blob_id = f"blob-{next(self._ids):08d}"
            self._blobs[blob_id] = BlobRecord(blob_id=blob_id, psize=psize)
            self._journal({"op": "create", "blob": blob_id, "psize": psize})
            return blob_id

    def branch(self, blob_id: str, version: int, client: Optional[str] = None) -> str:
        """BRANCH: fork ``blob_id`` at published snapshot ``version``."""
        self._charge(client)
        with self._lock:
            src = self._blob(blob_id)
            if version > src.published:
                raise VersionUnpublished(f"{blob_id} v{version} not published")
            bid = f"blob-{next(self._ids):08d}"
            self._blobs[bid] = BlobRecord(
                blob_id=bid,
                psize=src.psize,
                parent=(blob_id, version),
                base_version=version,
                last_assigned=version,
                published=version,
            )
            self._journal({"op": "branch", "blob": bid, "src": blob_id, "at": version})
            return bid

    def get_recent(self, blob_id: str, client: Optional[str] = None) -> int:
        """GET_RECENT: a recently published version (>= all published before)."""
        self._charge(client)
        with self._lock:
            return self._blob(blob_id).published

    def get_size(self, blob_id: str, version: int, client: Optional[str] = None) -> int:
        """GET_SIZE of a *published* snapshot (paper: fails otherwise)."""
        self._charge(client)
        with self._lock:
            if version > self._blob(blob_id).published:
                raise VersionUnpublished(f"{blob_id} v{version} not published")
            return self._size_of(blob_id, version)

    def psize_of(self, blob_id: str) -> int:
        with self._lock:
            return self._blob(blob_id).psize

    def sync(self, blob_id: str, version: int, timeout: Optional[float] = None,
             client: Optional[str] = None) -> None:
        """SYNC: block until ``version`` is published."""
        self._charge(client)
        deadline = None if timeout is None else self._clock.now() + timeout
        with self._cond:
            while self._blob(blob_id).published < version:
                remaining = None if deadline is None else deadline - self._clock.now()
                if remaining is not None and remaining <= 0:
                    raise TimeoutError(f"SYNC {blob_id} v{version}")
                self._cond.wait(remaining)

    def is_published(self, blob_id: str, version: int) -> bool:
        with self._lock:
            return version <= self._blob(blob_id).published

    # ----------------------------------------------------- update registration
    def assign_version(
        self,
        blob_id: str,
        offset: Optional[int],     # None => APPEND
        size: int,
        client: str,
        pd: Tuple = (),
    ) -> "AssignInfo":
        """Register an update; returns everything the writer needs (§4.2).

        The page descriptors ``pd`` (for pages already stored) are
        journaled so a recovery agent can replay BUILD_META if the
        writer dies before completing its metadata.
        """
        self._charge(client)
        with self._lock:
            b = self._blob(blob_id)
            prev_size = self._size_of(blob_id, b.last_assigned)
            if offset is None:
                offset = prev_size           # APPEND semantics
                is_append = True
            else:
                is_append = False
                if offset > prev_size:
                    raise WriteBeyondEnd(
                        f"offset {offset} > size {prev_size} of snapshot v{b.last_assigned}"
                    )
            if size <= 0:
                raise ValueError("update size must be positive")
            vw = b.last_assigned + 1
            b.last_assigned = vw
            new_size = max(prev_size, offset + size)
            root_pages = root_pages_for(new_size, b.psize)
            p0, p1 = pages_spanned(offset, size, b.psize)
            rec = UpdateRecord(
                version=vw, offset=offset, size=size, new_blob_size=new_size,
                root_pages=root_pages, p0=p0, p1=p1, is_append=is_append,
                client=client, pd=tuple(pd), assigned_at=self._clock.now(),
            )
            b.updates[vw] = rec
            # §4.2: ranges of every update between the last published
            # snapshot and vw — the information from which the writer
            # resolves border nodes of concurrent unpublished updates.
            vp = b.published
            recent: List[Tuple[int, int, int]] = []
            for u in range(vp + 1, vw):
                r = b.updates.get(u)
                if r is not None:
                    recent.append((r.version, r.p0, r.p1))
            vp_out: Optional[int] = vp if vp > 0 else None
            vp_root = self._root_pages_of(blob_id, vp) if vp > 0 else 0
            self._journal({
                "op": "assign", "blob": blob_id, "v": vw, "offset": offset,
                "size": size, "new_size": new_size, "append": is_append,
                "client": client, "pd": [list(x) for x in pd],
            })
            return AssignInfo(
                version=vw, offset=offset, prev_size=prev_size,
                new_size=new_size, root_pages=root_pages, p0=p0, p1=p1,
                vp=vp_out, vp_root_pages=vp_root, recent_updates=tuple(recent),
            )

    def register_pd(self, blob_id: str, version: int, pd: Tuple,
                    client: Optional[str] = None) -> None:
        """(Re-)journal the final page-descriptor set for an update.

        Used by APPENDs (which learn their offset at assignment) and by
        unaligned WRITEs (whose boundary pages are stored after
        assignment).  Keeps WAL-based recovery deterministic.
        """
        self._charge(client)
        with self._lock:
            rec = self._blob(blob_id).updates[version]
            rec.pd = tuple(pd)
            self._journal({
                "op": "pd", "blob": blob_id, "v": version,
                "pd": [list(x) for x in pd],
            })

    def metadata_complete(self, blob_id: str, version: int,
                          client: Optional[str] = None) -> None:
        """Writer finished BUILD_META; publish in order (atomicity)."""
        self._charge(client)
        with self._cond:
            b = self._blob(blob_id)
            rec = b.updates[version]
            rec.complete = True
            self._journal({"op": "complete", "blob": blob_id, "v": version})
            # In-order publication: snapshot v is revealed only once every
            # snapshot < v is published, so readers can always resolve the
            # full weaved tree of anything they are allowed to see.
            while True:
                nxt = b.updates.get(b.published + 1)
                if nxt is None or not nxt.complete:
                    break
                b.published += 1
                self._journal({"op": "publish", "blob": blob_id, "v": b.published})
            self._cond.notify_all()

    def wait_metadata(self, blob_id: str, version: int,
                      timeout: Optional[float] = None) -> None:
        """Block until ``version``'s metadata is complete (not necessarily
        published).  Needed only by unaligned writes that must merge
        boundary-page content from snapshot ``version`` (§3 "slightly
        more complex" path)."""
        deadline = None if timeout is None else self._clock.now() + timeout
        with self._cond:
            while True:
                b = self._blob(blob_id)
                if version <= b.base_version and b.parent is not None:
                    if self._record(blob_id, version) is not None or version == 0:
                        return
                rec = b.updates.get(version)
                if version == 0 or version <= b.published or (rec is not None and rec.complete):
                    return
                remaining = None if deadline is None else deadline - self._clock.now()
                if remaining is not None and remaining <= 0:
                    raise TimeoutError(f"metadata {blob_id} v{version}")
                self._cond.wait(remaining)

    # ----------------------------------------------------------- introspection
    def update_log(self, blob_id: str, version: int) -> UpdateRecord:
        with self._lock:
            rec = self._record(blob_id, version)
            if rec is None:
                raise VersionUnpublished(f"{blob_id} v{version} not assigned")
            return rec

    def root_pages_published(self, blob_id: str, version: int) -> int:
        with self._lock:
            if version > self._blob(blob_id).published:
                raise VersionUnpublished(f"{blob_id} v{version} not published")
            return self._root_pages_of(blob_id, version)

    # ------------------------------------------------------- failure handling
    def find_stalled(self, timeout: float) -> List[Tuple[str, UpdateRecord]]:
        """Assigned-but-incomplete updates older than ``timeout`` seconds.

        These block the publication pipeline (in-order publishing); a
        recovery agent replays their metadata from the journaled page
        descriptors and calls :meth:`metadata_complete`.
        """
        now = self._clock.now()
        out = []
        with self._lock:
            for b in self._blobs.values():
                for v in range(b.published + 1, b.last_assigned + 1):
                    rec = b.updates.get(v)
                    if rec is not None and not rec.complete and now - rec.assigned_at > timeout:
                        out.append((b.blob_id, rec))
        return out

    def assign_info_for_recovery(self, blob_id: str, version: int) -> "AssignInfo":
        """Reconstruct the AssignInfo a dead writer was handed."""
        with self._lock:
            b = self._blob(blob_id)
            rec = b.updates[version]
            vp = b.published
            recent = tuple(
                (r.version, r.p0, r.p1)
                for u in range(vp + 1, version)
                if (r := b.updates.get(u)) is not None
            )
            return AssignInfo(
                version=version, offset=rec.offset,
                prev_size=self._size_of(blob_id, version - 1) if version > 1 else 0,
                new_size=rec.new_blob_size, root_pages=rec.root_pages,
                p0=rec.p0, p1=rec.p1,
                vp=vp if vp > 0 else None,
                vp_root_pages=self._root_pages_of(blob_id, vp) if vp > 0 else 0,
                recent_updates=recent,
            )

    # ------------------------------------------------------------ WAL recovery
    @classmethod
    def recover_from_wal(cls, wal_path: str, wire: Optional[Wire] = None) -> "VersionManager":
        """Rebuild full version-manager state from the journal."""
        vm = cls(wire=wire)
        max_id = 0
        with open(wal_path) as f:
            for line in f:
                rec = json.loads(line)
                op = rec["op"]
                if op == "create":
                    vm._blobs[rec["blob"]] = BlobRecord(rec["blob"], rec["psize"])
                    max_id = max(max_id, int(rec["blob"].split("-")[1]))
                elif op == "branch":
                    src = vm._blobs[rec["src"]]
                    vm._blobs[rec["blob"]] = BlobRecord(
                        blob_id=rec["blob"], psize=src.psize,
                        parent=(rec["src"], rec["at"]), base_version=rec["at"],
                        last_assigned=rec["at"], published=rec["at"],
                    )
                    max_id = max(max_id, int(rec["blob"].split("-")[1]))
                elif op == "assign":
                    b = vm._blobs[rec["blob"]]
                    psz = b.psize
                    p0, p1 = pages_spanned(rec["offset"], rec["size"], psz)
                    b.updates[rec["v"]] = UpdateRecord(
                        version=rec["v"], offset=rec["offset"], size=rec["size"],
                        new_blob_size=rec["new_size"],
                        root_pages=root_pages_for(rec["new_size"], psz),
                        p0=p0, p1=p1, is_append=rec["append"], client=rec["client"],
                        pd=tuple(tuple(x) for x in rec["pd"]),
                        # stamp on the VM's own clock: the wall-time default
                        # would make find_stalled never fire under a virtual
                        # clock (now() - monotonic is hugely negative)
                        assigned_at=vm._clock.now(),
                    )
                    b.last_assigned = max(b.last_assigned, rec["v"])
                elif op == "pd":
                    vm._blobs[rec["blob"]].updates[rec["v"]].pd = tuple(
                        tuple(x) for x in rec["pd"]
                    )
                elif op == "complete":
                    vm._blobs[rec["blob"]].updates[rec["v"]].complete = True
                elif op == "publish":
                    vm._blobs[rec["blob"]].published = rec["v"]
        vm._ids = itertools.count(max_id + 1)
        vm._wal_path = wal_path
        vm._wal_file = open(wal_path, "a")
        return vm


@dataclass(frozen=True)
class AssignInfo:
    """Everything a writer receives from the version manager (§4.2)."""

    version: int
    offset: int
    prev_size: int
    new_size: int
    root_pages: int
    p0: int
    p1: int
    vp: Optional[int]                       # recently published snapshot
    vp_root_pages: int
    recent_updates: Tuple[Tuple[int, int, int], ...]  # (version, p0, p1), unpublished-at-assign
