"""The version manager (paper §3.1, §4.2, §4.3).

"The version manager is the key actor of the system.  It registers
update requests (APPEND and WRITE), assigning snapshot version numbers,
and eventually publishes these updates, guaranteeing total ordering and
atomicity."

Responsibilities implemented here, faithfully:

* assign strictly increasing snapshot versions per blob; APPEND offsets
  are the size of the previous snapshot (assigned, possibly unpublished);
* keep the in-flight registry of assigned-but-unpublished updates and
  hand each new writer (a) the ranges of every update between the last
  published snapshot and its own version — the *partial border set*
  information of §4.2 — and (b) a recently published snapshot version to
  resolve the rest of its border nodes;
* publish versions **in order** once their metadata is complete, so a
  reader can never observe snapshot ``v`` without snapshots ``< v``
  being fully resolvable (atomicity in the sense of [9]);
* serve GET_RECENT / GET_SIZE / SYNC.

Beyond-paper (the paper defers failure handling):

* every version assignment is journaled to a write-ahead log together
  with the update's page descriptors (pages are already durably stored
  at assignment time), so a crashed writer's metadata can be rebuilt
  deterministically by any recovery agent (`find_stalled` +
  ``BlobClient.rebuild_metadata``) instead of stalling the publication
  pipeline forever;
* the version manager itself recovers its full state from the WAL.
"""

from __future__ import annotations

import itertools
import json
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, FrozenSet, Iterable, List, Optional, Sequence, Set, Tuple

from repro.core.pages import pages_spanned, root_pages_for
from repro.core.sim import Clock, WallClock
from repro.core.transport import Wire

VMGR_ENDPOINT = "vmgr"
_CTRL_MSG_BYTES = 96  # wire-cost estimate of one control-plane RPC


def owner_fn_for_lineage(chain: Sequence[Tuple[str, int]]):
    """Version -> owning blob id, from a :meth:`VersionManager.lineage`
    chain (youngest first).  The single home of the ownership rule —
    version ``v`` belongs to the first entry with ``v > base`` — shared
    by the client (cached chains), the GC mark walk and the manager."""

    def owner(version: int) -> str:
        for bid, base in chain:
            if version > base:
                return bid
        return chain[-1][0]

    return owner


class BlobUnknown(KeyError):
    """No blob with that id exists at this version manager."""


class VersionUnpublished(RuntimeError):
    """The snapshot version is not published (or not even assigned):
    reads, GET_SIZE and pins of it are rejected — the paper's READ
    'fails if the version is not published yet'."""


class WriteBeyondEnd(ValueError):
    """WRITE offset larger than the size of the previous snapshot."""


class RetiredVersion(RuntimeError):
    """The snapshot was retired by GC: its space has been (or is being)
    reclaimed.  Raised for reads, pins and branches of retired versions
    — a typed, deliberate answer, never a stray ``KeyError`` from a
    swept page or tree node."""


@dataclass
class UpdateRecord:
    """One assigned update (WRITE/APPEND) in a blob's history: the
    version manager's journaled source of truth for the update's range,
    page descriptors (``pd``), completion state and the published
    anchor ``vp`` its writer resolves border nodes against.  GC derives
    a retired version's sweep candidates from this record alone."""

    version: int
    offset: int            # bytes
    size: int              # bytes written
    new_blob_size: int     # bytes: size of this snapshot
    root_pages: int
    p0: int                # page extent of the update
    p1: int
    is_append: bool
    client: str
    pd: Tuple = ()         # ((pid, rel_page_index, providers, length), ...)
    complete: bool = False
    assigned_at: float = field(default_factory=time.monotonic)
    vp: Optional[int] = None  # published anchor handed to the writer (GC keeps it)


@dataclass
class PinLease:
    """One client's pin on ``(blob, version)``: GC keeps the snapshot
    until the lease is released or its clock-based expiry passes."""

    lease_id: str
    blob_id: str
    version: int
    client: Optional[str]
    expires_at: Optional[float]  # None = until released


@dataclass
class BlobRecord:
    """Per-blob manager state: page size, branch parentage, the update
    log, publication watermark, and the GC bookkeeping (retention
    policy, retired/swept sets, ``gc_epoch``)."""

    blob_id: str
    psize: int
    parent: Optional[Tuple[str, int]] = None  # (parent blob id, branch version)
    base_version: int = 0                     # versions <= base live in the parent
    updates: Dict[int, UpdateRecord] = field(default_factory=dict)
    last_assigned: int = 0
    published: int = 0
    keep_last: int = 0                        # retention policy; 0 = keep all
    retired: Set[int] = field(default_factory=set)  # retire-intent: reads rejected
    swept: Set[int] = field(default_factory=set)    # sweep finalized
    gc_epoch: int = 0                         # bumped at every retire-intent


class VersionManager:
    """The system's only global serialization point (paper §3.1): it
    assigns strictly increasing snapshot versions, keeps the in-flight
    registry concurrent writers resolve their border sets from, and
    publishes versions **in order** once their metadata completes.

    Beyond the paper it also owns the durability and GC control planes:
    every assignment is journaled to a WAL (crashed writers are
    rebuilt deterministically, the manager itself recovers via
    :meth:`recover_from_wal`), and retirement state — retention
    policies, pin leases, read leases/drain barrier, retire-intent and
    sweep finalization — lives here so that a single critical section
    decides what GC may reclaim (see ``core/gc.py``)."""

    def __init__(self, wire: Optional[Wire] = None, wal_path: Optional[str] = None,
                 clock: Optional[Clock] = None) -> None:
        self.wire = wire
        if clock is None:
            clock = wire.clock if wire is not None else WallClock()
        self._clock = clock
        self._blobs: Dict[str, BlobRecord] = {}
        self._lock = threading.RLock()
        # SYNC / publication waits block through the clock: real
        # threading.Condition on the wall backend, virtual-time waits
        # under a Simulator.
        self._cond = clock.condition(self._lock)
        self._ids = itertools.count(1)
        self._wal: List[dict] = []
        self._wal_path = wal_path
        self._wal_file = open(wal_path, "a") if wal_path else None
        # GC state: pin leases (volatile — leases die with the manager,
        # recovery falls back to retention), and in-flight read counts
        # per (owner blob, version) for the sweep's drain barrier.
        self._pins: Dict[str, PinLease] = {}
        self._pin_ids = itertools.count(1)
        self._active_reads: Dict[Tuple[str, int], int] = {}
        # Retire-intent listeners (gc_epoch notifications): fired after
        # every plan_retirement that retires something, OUTSIDE the
        # manager lock, with (blob_id, versions, epoch, page_ids).  The
        # deployment's page cache subscribes so a retired version's
        # pages are evicted the instant the epoch bumps.
        self._gc_listeners: List = []

    # ------------------------------------------------------------------ utils
    def _charge(self, client: Optional[str]) -> None:
        if self.wire is not None:
            self.wire.transfer(VMGR_ENDPOINT, _CTRL_MSG_BYTES, inbound=True, peer=client)

    def _journal(self, rec: dict) -> None:
        self._wal.append(rec)
        if self._wal_file is not None:
            self._wal_file.write(json.dumps(rec) + "\n")
            self._wal_file.flush()

    def _blob(self, blob_id: str) -> BlobRecord:
        try:
            return self._blobs[blob_id]
        except KeyError:
            raise BlobUnknown(blob_id)

    def _owner_record(self, blob_id: str, version: int) -> BlobRecord:
        """BlobRecord owning ``version`` (walks branch lineage)."""
        b = self._blob(blob_id)
        while version <= b.base_version and b.parent is not None:
            b = self._blob(b.parent[0])
        return b

    def _record(self, blob_id: str, version: int) -> Optional[UpdateRecord]:
        """Update record for ``version``, walking branch lineage."""
        return self._owner_record(blob_id, version).updates.get(version)

    def _check_not_retired(self, blob_id: str, version: int) -> None:
        # caller holds the lock; retirement is recorded on the owner blob,
        # so a branch reading an inherited snapshot sees it too
        if version in self._owner_record(blob_id, version).retired:
            raise RetiredVersion(f"{blob_id} v{version} retired by GC")

    def _latest_live_published(self, b: BlobRecord) -> int:
        """Newest published, non-retired version — what GET_RECENT hands
        out and what new updates anchor their border descents on (a
        retired anchor would race the sweep)."""
        v = b.published
        while v > 0 and v in self._owner_record(b.blob_id, v).retired:
            v -= 1
        return v

    def owner_of(self, blob_id: str, version: int) -> str:
        """Blob id owning the tree nodes of ``version`` (branch lineage)."""
        with self._lock:
            return self._owner_record(blob_id, version).blob_id

    def lineage(self, blob_id: str) -> Tuple[Tuple[str, int], ...]:
        """Branch chain as ((blob_id, base_version), ...) youngest first.

        Version ``v`` is owned by the first entry with ``v > base``.
        Clients cache this; it only ever grows by BRANCH.
        """
        with self._lock:
            chain: List[Tuple[str, int]] = []
            b = self._blob(blob_id)
            while True:
                chain.append((b.blob_id, b.base_version))
                if b.parent is None:
                    break
                b = self._blob(b.parent[0])
            return tuple(chain)

    def _size_of(self, blob_id: str, version: int) -> int:
        if version == 0:
            return 0
        rec = self._record(blob_id, version)
        if rec is None:
            raise VersionUnpublished(f"{blob_id} v{version} not assigned")
        return rec.new_blob_size

    def _root_pages_of(self, blob_id: str, version: int) -> int:
        if version == 0:
            return 0
        rec = self._record(blob_id, version)
        if rec is None:
            raise VersionUnpublished(f"{blob_id} v{version} not assigned")
        return rec.root_pages

    # ------------------------------------------------------------- public API
    def create(self, psize: int, client: Optional[str] = None) -> str:
        """CREATE: new empty blob, snapshot 0 (size 0)."""
        self._charge(client)
        with self._lock:
            blob_id = f"blob-{next(self._ids):08d}"
            self._blobs[blob_id] = BlobRecord(blob_id=blob_id, psize=psize)
            self._journal({"op": "create", "blob": blob_id, "psize": psize})
            return blob_id

    def branch(self, blob_id: str, version: int, client: Optional[str] = None) -> str:
        """BRANCH: fork ``blob_id`` at published snapshot ``version``."""
        self._charge(client)
        with self._lock:
            src = self._blob(blob_id)
            if version > src.published:
                raise VersionUnpublished(f"{blob_id} v{version} not published")
            if version > 0:
                self._check_not_retired(blob_id, version)
            bid = f"blob-{next(self._ids):08d}"
            self._blobs[bid] = BlobRecord(
                blob_id=bid,
                psize=src.psize,
                parent=(blob_id, version),
                base_version=version,
                last_assigned=version,
                published=version,
            )
            self._journal({"op": "branch", "blob": bid, "src": blob_id, "at": version})
            return bid

    def get_recent(self, blob_id: str, client: Optional[str] = None) -> int:
        """GET_RECENT: a recently published, still-live version.

        Retired snapshots are never handed out — after a GC round the
        recency pointer skips them (the retention policy always keeps
        the newest published version, so this only walks under an
        explicit-keep GC).
        """
        self._charge(client)
        with self._lock:
            return self._latest_live_published(self._blob(blob_id))

    def get_size(self, blob_id: str, version: int, client: Optional[str] = None) -> int:
        """GET_SIZE of a *published* snapshot (paper: fails otherwise)."""
        self._charge(client)
        with self._lock:
            if version > self._blob(blob_id).published:
                raise VersionUnpublished(f"{blob_id} v{version} not published")
            if version > 0:
                self._check_not_retired(blob_id, version)
            return self._size_of(blob_id, version)

    def psize_of(self, blob_id: str) -> int:
        """The blob's immutable page size (fixed at CREATE)."""
        with self._lock:
            return self._blob(blob_id).psize

    def sync(self, blob_id: str, version: int, timeout: Optional[float] = None,
             client: Optional[str] = None) -> None:
        """SYNC: block until ``version`` is published."""
        self._charge(client)
        deadline = None if timeout is None else self._clock.now() + timeout
        with self._cond:
            while self._blob(blob_id).published < version:
                remaining = None if deadline is None else deadline - self._clock.now()
                if remaining is not None and remaining <= 0:
                    raise TimeoutError(f"SYNC {blob_id} v{version}")
                self._cond.wait(remaining)

    def is_published(self, blob_id: str, version: int) -> bool:
        """Has ``version`` been published (atomically visible)?  True
        for retired versions too — reads of those get the typed
        :class:`RetiredVersion` from :meth:`enter_read`, not a
        'not published' answer."""
        with self._lock:
            return version <= self._blob(blob_id).published

    # ----------------------------------------------------- update registration
    def assign_version(
        self,
        blob_id: str,
        offset: Optional[int],     # None => APPEND
        size: int,
        client: str,
        pd: Tuple = (),
    ) -> "AssignInfo":
        """Register an update; returns everything the writer needs (§4.2).

        The page descriptors ``pd`` (for pages already stored) are
        journaled so a recovery agent can replay BUILD_META if the
        writer dies before completing its metadata.
        """
        self._charge(client)
        with self._lock:
            b = self._blob(blob_id)
            prev_size = self._size_of(blob_id, b.last_assigned)
            if offset is None:
                offset = prev_size           # APPEND semantics
                is_append = True
            else:
                is_append = False
                if offset > prev_size:
                    raise WriteBeyondEnd(
                        f"offset {offset} > size {prev_size} of snapshot v{b.last_assigned}"
                    )
            if size <= 0:
                raise ValueError("update size must be positive")
            vw = b.last_assigned + 1
            b.last_assigned = vw
            new_size = max(prev_size, offset + size)
            root_pages = root_pages_for(new_size, b.psize)
            p0, p1 = pages_spanned(offset, size, b.psize)
            rec = UpdateRecord(
                version=vw, offset=offset, size=size, new_blob_size=new_size,
                root_pages=root_pages, p0=p0, p1=p1, is_append=is_append,
                client=client, pd=tuple(pd), assigned_at=self._clock.now(),
            )
            b.updates[vw] = rec
            # §4.2: ranges of every update between the last published
            # snapshot and vw — the information from which the writer
            # resolves border nodes of concurrent unpublished updates.
            # The anchor vp must be a *live* (non-retired) published
            # version: the writer descends its tree, and GC keeps every
            # anchor of an in-flight update pinned until it completes.
            vp = self._latest_live_published(b)
            rec.vp = vp if vp > 0 else None
            recent: List[Tuple[int, int, int]] = []
            for u in range(vp + 1, vw):
                r = b.updates.get(u)
                if r is not None and u not in b.retired:
                    recent.append((r.version, r.p0, r.p1))
            vp_out: Optional[int] = vp if vp > 0 else None
            vp_root = self._root_pages_of(blob_id, vp) if vp > 0 else 0
            self._journal({
                "op": "assign", "blob": blob_id, "v": vw, "offset": offset,
                "size": size, "new_size": new_size, "append": is_append,
                "client": client, "pd": [list(x) for x in pd],
                "vp": rec.vp,
            })
            return AssignInfo(
                version=vw, offset=offset, prev_size=prev_size,
                new_size=new_size, root_pages=root_pages, p0=p0, p1=p1,
                vp=vp_out, vp_root_pages=vp_root, recent_updates=tuple(recent),
            )

    def register_pd(self, blob_id: str, version: int, pd: Tuple,
                    client: Optional[str] = None) -> None:
        """(Re-)journal the final page-descriptor set for an update.

        Used by APPENDs (which learn their offset at assignment) and by
        unaligned WRITEs (whose boundary pages are stored after
        assignment).  Keeps WAL-based recovery deterministic.
        """
        self._charge(client)
        with self._lock:
            rec = self._blob(blob_id).updates[version]
            rec.pd = tuple(pd)
            self._journal({
                "op": "pd", "blob": blob_id, "v": version,
                "pd": [list(x) for x in pd],
            })

    def metadata_complete(self, blob_id: str, version: int,
                          client: Optional[str] = None) -> None:
        """Writer finished BUILD_META; publish in order (atomicity)."""
        self._charge(client)
        with self._cond:
            b = self._blob(blob_id)
            rec = b.updates[version]
            rec.complete = True
            self._journal({"op": "complete", "blob": blob_id, "v": version})
            # In-order publication: snapshot v is revealed only once every
            # snapshot < v is published, so readers can always resolve the
            # full weaved tree of anything they are allowed to see.
            while True:
                nxt = b.updates.get(b.published + 1)
                if nxt is None or not nxt.complete:
                    break
                b.published += 1
                self._journal({"op": "publish", "blob": blob_id, "v": b.published})
            self._cond.notify_all()

    def wait_metadata(self, blob_id: str, version: int,
                      timeout: Optional[float] = None) -> None:
        """Block until ``version``'s metadata is complete (not necessarily
        published).  Needed only by unaligned writes that must merge
        boundary-page content from snapshot ``version`` (§3 "slightly
        more complex" path)."""
        deadline = None if timeout is None else self._clock.now() + timeout
        with self._cond:
            while True:
                b = self._blob(blob_id)
                if version <= b.base_version and b.parent is not None:
                    if self._record(blob_id, version) is not None or version == 0:
                        return
                rec = b.updates.get(version)
                if version == 0 or version <= b.published or (rec is not None and rec.complete):
                    return
                remaining = None if deadline is None else deadline - self._clock.now()
                if remaining is not None and remaining <= 0:
                    raise TimeoutError(f"metadata {blob_id} v{version}")
                self._cond.wait(remaining)

    # ----------------------------------------------------------- introspection
    def update_log(self, blob_id: str, version: int) -> UpdateRecord:
        """The journaled :class:`UpdateRecord` of ``version`` (walks
        branch lineage to the owner blob); raises
        :class:`VersionUnpublished` for never-assigned versions.
        Retirement does NOT hide the record — GC itself reads retired
        records to derive sweep candidates."""
        with self._lock:
            rec = self._record(blob_id, version)
            if rec is None:
                raise VersionUnpublished(f"{blob_id} v{version} not assigned")
            return rec

    def root_pages_published(self, blob_id: str, version: int) -> int:
        """Page span of the snapshot's segment-tree root, for published,
        non-retired versions (the read path's entry point to the tree)."""
        with self._lock:
            if version > self._blob(blob_id).published:
                raise VersionUnpublished(f"{blob_id} v{version} not published")
            if version > 0:
                self._check_not_retired(blob_id, version)
            return self._root_pages_of(blob_id, version)

    def known_blobs(self) -> List[str]:
        """Every blob id this manager has created (branches included)."""
        with self._lock:
            return list(self._blobs)

    # ------------------------------------------------ GC: pins + read leases
    def pin(self, blob_id: str, version: int, client: Optional[str] = None,
            ttl: Optional[float] = None) -> str:
        """Pin ``(blob, version)``: GC keeps it until :meth:`unpin` or the
        lease's clock-based expiry.  Returns the lease id."""
        self._charge(client)
        with self._lock:
            b = self._blob(blob_id)
            if version <= 0 or version > b.published:
                raise VersionUnpublished(f"{blob_id} v{version} not published")
            self._check_not_retired(blob_id, version)
            lease_id = f"pin-{next(self._pin_ids):08d}"
            expires = None if ttl is None else self._clock.now() + ttl
            self._pins[lease_id] = PinLease(lease_id, blob_id, version,
                                            client, expires)
            return lease_id

    def unpin(self, lease_id: str, client: Optional[str] = None) -> None:
        """Release a pin lease (idempotent: unknown/expired ids are
        no-ops); the snapshot becomes retireable at the next GC plan."""
        self._charge(client)
        with self._lock:
            self._pins.pop(lease_id, None)

    def _live_pins(self, blob_id: str) -> Set[int]:
        """Unexpired pinned versions, recorded on the *owner* blob of
        each pinned version (a pin through a branch pins the ancestor's
        snapshot).  Expired leases are pruned.  Caller holds the lock."""
        now = self._clock.now()
        expired = [lid for lid, p in self._pins.items()
                   if p.expires_at is not None and p.expires_at < now]
        for lid in expired:
            del self._pins[lid]
        out: Set[int] = set()
        for p in self._pins.values():
            if self._owner_record(p.blob_id, p.version).blob_id == blob_id:
                out.add(p.version)
        return out

    def pinned_versions(self, blob_id: str) -> FrozenSet[int]:
        """Versions currently protected by unexpired pin leases, keyed
        by *owner* blob (a pin taken through a branch shows up here on
        the ancestor that owns the pinned snapshot)."""
        with self._lock:
            return frozenset(self._live_pins(blob_id))

    def pins(self) -> List[PinLease]:
        """All currently held (possibly expired) pin leases."""
        with self._lock:
            return list(self._pins.values())

    def enter_read(self, blob_id: str, version: int,
                   client: Optional[str] = None) -> Tuple[int, int]:
        """Open a read lease on a published snapshot; returns the
        snapshot's ``(size, root_pages)`` atomically with admission.

        The lease makes the sweep's drain barrier possible: GC retires a
        version (after which ``enter_read`` answers ``RetiredVersion``)
        and then waits until every lease opened *before* the intent has
        been released — an in-flight read never races its pages being
        deleted.  Reads of kept versions are never blocked or drained;
        their safety comes from the mark phase.  Returning the root
        snapshot here means an admitted read needs no further
        retired-checked version-manager call: a retire-intent landing
        after admission cannot spuriously fail it (the drain barrier
        lets it complete).
        """
        self._charge(client)
        with self._lock:
            b = self._blob(blob_id)
            if version > b.published:
                raise VersionUnpublished(f"{blob_id} v{version} not published")
            if version == 0:
                return 0, 0
            self._check_not_retired(blob_id, version)
            owner = self._owner_record(blob_id, version).blob_id
            key = (owner, version)
            self._active_reads[key] = self._active_reads.get(key, 0) + 1
            return (self._size_of(blob_id, version),
                    self._root_pages_of(blob_id, version))

    def exit_read(self, blob_id: str, version: int,
                  client: Optional[str] = None) -> None:
        """Release a read lease opened by :meth:`enter_read`."""
        if version == 0:
            return
        self._charge(client)
        with self._cond:
            owner = self._owner_record(blob_id, version).blob_id
            key = (owner, version)
            n = self._active_reads.get(key, 0) - 1
            if n <= 0:
                self._active_reads.pop(key, None)
            else:
                self._active_reads[key] = n
            self._cond.notify_all()

    def wait_reads_drained(self, blob_id: str, versions: Iterable[int],
                           timeout: Optional[float] = None) -> None:
        """Block until no read lease on ``(blob, v in versions)`` remains.

        The sweep's drain barrier: called after retire-intent (so no new
        lease on those versions can be opened) and before any delete is
        issued.  Blocks through the clock, so it is virtual-time-correct
        under the simulator.
        """
        keys = [(blob_id, v) for v in sorted(set(versions))]
        deadline = None if timeout is None else self._clock.now() + timeout
        with self._cond:
            while any(self._active_reads.get(k, 0) > 0 for k in keys):
                remaining = None if deadline is None else deadline - self._clock.now()
                if remaining is not None and remaining <= 0:
                    raise TimeoutError(f"reads of {blob_id} did not drain")
                self._cond.wait(remaining)

    # -------------------------------------------- GC: retention + retirement
    def set_retention(self, blob_id: str, keep_last: int,
                      client: Optional[str] = None) -> None:
        """Retention policy: GC keeps the newest ``keep_last`` published
        snapshots (0 = keep everything).  Journaled, so a recovered
        manager enforces the same policy."""
        if keep_last < 0:
            raise ValueError("keep_last must be >= 0")
        self._charge(client)
        with self._lock:
            self._blob(blob_id).keep_last = keep_last
            self._journal({"op": "retention", "blob": blob_id,
                           "keep_last": keep_last})

    def gc_epoch(self, blob_id: str) -> int:
        """Monotone retirement epoch: bumped (and journaled) every time
        :meth:`plan_retirement` retires at least one version.  Cache
        layers key their eviction notifications off it (see
        :meth:`add_gc_listener`)."""
        with self._lock:
            return self._blob(blob_id).gc_epoch

    def retired_versions(self, blob_id: str) -> FrozenSet[int]:
        """Versions under retire-intent on this blob (swept or not):
        reads/pins/branches of them answer :class:`RetiredVersion`."""
        with self._lock:
            return frozenset(self._blob(blob_id).retired)

    def plan_retirement(
        self,
        blob_id: str,
        keep_extra: Optional[Iterable[int]] = None,
        explicit: bool = False,
        client: Optional[str] = None,
    ) -> Tuple[Tuple[int, ...], Tuple[int, ...]]:
        """Atomically decide and journal this blob's retirement set.

        Returns ``(kept, newly_retired)`` over the blob's *own* published
        versions (inherited versions ``<= base`` belong to the ancestor's
        plan).  Kept is the union of

        * the retention window (newest ``keep_last`` published; all of
          them when no policy is set and ``explicit`` is False),
        * ``keep_extra`` (the explicit keep set of the old GC API; with
          ``explicit=True`` it *replaces* the retention window),
        * unexpired pin leases,
        * branch roots: any version this blob *owns* that some blob was
          forked at — including forks taken through an intermediate
          branch at an inherited version,
        * the ``vp`` anchor of every assigned-but-incomplete update
          (an in-flight writer descends that tree for border nodes),
        * always the newest published version (new updates anchor on it).

        Marking is the retire-*intent*: from this instant every
        ``enter_read``/``pin``/``branch`` of a retired version answers
        ``RetiredVersion``.  The intent is journaled before any sweep
        RPC goes out, so recovery can never resurrect a version whose
        pages might be partially deleted.
        """
        self._charge(client)
        with self._lock:
            b = self._blob(blob_id)
            published = set(range(b.base_version + 1, b.published + 1))
            if not published:
                return (), ()
            if explicit:
                keep: Set[int] = set(keep_extra or ())
            elif b.keep_last > 0:
                keep = set(range(b.published - b.keep_last + 1,
                                 b.published + 1))
                keep.update(keep_extra or ())
            else:
                keep = set(published)
            keep.add(b.published)
            keep.update(self._live_pins(blob_id))
            for other in self._blobs.values():
                # owner-normalized like pins: a fork point at an inherited
                # version (C = branch(B, 3) where v3 is owned by A, B's
                # ancestor) must be kept by v3's *owner*, not by the blob
                # named in parent[0]
                if (other.parent is not None and other.parent[1] > 0
                        and self._owner_record(
                            other.parent[0], other.parent[1]).blob_id
                        == blob_id):
                    keep.add(other.parent[1])
                for u in range(other.published + 1, other.last_assigned + 1):
                    r = other.updates.get(u)
                    if (r is not None and not r.complete and r.vp is not None
                            and self._owner_record(other.blob_id, r.vp).blob_id
                            == blob_id):
                        keep.add(r.vp)
            newly = sorted(published - keep - b.retired)
            kept = tuple(sorted(published - set(newly) - b.retired))
            epoch = b.gc_epoch
            retired_page_ids: List[str] = []
            if newly:
                b.retired.update(newly)
                b.gc_epoch += 1
                epoch = b.gc_epoch
                self._journal({"op": "retire", "blob": blob_id,
                               "versions": newly, "epoch": epoch})
                for v in newly:
                    rec = b.updates.get(v)
                    if rec is not None:
                        retired_page_ids.extend(pid for pid, *_ in rec.pd)
        if newly:
            # Epoch notification outside the lock: listeners (the shared
            # page cache) may take their own locks; the journal record
            # above is already durable, so a listener crash cannot lose
            # the intent.
            for fn in list(self._gc_listeners):
                fn(blob_id, tuple(newly), epoch, tuple(retired_page_ids))
        return kept, tuple(newly)

    def add_gc_listener(self, fn) -> None:
        """Subscribe ``fn(blob_id, versions, gc_epoch, page_ids)`` to
        retire-intent (gc_epoch bump) notifications — the cache-eviction
        hook: a retired version's pages leave the shared page cache at
        intent time, before any sweep delete goes out."""
        self._gc_listeners.append(fn)

    def sweep_pending(self, blob_id: str) -> List[UpdateRecord]:
        """Retired-but-not-yet-finalized updates, oldest first.  The
        sweep derives each one's candidate set from the journaled page
        descriptors and the deterministic tree shape — no store scan."""
        with self._lock:
            b = self._blob(blob_id)
            return [b.updates[v] for v in sorted(b.retired - b.swept)
                    if v in b.updates]

    def finalize_sweep(self, blob_id: str, versions: Iterable[int],
                       client: Optional[str] = None) -> None:
        """Journal that the sweep of ``versions`` completed (all deletes
        acknowledged).  Unfinalized versions are re-swept next round —
        deletes are idempotent, so partial rounds are safe."""
        versions = sorted(set(versions))
        if not versions:
            return
        self._charge(client)
        with self._lock:
            self._blob(blob_id).swept.update(versions)
            self._journal({"op": "swept", "blob": blob_id,
                           "versions": versions})

    def unfinalize_sweep(self, blob_id: str, versions: Iterable[int],
                         client: Optional[str] = None) -> None:
        """Journal that ``versions`` need re-sweeping despite a prior
        finalize: the restore-time resweep found work left (restore
        resurrects a finalized version's nodes/pages, and a re-delete
        can partially fail, e.g. a provider down during recovery).
        Pulling them out of the finalized set puts them back in
        :meth:`sweep_pending`, so ordinary live rounds retry the
        deletes instead of leaking the resurrected items until the
        next restart."""
        versions = set(versions)
        if not versions:
            return
        self._charge(client)
        with self._lock:
            b = self._blob(blob_id)
            versions = sorted(versions & b.swept)
            if not versions:
                return  # never finalized: already pending, nothing to journal
            b.swept.difference_update(versions)
            self._journal({"op": "unswept", "blob": blob_id,
                           "versions": versions})

    def all_page_ids(self) -> Set[str]:
        """Every page id any assigned update (any blob, any version,
        published or in flight, retired or not) has ever journaled.
        The GC orphan scan treats pages outside this set — stored but
        never registered, e.g. a restriped optimistic append or a
        writer that died before version assignment — as collectable
        once they outlive the grace window."""
        with self._lock:
            out: Set[str] = set()
            for b in self._blobs.values():
                for rec in b.updates.values():
                    for pd in rec.pd:
                        out.add(pd[0])
            return out

    def mark_roots(self) -> Dict[str, List[Tuple[int, int]]]:
        """Every live snapshot the mark phase must walk: blob id ->
        [(version, root_pages)] over the blob's own published, non-retired
        versions.  Inherited versions appear under their owner blob."""
        with self._lock:
            out: Dict[str, List[Tuple[int, int]]] = {}
            for b in self._blobs.values():
                roots = [(v, b.updates[v].root_pages)
                         for v in range(b.base_version + 1, b.published + 1)
                         if v not in b.retired and v in b.updates]
                if roots:
                    out[b.blob_id] = roots
            return out

    # ------------------------------------------------------- failure handling
    def find_stalled(self, timeout: float) -> List[Tuple[str, UpdateRecord]]:
        """Assigned-but-incomplete updates older than ``timeout`` seconds.

        These block the publication pipeline (in-order publishing); a
        recovery agent replays their metadata from the journaled page
        descriptors and calls :meth:`metadata_complete`.
        """
        now = self._clock.now()
        out = []
        with self._lock:
            for b in self._blobs.values():
                for v in range(b.published + 1, b.last_assigned + 1):
                    rec = b.updates.get(v)
                    if rec is not None and not rec.complete and now - rec.assigned_at > timeout:
                        out.append((b.blob_id, rec))
        return out

    def assign_info_for_recovery(self, blob_id: str, version: int) -> "AssignInfo":
        """Reconstruct the AssignInfo a dead writer was handed."""
        with self._lock:
            b = self._blob(blob_id)
            rec = b.updates[version]
            vp = b.published
            recent = tuple(
                (r.version, r.p0, r.p1)
                for u in range(vp + 1, version)
                if (r := b.updates.get(u)) is not None
            )
            return AssignInfo(
                version=version, offset=rec.offset,
                prev_size=self._size_of(blob_id, version - 1) if version > 1 else 0,
                new_size=rec.new_blob_size, root_pages=rec.root_pages,
                p0=rec.p0, p1=rec.p1,
                vp=vp if vp > 0 else None,
                vp_root_pages=self._root_pages_of(blob_id, vp) if vp > 0 else 0,
                recent_updates=recent,
            )

    # ------------------------------------------------------------ WAL recovery
    @classmethod
    def recover_from_wal(cls, wal_path: str, wire: Optional[Wire] = None) -> "VersionManager":
        """Rebuild full version-manager state from the journal."""
        vm = cls(wire=wire)
        max_id = 0
        with open(wal_path) as f:
            for line in f:
                rec = json.loads(line)
                op = rec["op"]
                if op == "create":
                    vm._blobs[rec["blob"]] = BlobRecord(rec["blob"], rec["psize"])
                    max_id = max(max_id, int(rec["blob"].split("-")[1]))
                elif op == "branch":
                    src = vm._blobs[rec["src"]]
                    vm._blobs[rec["blob"]] = BlobRecord(
                        blob_id=rec["blob"], psize=src.psize,
                        parent=(rec["src"], rec["at"]), base_version=rec["at"],
                        last_assigned=rec["at"], published=rec["at"],
                    )
                    max_id = max(max_id, int(rec["blob"].split("-")[1]))
                elif op == "assign":
                    b = vm._blobs[rec["blob"]]
                    psz = b.psize
                    p0, p1 = pages_spanned(rec["offset"], rec["size"], psz)
                    b.updates[rec["v"]] = UpdateRecord(
                        version=rec["v"], offset=rec["offset"], size=rec["size"],
                        new_blob_size=rec["new_size"],
                        root_pages=root_pages_for(rec["new_size"], psz),
                        p0=p0, p1=p1, is_append=rec["append"], client=rec["client"],
                        pd=tuple(tuple(x) for x in rec["pd"]),
                        # stamp on the VM's own clock: the wall-time default
                        # would make find_stalled never fire under a virtual
                        # clock (now() - monotonic is hugely negative)
                        assigned_at=vm._clock.now(),
                        vp=rec.get("vp"),
                    )
                    b.last_assigned = max(b.last_assigned, rec["v"])
                elif op == "pd":
                    vm._blobs[rec["blob"]].updates[rec["v"]].pd = tuple(
                        tuple(x) for x in rec["pd"]
                    )
                elif op == "complete":
                    vm._blobs[rec["blob"]].updates[rec["v"]].complete = True
                elif op == "publish":
                    vm._blobs[rec["blob"]].published = rec["v"]
                elif op == "retention":
                    vm._blobs[rec["blob"]].keep_last = rec["keep_last"]
                elif op == "retire":
                    b = vm._blobs[rec["blob"]]
                    b.retired.update(rec["versions"])
                    b.gc_epoch = max(b.gc_epoch, rec.get("epoch", 0))
                elif op == "swept":
                    vm._blobs[rec["blob"]].swept.update(rec["versions"])
                elif op == "unswept":
                    vm._blobs[rec["blob"]].swept.difference_update(
                        rec["versions"])
        vm._ids = itertools.count(max_id + 1)
        vm._wal_path = wal_path
        vm._wal_file = open(wal_path, "a")
        return vm


@dataclass(frozen=True)
class AssignInfo:
    """Everything a writer receives from the version manager (§4.2)."""

    version: int
    offset: int
    prev_size: int
    new_size: int
    root_pages: int
    p0: int
    p1: int
    vp: Optional[int]                       # recently published snapshot
    vp_root_pages: int
    recent_updates: Tuple[Tuple[int, int, int], ...]  # (version, p0, p1), unpublished-at-assign
