"""The version manager (paper §3.1, §4.2, §4.3) — sharded by lineage.

"The version manager is the key actor of the system.  It registers
update requests (APPEND and WRITE), assigning snapshot version numbers,
and eventually publishes these updates, guaranteeing total ordering and
atomicity."

Responsibilities implemented here, faithfully:

* assign strictly increasing snapshot versions per blob; APPEND offsets
  are the size of the previous snapshot (assigned, possibly unpublished);
* keep the in-flight registry of assigned-but-unpublished updates and
  hand each new writer (a) the ranges of every update between the last
  published snapshot and its own version — the *partial border set*
  information of §4.2 — and (b) a recently published snapshot version to
  resolve the rest of its border nodes;
* publish versions **in order** once their metadata is complete, so a
  reader can never observe snapshot ``v`` without snapshots ``< v``
  being fully resolvable (atomicity in the sense of [9]);
* serve GET_RECENT / GET_SIZE / SYNC.

Scale-out write plane (beyond paper; the paper calls the version
manager the potential bottleneck):

* manager state is **partitioned into per-lineage shards** — one
  :class:`LineageShard` per CREATE-rooted branch family, each with its
  own lock and publication condition.  The ordering guarantee the paper
  needs is *per blob*, so nothing is lost: versions of one blob still
  publish strictly in order, but a slow writer on blob A never holds
  any lock or condition a writer/reader of blob B touches.  Branches
  share their ancestor's shard because every cross-blob rule
  (branch-root pinning, inherited-version ownership, in-flight ``vp``
  anchors) stays inside one lineage by construction;
* **batched writer verbs** — :meth:`VersionManager.assign_versions_many`
  and :meth:`VersionManager.metadata_complete_many` carry many updates
  in ONE control round trip (costed per item in ``transport.py``), the
  write-plane mirror of the read plane's ``get_many``.  Per-verb
  counters are exposed through :meth:`rpc_counters` and show up in
  ``service.rpc_report()`` as ``vm_*``.

Beyond-paper (the paper defers failure handling):

* every version assignment is journaled to a write-ahead log together
  with the update's page descriptors (pages are already durably stored
  at assignment time), so a crashed writer's metadata can be rebuilt
  deterministically by any recovery agent (`find_stalled` +
  ``BlobClient.rebuild_metadata``) instead of stalling the publication
  pipeline forever;
* the version manager itself recovers its full state from the WAL.
  Every WAL record carries its **lineage id**, so a recovered manager
  rebuilds the same shard layout; records of different lineages commute
  (the journal only promises order *within* a lineage, which is exactly
  what each shard's lock serializes).

HA control plane (``replication > 0``; see ARCHITECTURE.md):

* each lineage shard becomes a **replicated state machine**: its journal
  records stream to F follower endpoints over the wire (batched,
  fire-and-forget), a clock-based lease marks the leader, and when the
  leader endpoint dies mid-burst the next verb waits out the lease and
  promotes the most-caught-up follower — replaying its copy of the
  journal with exactly the rules :meth:`VersionManager.recover_from_wal`
  applies to the on-disk WAL.  Publication acks barrier on the stream's
  completion instant, so an acked publication is never lost to failover.

Subscription plane (watch/notify; see docs/watch.md):

* :meth:`VersionManager.watch` leases a push subscription on a blob —
  publications past ``from_version`` are coalesced per watcher and
  shipped as ONE fire-and-forget batch per inbox endpoint at
  publication time, so a K-publication burst to W watchers costs
  O(K x endpoints-with-watchers) notify RPCs, never O(W).  Leases use
  the GC pin-lease clock machinery (absolute expiry, renewal) and
  replicate through the journal, so watches survive leader failover;
  a cold restart drops them (clients re-watch), like pins.
"""

from __future__ import annotations

import itertools
import json
import os
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, FrozenSet, Iterable, List, Optional, Sequence, Set, Tuple

from repro.core.pages import pages_spanned, root_pages_for
from repro.core.sim import Clock, WallClock
from repro.core.transport import (
    VM_ASSIGN_REQ_BYTES,
    VM_COMPLETE_CMD_BYTES,
    VM_CTRL_MSG_BYTES,
    VM_WAL_PROMOTE_BYTES,
    VM_WAL_REC_BYTES,
    VM_WATCH_REQ_BYTES,
    WATCH_NOTIFY_EVT_BYTES,
    EndpointDown,
    Wire,
)

VMGR_ENDPOINT = "vmgr"
_CTRL_MSG_BYTES = VM_CTRL_MSG_BYTES  # wire-cost estimate of one control RPC


def owner_fn_for_lineage(chain: Sequence[Tuple[str, int]]):
    """Version -> owning blob id, from a :meth:`VersionManager.lineage`
    chain (youngest first).  The single home of the ownership rule —
    version ``v`` belongs to the first entry with ``v > base`` — shared
    by the client (cached chains), the GC mark walk and the manager."""

    def owner(version: int) -> str:
        for bid, base in chain:
            if version > base:
                return bid
        return chain[-1][0]

    return owner


class BlobUnknown(KeyError):
    """No blob with that id exists at this version manager."""


class VersionUnpublished(RuntimeError):
    """The snapshot version is not published (or not even assigned):
    reads, GET_SIZE and pins of it are rejected — the paper's READ
    'fails if the version is not published yet'."""


class WriteBeyondEnd(ValueError):
    """WRITE offset larger than the size of the previous snapshot."""


class RetiredVersion(RuntimeError):
    """The snapshot was retired by GC: its space has been (or is being)
    reclaimed.  Raised for reads, pins and branches of retired versions
    — a typed, deliberate answer, never a stray ``KeyError`` from a
    swept page or tree node."""


@dataclass
class UpdateRecord:
    """One assigned update (WRITE/APPEND) in a blob's history: the
    version manager's journaled source of truth for the update's range,
    page descriptors (``pd``), completion state and the published
    anchor ``vp`` its writer resolves border nodes against.  GC derives
    a retired version's sweep candidates from this record alone."""

    version: int
    offset: int            # bytes
    size: int              # bytes written
    new_blob_size: int     # bytes: size of this snapshot
    root_pages: int
    p0: int                # page extent of the update
    p1: int
    is_append: bool
    client: str
    pd: Tuple = ()         # ((pid, rel_page_index, providers, length), ...)
    complete: bool = False
    assigned_at: float = field(default_factory=time.monotonic)
    vp: Optional[int] = None  # published anchor handed to the writer (GC keeps it)


@dataclass
class PinLease:
    """One client's pin on ``(blob, version)``: GC keeps the snapshot
    until the lease is released or its clock-based expiry passes."""

    lease_id: str
    blob_id: str
    version: int
    client: Optional[str]
    expires_at: Optional[float]  # None = until released


@dataclass
class WatchLease:
    """One client's watch on a blob: every publication past
    ``from_version`` is pushed (coalesced) to the lease's inbox
    endpoint until :meth:`VersionManager.unwatch` or the clock-based
    expiry passes — the same absolute-expiry/renewal machinery as
    :class:`PinLease`.  ``delivered_up_to`` is the per-watcher
    coalescing watermark: a flush sends one entry covering
    ``(delivered_up_to, published]`` and advances it, so deliveries
    are monotone and never skip a version past ``from_version``."""

    watch_id: str
    blob_id: str
    client: Optional[str]
    endpoint: str                # inbox endpoint notifies are shipped to
    from_version: int
    delivered_up_to: int
    expires_at: Optional[float]  # None = until unwatched


@dataclass
class BlobRecord:
    """Per-blob manager state: page size, branch parentage, the update
    log, publication watermark, and the GC bookkeeping (retention
    policy, retired/swept sets, ``gc_epoch``)."""

    blob_id: str
    psize: int
    parent: Optional[Tuple[str, int]] = None  # (parent blob id, branch version)
    base_version: int = 0                     # versions <= base live in the parent
    updates: Dict[int, UpdateRecord] = field(default_factory=dict)
    last_assigned: int = 0
    published: int = 0
    keep_last: int = 0                        # retention policy; 0 = keep all
    retired: Set[int] = field(default_factory=set)  # retire-intent: reads rejected
    swept: Set[int] = field(default_factory=set)    # sweep finalized
    gc_epoch: int = 0                         # bumped at every retire-intent
    lineage_id: str = ""                      # shard key (root blob of the family)


class _FollowerReplica:
    """One follower's copy of a lineage's replicated journal.

    ``records`` is exactly the prefix of the leader's journal that was
    successfully streamed to this endpoint.  A single failed stream
    marks the follower ``lost`` forever: its journal now has a gap, so
    it can never be promoted (a promoted gap would silently unassign
    versions the leader already acked)."""

    __slots__ = ("endpoint", "records", "lost")

    def __init__(self, endpoint: str) -> None:
        self.endpoint = endpoint
        self.records: List[dict] = []
        self.lost = False


class _ShardReplication:
    """Replication state of one lineage shard (the HA control plane).

    The leader is an endpoint name, not a process: every verb on the
    lineage charges the leader endpoint, which both accounts the RPC
    and *detects death* (``EndpointDown``).  ``lease_expires_at`` is
    renewed on every successfully charged verb; failover must wait it
    out before promoting, because the old leader may still be acking
    verbs issued before the fault was observed (the same clock-based
    expiry rule as GC pin leases).  Mutated only under the shard lock,
    except the benign lease-renewal stamp."""

    __slots__ = ("leader_ep", "followers", "lease_ttl", "lease_expires_at",
                 "epoch", "pending", "failing_over", "barrier_at",
                 "assigned_keys")

    def __init__(self, lineage_id: str, n_followers: int, lease_ttl: float,
                 now: float) -> None:
        self.leader_ep = f"vm-{lineage_id}"
        self.followers: Tuple[_FollowerReplica, ...] = tuple(
            _FollowerReplica(f"vm-{lineage_id}-f{k}")
            for k in range(1, n_followers + 1)
        )
        self.lease_ttl = lease_ttl
        self.lease_expires_at = now + lease_ttl
        self.epoch = 1                    # bumped at every failover
        self.pending: List[dict] = []     # records journaled by the verb in flight
        self.failing_over = False         # guards concurrent failover attempts
        self.barrier_at = 0.0             # completion instant of the newest stream
        # idempotency: journaled assign key -> (blob, version); a re-driven
        # assign with a known key returns the already-assigned version
        self.assigned_keys: Dict[str, Tuple[str, int]] = {}


class LineageShard:
    """One partition of the version manager's state: a CREATE-rooted
    blob plus every branch forked (transitively) from it.

    Each shard owns its blobs' records, read-lease counts, an RLock and
    a clock-bound condition for SYNC / publication / drain waits.  Every
    per-blob verb takes exactly this one lock, so the only writers that
    ever contend on a version-manager critical section are writers of
    the *same lineage* — publication on blob B proceeds even while a
    task holds blob A's shard lock (see ``tests/test_write_plane.py``).

    Branches join their ancestor's shard: inherited-version ownership,
    branch-root retention and in-flight ``vp`` anchors are then all
    intra-shard facts, which is what lets :meth:`VersionManager.\
plan_retirement` run under a single shard lock.
    """

    __slots__ = ("lineage_id", "lock", "cond", "blobs", "active_reads",
                 "repl", "watches")

    def __init__(self, lineage_id: str, clock: Clock) -> None:
        self.lineage_id = lineage_id
        self.lock = threading.RLock()
        # SYNC / publication / drain waits block through the clock:
        # real threading.Condition on the wall backend, virtual-time
        # waits under a Simulator.
        self.cond = clock.condition(self.lock)
        self.blobs: Dict[str, BlobRecord] = {}
        # in-flight read counts per (owner blob, version), for the GC
        # sweep's drain barrier
        self.active_reads: Dict[Tuple[str, int], int] = {}
        # HA replication group (None with replication off: every verb
        # then charges the shared VMGR_ENDPOINT exactly as before)
        self.repl: Optional[_ShardReplication] = None
        # subscription plane: blob id -> {watch id -> WatchLease},
        # mutated under the shard lock, rebuilt on failover from the
        # replicated journal's watch/unwatch/renew/notify records
        self.watches: Dict[str, Dict[str, WatchLease]] = {}


class VersionManager:
    """The system's serialization point (paper §3.1), sharded by
    lineage: it assigns strictly increasing snapshot versions per blob,
    keeps the in-flight registry concurrent writers resolve their
    border sets from, and publishes each blob's versions **in order**
    once their metadata completes.  The critical section is per
    lineage (:class:`LineageShard`), so unrelated blobs never contend.

    Beyond the paper it also owns the durability and GC control planes:
    every assignment is journaled to a WAL (crashed writers are
    rebuilt deterministically, the manager itself recovers via
    :meth:`recover_from_wal`), and retirement state — retention
    policies, pin leases, read leases/drain barrier, retire-intent and
    sweep finalization — lives here so that a single critical section
    per lineage decides what GC may reclaim (see ``core/gc.py``)."""

    #: batch fsync policy: coalesce at most this many journal records
    #: between fsyncs (publication acks always sync, see _repl_barrier)
    FSYNC_COALESCE = 256

    def __init__(self, wire: Optional[Wire] = None, wal_path: Optional[str] = None,
                 clock: Optional[Clock] = None, *, replication: int = 0,
                 lease_ttl: float = 0.25,
                 fsync_policy: str = "batch") -> None:
        if fsync_policy not in ("never", "batch", "always"):
            raise ValueError(f"fsync_policy must be never/batch/always, "
                             f"got {fsync_policy!r}")
        if replication < 0:
            raise ValueError("replication must be >= 0")
        self.wire = wire
        if clock is None:
            clock = wire.clock if wire is not None else WallClock()
        self._clock = clock
        # HA config: replication = follower count per lineage shard
        # (0 = single shared endpoint, the pre-HA behavior).
        self._replication = replication
        self._lease_ttl = lease_ttl
        self._fsync_policy = fsync_policy
        self._wal_dirty = 0   # records written since the last fsync
        # Lineage registry: blob id -> lineage id -> shard.  The
        # registry lock guards only these maps and the id counter; it
        # is never held across a shard operation (lock order:
        # shard lock > registry/pins/WAL/counter locks, one shard lock
        # at a time — cross-lineage iteration visits shards serially).
        self._registry_lock = threading.Lock()
        self._shards: Dict[str, LineageShard] = {}
        self._lineage_of: Dict[str, str] = {}
        self._blob_order: List[str] = []   # global creation order
        self._ids = itertools.count(1)
        self._wal_lock = threading.Lock()
        self._wal: List[dict] = []
        self._wal_path = wal_path
        self._wal_file = open(wal_path, "a") if wal_path else None
        # GC state: pin leases (volatile — leases die with the manager,
        # recovery falls back to retention).
        self._pins_lock = threading.Lock()
        self._pins: Dict[str, PinLease] = {}
        self._pin_ids = itertools.count(1)
        # Subscription plane: watch leases live on their lineage shard
        # (sh.watches, under the shard lock, replicated via the
        # journal).  The facade keeps only the routing map (watch id ->
        # blob id), the id counter, and the registered delivery inboxes.
        # Inboxes are process memory: they survive leader failover (the
        # promoted leader keeps pushing to the same endpoints) but die
        # with the manager process — after a cold restart clients
        # re-watch, exactly like pin leases.
        self._watches_lock = threading.Lock()
        self._watch_of: Dict[str, str] = {}
        self._watch_ids = itertools.count(1)
        self._inboxes: Dict[str, object] = {}
        # Retire-intent listeners (gc_epoch notifications): fired after
        # every plan_retirement that retires something, OUTSIDE the
        # shard lock, with (blob_id, versions, epoch, page_ids).  The
        # deployment's page cache subscribes so a retired version's
        # pages are evicted the instant the epoch bumps.
        self._gc_listeners: List = []
        # Control-plane accounting (see rpc_counters / rpc_report):
        # ops = logical verbs, round_trips = RPCs actually paid,
        # batched_ops = verbs that rode a batched RPC.
        self._ctr_lock = threading.Lock()
        self._counters: Dict[str, int] = {
            "ops": 0,
            "round_trips": 0,
            "batched_ops": 0,
            "assign_batches": 0,
            "complete_batches": 0,
            "wal_records": 0,        # journal records streamed to followers
            "wal_stream_batches": 0,  # fire-and-forget stream batches sent
            "wal_fsyncs": 0,
            "failovers": 0,
        }
        # watch_* counter family (service.rpc_report): registration
        # traffic plus notify fan-out accounting — notify_rpcs is the
        # number the bench gate compares against the poll twin.
        self._watch_ctr: Dict[str, int] = {
            "registered": 0,
            "renewed": 0,
            "unwatched": 0,
            "expired": 0,
            "notify_rpcs": 0,      # fire-and-forget batches shipped
            "notify_entries": 0,   # coalesced per-watcher entries in them
            "notify_versions": 0,  # versions those entries covered
            "dropped_sends": 0,    # batches lost to a down inbox endpoint
        }

    # ------------------------------------------------------------------ utils
    def _charge(self, client: Optional[str], sh: Optional[LineageShard] = None,
                nbytes: int = _CTRL_MSG_BYTES) -> None:
        """Account one singleton control-plane verb (routed to the
        lineage's leader endpoint when the shard is replicated)."""
        with self._ctr_lock:
            self._counters["ops"] += 1
            self._counters["round_trips"] += 1
        self._charge_wire(sh, lambda ep: self.wire.transfer(
            ep, nbytes, inbound=True, peer=client))

    def _charge_batch(self, n_items: int, item_bytes: int, kind: str,
                      client: Optional[str],
                      shards: Optional[Sequence[LineageShard]] = None) -> None:
        """Account one batched control RPC carrying ``n_items`` verbs.

        With replication on, ``shards`` (aligned with the items) routes
        each item to its lineage's leader: the batch becomes one RPC
        *per touched leader* — cross-lineage batches split, same-lineage
        bursts still amortize exactly as before."""
        repl_groups: Optional[Dict[str, Tuple[LineageShard, int]]] = None
        if shards is not None and any(s.repl is not None for s in shards):
            repl_groups = {}
            for s in shards:
                lid = s.lineage_id
                repl_groups[lid] = (s, repl_groups.get(lid, (s, 0))[1] + 1)
        n_rpcs = 1 if repl_groups is None else len(repl_groups)
        with self._ctr_lock:
            self._counters["ops"] += n_items
            self._counters["batched_ops"] += n_items
            self._counters["round_trips"] += n_rpcs
            self._counters[f"{kind}_batches"] += n_rpcs
        if repl_groups is None:
            if self.wire is not None:
                self.wire.transfer_batch(VMGR_ENDPOINT, [item_bytes] * n_items,
                                         inbound=True, peer=client)
            return
        for lid in sorted(repl_groups):
            s, cnt = repl_groups[lid]
            self._charge_wire(s, lambda ep, cnt=cnt: self.wire.transfer_batch(
                ep, [item_bytes] * cnt, inbound=True, peer=client))

    def _charge_wire(self, sh: Optional[LineageShard],
                     send: Callable[[str], float]) -> None:
        """Issue one control RPC, retrying through failover: a dead
        leader endpoint triggers promotion of a follower, after which
        the verb is re-charged against the new leader.  Must be called
        with NO shard lock held (failover sleeps out the old lease)."""
        if self.wire is None:
            return
        repl = sh.repl if sh is not None else None
        if repl is None:
            send(VMGR_ENDPOINT)
            return
        while True:
            try:
                send(repl.leader_ep)
            except EndpointDown:
                self._failover(sh)
                continue
            # the leader answered: it provably held the lease just now
            repl.lease_expires_at = self._clock.now() + repl.lease_ttl
            return

    def rpc_counters(self) -> Dict[str, int]:
        """Control-plane accounting: ``ops`` (logical verbs),
        ``round_trips`` (control RPCs actually paid — a batched verb
        counts once), ``batched_ops`` (verbs that rode a batch), and
        per-verb batch counts.  ``ops / round_trips`` is the write
        plane's amortization factor; ``service.rpc_report()`` surfaces
        these as ``vm_*``."""
        with self._ctr_lock:
            return dict(self._counters)

    def reset_rpc_counters(self) -> None:
        with self._ctr_lock:
            for k in self._counters:
                self._counters[k] = 0

    def watch_counters(self) -> Dict[str, int]:
        """Subscription-plane accounting (``watch_*`` in
        ``service.rpc_report()``): lease traffic plus notify fan-out —
        ``notify_rpcs`` counts fire-and-forget batches (one per inbox
        endpoint per flush), ``notify_entries`` the coalesced
        per-watcher entries they carried, ``notify_versions`` the
        versions those entries covered."""
        with self._ctr_lock:
            return dict(self._watch_ctr)

    def reset_watch_counters(self) -> None:
        with self._ctr_lock:
            for k in self._watch_ctr:
                self._watch_ctr[k] = 0

    def _journal(self, sh: LineageShard, rec: dict) -> None:
        """Append one WAL record (stamped with its lineage id).

        Called while holding the lineage's shard lock, so the journal
        order of any single lineage matches its state-mutation order;
        records of different lineages may interleave freely — they
        reference disjoint state, so replay commutes across lineages.

        With replication on the record is also buffered on the shard;
        the verb streams its whole buffer to the followers in one batch
        per follower via :meth:`_repl_flush` before releasing the lock.
        """
        rec = dict(rec)
        rec["lineage"] = sh.lineage_id
        with self._wal_lock:
            self._wal.append(rec)
            if self._wal_file is not None:
                self._wal_file.write(json.dumps(rec) + "\n")
                self._wal_file.flush()
                if self._fsync_policy == "always":
                    os.fsync(self._wal_file.fileno())
                    with self._ctr_lock:
                        self._counters["wal_fsyncs"] += 1
                elif self._fsync_policy == "batch":
                    self._wal_dirty += 1
        if sh.repl is not None:
            sh.repl.pending.append(rec)
        if self._fsync_policy == "batch" and self._wal_dirty >= self.FSYNC_COALESCE:
            self._wal_sync()

    def _wal_sync(self) -> None:
        """Force journaled records to stable storage (fsync).  Called at
        publication-ack points and when the batch-coalescing threshold
        fills; a no-op with ``fsync_policy='never'`` or a clean file."""
        if self._fsync_policy == "never":
            return
        with self._wal_lock:
            if self._wal_file is None or self._wal_dirty == 0:
                return
            self._wal_file.flush()
            os.fsync(self._wal_file.fileno())
            self._wal_dirty = 0
        with self._ctr_lock:
            self._counters["wal_fsyncs"] += 1

    # ------------------------------------------------------- HA replication
    def _repl_flush(self, sh: LineageShard) -> None:
        """Stream the records the current verb journaled to every live
        follower: ONE fire-and-forget batch per follower (latency paid
        once, ``VM_WAL_REC_BYTES`` per record).  Caller holds the shard
        lock, so follower journals extend in exactly leader-journal
        order.  A follower whose endpoint is down misses the batch and
        is dropped from the group for good (its journal has a gap)."""
        repl = sh.repl
        if repl is None or not repl.pending:
            return
        recs, repl.pending = repl.pending, []
        live = 0
        for f in repl.followers:
            if f.lost:
                continue
            if self.wire is not None:
                try:
                    done = self.wire.transfer_batch(
                        f.endpoint, [VM_WAL_REC_BYTES] * len(recs),
                        inbound=True, peer=repl.leader_ep,
                        fire_and_forget=True)
                except EndpointDown:
                    f.lost = True
                    continue
                if done > repl.barrier_at:
                    repl.barrier_at = done
            f.records.extend(recs)
            live += 1
        if live:
            with self._ctr_lock:
                self._counters["wal_records"] += len(recs) * live
                self._counters["wal_stream_batches"] += live

    def _repl_barrier(self, sh: LineageShard) -> None:
        """Durability barrier before a publication-affecting ack: fsync
        the local WAL and (under a virtual clock) wait until the newest
        follower stream has arrived.  Endpoint FIFO makes the newest
        stream's completion instant cover every earlier record too, so
        one wait suffices.  Must be called with NO shard lock held —
        under the simulator this sleeps in virtual time."""
        self._wal_sync()
        repl = sh.repl
        if repl is None or self.wire is None:
            return
        t = repl.barrier_at
        if self._clock.is_virtual and t > self._clock.now():
            self._clock.sleep_until(t)

    def _failover(self, sh: LineageShard) -> None:
        """Promote the most-caught-up live follower of a dead leader.

        Called from :meth:`_charge_wire` (no shard lock held) when the
        leader endpoint answered :class:`EndpointDown`.  Exactly one
        task runs the promotion; concurrent verbs wait on the shard
        condition and retry against the new leader.  The promotion:

        1. waits out the dead leader's lease (it may still be acking
           verbs issued before the fault was observed);
        2. picks the live follower with the longest journal (ties break
           by endpoint name — deterministic under the simulator);
        3. pays one blocking promotion handshake RPC;
        4. replays the follower's journal with the same rules as
           :meth:`recover_from_wal` — plus the soft state a same-epoch
           failover can keep that a cold restart drops: pin leases and
           assign idempotency keys are rebuilt from their records, and
           read leases carry over (re-registration with the new leader);
        5. swaps the shard's blob records, bumps the epoch, renews the
           lease and journals a ``failover`` audit record (ignored by
           WAL replay).

        Raises :class:`EndpointDown` when no live follower remains.
        """
        repl = sh.repl
        with sh.cond:
            if repl.failing_over:
                epoch0 = repl.epoch
                while repl.failing_over and repl.epoch == epoch0:
                    sh.cond.wait(repl.lease_ttl)
                return
            if not self.wire.is_down(repl.leader_ep):
                return   # a concurrent failover already installed a new leader
            repl.failing_over = True
            lease_until = repl.lease_expires_at
            candidates = [f for f in repl.followers
                          if not f.lost and not self.wire.is_down(f.endpoint)]
        try:
            if lease_until > self._clock.now():
                self._clock.sleep_until(lease_until)
            if not candidates:
                raise EndpointDown(
                    f"{repl.leader_ep}: no live follower to promote")
            promoted = max(candidates,
                           key=lambda f: (len(f.records), f.endpoint))
            self.wire.transfer(promoted.endpoint, VM_WAL_PROMOTE_BYTES,
                               inbound=True)
            blobs, pins, keys, watches = self.replay_lineage(promoted.records)
            with sh.cond:
                old_blobs = set(sh.blobs)
                old_watch_ids = [wid for table in sh.watches.values()
                                 for wid in table]
                sh.blobs = blobs
                sh.watches = watches
                repl.followers = tuple(f for f in repl.followers
                                       if f is not promoted)
                repl.leader_ep = promoted.endpoint
                repl.epoch += 1
                repl.assigned_keys = keys
                repl.lease_expires_at = self._clock.now() + repl.lease_ttl
                with self._pins_lock:
                    for lid in [lid for lid, p in self._pins.items()
                                if p.blob_id in old_blobs]:
                        del self._pins[lid]
                    self._pins.update(pins)
                with self._watches_lock:
                    for wid in old_watch_ids:
                        self._watch_of.pop(wid, None)
                    for bid, table in watches.items():
                        for wid in table:
                            self._watch_of[wid] = bid
                self._journal(sh, {"op": "failover", "epoch": repl.epoch,
                                   "leader": promoted.endpoint})
                # resume deliveries: any publication the old leader
                # acked but whose notify record never reached this
                # follower re-flushes now — the inbox watermark drops
                # what was already delivered (no gap, no duplicate)
                for bid in sorted(sh.watches):
                    self._flush_watch_locked(sh, bid)
                self._repl_flush(sh)
                sh.cond.notify_all()
            with self._ctr_lock:
                self._counters["failovers"] += 1
        finally:
            with sh.cond:
                repl.failing_over = False
                sh.cond.notify_all()

    def _shard_of(self, blob_id: str) -> LineageShard:
        with self._registry_lock:
            lid = self._lineage_of.get(blob_id)
            if lid is None:
                raise BlobUnknown(blob_id)
            return self._shards[lid]

    def _all_shards(self) -> List[LineageShard]:
        """Every shard, in lineage-creation order (deterministic)."""
        with self._registry_lock:
            return [self._shards[lid] for lid in sorted(self._shards)]

    def lineage_id(self, blob_id: str) -> str:
        """The shard key of ``blob_id``'s lineage: the root blob the
        family was CREATEd as.  Blobs with different lineage ids share
        no version-manager lock — publication on one can never wait on
        the other (the write plane's independence contract)."""
        with self._registry_lock:
            lid = self._lineage_of.get(blob_id)
            if lid is None:
                raise BlobUnknown(blob_id)
            return lid

    @staticmethod
    def _blob_in(sh: LineageShard, blob_id: str) -> BlobRecord:
        try:
            return sh.blobs[blob_id]
        except KeyError:
            raise BlobUnknown(blob_id)

    def _owner_record(self, sh: LineageShard, blob_id: str, version: int) -> BlobRecord:
        """BlobRecord owning ``version`` (walks branch lineage).
        Caller holds the shard lock; the whole walk stays in-shard."""
        b = self._blob_in(sh, blob_id)
        while version <= b.base_version and b.parent is not None:
            b = self._blob_in(sh, b.parent[0])
        return b

    def _record(self, sh: LineageShard, blob_id: str, version: int) -> Optional[UpdateRecord]:
        """Update record for ``version``, walking branch lineage."""
        return self._owner_record(sh, blob_id, version).updates.get(version)

    def _check_not_retired(self, sh: LineageShard, blob_id: str, version: int) -> None:
        # caller holds the shard lock; retirement is recorded on the owner
        # blob, so a branch reading an inherited snapshot sees it too
        if version in self._owner_record(sh, blob_id, version).retired:
            raise RetiredVersion(f"{blob_id} v{version} retired by GC")

    def _latest_live_published(self, sh: LineageShard, b: BlobRecord) -> int:
        """Newest published, non-retired version — what GET_RECENT hands
        out and what new updates anchor their border descents on (a
        retired anchor would race the sweep)."""
        v = b.published
        while v > 0 and v in self._owner_record(sh, b.blob_id, v).retired:
            v -= 1
        return v

    def owner_of(self, blob_id: str, version: int) -> str:
        """Blob id owning the tree nodes of ``version`` (branch lineage)."""
        sh = self._shard_of(blob_id)
        with sh.lock:
            return self._owner_record(sh, blob_id, version).blob_id

    def lineage(self, blob_id: str) -> Tuple[Tuple[str, int], ...]:
        """Branch chain as ((blob_id, base_version), ...) youngest first.

        Version ``v`` is owned by the first entry with ``v > base``.
        Clients cache this; it only ever grows by BRANCH.
        """
        sh = self._shard_of(blob_id)
        with sh.lock:
            chain: List[Tuple[str, int]] = []
            b = self._blob_in(sh, blob_id)
            while True:
                chain.append((b.blob_id, b.base_version))
                if b.parent is None:
                    break
                b = self._blob_in(sh, b.parent[0])
            return tuple(chain)

    def _size_of(self, sh: LineageShard, blob_id: str, version: int) -> int:
        if version == 0:
            return 0
        rec = self._record(sh, blob_id, version)
        if rec is None:
            raise VersionUnpublished(f"{blob_id} v{version} not assigned")
        return rec.new_blob_size

    def _root_pages_of(self, sh: LineageShard, blob_id: str, version: int) -> int:
        if version == 0:
            return 0
        rec = self._record(sh, blob_id, version)
        if rec is None:
            raise VersionUnpublished(f"{blob_id} v{version} not assigned")
        return rec.root_pages

    # ------------------------------------------------------------- public API
    def create(self, psize: int, client: Optional[str] = None) -> str:
        """CREATE: new empty blob, snapshot 0 (size 0).  Roots a fresh
        lineage shard — updates to it will never contend with any
        existing blob's version-manager critical section."""
        self._charge(client)   # CREATE is a registry verb: always "vmgr"
        with self._registry_lock:
            blob_id = f"blob-{next(self._ids):08d}"
            sh = LineageShard(blob_id, self._clock)
            sh.blobs[blob_id] = BlobRecord(blob_id=blob_id, psize=psize,
                                           lineage_id=blob_id)
            if self._replication > 0:
                sh.repl = _ShardReplication(blob_id, self._replication,
                                            self._lease_ttl, self._clock.now())
            self._shards[blob_id] = sh
            self._lineage_of[blob_id] = blob_id
            self._blob_order.append(blob_id)
            # journal BEFORE the registry lock drops: the instant the
            # blob is visible, another thread may journal an op on it,
            # and recovery requires the 'create' record to come first
            self._journal(sh, {"op": "create", "blob": blob_id,
                               "psize": psize})
        with sh.lock:
            # the create record opens the lineage's replicated journal,
            # so each follower's copy is self-contained from record one
            self._repl_flush(sh)
        return blob_id

    def branch(self, blob_id: str, version: int, client: Optional[str] = None) -> str:
        """BRANCH: fork ``blob_id`` at published snapshot ``version``.
        The fork joins its ancestor's lineage shard (inherited versions,
        branch-root retention and border anchors stay intra-shard)."""
        sh = self._shard_of(blob_id)
        self._charge(client, sh)
        with sh.lock:
            src = self._blob_in(sh, blob_id)
            if version > src.published:
                raise VersionUnpublished(f"{blob_id} v{version} not published")
            if version > 0:
                self._check_not_retired(sh, blob_id, version)
            with self._registry_lock:
                bid = f"blob-{next(self._ids):08d}"
                self._lineage_of[bid] = sh.lineage_id
                self._blob_order.append(bid)
            sh.blobs[bid] = BlobRecord(
                blob_id=bid,
                psize=src.psize,
                parent=(blob_id, version),
                base_version=version,
                last_assigned=version,
                published=version,
                lineage_id=sh.lineage_id,
            )
            self._journal(sh, {"op": "branch", "blob": bid, "src": blob_id,
                               "at": version})
            self._repl_flush(sh)
            return bid

    def get_recent(self, blob_id: str, client: Optional[str] = None) -> int:
        """GET_RECENT: a recently published, still-live version.

        Retired snapshots are never handed out — after a GC round the
        recency pointer skips them (the retention policy always keeps
        the newest published version, so this only walks under an
        explicit-keep GC).
        """
        sh = self._shard_of(blob_id)
        self._charge(client, sh)
        with sh.lock:
            return self._latest_live_published(sh, self._blob_in(sh, blob_id))

    def get_size(self, blob_id: str, version: int, client: Optional[str] = None) -> int:
        """GET_SIZE of a *published* snapshot (paper: fails otherwise)."""
        sh = self._shard_of(blob_id)
        self._charge(client, sh)
        with sh.lock:
            if version > self._blob_in(sh, blob_id).published:
                raise VersionUnpublished(f"{blob_id} v{version} not published")
            if version > 0:
                self._check_not_retired(sh, blob_id, version)
            return self._size_of(sh, blob_id, version)

    def psize_of(self, blob_id: str) -> int:
        """The blob's immutable page size (fixed at CREATE)."""
        sh = self._shard_of(blob_id)
        with sh.lock:
            return self._blob_in(sh, blob_id).psize

    def sync(self, blob_id: str, version: int, timeout: Optional[float] = None,
             client: Optional[str] = None) -> None:
        """SYNC: block until ``version`` is published (waits on the
        blob's lineage shard — publication on other lineages neither
        wakes nor delays this)."""
        sh = self._shard_of(blob_id)
        self._charge(client, sh)
        deadline = None if timeout is None else self._clock.now() + timeout
        with sh.cond:
            while self._blob_in(sh, blob_id).published < version:
                remaining = None if deadline is None else deadline - self._clock.now()
                if remaining is not None and remaining <= 0:
                    raise TimeoutError(f"SYNC {blob_id} v{version}")
                sh.cond.wait(remaining)

    def is_published(self, blob_id: str, version: int) -> bool:
        """Has ``version`` been published (atomically visible)?  True
        for retired versions too — reads of those get the typed
        :class:`RetiredVersion` from :meth:`enter_read`, not a
        'not published' answer."""
        sh = self._shard_of(blob_id)
        with sh.lock:
            return version <= self._blob_in(sh, blob_id).published

    # ----------------------------------------------------- update registration
    def _reassign_info_locked(self, sh: LineageShard, blob_id: str,
                              version: int) -> "AssignInfo":
        """Reconstruct the AssignInfo of an already-assigned version for
        an idempotent re-drive (same journaled key seen again, e.g. a
        batch retried across a failover).  Caller holds the shard lock."""
        b = self._blob_in(sh, blob_id)
        rec = b.updates[version]
        vp = rec.vp if rec.vp is not None else 0
        recent: List[Tuple[int, int, int]] = []
        for u in range(vp + 1, version):
            r = b.updates.get(u)
            if r is not None and u not in b.retired:
                recent.append((r.version, r.p0, r.p1))
        return AssignInfo(
            version=version, offset=rec.offset,
            prev_size=self._size_of(sh, blob_id, version - 1) if version > 1 else 0,
            new_size=rec.new_blob_size, root_pages=rec.root_pages,
            p0=rec.p0, p1=rec.p1, vp=rec.vp,
            vp_root_pages=self._root_pages_of(sh, blob_id, vp) if vp > 0 else 0,
            recent_updates=tuple(recent),
        )

    def _assign_locked(
        self,
        sh: LineageShard,
        blob_id: str,
        offset: Optional[int],
        size: int,
        client: str,
        pd: Tuple,
        key: Optional[str] = None,
    ) -> "AssignInfo":
        """Register one update; caller holds the shard lock and has
        already charged the wire."""
        if key is not None and sh.repl is not None:
            hit = sh.repl.assigned_keys.get(key)
            if hit is not None:
                # idempotent re-drive: this key's assignment is already
                # in the replicated journal — hand back the same version
                # instead of double-assigning
                return self._reassign_info_locked(sh, hit[0], hit[1])
        b = self._blob_in(sh, blob_id)
        prev_size = self._size_of(sh, blob_id, b.last_assigned)
        if offset is None:
            offset = prev_size           # APPEND semantics
            is_append = True
        else:
            is_append = False
            if offset > prev_size:
                raise WriteBeyondEnd(
                    f"offset {offset} > size {prev_size} of snapshot v{b.last_assigned}"
                )
        if size <= 0:
            raise ValueError("update size must be positive")
        vw = b.last_assigned + 1
        b.last_assigned = vw
        new_size = max(prev_size, offset + size)
        root_pages = root_pages_for(new_size, b.psize)
        p0, p1 = pages_spanned(offset, size, b.psize)
        rec = UpdateRecord(
            version=vw, offset=offset, size=size, new_blob_size=new_size,
            root_pages=root_pages, p0=p0, p1=p1, is_append=is_append,
            client=client, pd=tuple(pd), assigned_at=self._clock.now(),
        )
        b.updates[vw] = rec
        # §4.2: ranges of every update between the last published
        # snapshot and vw — the information from which the writer
        # resolves border nodes of concurrent unpublished updates.
        # The anchor vp must be a *live* (non-retired) published
        # version: the writer descends its tree, and GC keeps every
        # anchor of an in-flight update pinned until it completes.
        vp = self._latest_live_published(sh, b)
        rec.vp = vp if vp > 0 else None
        recent: List[Tuple[int, int, int]] = []
        for u in range(vp + 1, vw):
            r = b.updates.get(u)
            if r is not None and u not in b.retired:
                recent.append((r.version, r.p0, r.p1))
        vp_out: Optional[int] = vp if vp > 0 else None
        vp_root = self._root_pages_of(sh, blob_id, vp) if vp > 0 else 0
        self._journal(sh, {
            "op": "assign", "blob": blob_id, "v": vw, "offset": offset,
            "size": size, "new_size": new_size, "append": is_append,
            "client": client, "pd": [list(x) for x in pd],
            "vp": rec.vp, "key": key,
        })
        if key is not None and sh.repl is not None:
            sh.repl.assigned_keys[key] = (blob_id, vw)
        return AssignInfo(
            version=vw, offset=offset, prev_size=prev_size,
            new_size=new_size, root_pages=root_pages, p0=p0, p1=p1,
            vp=vp_out, vp_root_pages=vp_root, recent_updates=tuple(recent),
        )

    def assign_version(
        self,
        blob_id: str,
        offset: Optional[int],     # None => APPEND
        size: int,
        client: str,
        pd: Tuple = (),
        key: Optional[str] = None,
    ) -> "AssignInfo":
        """Register an update; returns everything the writer needs (§4.2).

        The page descriptors ``pd`` (for pages already stored) are
        journaled so a recovery agent can replay BUILD_META if the
        writer dies before completing its metadata.  The returned
        :class:`AssignInfo` carries the full border context (``vp``,
        ``vp_root_pages``, ``recent_updates``, the update's page
        extent), which is what lets the client *prefetch* its whole
        border set in level-batched waves before BUILD_META starts.

        ``key`` is an optional client-chosen idempotency token (see
        :meth:`assign_versions_many`).
        """
        sh = self._shard_of(blob_id)
        self._charge(client, sh)
        with sh.lock:
            info = self._assign_locked(sh, blob_id, offset, size, client,
                                       tuple(pd), key)
            self._repl_flush(sh)
            return info

    def assign_versions_many(
        self,
        requests: Sequence[Tuple[str, Optional[int], int, Tuple]],
        client: str,
        keys: Optional[Sequence[Optional[str]]] = None,
    ) -> List["AssignInfo"]:
        """Batched :meth:`assign_version`: ONE control round trip for
        many updates.

        ``requests`` holds ``(blob_id, offset_or_None, size, pd)``
        tuples (``None`` offset = APPEND); the result list matches the
        request order.  The whole batch pays a single wire latency plus
        ``VM_ASSIGN_REQ_BYTES`` per request — an appender issuing
        bursts of K amortizes the version-manager round trip K-fold,
        the paper's Fig 3 concern addressed the way ``get_many`` fixed
        the metadata read plane.

        Requests for one blob are assigned in list order, and each
        later request's ``recent_updates`` includes the earlier ones
        (they are in-flight registry entries by then), so a client can
        weave an entire burst without any extra border round trips.
        Requests for different blobs are routed to their lineage shards
        independently.  The batch is **atomic with respect to
        validation**: every request is validated against the batch's
        own running state (all touched shards locked, in sorted lineage
        order) before anything is assigned, so a request that fails
        (:class:`WriteBeyondEnd`, non-positive size, unknown blob)
        raises with NO version assigned — a failed batch never leaves
        half-assigned updates stalling a publication pipeline.

        ``keys`` (optional, aligned with ``requests``) are client-chosen
        idempotency tokens, journaled on the assign records.  With a
        replicated shard, re-driving a request whose key is already in
        the journal — a batch retried across a leader failover — returns
        the previously assigned version instead of assigning a new one,
        which is what makes writer retry loops double-assign-safe.
        """
        requests = [(blob_id, offset, size, tuple(pd))
                    for blob_id, offset, size, pd in requests]
        if not requests:
            return []
        if keys is None:
            keys = [None] * len(requests)
        shard_of: List[LineageShard] = [self._shard_of(blob_id)
                                        for blob_id, *_ in requests]
        self._charge_batch(len(requests), VM_ASSIGN_REQ_BYTES, "assign",
                           client, shards=shard_of)
        ordered = sorted({sh.lineage_id: sh for sh in shard_of}.values(),
                         key=lambda sh: sh.lineage_id)
        for sh in ordered:                 # sorted order: deadlock-free
            sh.lock.acquire()
        try:
            # phase 1: validate the whole batch against its running
            # per-blob state (sizes only grow within the batch);
            # re-driven requests (key already assigned) don't re-apply
            running: Dict[str, int] = {}   # blob -> projected size
            for i, (blob_id, offset, size, _pd) in enumerate(requests):
                sh = shard_of[i]
                if (keys[i] is not None and sh.repl is not None
                        and keys[i] in sh.repl.assigned_keys):
                    continue
                b = self._blob_in(sh, blob_id)
                prev = running.get(blob_id)
                if prev is None:
                    prev = self._size_of(sh, blob_id, b.last_assigned)
                if size <= 0:
                    raise ValueError("update size must be positive")
                if offset is not None and offset > prev:
                    raise WriteBeyondEnd(
                        f"offset {offset} > projected size {prev} "
                        f"of {blob_id} (request {i} of the batch)"
                    )
                off = prev if offset is None else offset
                running[blob_id] = max(prev, off + size)
            # phase 2: apply in request order (locks held throughout)
            out = [
                self._assign_locked(shard_of[i], blob_id, offset, size,
                                    client, pd, keys[i])
                for i, (blob_id, offset, size, pd) in enumerate(requests)
            ]
            for sh in ordered:
                self._repl_flush(sh)
            return out
        finally:
            for sh in reversed(ordered):
                sh.lock.release()

    def register_pd(self, blob_id: str, version: int, pd: Tuple,
                    client: Optional[str] = None) -> None:
        """(Re-)journal the final page-descriptor set for an update.

        Used by APPENDs (which learn their offset at assignment) and by
        unaligned WRITEs (whose boundary pages are stored after
        assignment).  Keeps WAL-based recovery deterministic.
        """
        sh = self._shard_of(blob_id)
        self._charge(client, sh)
        with sh.lock:
            rec = self._blob_in(sh, blob_id).updates[version]
            rec.pd = tuple(pd)
            self._journal(sh, {
                "op": "pd", "blob": blob_id, "v": version,
                "pd": [list(x) for x in pd],
            })
            self._repl_flush(sh)

    def _complete_locked(self, sh: LineageShard, blob_id: str,
                         version: int) -> None:
        """Mark ``version`` complete and publish in order; caller holds
        the shard cond's lock."""
        b = self._blob_in(sh, blob_id)
        rec = b.updates[version]
        rec.complete = True
        self._journal(sh, {"op": "complete", "blob": blob_id, "v": version})
        # In-order publication *per blob*: snapshot v is revealed only
        # once every snapshot < v of the same blob is published, so
        # readers can always resolve the full weaved tree of anything
        # they are allowed to see.  Other blobs — even in this lineage
        # — publish independently.
        while True:
            nxt = b.updates.get(b.published + 1)
            if nxt is None or not nxt.complete:
                break
            b.published += 1
            self._journal(sh, {"op": "publish", "blob": blob_id, "v": b.published})

    def metadata_complete(self, blob_id: str, version: int,
                          client: Optional[str] = None) -> None:
        """Writer finished BUILD_META; publish in order (atomicity).

        With a replicated shard the ack barriers on the follower
        streams (and the local fsync): a publication acked to a writer
        is durable on every live replica, so no failover can lose it."""
        sh = self._shard_of(blob_id)
        self._charge(client, sh)
        with sh.cond:
            self._complete_locked(sh, blob_id, version)
            self._flush_watch_locked(sh, blob_id)
            self._repl_flush(sh)
            sh.cond.notify_all()
        self._repl_barrier(sh)

    def metadata_complete_many(
        self,
        items: Sequence[Tuple[str, int]],
        client: Optional[str] = None,
    ) -> None:
        """Batched :meth:`metadata_complete`: ONE control round trip
        marks many ``(blob_id, version)`` updates complete and runs
        each blob's in-order publication.

        The batch pays one wire latency plus ``VM_COMPLETE_CMD_BYTES``
        per command.  Items are applied in list order per lineage
        (publication is per blob, so cross-blob order inside the batch
        is immaterial); SYNC waiters of every touched lineage are woken
        once per lineage.
        """
        items = list(items)
        if not items:
            return
        item_shards = [self._shard_of(blob_id) for blob_id, _ in items]
        self._charge_batch(len(items), VM_COMPLETE_CMD_BYTES, "complete",
                           client, shards=item_shards)
        groups: Dict[str, List[Tuple[str, int]]] = {}
        shards: Dict[str, LineageShard] = {}
        for (blob_id, version), sh in zip(items, item_shards):
            shards.setdefault(sh.lineage_id, sh)
            groups.setdefault(sh.lineage_id, []).append((blob_id, version))
        for lid in sorted(groups):
            sh = shards[lid]
            with sh.cond:
                for blob_id, version in groups[lid]:
                    self._complete_locked(sh, blob_id, version)
                # notify AFTER the whole lineage group published: a
                # K-item burst on one blob is ONE flush — one coalesced
                # entry per watcher, one RPC per inbox endpoint
                for bid in sorted({b for b, _ in groups[lid]}):
                    self._flush_watch_locked(sh, bid)
                self._repl_flush(sh)
                sh.cond.notify_all()
        for lid in sorted(groups):
            # durability barrier per touched lineage, outside every lock
            self._repl_barrier(shards[lid])

    def wait_metadata(self, blob_id: str, version: int,
                      timeout: Optional[float] = None) -> None:
        """Block until ``version``'s metadata is complete (not necessarily
        published).  Needed only by unaligned writes that must merge
        boundary-page content from snapshot ``version`` (§3 "slightly
        more complex" path)."""
        sh = self._shard_of(blob_id)
        deadline = None if timeout is None else self._clock.now() + timeout
        with sh.cond:
            while True:
                b = self._blob_in(sh, blob_id)
                if version <= b.base_version and b.parent is not None:
                    if self._record(sh, blob_id, version) is not None or version == 0:
                        return
                rec = b.updates.get(version)
                if version == 0 or version <= b.published or (rec is not None and rec.complete):
                    return
                remaining = None if deadline is None else deadline - self._clock.now()
                if remaining is not None and remaining <= 0:
                    raise TimeoutError(f"metadata {blob_id} v{version}")
                sh.cond.wait(remaining)

    # ----------------------------------------------------------- introspection
    def update_log(self, blob_id: str, version: int) -> UpdateRecord:
        """The journaled :class:`UpdateRecord` of ``version`` (walks
        branch lineage to the owner blob); raises
        :class:`VersionUnpublished` for never-assigned versions.
        Retirement does NOT hide the record — GC itself reads retired
        records to derive sweep candidates."""
        sh = self._shard_of(blob_id)
        with sh.lock:
            rec = self._record(sh, blob_id, version)
            if rec is None:
                raise VersionUnpublished(f"{blob_id} v{version} not assigned")
            return rec

    def version_bounds(self, blob_id: str) -> Tuple[int, int]:
        """``(base_version, last_assigned)`` of the blob: the half-open
        version interval ``(base, last]`` this blob *owns* (everything
        ``<= base`` is inherited from its branch parent).  Restore and
        GC iterate a blob's own history with this instead of reaching
        into manager internals."""
        sh = self._shard_of(blob_id)
        with sh.lock:
            b = self._blob_in(sh, blob_id)
            return b.base_version, b.last_assigned

    def root_pages_published(self, blob_id: str, version: int) -> int:
        """Page span of the snapshot's segment-tree root, for published,
        non-retired versions (the read path's entry point to the tree)."""
        sh = self._shard_of(blob_id)
        with sh.lock:
            if version > self._blob_in(sh, blob_id).published:
                raise VersionUnpublished(f"{blob_id} v{version} not published")
            if version > 0:
                self._check_not_retired(sh, blob_id, version)
            return self._root_pages_of(sh, blob_id, version)

    def known_blobs(self) -> List[str]:
        """Every blob id this manager has created (branches included),
        in global creation order."""
        with self._registry_lock:
            return list(self._blob_order)

    def leader_endpoint(self, blob_id: str) -> str:
        """The wire endpoint currently serving this blob's lineage:
        the shared ``vmgr`` endpoint with replication off, the lineage's
        current leader (followers promote on failover) with it on.
        Failure injection kills *this* to exercise a failover."""
        sh = self._shard_of(blob_id)
        with sh.lock:
            return sh.repl.leader_ep if sh.repl is not None else VMGR_ENDPOINT

    def replication_report(self, blob_id: str) -> dict:
        """HA state of the blob's lineage, for tests and operators:
        leader endpoint, per-follower journal length and lost flag,
        failover epoch and current lease expiry."""
        sh = self._shard_of(blob_id)
        with sh.lock:
            repl = sh.repl
            if repl is None:
                return {"leader": VMGR_ENDPOINT, "followers": [],
                        "epoch": 0, "lease_expires_at": None}
            return {
                "leader": repl.leader_ep,
                "followers": [(f.endpoint, len(f.records), f.lost)
                              for f in repl.followers],
                "epoch": repl.epoch,
                "lease_expires_at": repl.lease_expires_at,
            }

    def follower_records(self, blob_id: str, index: int = 0) -> List[dict]:
        """Copy of one follower's replicated journal (the prefix of the
        leader's journal successfully streamed to it) — the input the
        follower-replay equivalence property test feeds back through
        :meth:`replay_lineage`."""
        sh = self._shard_of(blob_id)
        with sh.lock:
            if sh.repl is None:
                return []
            return list(sh.repl.followers[index].records)

    # ------------------------------------------- subscription plane: watch
    def register_inbox(self, inbox) -> None:
        """Register a delivery inbox (anything with ``.endpoint`` and
        ``.deliver(entries, ready_at=...)``) as a notify target.
        Inboxes are process memory: they survive leader failover (the
        promoted leader keeps pushing to the same endpoints) but die
        with the manager process — after a cold restart clients
        re-watch and re-register."""
        with self._watches_lock:
            self._inboxes[inbox.endpoint] = inbox

    def watch(self, blob_id: str, from_version: int = 0, *,
              endpoint: str, client: Optional[str] = None,
              ttl: Optional[float] = None) -> str:
        """WATCH: lease a push subscription on ``blob_id``.

        Every publication with version ``> from_version`` is delivered
        to ``endpoint`` (see :meth:`register_inbox`), coalesced per
        watcher — versions already published at registration time are
        caught up immediately in one entry.  ``ttl`` arms the same
        absolute-clock expiry as GC pin leases (renewable via
        :meth:`renew_watch`; ``None`` = until :meth:`unwatch`).  The
        lease replicates through the lineage journal, so it survives
        leader failover; retired versions are skipped (a watcher never
        receives a version its own poll could not read), but the
        watermark still advances past them.  Returns the lease id."""
        if from_version < 0:
            raise ValueError("from_version must be >= 0")
        sh = self._shard_of(blob_id)
        self._charge(client, sh, nbytes=VM_WATCH_REQ_BYTES)
        with sh.cond:
            self._blob_in(sh, blob_id)
            with self._watches_lock:
                wid = f"watch-{next(self._watch_ids):08d}"
                self._watch_of[wid] = blob_id
            expires = None if ttl is None else self._clock.now() + ttl
            lease = WatchLease(wid, blob_id, client, endpoint,
                               from_version, from_version, expires)
            sh.watches.setdefault(blob_id, {})[wid] = lease
            self._journal(sh, {"op": "watch", "blob": blob_id,
                               "watch": wid, "from": from_version,
                               "endpoint": endpoint, "client": client,
                               "expires": expires})
            # catch-up delivery: anything already published past
            # from_version goes out now, as one coalesced entry
            self._flush_watch_locked(sh, blob_id)
            self._repl_flush(sh)
        with self._ctr_lock:
            self._watch_ctr["registered"] += 1
        self._repl_barrier(sh)
        return wid

    def unwatch(self, watch_id: str, client: Optional[str] = None) -> None:
        """Cancel a watch lease (idempotent: unknown/expired ids are
        no-ops, like :meth:`unpin`); nothing is delivered afterward."""
        with self._watches_lock:
            blob_id = self._watch_of.get(watch_id)
        if blob_id is None:
            self._charge(client, nbytes=VM_WATCH_REQ_BYTES)
            return
        sh = self._shard_of(blob_id)
        self._charge(client, sh, nbytes=VM_WATCH_REQ_BYTES)
        with sh.lock:
            if sh.watches.get(blob_id, {}).pop(watch_id, None) is None:
                return
            with self._watches_lock:
                self._watch_of.pop(watch_id, None)
            self._journal(sh, {"op": "unwatch", "watch": watch_id,
                               "blob": blob_id})
            self._repl_flush(sh)
        with self._ctr_lock:
            self._watch_ctr["unwatched"] += 1

    def renew_watch(self, watch_id: str, ttl: Optional[float],
                    client: Optional[str] = None) -> None:
        """Extend (or make permanent, ``ttl=None``) a watch lease's
        expiry — the pin-lease renewal rule on the watch table.  Raises
        ``KeyError`` for unknown/already-expired leases."""
        with self._watches_lock:
            blob_id = self._watch_of.get(watch_id)
        if blob_id is None:
            raise KeyError(f"unknown watch lease {watch_id!r}")
        sh = self._shard_of(blob_id)
        self._charge(client, sh, nbytes=VM_WATCH_REQ_BYTES)
        with sh.lock:
            lease = sh.watches.get(blob_id, {}).get(watch_id)
            if lease is None:
                raise KeyError(f"unknown watch lease {watch_id!r}")
            lease.expires_at = (None if ttl is None
                                else self._clock.now() + ttl)
            self._journal(sh, {"op": "watch_renew", "watch": watch_id,
                               "blob": blob_id,
                               "expires": lease.expires_at})
            self._repl_flush(sh)
        with self._ctr_lock:
            self._watch_ctr["renewed"] += 1

    def watch_report(self, blob_id: str) -> List[WatchLease]:
        """Current watch leases on ``blob_id`` (tests and operators)."""
        sh = self._shard_of(blob_id)
        with sh.lock:
            return list(sh.watches.get(blob_id, {}).values())

    def _flush_watch_locked(self, sh: LineageShard, blob_id: str) -> None:
        """Coalesce and push the pending publication gap of every live
        watcher of ``blob_id``; caller holds the shard lock (runs at
        publication, at registration catch-up and after failover
        replay).

        Per flush each watcher costs ONE coalesced entry covering its
        whole ``(delivered_up_to, published]`` gap, and all entries
        bound for the same inbox endpoint ride ONE fire-and-forget
        batch — a K-publication burst pays O(endpoints-with-watchers)
        notify RPCs, independent of the watcher count.  Expired leases
        are pruned here (nothing is sent to them); retired versions are
        filtered out but the watermark still advances past them."""
        table = sh.watches.get(blob_id)
        if not table:
            return
        b = self._blob_in(sh, blob_id)
        pub = b.published
        now = self._clock.now()
        expired = [wid for wid, lease in table.items()
                   if lease.expires_at is not None and lease.expires_at < now]
        if expired:
            for wid in expired:
                del table[wid]
            with self._watches_lock:
                for wid in expired:
                    self._watch_of.pop(wid, None)
            with self._ctr_lock:
                self._watch_ctr["expired"] += len(expired)
        by_ep: Dict[str, List[Tuple[str, Tuple[int, ...]]]] = {}
        advanced = False
        for wid in sorted(table):       # sorted: deterministic fan-out
            lease = table[wid]
            if lease.delivered_up_to >= pub:
                continue
            versions = tuple(
                v for v in range(lease.delivered_up_to + 1, pub + 1)
                if v not in self._owner_record(sh, blob_id, v).retired)
            lease.delivered_up_to = pub
            advanced = True
            if versions:
                by_ep.setdefault(lease.endpoint, []).append((wid, versions))
        if by_ep:
            self._send_notify(sh, blob_id, by_ep)
        if advanced:
            # coarse per-blob watermark record: replay raises every
            # lease registered before it to pub (see replay_lineage),
            # which is what lets a promoted follower resume deliveries
            # with no gap (stale watermark -> re-flush; the inbox
            # watermark dedups) and no duplicate
            self._journal(sh, {"op": "notify", "blob": blob_id, "v": pub})

    def _send_notify(self, sh: LineageShard, blob_id: str,
                     by_ep: Dict[str, List[Tuple[str, Tuple[int, ...]]]]) -> None:
        """Ship one batched fire-and-forget notify per inbox endpoint
        (the PR 4/5 primitive: charged on the receiving endpoint, never
        blocks the publishing verb — safe under the shard lock).  A
        down endpoint drops its batch: at-most-once to dead inboxes;
        the lease still advances and eventually expires via its ttl."""
        repl = sh.repl
        leader = repl.leader_ep if repl is not None else VMGR_ENDPOINT
        rpcs = entries = nvers = dropped = 0
        for ep in sorted(by_ep):
            batch = by_ep[ep]
            done_at = 0.0
            if self.wire is not None:
                try:
                    done_at = self.wire.transfer_batch(
                        ep, [WATCH_NOTIFY_EVT_BYTES] * len(batch),
                        inbound=True, peer=leader, fire_and_forget=True)
                except EndpointDown:
                    dropped += len(batch)
                    continue
            rpcs += 1
            entries += len(batch)
            nvers += sum(len(vs) for _, vs in batch)
            with self._watches_lock:
                inbox = self._inboxes.get(ep)
            if inbox is not None:
                inbox.deliver([(wid, blob_id, vs) for wid, vs in batch],
                              ready_at=done_at)
        with self._ctr_lock:
            self._watch_ctr["notify_rpcs"] += rpcs
            self._watch_ctr["notify_entries"] += entries
            self._watch_ctr["notify_versions"] += nvers
            self._watch_ctr["dropped_sends"] += dropped

    # ------------------------------------------------ GC: pins + read leases
    def pin(self, blob_id: str, version: int, client: Optional[str] = None,
            ttl: Optional[float] = None) -> str:
        """Pin ``(blob, version)``: GC keeps it until :meth:`unpin` or the
        lease's clock-based expiry.  Returns the lease id.

        Pin records replicate with the journal: a failover rebuilds the
        new leader's lease table from them (expiries are absolute clock
        instants, so they stay valid across the promotion), while a cold
        :meth:`recover_from_wal` still drops all leases — process death
        releases pins, leader death does not."""
        sh = self._shard_of(blob_id)
        self._charge(client, sh)
        with sh.lock:
            b = self._blob_in(sh, blob_id)
            if version <= 0 or version > b.published:
                raise VersionUnpublished(f"{blob_id} v{version} not published")
            self._check_not_retired(sh, blob_id, version)
            with self._pins_lock:
                lease_id = f"pin-{next(self._pin_ids):08d}"
                expires = None if ttl is None else self._clock.now() + ttl
                self._pins[lease_id] = PinLease(lease_id, blob_id, version,
                                                client, expires)
            self._journal(sh, {"op": "pin", "blob": blob_id, "v": version,
                               "lease": lease_id, "client": client,
                               "expires": expires})
            self._repl_flush(sh)
            return lease_id

    def unpin(self, lease_id: str, client: Optional[str] = None) -> None:
        """Release a pin lease (idempotent: unknown/expired ids are
        no-ops); the snapshot becomes retireable at the next GC plan."""
        with self._pins_lock:
            pin = self._pins.get(lease_id)
        if pin is None:
            self._charge(client)
            return
        sh = self._shard_of(pin.blob_id)
        self._charge(client, sh)
        with sh.lock:
            with self._pins_lock:
                if self._pins.pop(lease_id, None) is None:
                    return
            self._journal(sh, {"op": "unpin", "lease": lease_id})
            self._repl_flush(sh)

    def _live_pins(self, sh: LineageShard, blob_id: str) -> Set[int]:
        """Unexpired pinned versions, recorded on the *owner* blob of
        each pinned version (a pin through a branch pins the ancestor's
        snapshot).  Expired leases are pruned.  Caller holds the shard
        lock; only pins of this shard's lineage can resolve to
        ``blob_id``, so the owner walk stays in-shard."""
        now = self._clock.now()
        with self._pins_lock:
            expired = [lid for lid, p in self._pins.items()
                       if p.expires_at is not None and p.expires_at < now]
            for lid in expired:
                del self._pins[lid]
            candidates = [p for p in self._pins.values()
                          if p.blob_id in sh.blobs]
        out: Set[int] = set()
        for p in candidates:
            if self._owner_record(sh, p.blob_id, p.version).blob_id == blob_id:
                out.add(p.version)
        return out

    def pinned_versions(self, blob_id: str) -> FrozenSet[int]:
        """Versions currently protected by unexpired pin leases, keyed
        by *owner* blob (a pin taken through a branch shows up here on
        the ancestor that owns the pinned snapshot)."""
        sh = self._shard_of(blob_id)
        with sh.lock:
            return frozenset(self._live_pins(sh, blob_id))

    def pins(self) -> List[PinLease]:
        """All currently held (possibly expired) pin leases."""
        with self._pins_lock:
            return list(self._pins.values())

    def enter_read(self, blob_id: str, version: int,
                   client: Optional[str] = None) -> Tuple[int, int]:
        """Open a read lease on a published snapshot; returns the
        snapshot's ``(size, root_pages)`` atomically with admission.

        The lease makes the sweep's drain barrier possible: GC retires a
        version (after which ``enter_read`` answers ``RetiredVersion``)
        and then waits until every lease opened *before* the intent has
        been released — an in-flight read never races its pages being
        deleted.  Reads of kept versions are never blocked or drained;
        their safety comes from the mark phase.  Returning the root
        snapshot here means an admitted read needs no further
        retired-checked version-manager call: a retire-intent landing
        after admission cannot spuriously fail it (the drain barrier
        lets it complete).
        """
        sh = self._shard_of(blob_id)
        self._charge(client, sh)
        with sh.lock:
            b = self._blob_in(sh, blob_id)
            if version > b.published:
                raise VersionUnpublished(f"{blob_id} v{version} not published")
            if version == 0:
                return 0, 0
            self._check_not_retired(sh, blob_id, version)
            owner = self._owner_record(sh, blob_id, version).blob_id
            key = (owner, version)
            sh.active_reads[key] = sh.active_reads.get(key, 0) + 1
            return (self._size_of(sh, blob_id, version),
                    self._root_pages_of(sh, blob_id, version))

    def exit_read(self, blob_id: str, version: int,
                  client: Optional[str] = None) -> None:
        """Release a read lease opened by :meth:`enter_read`."""
        if version == 0:
            return
        sh = self._shard_of(blob_id)
        self._charge(client, sh)
        with sh.cond:
            owner = self._owner_record(sh, blob_id, version).blob_id
            key = (owner, version)
            n = sh.active_reads.get(key, 0) - 1
            if n <= 0:
                sh.active_reads.pop(key, None)
            else:
                sh.active_reads[key] = n
            sh.cond.notify_all()

    def wait_reads_drained(self, blob_id: str, versions: Iterable[int],
                           timeout: Optional[float] = None) -> None:
        """Block until no read lease on ``(blob, v in versions)`` remains.

        The sweep's drain barrier: called after retire-intent (so no new
        lease on those versions can be opened) and before any delete is
        issued.  Blocks through the clock, so it is virtual-time-correct
        under the simulator, and waits only on the blob's lineage shard.
        """
        keys = [(blob_id, v) for v in sorted(set(versions))]
        sh = self._shard_of(blob_id)
        deadline = None if timeout is None else self._clock.now() + timeout
        with sh.cond:
            while any(sh.active_reads.get(k, 0) > 0 for k in keys):
                remaining = None if deadline is None else deadline - self._clock.now()
                if remaining is not None and remaining <= 0:
                    raise TimeoutError(f"reads of {blob_id} did not drain")
                sh.cond.wait(remaining)

    # -------------------------------------------- GC: retention + retirement
    def set_retention(self, blob_id: str, keep_last: int,
                      client: Optional[str] = None) -> None:
        """Retention policy: GC keeps the newest ``keep_last`` published
        snapshots (0 = keep everything).  Journaled, so a recovered
        manager enforces the same policy."""
        if keep_last < 0:
            raise ValueError("keep_last must be >= 0")
        sh = self._shard_of(blob_id)
        self._charge(client, sh)
        with sh.lock:
            self._blob_in(sh, blob_id).keep_last = keep_last
            self._journal(sh, {"op": "retention", "blob": blob_id,
                               "keep_last": keep_last})
            self._repl_flush(sh)

    def gc_epoch(self, blob_id: str) -> int:
        """Monotone retirement epoch: bumped (and journaled) every time
        :meth:`plan_retirement` retires at least one version.  Cache
        layers key their eviction notifications off it (see
        :meth:`add_gc_listener`)."""
        sh = self._shard_of(blob_id)
        with sh.lock:
            return self._blob_in(sh, blob_id).gc_epoch

    def retired_versions(self, blob_id: str) -> FrozenSet[int]:
        """Versions under retire-intent on this blob (swept or not):
        reads/pins/branches of them answer :class:`RetiredVersion`."""
        sh = self._shard_of(blob_id)
        with sh.lock:
            return frozenset(self._blob_in(sh, blob_id).retired)

    def plan_retirement(
        self,
        blob_id: str,
        keep_extra: Optional[Iterable[int]] = None,
        explicit: bool = False,
        client: Optional[str] = None,
    ) -> Tuple[Tuple[int, ...], Tuple[int, ...]]:
        """Atomically decide and journal this blob's retirement set.

        Returns ``(kept, newly_retired)`` over the blob's *own* published
        versions (inherited versions ``<= base`` belong to the ancestor's
        plan).  Kept is the union of

        * the retention window (newest ``keep_last`` published; all of
          them when no policy is set and ``explicit`` is False),
        * ``keep_extra`` (the explicit keep set of the old GC API; with
          ``explicit=True`` it *replaces* the retention window),
        * unexpired pin leases,
        * branch roots: any version this blob *owns* that some blob was
          forked at — including forks taken through an intermediate
          branch at an inherited version,
        * the ``vp`` anchor of every assigned-but-incomplete update
          (an in-flight writer descends that tree for border nodes),
        * always the newest published version (new updates anchor on it).

        Every rule above is an intra-lineage fact (branches join their
        ancestor's shard), so the whole plan runs under ONE shard lock
        and scans only this lineage's blobs — a GC round never touches
        another lineage's critical section.

        Marking is the retire-*intent*: from this instant every
        ``enter_read``/``pin``/``branch`` of a retired version answers
        ``RetiredVersion``.  The intent is journaled before any sweep
        RPC goes out, so recovery can never resurrect a version whose
        pages might be partially deleted.
        """
        sh = self._shard_of(blob_id)
        self._charge(client, sh)
        with sh.lock:
            b = self._blob_in(sh, blob_id)
            published = set(range(b.base_version + 1, b.published + 1))
            if not published:
                return (), ()
            if explicit:
                keep: Set[int] = set(keep_extra or ())
            elif b.keep_last > 0:
                keep = set(range(b.published - b.keep_last + 1,
                                 b.published + 1))
                keep.update(keep_extra or ())
            else:
                keep = set(published)
            keep.add(b.published)
            keep.update(self._live_pins(sh, blob_id))
            for other in sh.blobs.values():
                # owner-normalized like pins: a fork point at an inherited
                # version (C = branch(B, 3) where v3 is owned by A, B's
                # ancestor) must be kept by v3's *owner*, not by the blob
                # named in parent[0]
                if (other.parent is not None and other.parent[1] > 0
                        and self._owner_record(
                            sh, other.parent[0], other.parent[1]).blob_id
                        == blob_id):
                    keep.add(other.parent[1])
                for u in range(other.published + 1, other.last_assigned + 1):
                    r = other.updates.get(u)
                    if (r is not None and not r.complete and r.vp is not None
                            and self._owner_record(sh, other.blob_id, r.vp).blob_id
                            == blob_id):
                        keep.add(r.vp)
            newly = sorted(published - keep - b.retired)
            kept = tuple(sorted(published - set(newly) - b.retired))
            epoch = b.gc_epoch
            retired_page_ids: List[str] = []
            if newly:
                b.retired.update(newly)
                b.gc_epoch += 1
                epoch = b.gc_epoch
                self._journal(sh, {"op": "retire", "blob": blob_id,
                                   "versions": newly, "epoch": epoch})
                self._repl_flush(sh)
                for v in newly:
                    rec = b.updates.get(v)
                    if rec is not None:
                        retired_page_ids.extend(pid for pid, *_ in rec.pd)
        if newly:
            # retire-intent is GC-visible state: make it durable on the
            # replicas before any sweep delete can go out (a failover
            # must never resurrect a version whose pages are half gone)
            self._repl_barrier(sh)
            # Epoch notification outside the lock: listeners (the shared
            # page cache) may take their own locks; the journal record
            # above is already durable, so a listener crash cannot lose
            # the intent.
            for fn in list(self._gc_listeners):
                fn(blob_id, tuple(newly), epoch, tuple(retired_page_ids))
        return kept, tuple(newly)

    def add_gc_listener(self, fn) -> None:
        """Subscribe ``fn(blob_id, versions, gc_epoch, page_ids)`` to
        retire-intent (gc_epoch bump) notifications — the cache-eviction
        hook: a retired version's pages leave the shared page cache at
        intent time, before any sweep delete goes out."""
        self._gc_listeners.append(fn)

    def sweep_pending(self, blob_id: str) -> List[UpdateRecord]:
        """Retired-but-not-yet-finalized updates, oldest first.  The
        sweep derives each one's candidate set from the journaled page
        descriptors and the deterministic tree shape — no store scan."""
        sh = self._shard_of(blob_id)
        with sh.lock:
            b = self._blob_in(sh, blob_id)
            return [b.updates[v] for v in sorted(b.retired - b.swept)
                    if v in b.updates]

    def finalize_sweep(self, blob_id: str, versions: Iterable[int],
                       client: Optional[str] = None) -> None:
        """Journal that the sweep of ``versions`` completed (all deletes
        acknowledged).  Unfinalized versions are re-swept next round —
        deletes are idempotent, so partial rounds are safe."""
        versions = sorted(set(versions))
        if not versions:
            return
        sh = self._shard_of(blob_id)
        self._charge(client, sh)
        with sh.lock:
            self._blob_in(sh, blob_id).swept.update(versions)
            self._journal(sh, {"op": "swept", "blob": blob_id,
                               "versions": versions})
            self._repl_flush(sh)
        self._repl_barrier(sh)

    def unfinalize_sweep(self, blob_id: str, versions: Iterable[int],
                         client: Optional[str] = None) -> None:
        """Journal that ``versions`` need re-sweeping despite a prior
        finalize: the restore-time resweep found work left (restore
        resurrects a finalized version's nodes/pages, and a re-delete
        can partially fail, e.g. a provider down during recovery).
        Pulling them out of the finalized set puts them back in
        :meth:`sweep_pending`, so ordinary live rounds retry the
        deletes instead of leaking the resurrected items until the
        next restart."""
        versions = set(versions)
        if not versions:
            return
        sh = self._shard_of(blob_id)
        self._charge(client, sh)
        with sh.lock:
            b = self._blob_in(sh, blob_id)
            versions = sorted(versions & b.swept)
            if not versions:
                return  # never finalized: already pending, nothing to journal
            b.swept.difference_update(versions)
            self._journal(sh, {"op": "unswept", "blob": blob_id,
                               "versions": versions})
            self._repl_flush(sh)
        self._repl_barrier(sh)

    def all_page_ids(self) -> Set[str]:
        """Every page id any assigned update (any blob, any version,
        published or in flight, retired or not) has ever journaled.
        The GC orphan scan treats pages outside this set — stored but
        never registered, e.g. a restriped optimistic append or a
        writer that died before version assignment — as collectable
        once they outlive the grace window."""
        out: Set[str] = set()
        for sh in self._all_shards():
            with sh.lock:
                for b in sh.blobs.values():
                    for rec in b.updates.values():
                        for pd in rec.pd:
                            out.add(pd[0])
        return out

    def page_locations(self) -> Dict[str, Tuple[str, Tuple[str, ...], int]]:
        """Durability inventory: every *live* journaled page's
        ``page_id -> (blob_id, providers, length)``.

        The scrub plane diffs this against what providers actually hold
        to find dead-provider gaps and missing copies; the lifecycle
        plane uses the blob id to apply per-blob demotion policy.  Pages
        of swept versions are excluded (their bytes are gone or going —
        repairing them would resurrect garbage), and a page journaled by
        several versions (copy-on-write sharing, dedup hits) reports the
        first descriptor seen — descriptors for one page are identical
        by construction.  Local control-plane bookkeeping, like
        :meth:`all_page_ids` (the GC's orphan scan twin).
        """
        out: Dict[str, Tuple[str, Tuple[str, ...], int]] = {}
        for sh in self._all_shards():
            with sh.lock:
                for bid in sorted(sh.blobs):
                    b = sh.blobs[bid]
                    for v in sorted(b.updates):
                        if v in b.swept:
                            continue
                        for pd in b.updates[v].pd:
                            pid, _rel, provs, length = pd
                            out.setdefault(
                                pid, (b.blob_id, tuple(provs), length))
        return out

    def mark_roots(self) -> Dict[str, List[Tuple[int, int]]]:
        """Every live snapshot the mark phase must walk: blob id ->
        [(version, root_pages)] over the blob's own published, non-retired
        versions.  Inherited versions appear under their owner blob."""
        out: Dict[str, List[Tuple[int, int]]] = {}
        for sh in self._all_shards():
            with sh.lock:
                for b in sh.blobs.values():
                    roots = [(v, b.updates[v].root_pages)
                             for v in range(b.base_version + 1, b.published + 1)
                             if v not in b.retired and v in b.updates]
                    if roots:
                        out[b.blob_id] = roots
        return out

    # ------------------------------------------------------- failure handling
    def find_stalled(self, timeout: float) -> List[Tuple[str, UpdateRecord]]:
        """Assigned-but-incomplete updates older than ``timeout`` seconds.

        These block their own blob's publication pipeline (in-order
        publishing is per blob — other blobs keep publishing); a
        recovery agent replays their metadata from the journaled page
        descriptors and calls :meth:`metadata_complete`.
        """
        now = self._clock.now()
        out = []
        for sh in self._all_shards():
            with sh.lock:
                for b in sh.blobs.values():
                    for v in range(b.published + 1, b.last_assigned + 1):
                        rec = b.updates.get(v)
                        if rec is not None and not rec.complete and now - rec.assigned_at > timeout:
                            out.append((b.blob_id, rec))
        return out

    def assign_info_for_recovery(self, blob_id: str, version: int) -> "AssignInfo":
        """Reconstruct the AssignInfo a dead writer was handed."""
        sh = self._shard_of(blob_id)
        with sh.lock:
            b = self._blob_in(sh, blob_id)
            rec = b.updates[version]
            vp = b.published
            recent = tuple(
                (r.version, r.p0, r.p1)
                for u in range(vp + 1, version)
                if (r := b.updates.get(u)) is not None
            )
            return AssignInfo(
                version=version, offset=rec.offset,
                prev_size=self._size_of(sh, blob_id, version - 1) if version > 1 else 0,
                new_size=rec.new_blob_size, root_pages=rec.root_pages,
                p0=rec.p0, p1=rec.p1,
                vp=vp if vp > 0 else None,
                vp_root_pages=self._root_pages_of(sh, blob_id, vp) if vp > 0 else 0,
                recent_updates=recent,
            )

    # ------------------------------------------------------------ WAL recovery
    @staticmethod
    def _apply_blob_op(b: BlobRecord, rec: dict, now: float) -> None:
        """Apply one journaled blob op to its record — THE replay rule,
        shared verbatim by cold WAL recovery and failover promotion (so
        a promoted follower rebuilds exactly the state a restarted
        manager would)."""
        op = rec["op"]
        if op == "assign":
            psz = b.psize
            p0, p1 = pages_spanned(rec["offset"], rec["size"], psz)
            b.updates[rec["v"]] = UpdateRecord(
                version=rec["v"], offset=rec["offset"], size=rec["size"],
                new_blob_size=rec["new_size"],
                root_pages=root_pages_for(rec["new_size"], psz),
                p0=p0, p1=p1, is_append=rec["append"], client=rec["client"],
                pd=tuple(tuple(x) for x in rec["pd"]),
                # stamp on the VM's own clock: the wall-time default
                # would make find_stalled never fire under a virtual
                # clock (now() - monotonic is hugely negative)
                assigned_at=now,
                vp=rec.get("vp"),
            )
            b.last_assigned = max(b.last_assigned, rec["v"])
        elif op == "pd":
            b.updates[rec["v"]].pd = tuple(tuple(x) for x in rec["pd"])
        elif op == "complete":
            b.updates[rec["v"]].complete = True
        elif op == "publish":
            b.published = rec["v"]
        elif op == "retention":
            b.keep_last = rec["keep_last"]
        elif op == "retire":
            b.retired.update(rec["versions"])
            b.gc_epoch = max(b.gc_epoch, rec.get("epoch", 0))
        elif op == "swept":
            b.swept.update(rec["versions"])
        elif op == "unswept":
            b.swept.difference_update(rec["versions"])

    def replay_lineage(
        self, records: Sequence[dict],
    ) -> Tuple[Dict[str, BlobRecord], Dict[str, PinLease],
               Dict[str, Tuple[str, int]], Dict[str, Dict[str, WatchLease]]]:
        """Rebuild one lineage's state from a journal prefix: the blob
        records, the still-unexpired pin leases, the assign idempotency
        keys and the watch-lease tables.  This is what failover runs on
        the promoted follower's journal; the follower-replay
        equivalence property test replays arbitrary prefixes through it
        and compares against the leader.  Records must be a *prefix* of
        one lineage's journal (the order its shard lock serialized).

        Watch rules: a ``watch`` record opens the lease at its
        ``from`` watermark; each ``notify`` record raises every lease
        of its blob registered before it to the journaled publication
        watermark — so a promoted leader's ``delivered_up_to`` is
        exactly what the old leader last journaled, and its first
        post-failover flush re-covers at most the un-journaled tail
        (the inbox watermark drops the overlap).  Expired leases are
        pruned once at the end (renewals may extend mid-journal)."""
        now = self._clock.now()
        blobs: Dict[str, BlobRecord] = {}
        pins: Dict[str, PinLease] = {}
        keys: Dict[str, Tuple[str, int]] = {}
        watches: Dict[str, Dict[str, WatchLease]] = {}
        for rec in records:
            op = rec["op"]
            if op == "create":
                bid = rec["blob"]
                blobs[bid] = BlobRecord(bid, rec["psize"], lineage_id=rec["lineage"])
            elif op == "branch":
                src = blobs[rec["src"]]
                blobs[rec["blob"]] = BlobRecord(
                    blob_id=rec["blob"], psize=src.psize,
                    parent=(rec["src"], rec["at"]), base_version=rec["at"],
                    last_assigned=rec["at"], published=rec["at"],
                    lineage_id=src.lineage_id,
                )
            elif op == "pin":
                exp = rec["expires"]
                if exp is None or exp > now:
                    pins[rec["lease"]] = PinLease(rec["lease"], rec["blob"],
                                                  rec["v"], rec.get("client"),
                                                  exp)
            elif op == "unpin":
                pins.pop(rec["lease"], None)
            elif op == "watch":
                watches.setdefault(rec["blob"], {})[rec["watch"]] = WatchLease(
                    rec["watch"], rec["blob"], rec.get("client"),
                    rec["endpoint"], rec["from"], rec["from"],
                    rec["expires"])
            elif op == "unwatch":
                watches.get(rec["blob"], {}).pop(rec["watch"], None)
            elif op == "watch_renew":
                lease = watches.get(rec["blob"], {}).get(rec["watch"])
                if lease is not None:
                    lease.expires_at = rec["expires"]
            elif op == "notify":
                for lease in watches.get(rec["blob"], {}).values():
                    if lease.delivered_up_to < rec["v"]:
                        lease.delivered_up_to = rec["v"]
            elif op == "failover":
                pass   # audit record: carries no state
            else:
                b = blobs[rec["blob"]]
                self._apply_blob_op(b, rec, now)
                if op == "assign" and rec.get("key") is not None:
                    keys[rec["key"]] = (rec["blob"], rec["v"])
        for table in watches.values():
            for wid in [w for w, lease in table.items()
                        if lease.expires_at is not None
                        and lease.expires_at < now]:
                del table[wid]
        return blobs, pins, keys, watches

    @classmethod
    def recover_from_wal(cls, wal_path: str, wire: Optional[Wire] = None, *,
                         replication: int = 0, lease_ttl: float = 0.25,
                         fsync_policy: str = "batch") -> "VersionManager":
        """Rebuild full version-manager state from the journal.

        ``create`` records root a lineage shard (the record's lineage
        id is the blob itself); ``branch`` records join their source's
        shard.  Every other record is routed to its lineage's shard —
        replay order only matters *within* a lineage, which is exactly
        the order each shard's lock serialized at journal time.  Pin
        (lease) and ``failover`` audit records are skipped: leases die
        with the process, and epochs restart at 1.

        With ``replication > 0`` the recovered manager also rebuilds
        each lineage's replica group, bulk-streaming the recovered
        journal to the fresh followers (wire-accounted) so they are
        caught up from the first verb.
        """
        vm = cls(wire=wire, replication=replication, lease_ttl=lease_ttl,
                 fsync_policy=fsync_policy)
        max_id = 0
        records_by_lineage: Dict[str, List[dict]] = {}

        def blob_rec(blob_id: str) -> BlobRecord:
            return vm._shards[vm._lineage_of[blob_id]].blobs[blob_id]

        with open(wal_path) as f:
            for line in f:
                rec = json.loads(line)
                op = rec["op"]
                if "lineage" in rec:
                    records_by_lineage.setdefault(rec["lineage"], []).append(rec)
                if op == "create":
                    bid = rec["blob"]
                    sh = LineageShard(bid, vm._clock)
                    sh.blobs[bid] = BlobRecord(bid, rec["psize"],
                                               lineage_id=bid)
                    vm._shards[bid] = sh
                    vm._lineage_of[bid] = bid
                    vm._blob_order.append(bid)
                    max_id = max(max_id, int(bid.split("-")[1]))
                elif op == "branch":
                    src = blob_rec(rec["src"])
                    lid = src.lineage_id
                    vm._shards[lid].blobs[rec["blob"]] = BlobRecord(
                        blob_id=rec["blob"], psize=src.psize,
                        parent=(rec["src"], rec["at"]), base_version=rec["at"],
                        last_assigned=rec["at"], published=rec["at"],
                        lineage_id=lid,
                    )
                    vm._lineage_of[rec["blob"]] = lid
                    vm._blob_order.append(rec["blob"])
                    max_id = max(max_id, int(rec["blob"].split("-")[1]))
                elif op in ("pin", "unpin", "failover",
                            "watch", "unwatch", "watch_renew", "notify"):
                    # soft state: a restarted manager drops pin AND
                    # watch leases (inboxes are process memory —
                    # clients re-watch after a cold restart)
                    pass
                else:
                    vm._apply_blob_op(blob_rec(rec["blob"]), rec, vm._clock.now())
        vm._ids = itertools.count(max_id + 1)
        vm._wal_path = wal_path
        vm._wal_file = open(wal_path, "a")
        if replication > 0:
            for lid in sorted(vm._shards):
                sh = vm._shards[lid]
                sh.repl = _ShardReplication(lid, replication, lease_ttl,
                                            vm._clock.now())
                for rec in records_by_lineage.get(lid, ()):
                    if rec["op"] == "assign" and rec.get("key") is not None:
                        sh.repl.assigned_keys[rec["key"]] = (rec["blob"], rec["v"])
                sh.repl.pending = list(records_by_lineage.get(lid, ()))
                with sh.lock:
                    vm._repl_flush(sh)
        return vm


@dataclass(frozen=True)
class AssignInfo:
    """Everything a writer receives from the version manager (§4.2).

    This is the full *border context* of the update: ``vp`` (the
    published anchor tree to descend), ``vp_root_pages``,
    ``recent_updates`` (ranges of every in-flight update between
    ``vp`` and ``version``) plus the update's own page extent
    ``(p0, p1, root_pages)`` — enough for the client to enumerate every
    border range BUILD_META will touch (``segment_tree.border_ranges``)
    and prefetch them in level-batched waves before the weave starts.
    """

    version: int
    offset: int
    prev_size: int
    new_size: int
    root_pages: int
    p0: int
    p1: int
    vp: Optional[int]                       # recently published snapshot
    vp_root_pages: int
    recent_updates: Tuple[Tuple[int, int, int], ...]  # (version, p0, p1), unpublished-at-assign
