"""Deterministic discrete-event concurrency engine (virtual time).

The paper's headline claim is scalability *under heavy access
concurrency* (§5: up to 175 Grid'5000 nodes of concurrent readers,
writers and appenders).  Real Python threads cannot reproduce that —
they are slow, nondeterministic and capped by the GIL — so this module
provides a **virtual clock plus an event scheduler** that runs client
programs as cooperatively-scheduled tasks:

* exactly one task runs at any instant; every blocking point in the
  core (wire transfers, SYNC/publication waits) yields back to the
  scheduler through the :class:`Clock` interface,
* virtual time advances only when the scheduler dispatches the next
  event, so a 100-second simulated experiment takes milliseconds of
  wall time,
* events at the same virtual instant are ordered by a **seeded
  tie-break** drawn from a private RNG: every run with the same seed
  replays the exact same interleaving (the scheduler records a trace
  you can digest and compare), while different seeds explore different
  schedules.

The default backend, :class:`WallClock`, preserves the pre-existing
behavior exactly: real ``time.monotonic()``, real ``threading``
primitives, no virtual scheduling.  Components never import
``threading.Condition`` or call ``time.monotonic()`` directly any more;
they ask their clock, so the same code runs under both backends.

Scheduling model for the wire (see ``transport.Wire.transfer``): the
per-endpoint queueing the wire always *accounted*
(``start = max(now, busy_until)``; ``busy_until = start + cost``) is
promoted to actual scheduling — the issuing task sleeps until its
request's completion instant, so two clients hitting the same provider
really do serialize there in virtual time, exactly the §4.3 contention
the paper measures.
"""

from __future__ import annotations

import hashlib
import heapq
import itertools
import random
import threading
import time
from typing import Callable, Dict, List, Optional, Tuple


class SimDeadlock(RuntimeError):
    """The event heap drained while tasks were still blocked."""


class Clock:
    """Time + blocking interface the core components schedule against.

    ``is_virtual`` tells call sites whether blocking charges virtual
    time (simulation) or real time (default threads backend).
    """

    is_virtual = False

    def now(self) -> float:
        raise NotImplementedError

    def sleep(self, seconds: float) -> None:
        raise NotImplementedError

    def sleep_until(self, t: float) -> None:
        raise NotImplementedError

    def condition(self, lock=None):
        """A condition variable bound to this clock's notion of blocking."""
        raise NotImplementedError


class WallClock(Clock):
    """Default backend: real time, real threads (pre-harness behavior)."""

    is_virtual = False

    def now(self) -> float:
        return time.monotonic()

    def sleep(self, seconds: float) -> None:
        if seconds > 0:
            time.sleep(seconds)

    def sleep_until(self, t: float) -> None:
        self.sleep(t - self.now())

    def condition(self, lock=None):
        return threading.Condition(lock)


class _Task:
    """One cooperatively-scheduled client program."""

    __slots__ = ("name", "fn", "thread", "resume", "done", "started",
                 "result", "error", "gen", "waiting_on")

    def __init__(self, name: str, fn: Callable[[], object]) -> None:
        self.name = name
        self.fn = fn
        self.thread: Optional[threading.Thread] = None
        self.resume = threading.Event()
        self.done = False
        self.started = False
        self.result: object = None
        self.error: Optional[BaseException] = None
        self.gen = 0                 # bumped at every resume; stale events skip
        self.waiting_on: Optional["SimCondition"] = None

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"<task {self.name} done={self.done}>"


class Simulator(Clock):
    """Deterministic virtual clock + event scheduler.

    Usage::

        sim = Simulator(seed=7)
        svc = BlobSeerService(wire=Wire(clock=sim))
        sim.spawn(lambda: svc.client("w0").append(bid, b"x" * 4096), name="w0")
        sim.run()

    ``run()`` drives tasks until all finish; ``sim.now()`` is then the
    virtual makespan.  Called from a *task*, ``sleep``/``sleep_until``
    advance virtual time; called from the driver thread (scenario
    setup) they are free — setup work happens "before" the experiment.
    """

    is_virtual = True

    def __init__(self, seed: int = 0, record_trace: bool = True) -> None:
        self.seed = seed
        self._rng = random.Random(seed)
        self._now = 0.0
        self._seq = itertools.count()
        self._heap: List[Tuple[float, float, int, int, _Task, str]] = []
        self._tasks: List[_Task] = []
        self._current: Optional[_Task] = None
        self._sched_evt = threading.Event()
        self._driver = None  # thread identity of whoever calls run()
        self._record_trace = record_trace
        self.trace: List[Tuple[float, str, str]] = []
        self._trace_hash = hashlib.sha256()
        self.events_dispatched = 0

    # ----------------------------------------------------------- Clock API
    def now(self) -> float:
        return self._now

    def sleep(self, seconds: float) -> None:
        self.sleep_until(self._now + max(0.0, seconds))

    def sleep_until(self, t: float) -> None:
        task = self._current_task()
        if task is None:
            # Driver-thread (scenario setup) work is free: it happens
            # logically before t=0 of the experiment.
            return
        self._schedule(task, max(t, self._now), "wake")
        self._switch_out(task)

    def condition(self, lock=None) -> "SimCondition":
        return SimCondition(self, lock)

    # ------------------------------------------------------------ task API
    def spawn(self, fn: Callable[[], object], name: Optional[str] = None) -> _Task:
        """Register a client program; it starts running at ``run()``."""
        task = _Task(name if name is not None else f"task-{len(self._tasks)}", fn)
        self._tasks.append(task)
        self._schedule(task, self._now, "spawn")
        return task

    def spawn_at(self, t: float, fn: Callable[[], object],
                 name: Optional[str] = None) -> _Task:
        """Register a task that starts at virtual instant ``t`` — the
        failure-injection primitive (kill endpoint X at t=0.8).  Same
        as a spawned task whose first statement sleeps to ``t``, minus
        the extra wake event in the trace."""
        task = _Task(name if name is not None else f"task-{len(self._tasks)}", fn)
        self._tasks.append(task)
        self._schedule(task, max(t, self._now), "spawn")
        return task

    def run(self, raise_errors: bool = True) -> None:
        """Dispatch events until the heap drains; detects deadlock."""
        if self._current is not None:
            raise RuntimeError("run() called from inside a task")
        while self._heap:
            t, _tie, _seq, gen, task, label = heapq.heappop(self._heap)
            if task.done or gen != task.gen:
                continue  # cancelled/stale event (e.g. timeout after notify)
            self._now = max(self._now, t)
            self.events_dispatched += 1
            task.gen += 1
            if self._record_trace:
                self.trace.append((self._now, task.name, label))
            self._trace_hash.update(
                f"{self._now:.9f}|{task.name}|{label}\n".encode()
            )
            self._dispatch(task)
            if raise_errors and task.done and task.error is not None:
                raise task.error
        blocked = [t for t in self._tasks if t.started and not t.done]
        if blocked:
            raise SimDeadlock(
                "event heap empty but tasks still blocked: "
                + ", ".join(t.name for t in blocked)
            )

    def results(self) -> Dict[str, object]:
        return {t.name: t.result for t in self._tasks}

    def errors(self) -> Dict[str, BaseException]:
        return {t.name: t.error for t in self._tasks if t.error is not None}

    def trace_digest(self) -> str:
        """Stable digest of the full dispatch trace (determinism checks)."""
        return self._trace_hash.hexdigest()

    # ----------------------------------------------------------- internals
    def _current_task(self) -> Optional[_Task]:
        cur = self._current
        if cur is not None and cur.thread is threading.current_thread():
            return cur
        return None

    def _require_task(self) -> _Task:
        task = self._current_task()
        if task is None:
            raise RuntimeError(
                "this operation blocks and must run inside a simulated task "
                "(Simulator.spawn), not the driver thread"
            )
        return task

    def _schedule(self, task: _Task, t: float, label: str) -> None:
        # Seeded tie-break: events at the same virtual instant dispatch
        # in an order fully determined by the seed.  The final seq field
        # makes heap entries totally ordered (tasks are never compared).
        heapq.heappush(
            self._heap, (t, self._rng.random(), next(self._seq), task.gen, task, label)
        )

    def _dispatch(self, task: _Task) -> None:
        """Hand the CPU to ``task`` until it yields back or finishes."""
        if not task.started:
            task.started = True
            task.thread = threading.Thread(
                target=self._task_main, args=(task,), name=f"sim:{task.name}",
                daemon=True,
            )
            self._current = task
            task.thread.start()
        else:
            self._current = task
            task.resume.set()
        self._sched_evt.wait()
        self._sched_evt.clear()
        self._current = None

    def _task_main(self, task: _Task) -> None:
        try:
            task.result = task.fn()
        except BaseException as e:  # noqa: BLE001 - surfaced via run()/errors()
            task.error = e
        task.done = True
        self._sched_evt.set()

    def _switch_out(self, task: _Task) -> None:
        """Yield the CPU back to the scheduler; returns when re-dispatched."""
        self._sched_evt.set()
        task.resume.wait()
        task.resume.clear()


class SimCondition:
    """Condition variable blocking in virtual time.

    Drop-in for ``threading.Condition`` at the call sites the core
    uses: ``with cond: ... cond.wait(timeout) ... cond.notify_all()``.
    The underlying lock is a real (but never contended — only one task
    runs at a time) ``threading.RLock``; ``wait`` releases it around a
    scheduler yield and re-acquires on resume, exactly like the real
    Condition does.
    """

    def __init__(self, sim: Simulator, lock=None) -> None:
        self._sim = sim
        self._lock = lock if lock is not None else threading.RLock()
        self._waiters: List[_Task] = []
        # mirror threading.Condition's lock-state save/restore protocol
        self._release_save = getattr(self._lock, "_release_save", None)
        self._acquire_restore = getattr(self._lock, "_acquire_restore", None)

    # lock protocol -------------------------------------------------------
    def __enter__(self):
        self._lock.acquire()
        return self

    def __exit__(self, *exc):
        self._lock.release()
        return False

    def acquire(self, *a, **kw):
        return self._lock.acquire(*a, **kw)

    def release(self):
        self._lock.release()

    # condition protocol --------------------------------------------------
    def wait(self, timeout: Optional[float] = None) -> bool:
        task = self._sim._require_task()
        task.waiting_on = self
        self._waiters.append(task)
        if timeout is not None:
            self._sim._schedule(task, self._sim._now + max(0.0, timeout),
                                "timeout")
        if self._release_save is not None:
            saved = self._release_save()
        else:  # pragma: no cover - plain Lock fallback
            saved = None
            self._lock.release()
        try:
            self._sim._switch_out(task)
        finally:
            if self._acquire_restore is not None:
                self._acquire_restore(saved)
            else:  # pragma: no cover
                self._lock.acquire()
            task.waiting_on = None
        if task in self._waiters:  # resumed by the timeout event
            self._waiters.remove(task)
            return False
        return True

    def notify_all(self) -> None:
        waiters, self._waiters = self._waiters, []
        for task in waiters:
            # bump gen so a pending timeout event for this wait is stale
            task.gen += 1
            self._sim._schedule(task, self._sim._now, "notify")

    def notify(self, n: int = 1) -> None:
        for _ in range(min(n, len(self._waiters))):
            task = self._waiters.pop(0)
            task.gen += 1
            self._sim._schedule(task, self._sim._now, "notify")
