"""Page placement policies: N-way replication and Reed-Solomon erasure
coding.

The paper buys availability with plain replication and never costs it:
``rep:3`` pays 3x the logical bytes for tolerance of 2 losses.  A
Reed-Solomon code ``ec:k+m`` stripes each page into ``k`` data shards
plus ``m`` parity shards on ``k+m`` *distinct* providers and tolerates
any ``m`` losses at ``(k+m)/k`` overhead — 1.33x for the default
``ec:6+2`` versus 3x for the replication twin (SNIPPETS.md §1-2's
trade-off).  Policies are selected **per blob**
(``BlobSeerService.set_blob_placement``) and ride the existing
descriptor format unchanged:

* An erasure-coded page's id is self-describing: ``fresh_page_id`` tags
  it ``pg-<hex>-ec6+2``, so every layer (DHT descriptors, WAL records,
  dedup index, GC sweep) carries plain ``(pid, providers, length)``
  tuples and only the provider manager interprets the codec.
* Shard ``j`` of page ``pid`` is stored under the physical id
  ``f"{pid}.s{j}"`` on ``descriptor.providers[j]`` — the provider group
  is *positional* for EC pages.
* Each shard carries a small header (:data:`SHARD_HDR_BYTES`) encoding
  the code geometry and the page's logical length, so a decoder needs
  nothing but ``k`` surviving shards.

The arithmetic is GF(256) (polynomial 0x11d) with log/exp tables and a
**Cauchy** generator matrix: ``G = [I_k ; C]`` where
``C[i][j] = 1 / (x_i ^ y_j)`` over distinct ``x_i = k + i`` (parity
rows) and ``y_j = j`` (data columns).  Every k-row subset of ``G`` is
invertible (Cauchy minors are nonzero), so *any* ``k`` surviving shards
reconstruct the page — a plain Vandermonde block under an identity does
not have this property in GF(256).  Encode/decode are numpy-vectorized
table lookups; matrix inversion is a tiny (<= k x k) Gaussian
elimination in pure Python.
"""

from __future__ import annotations

import bisect
import hashlib
import re
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

import numpy as np

# --------------------------------------------------------------- GF(256)
_GF_POLY = 0x11D  # x^8 + x^4 + x^3 + x^2 + 1, the classic RS field

_GF_EXP = np.zeros(512, dtype=np.uint8)
_GF_LOG = np.zeros(256, dtype=np.int32)
_x = 1
for _i in range(255):
    _GF_EXP[_i] = _x
    _GF_LOG[_x] = _i
    _x <<= 1
    if _x & 0x100:
        _x ^= _GF_POLY
for _i in range(255, 512):
    _GF_EXP[_i] = _GF_EXP[_i - 255]


def gf_mul(a: int, b: int) -> int:
    if a == 0 or b == 0:
        return 0
    return int(_GF_EXP[int(_GF_LOG[a]) + int(_GF_LOG[b])])


def gf_inv(a: int) -> int:
    if a == 0:
        raise ZeroDivisionError("GF(256) inverse of 0")
    return int(_GF_EXP[255 - int(_GF_LOG[a])])


def _gf_mul_vec(c: int, v: np.ndarray) -> np.ndarray:
    """Scalar x vector product in GF(256), vectorized via the log table."""
    if c == 0:
        return np.zeros_like(v)
    if c == 1:
        return v.copy()
    logs = _GF_LOG[v.astype(np.int32)] + int(_GF_LOG[c])
    out = _GF_EXP[logs]
    return np.where(v == 0, 0, out).astype(np.uint8)


def _cauchy_rows(k: int, m: int) -> List[List[int]]:
    """The m parity rows C[i][j] = inv(x_i ^ y_j), x_i = k+i, y_j = j."""
    return [[gf_inv((k + i) ^ j) for j in range(k)] for i in range(m)]


def _generator(k: int, m: int) -> List[List[int]]:
    """(k+m) x k generator [I_k ; C]: row r is shard r's data coefficients."""
    rows = [[1 if c == r else 0 for c in range(k)] for r in range(k)]
    rows.extend(_cauchy_rows(k, m))
    return rows


def _gf_solve(rows: List[List[int]], k: int) -> List[List[int]]:
    """Invert a k x k GF(256) matrix by Gaussian elimination (k <= 16)."""
    aug = [list(rows[i]) + [1 if j == i else 0 for j in range(k)]
           for i in range(k)]
    for col in range(k):
        piv = next((r for r in range(col, k) if aug[r][col] != 0), None)
        if piv is None:
            raise ValueError("singular shard matrix")  # unreachable: Cauchy
        aug[col], aug[piv] = aug[piv], aug[col]
        inv = gf_inv(aug[col][col])
        aug[col] = [gf_mul(inv, v) for v in aug[col]]
        for r in range(k):
            if r != col and aug[r][col]:
                f = aug[r][col]
                aug[r] = [a ^ gf_mul(f, b) for a, b in zip(aug[r], aug[col])]
    return [row[k:] for row in aug]


# ------------------------------------------------------------ shard format
SHARD_MAGIC = b"ECS1"
SHARD_HDR_BYTES = 16  # magic(4) + k(1) + m(1) + index(1) + pad(1) + L(8 LE)


def _shard_header(k: int, m: int, index: int, length: int) -> bytes:
    return (SHARD_MAGIC + bytes([k, m, index, 0])
            + length.to_bytes(8, "little"))


def parse_shard_header(shard: bytes) -> Tuple[int, int, int, int]:
    """Return ``(k, m, index, logical_length)``; raises on a bad header."""
    if len(shard) < SHARD_HDR_BYTES or shard[:4] != SHARD_MAGIC:
        raise ValueError("not an EC shard")
    k, m, index = shard[4], shard[5], shard[6]
    length = int.from_bytes(shard[8:16], "little")
    return k, m, index, length


def ec_encode(payload: bytes, k: int, m: int) -> List[bytes]:
    """Encode ``payload`` into ``k + m`` self-describing shards."""
    L = len(payload)
    slen = max(1, -(-L // k))  # ceil; >=1 so empty-ish pages still shard
    buf = np.zeros(k * slen, dtype=np.uint8)
    buf[:L] = np.frombuffer(payload, dtype=np.uint8)
    data = buf.reshape(k, slen)
    shards: List[bytes] = []
    for j in range(k):
        shards.append(_shard_header(k, m, j, L) + data[j].tobytes())
    for i, row in enumerate(_cauchy_rows(k, m)):
        acc = np.zeros(slen, dtype=np.uint8)
        for j, coef in enumerate(row):
            acc ^= _gf_mul_vec(coef, data[j])
        shards.append(_shard_header(k, m, k + i, L) + acc.tobytes())
    return shards


def ec_decode(shards: Sequence[Tuple[int, bytes]], k: int, m: int) -> bytes:
    """Reconstruct the page from any ``k`` of its shards.

    ``shards`` holds ``(shard_index, shard_bytes)`` pairs (header
    included).  Raises :class:`ValueError` when fewer than ``k``
    distinct shards are supplied or a header disagrees.
    """
    by_index = {}
    length = None
    for idx, raw in shards:
        hk, hm, hidx, hlen = parse_shard_header(raw)
        if (hk, hm) != (k, m) or hidx != idx:
            raise ValueError(f"shard header mismatch for index {idx}")
        if length is None:
            length = hlen
        elif length != hlen:
            raise ValueError("shards disagree on logical length")
        by_index.setdefault(idx, raw[SHARD_HDR_BYTES:])
    if length is None or len(by_index) < k:
        raise ValueError(
            f"need {k} shards to decode, have {len(by_index)}")
    use = sorted(by_index)[:k]
    slen = max(1, -(-length // k))
    bodies = [np.frombuffer(by_index[i], dtype=np.uint8)[:slen] for i in use]
    if all(i < k for i in use) and use == list(range(k)):
        out = np.concatenate(bodies)
        return out.tobytes()[:length]
    G = _generator(k, m)
    inv = _gf_solve([G[i] for i in use], k)
    data = []
    for r in range(k):
        acc = np.zeros(slen, dtype=np.uint8)
        for c in range(k):
            acc ^= _gf_mul_vec(inv[r][c], bodies[c])
        data.append(acc)
    return np.concatenate(data).tobytes()[:length]


def ec_shard_for(payload: bytes, k: int, m: int, index: int) -> bytes:
    """Re-encode a single shard (repair path: rebuild just the lost one)."""
    return ec_encode(payload, k, m)[index]


# ----------------------------------------------------------- page-id codec
_EC_TAG_RE = re.compile(r"-ec(\d+)\+(\d+)$")
_SHARD_RE = re.compile(r"^(.*)\.s(\d+)$")


def ec_tag(k: int, m: int) -> str:
    return f"ec{k}+{m}"


def page_codec(page_id: str) -> Optional[Tuple[int, int]]:
    """``(k, m)`` when ``page_id`` is erasure-coded, else ``None``."""
    mt = _EC_TAG_RE.search(page_id)
    if mt is None:
        return None
    return int(mt.group(1)), int(mt.group(2))


def shard_id(page_id: str, index: int) -> str:
    """Physical store id of shard ``index`` of an EC page."""
    return f"{page_id}.s{index}"


def split_shard(phys_id: str) -> Optional[Tuple[str, int]]:
    """``(logical_page_id, shard_index)`` for a shard id, else ``None``."""
    mt = _SHARD_RE.match(phys_id)
    if mt is None or page_codec(mt.group(1)) is None:
        return None
    return mt.group(1), int(mt.group(2))


def logical_pid(phys_id: str) -> str:
    """Map a physical store id back to its logical page id (identity for
    replicated pages)."""
    split = split_shard(phys_id)
    return phys_id if split is None else split[0]


# ---------------------------------------------------------------- policies
@dataclass(frozen=True)
class PlacementPolicy:
    """How one blob's pages map onto provider endpoints."""

    def width(self, default_replication: int) -> int:
        raise NotImplementedError

    @property
    def tag(self) -> str:
        return ""


@dataclass(frozen=True)
class ReplicationPolicy(PlacementPolicy):
    """N full copies on distinct providers (the paper's model).
    ``n = 0`` means "the deployment default"."""

    n: int = 0

    def width(self, default_replication: int) -> int:
        return self.n if self.n > 0 else default_replication


@dataclass(frozen=True)
class ErasureCodedPolicy(PlacementPolicy):
    """``k`` data + ``m`` parity shards on ``k + m`` distinct providers."""

    k: int = 6
    m: int = 2

    def __post_init__(self) -> None:
        if not (1 <= self.k and 1 <= self.m and self.k + self.m <= 255):
            raise ValueError(f"bad EC geometry k={self.k} m={self.m}")

    def width(self, default_replication: int) -> int:
        return self.k + self.m

    @property
    def tag(self) -> str:
        return ec_tag(self.k, self.m)


def parse_policy(spec) -> PlacementPolicy:
    """``"rep:3"`` / ``"ec:6+2"`` / an already-built policy object."""
    if isinstance(spec, PlacementPolicy):
        return spec
    if not isinstance(spec, str):
        raise ValueError(f"bad placement spec: {spec!r}")
    kind, _, arg = spec.partition(":")
    if kind == "rep":
        return ReplicationPolicy(int(arg) if arg else 0)
    if kind == "ec":
        mt = re.fullmatch(r"(\d+)\+(\d+)", arg)
        if mt is None:
            raise ValueError(f"bad EC spec: {spec!r} (want 'ec:K+M')")
        return ErasureCodedPolicy(int(mt.group(1)), int(mt.group(2)))
    raise ValueError(f"unknown placement spec: {spec!r}")


# ------------------------------------------------------------ hash ring
#: virtual nodes per ring member.  High enough that 8 members spread a
#: few hundred keys within the balance bounds the DHT tests assert, low
#: enough that ring rebuilds stay O(members * vnodes * log) cheap.
DEFAULT_VNODES = 64

_RING_SPACE = 1 << 64


def stable_hash(key: str) -> int:
    """64-bit position of ``key`` on the ring.

    Process-independent by construction (``hash()`` is randomized per
    interpreter run): the same ring state + key always maps to the same
    owners, which is what makes placement replayable across same-seed
    runs and recomputable by any node without a directory lookup.
    """
    digest = hashlib.blake2b(key.encode(), digest_size=8).digest()
    return int.from_bytes(digest, "big")


class HashRing:
    """Consistent-hash ring with virtual nodes (membership plane).

    Placement is a pure function of (ring members, key): every member
    is hashed onto the 64-bit ring at :data:`DEFAULT_VNODES` points,
    and a key's owner group is the first ``width`` *distinct* members
    found walking clockwise from the key's hash.  Adding or removing
    one member therefore remaps only the arcs that member gains or
    loses — the consistent-hashing minimal-movement property the
    rebalance gate (``BENCH_ring.json``) measures.

    The ring is membership state only — it holds node *ids*, never
    sockets or stores — so two rings with the same members are
    interchangeable, and a reconfiguration can diff an old ring
    against a new one arc by arc (see ``MetadataDHT``'s ARES-style
    per-range pointer flips).
    """

    def __init__(self, nodes: Iterable[str] = (), *,
                 vnodes: int = DEFAULT_VNODES) -> None:
        self.vnodes = vnodes
        self._nodes: Set[str] = set()
        self._points: List[int] = []          # sorted vnode positions
        self._owner_at: Dict[int, str] = {}   # position -> member id
        for node in nodes:
            self.add(node)

    # -- membership --------------------------------------------------------
    def add(self, node: str) -> None:
        if node in self._nodes:
            return
        self._nodes.add(node)
        for i in range(self.vnodes):
            pos = stable_hash(f"{node}#{i}")
            # vanishing-probability collision: keep the lexically first
            # owner so both colliders resolve identically everywhere
            cur = self._owner_at.get(pos)
            if cur is not None:
                if node < cur:
                    self._owner_at[pos] = node
                continue
            self._owner_at[pos] = node
            bisect.insort(self._points, pos)

    def remove(self, node: str) -> None:
        if node not in self._nodes:
            return
        self._nodes.discard(node)
        for i in range(self.vnodes):
            pos = stable_hash(f"{node}#{i}")
            if self._owner_at.get(pos) != node:
                continue
            del self._owner_at[pos]
            idx = bisect.bisect_left(self._points, pos)
            if idx < len(self._points) and self._points[idx] == pos:
                self._points.pop(idx)

    def nodes(self) -> Set[str]:
        return set(self._nodes)

    def __contains__(self, node: str) -> bool:
        return node in self._nodes

    def __len__(self) -> int:
        return len(self._nodes)

    # -- placement ---------------------------------------------------------
    def owners(self, key: str, width: int,
               eligible: Optional[Set[str]] = None) -> List[str]:
        """The ``width`` distinct members owning ``key``, clockwise from
        its hash.  ``eligible`` (when given) filters the walk — a downed
        member is skipped deterministically, so the group for a key is a
        pure function of (ring, key, eligible set).  Returns fewer than
        ``width`` when the ring has fewer distinct eligible members."""
        return self.owners_at(stable_hash(key), width, eligible)

    def owners_at(self, pos: int, width: int,
                  eligible: Optional[Set[str]] = None) -> List[str]:
        if not self._points or width <= 0:
            return []
        out: List[str] = []
        start = bisect.bisect_right(self._points, pos % _RING_SPACE)
        n = len(self._points)
        for step in range(n):
            node = self._owner_at[self._points[(start + step) % n]]
            if node in out:
                continue
            if eligible is not None and node not in eligible:
                continue
            out.append(node)
            if len(out) >= width:
                break
        return out

    # -- reconfiguration geometry ------------------------------------------
    def arc_starts(self) -> List[int]:
        """Sorted vnode positions — the ring's native arc boundaries.
        Arc ``i`` is the clockwise interval ``(points[i-1], points[i]]``
        (wrapping), whose keys are owned starting at ``points[i]``'s
        successor walk."""
        return list(self._points)

    @staticmethod
    def merged_arcs(old: "HashRing", new: "HashRing") -> List[int]:
        """Union of both rings' arc boundaries: within one merged arc the
        owner group is constant under BOTH configurations, which is the
        granularity the ARES-style per-range configuration pointer flips
        at."""
        return sorted(set(old._points) | set(new._points))

    @staticmethod
    def arc_index(arcs: List[int], pos: int) -> int:
        """Index of the merged arc containing ring position ``pos``:
        keys in arc ``i`` satisfy ``arcs[i-1] < pos <= arcs[i]`` (arc 0
        wraps past the last boundary)."""
        if not arcs:
            return 0
        return bisect.bisect_left(arcs, pos % _RING_SPACE) % len(arcs)
