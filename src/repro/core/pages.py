"""Page math for BlobSeer blobs.

A blob is striped into fixed-size *pages* of ``psize`` bytes (a power of
two, paper §3).  Page ``k`` owns the byte range
``[k * psize, (k + 1) * psize)``.  The metadata segment tree (see
``segment_tree.py``) works in *page units*: a leaf covers exactly one
page, an inner node covers a power-of-two page range.

This module holds the pure range algebra shared by the client, the
version manager and the metadata tree: byte<->page conversion, range
intersection, and the deterministic tree-shape rule that lets any actor
predict which tree nodes an update creates from ``(range, root_pages)``
alone (used by the version manager to hand out border sets for
concurrent, unpublished writers — paper §4.2).
"""

from __future__ import annotations

import itertools
import threading
from dataclasses import dataclass
from typing import Iterator, Tuple

# ---------------------------------------------------------------------------
# Basic helpers
# ---------------------------------------------------------------------------


def next_pow2(x: int) -> int:
    """Smallest power of two >= x (>=1)."""
    if x <= 1:
        return 1
    return 1 << (x - 1).bit_length()


def is_pow2(x: int) -> bool:
    return x > 0 and (x & (x - 1)) == 0


def pages_spanned(offset: int, size: int, psize: int) -> Tuple[int, int]:
    """Half-open page interval ``[p0, p1)`` touched by byte range."""
    if size == 0:
        p0 = offset // psize
        return (p0, p0)
    return (offset // psize, -(-(offset + size) // psize))


def root_pages_for(size_bytes: int, psize: int) -> int:
    """Page span of the segment-tree root for a blob of ``size_bytes``.

    The root always covers a power-of-two number of pages (paper §4.1
    assumes psize is a power of two and the tree is binary).
    """
    if size_bytes <= 0:
        return 1
    return next_pow2(-(-size_bytes // psize))


def intersects(a0: int, a1: int, b0: int, b1: int) -> bool:
    """Do half-open intervals [a0,a1) and [b0,b1) intersect?"""
    return a0 < b1 and b0 < a1


# ---------------------------------------------------------------------------
# Page range of an update + deterministic tree shape
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class UpdateExtent:
    """Everything the tree shape of one update is determined by.

    ``p0, p1``     half-open page interval the update's *new pages* cover
    ``root_pages`` page span of the update's tree root (power of two)

    The set of tree nodes *created* by an update is exactly: every node
    of the binary tree over ``[0, root_pages)`` whose page range
    intersects ``[p0, p1)`` (paper §4.2 — "the smallest (possibly
    incomplete) binary tree such that its leaves are exactly the leaves
    covering the pages of range that is written").
    """

    p0: int
    p1: int
    root_pages: int

    def creates_node(self, offset: int, size: int) -> bool:
        """Does this update create tree node ``(offset, size)`` (pages)?"""
        if offset + size > self.root_pages:
            return False
        return intersects(offset, offset + size, self.p0, self.p1)

    def intersects_pages(self, offset: int, size: int) -> bool:
        return intersects(offset, offset + size, self.p0, self.p1)


def node_parent(offset: int, size: int) -> Tuple[int, int, bool]:
    """Parent of tree node ``(offset, size)``.

    Returns ``(parent_offset, parent_size, is_left_child)`` following
    Algorithm 4 of the paper: a node is the LEFT child of its parent iff
    ``offset % (2 * size) == 0``.
    """
    if offset % (2 * size) == 0:
        return offset, 2 * size, True
    return offset - size, 2 * size, False


def node_children(offset: int, size: int) -> Tuple[Tuple[int, int], Tuple[int, int]]:
    """Children ranges of an inner node: left half, right half."""
    half = size // 2
    return (offset, half), (offset + half, half)


def iter_created_nodes(extent: UpdateExtent) -> Iterator[Tuple[int, int]]:
    """All (offset, size) nodes an update creates, leaves first.

    Mirrors the bottom-up construction of Algorithm 4 (BUILD_META) and
    is used by the version manager to compute partial border sets for
    concurrent writers without touching the DHT.
    """
    seen = set()
    frontier = [(p, 1) for p in range(extent.p0, extent.p1)]
    for node in frontier:
        seen.add(node)
        yield node
    while frontier:
        nxt = []
        for off, size in frontier:
            if size >= extent.root_pages:
                continue
            poff, psize_, _ = node_parent(off, size)
            if (poff, psize_) not in seen:
                seen.add((poff, psize_))
                nxt.append((poff, psize_))
                yield (poff, psize_)
        frontier = nxt


# ---------------------------------------------------------------------------
# Globally unique page ids (paper §3.3 line 5: "pid <- unique page id")
# ---------------------------------------------------------------------------

_pid_counter = itertools.count()
_pid_lock = threading.Lock()
_PID_NAMESPACE = "pg"


def fresh_page_id(tag: str = "") -> str:
    """Globally unique page id.

    A monotone counter + namespace is enough inside one process; a real
    deployment would prefix the client's node id (the paper only
    requires global uniqueness, not structure).

    ``tag`` makes the id self-describing for non-default placements
    (e.g. ``"ec6+2"`` marks an erasure-coded page; see
    ``repro.core.placement``): every metadata layer carries the id
    opaquely, only the provider manager interprets the suffix.
    """
    with _pid_lock:
        n = next(_pid_counter)
    base = f"{_PID_NAMESPACE}-{n:012x}"
    return f"{base}-{tag}" if tag else base
