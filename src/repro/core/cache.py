"""Immutability-aware read-path caches.

BlobSeer's central design choice (paper §4) is that published metadata
and data are **immutable**: tree nodes are created, never updated, and
every WRITE/APPEND stores *new* pages.  Caching is therefore
unconditionally safe for anything a published snapshot can reach — a
cached value can never be stale, it can only be *deleted* (by the GC
sweep, which retires whole snapshots).  This module holds the two
caches the read path layers on top of that invariant:

* :class:`NodeCache` — a per-client bounded LRU over the metadata DHT
  (promoted out of ``blob.py``).  Sequential appends re-descend the
  same published root for border resolution and repeated reads
  re-fetch the top tree levels; both become local hits.
* :class:`PageCache` — a **shared**, byte-budgeted LRU over data pages,
  layered under :meth:`~repro.core.provider.ProviderManager.fetch_pages`.
  It adds *single-flight de-duplication*: concurrent readers of the
  same page issue ONE provider RPC — the first requester becomes the
  leader, everyone else waits (in virtual time under the Simulator) for
  the leader's fill.

GC coherence (the one way a cached value can die): the version manager
fires a retire-intent notification at every ``gc_epoch`` bump and
``ProviderManager.delete_pages`` invalidates swept page ids before any
delete RPC goes out, so a cached page never outlives its sweep.  A
retired-version read is rejected by ``enter_read`` with a typed
``RetiredVersion`` *before* it could reach either cache — the cache
can reduce RPCs, never resurrect retired data.
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Dict, Hashable, Iterable, List, Optional, Sequence, Set, Tuple

from repro.core.sim import Clock, WallClock

# A page-cache key: (page_id, offset_within_page, length_or_None).
# Pages are immutable, so the key fully determines the bytes.
PageKey = Tuple[str, int, Optional[int]]


class NodeCache:
    """Client-side cache over the metadata DHT.

    Tree nodes are immutable once written (the system never updates
    metadata in place — the paper's key design choice), so caching is
    unconditionally safe.  Sequential appends re-descend the same
    published root for border resolution and repeated reads re-fetch the
    top tree levels; both become local hits.  Negative lookups are never
    cached (the node may be written later).

    Bounded LRU: at capacity the oldest entry is evicted, so the hot top
    levels of the tree stay resident (a clear-all here would stampede
    every client back to the DHT exactly when the cache is hottest).
    Batch-aware: ``get_many`` serves hits locally and forwards only the
    misses to the DHT's batched path.

    Counters: ``hits``/``misses`` count logical keys; ``hit_bytes``
    estimates the wire bytes the hits saved (``dht.node_nbytes`` per
    node).  Hits are also reported to the DHT's ``get_keys_cached``
    counter so ``service.rpc_report()`` shows cache-hit vs RPC
    accounting for the metadata plane in one place.
    """

    MAX_ENTRIES = 65536

    def __init__(self, dht) -> None:
        self._dht = dht
        self._cache: "OrderedDict" = OrderedDict()
        self._lock = threading.Lock()
        self._node_nbytes = getattr(dht, "node_nbytes", 64)
        self.hits = 0
        self.misses = 0
        self.hit_bytes = 0
        self.miss_bytes = 0

    # ------------------------------------------------------------- accounting
    def counters(self) -> Dict[str, int]:
        with self._lock:
            return {
                "hits": self.hits,
                "misses": self.misses,
                "hit_bytes": self.hit_bytes,
                "miss_bytes": self.miss_bytes,
            }

    def reset_counters(self) -> None:
        with self._lock:
            self.hits = self.misses = 0
            self.hit_bytes = self.miss_bytes = 0

    def _note_hits(self, n: int) -> None:
        # caller holds self._lock
        self.hits += n
        self.hit_bytes += n * self._node_nbytes
        note = getattr(self._dht, "note_cache_hits", None)
        if note is not None:
            note(n)

    def _insert(self, key, value) -> None:
        # caller holds self._lock
        if key in self._cache:
            self._cache.move_to_end(key)
        self._cache[key] = value
        while len(self._cache) > self.MAX_ENTRIES:
            self._cache.popitem(last=False)

    # -------------------------------------------------------------- DHT facade
    def get(self, key, peer=None):
        with self._lock:
            if key in self._cache:
                self._note_hits(1)
                self._cache.move_to_end(key)
                return self._cache[key]
        value = self._dht.get(key, peer=peer)
        with self._lock:
            self.misses += 1
            self.miss_bytes += self._node_nbytes
            if value is not None:
                self._insert(key, value)
        return value

    def get_many(self, keys, peer=None):
        out: Dict = {}
        missing: List = []
        with self._lock:
            for key in dict.fromkeys(keys):
                if key in self._cache:
                    self._note_hits(1)
                    self._cache.move_to_end(key)
                    out[key] = self._cache[key]
                else:
                    missing.append(key)
        if missing:
            fetched = self._dht.get_many(missing, peer=peer)
            with self._lock:
                self.misses += len(missing)
                self.miss_bytes += len(missing) * self._node_nbytes
                for key, value in fetched.items():
                    if value is not None:
                        self._insert(key, value)
            out.update(fetched)
        return out

    def put(self, key, value, peer=None):
        self._dht.put(key, value, peer=peer)
        with self._lock:
            self._insert(key, value)

    def put_many(self, items, peer=None):
        done_at = self._dht.put_many(items, peer=peer)
        with self._lock:
            for key, value in items:
                self._insert(key, value)
        return done_at


class PageCache:
    """Shared, byte-budgeted LRU over immutable data pages.

    One instance per deployment (``BlobSeerService.page_cache``),
    layered under ``ProviderManager.fetch_pages``: every client of the
    deployment shares it, so a page any reader fetched serves every
    later reader locally.  ``budget_bytes = 0`` disables the cache
    entirely (every call falls through to the provider RPC path).

    **Single-flight**: ``claim`` partitions wanted keys into hits
    (served now), *leaders* (this caller must fetch them) and *waiters*
    (another caller is fetching right now) — concurrent readers of the
    same page issue exactly one provider RPC.  A leader MUST resolve
    every claimed key with :meth:`fill` or :meth:`abandon` (failure),
    or waiters would block forever.  Waiting blocks through the
    deployment clock, so it is virtual-time-correct under the
    Simulator and adds no wall time to simulated runs.

    **GC coherence**: :meth:`invalidate_pages` drops every entry of the
    given page ids and *dooms* their in-flight fetches (a leader's
    ``fill`` racing a sweep discards the data instead of inserting it),
    so a cached page can never outlive its sweep.  The version manager
    fires it at retire-intent (``gc_epoch`` bump) and
    ``ProviderManager.delete_pages`` fires it again before the delete
    RPCs go out.
    """

    def __init__(self, budget_bytes: int, clock: Optional[Clock] = None) -> None:
        self.budget_bytes = max(0, int(budget_bytes))
        self._clock = clock if clock is not None else WallClock()
        # One condition guards all state; it is the single-flight
        # rendezvous (waiters wait on it, leaders notify after fill).
        self._cond = self._clock.condition()
        # key -> (bytes, ready_at).  ready_at is the simulated-clock
        # instant an async prefetch's bytes arrive (0.0 = already
        # arrived); a reader hitting an in-flight prefetch gates on it,
        # so the cache can serve "early" data without ever serving it
        # before its wire transfer would have completed.
        self._entries: "OrderedDict[PageKey, Tuple[bytes, float]]" = OrderedDict()
        self._by_page: Dict[str, Set[PageKey]] = {}
        self._bytes = 0
        self._inflight: Set[PageKey] = set()
        self._doomed: Set[PageKey] = set()
        # counters (guarded by self._cond's lock)
        self.hits = 0
        self.misses = 0
        self.hit_bytes = 0
        self.evictions = 0
        self.inflight_waits = 0
        self.invalidated_entries = 0
        self.prefetch_fills = 0

    # --------------------------------------------------------------- basics
    @property
    def enabled(self) -> bool:
        return self.budget_bytes > 0

    def used_bytes(self) -> int:
        with self._cond:
            return self._bytes

    def __len__(self) -> int:
        with self._cond:
            return len(self._entries)

    def cached_page_ids(self) -> Set[str]:
        """Page ids with at least one resident entry (tests/GC checks)."""
        with self._cond:
            return set(self._by_page)

    def counters(self) -> Dict[str, int]:
        with self._cond:
            return {
                "hits": self.hits,
                "misses": self.misses,
                "hit_bytes": self.hit_bytes,
                "evictions": self.evictions,
                "inflight_waits": self.inflight_waits,
                "invalidated_entries": self.invalidated_entries,
                "prefetch_fills": self.prefetch_fills,
                "used_bytes": self._bytes,
                "entries": len(self._entries),
            }

    def reset_counters(self) -> None:
        """Zero the counters; cached contents are kept (counter resets
        bracket a measurement, they must not change the wire schedule)."""
        with self._cond:
            self.hits = self.misses = 0
            self.hit_bytes = self.evictions = 0
            self.inflight_waits = self.invalidated_entries = 0
            self.prefetch_fills = 0

    # --------------------------------------------------------- single-flight
    def claim(
        self, keys: Sequence[PageKey], count: bool = True
    ) -> Tuple[Dict[PageKey, Tuple[bytes, float]], List[PageKey], List[PageKey]]:
        """Partition ``keys`` into ``(hits, leaders, waiters)`` atomically.

        Hits are returned as ``(bytes, ready_at)`` (LRU-touched); a
        ``ready_at`` in the future means the bytes are an async prefetch
        still on the wire — the caller gates on it before serving them.
        Leader keys are marked in-flight — the caller owns fetching them
        and must ``fill`` or ``abandon`` each one.  Waiter keys are in
        flight on behalf of another caller; resolve them with
        :meth:`wait`.

        ``count=False`` marks a *probe* claim (prefetch candidates): the
        single-flight bookkeeping is identical but the hit/miss counters
        are untouched, so ``page_cache_hits`` keeps meaning "bytes
        actually served to a reader", not "prefetch found its sibling
        already resident".
        """
        hits: Dict[PageKey, Tuple[bytes, float]] = {}
        leaders: List[PageKey] = []
        waiters: List[PageKey] = []
        with self._cond:
            for key in keys:
                entry = self._entries.get(key)
                if entry is not None:
                    self._entries.move_to_end(key)
                    if count:
                        self.hits += 1
                        self.hit_bytes += len(entry[0])
                    hits[key] = entry
                elif key in self._inflight:
                    waiters.append(key)
                else:
                    self._inflight.add(key)
                    if count:
                        self.misses += 1
                    leaders.append(key)
        return hits, leaders, waiters

    def fill(self, key: PageKey, data: bytes, prefetch: bool = False,
             ready_at: float = 0.0) -> None:
        """Leader resolution: insert the fetched bytes and wake waiters.

        ``ready_at``: arrival instant of a fire-and-forget prefetch
        (0.0 for blocking fetches — the transfer completed before this
        call).  A key doomed by a concurrent :meth:`invalidate_pages`
        (its page was swept while the fetch was in flight) is discarded
        — waiters wake and re-fetch; they will fail over or get the
        typed ``RetiredVersion`` upstream, never swept bytes from the
        cache.
        """
        with self._cond:
            self._inflight.discard(key)
            if key in self._doomed:
                self._doomed.discard(key)
            else:
                self._insert(key, data, ready_at)
                if prefetch:
                    self.prefetch_fills += 1
            self._cond.notify_all()

    def abandon(self, key: PageKey) -> None:
        """Leader resolution on failure: release the claim, wake waiters
        (they re-claim and retry against the remaining replicas)."""
        with self._cond:
            self._inflight.discard(key)
            self._doomed.discard(key)
            self._cond.notify_all()

    def wait(self, key: PageKey) -> Optional[Tuple[bytes, float]]:
        """Block until ``key``'s in-flight fetch resolves; returns
        ``(bytes, ready_at)``, or ``None`` if the leader abandoned
        (caller re-claims and retries)."""
        with self._cond:
            if key in self._inflight:
                self.inflight_waits += 1
                while key in self._inflight:
                    self._cond.wait()
            entry = self._entries.get(key)
            if entry is not None:
                self._entries.move_to_end(key)
                self.hits += 1
                self.hit_bytes += len(entry[0])
            return entry

    # ----------------------------------------------------------- GC coherence
    def invalidate_pages(self, page_ids: Iterable[str]) -> int:
        """Drop every entry of ``page_ids`` and doom their in-flight
        fetches.  Returns the number of entries removed.  Fired at
        retire-intent (gc_epoch bump) and again by the sweep's
        ``delete_pages`` — a cached page can never outlive its sweep."""
        removed = 0
        with self._cond:
            for pid in page_ids:
                for key in self._by_page.pop(pid, ()):  # resident entries
                    entry = self._entries.pop(key, None)
                    if entry is not None:
                        self._bytes -= len(entry[0])
                        removed += 1
                for key in list(self._inflight):
                    if key[0] == pid:
                        self._doomed.add(key)
            self.invalidated_entries += removed
        return removed

    # ---------------------------------------------------------------- eviction
    def _insert(self, key: PageKey, data: bytes, ready_at: float = 0.0) -> None:
        # caller holds the condition's lock
        if not self.enabled or len(data) > self.budget_bytes:
            return
        old = self._entries.pop(key, None)
        if old is not None:
            self._bytes -= len(old[0])
            self._by_page.get(key[0], set()).discard(key)
        self._entries[key] = (data, ready_at)
        self._bytes += len(data)
        self._by_page.setdefault(key[0], set()).add(key)
        while self._bytes > self.budget_bytes and self._entries:
            vkey, (vdata, _vready) = self._entries.popitem(last=False)
            self._bytes -= len(vdata)
            self.evictions += 1
            keys = self._by_page.get(vkey[0])
            if keys is not None:
                keys.discard(vkey)
                if not keys:
                    del self._by_page[vkey[0]]


class InvalidationSubscriber:
    """Push-based cache invalidation: the subscription plane's answer
    to the PR 4 retire-intent hook.

    The version manager's GC listener interface stays the same
    (``fn(blob_id, versions, gc_epoch, page_ids)``), but delivery is
    now modelled as a *push*: the retiring leader ships one batched
    fire-and-forget invalidation event per retire intent to this
    subscriber's endpoint (``CACHE_INVAL_EVT_BYTES`` per page id), and
    the page cache evicts at the event — the wire-accounted twin of a
    real deployment where cache nodes subscribe to gc_epoch bumps
    instead of polling them.  A down endpoint still invalidates
    (conservative: eviction is always safe, serving swept bytes never
    is).
    """

    def __init__(self, cache: PageCache, wire=None,
                 endpoint: str = "cache-inval") -> None:
        self._cache = cache
        self._wire = wire
        self.endpoint = endpoint
        self._lock = threading.Lock()
        self.pushes = 0         # invalidation batches received
        self.page_ids = 0       # page ids those batches carried
        self.invalidated = 0    # cache entries actually evicted

    def __call__(self, blob_id: str, versions: Tuple[int, ...],
                 gc_epoch: int, page_ids: Tuple[str, ...]) -> None:
        """GC-listener entry point (fired outside the shard lock)."""
        if not page_ids:
            return
        if self._wire is not None:
            from repro.core.transport import (CACHE_INVAL_EVT_BYTES,
                                              EndpointDown)
            try:
                self._wire.transfer_batch(
                    self.endpoint, [CACHE_INVAL_EVT_BYTES] * len(page_ids),
                    inbound=True, fire_and_forget=True)
            except EndpointDown:
                pass  # evict anyway: stale eviction is safe, stale data is not
        removed = self._cache.invalidate_pages(page_ids)
        with self._lock:
            self.pushes += 1
            self.page_ids += len(page_ids)
            self.invalidated += removed

    def counters(self) -> Dict[str, int]:
        with self._lock:
            return {"pushes": self.pushes, "page_ids": self.page_ids,
                    "invalidated": self.invalidated}

    def reset_counters(self) -> None:
        with self._lock:
            self.pushes = self.page_ids = self.invalidated = 0
