"""Deployment facade: wire + version manager + DHT + providers in one box.

Mirrors the paper's §5 experimental deployments ("we deploy each the
version manager and the provider manager on two distinct dedicated
nodes, and we co-deploy a data provider and a metadata provider on the
other nodes").  Tests, benchmarks, the checkpoint layer and the data
pipeline all build one of these.
"""

from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional

from repro.core.blob import BlobClient
from repro.core.dht import MetadataDHT
from repro.core.provider import DataProvider, ProviderManager
from repro.core.sim import Clock
from repro.core.transport import Wire
from repro.core.version_manager import VersionManager
from repro.store.file import FilePageStore
from repro.store.memory import MemoryPageStore


class BlobSeerService:
    """One BlobSeer deployment (in-process, simulated wire)."""

    def __init__(
        self,
        n_providers: int = 4,
        n_meta_shards: int = 4,
        *,
        data_replication: int = 1,
        meta_replication: int = 1,
        placement: str = "round_robin",
        verify_digests: bool = False,
        wire: Optional[Wire] = None,
        wal_path: Optional[str] = None,
        spool_dir: Optional[str] = None,
        heartbeat_timeout: float = 5.0,
        io_workers: int = 0,
        clock: Optional[Clock] = None,
    ) -> None:
        """``clock``: scheduling backend for every blocking point in the
        deployment (wall-clock threads by default; pass a
        ``repro.core.sim.Simulator`` for deterministic virtual time).
        Ignored when an explicit ``wire`` is supplied — the wire's
        clock wins, so a deployment never mixes time sources."""
        if wire is not None:
            self.wire = wire
        elif clock is not None:
            self.wire = Wire(clock=clock)
        else:
            self.wire = Wire()
        self.clock = self.wire.clock
        self.vm = VersionManager(wire=self.wire, wal_path=wal_path)
        self.dht = MetadataDHT(self.wire, n_meta_shards, replication=meta_replication)
        self.pm = ProviderManager(
            self.wire,
            strategy=placement,
            replication=data_replication,
            heartbeat_timeout=heartbeat_timeout,
        )
        self.io_workers = io_workers
        self._spool_dir = spool_dir
        self._verify = verify_digests
        self._monitor: Optional[threading.Thread] = None
        self._monitor_stop = threading.Event()
        for i in range(n_providers):
            self.add_provider(f"prov-{i:04d}")

    # ------------------------------------------------------------- membership
    def add_provider(self, pid: str) -> DataProvider:
        """A provider joins and registers with the provider manager."""
        store = (
            FilePageStore(f"{self._spool_dir}/{pid}") if self._spool_dir else MemoryPageStore()
        )
        prov = DataProvider(pid=pid, wire=self.wire, store=store, verify_digests=self._verify)
        self.pm.register(prov)
        return prov

    def client(self, name: Optional[str] = None) -> BlobClient:
        return BlobClient(self.vm, self.dht, self.pm, self.wire, name=name,
                          io_workers=self.io_workers)

    # -------------------------------------------------------- failure injection
    def kill_provider(self, pid: str) -> None:
        self.wire.set_down(pid, True)

    def revive_provider(self, pid: str) -> None:
        self.wire.set_down(pid, False)
        self.pm.get(pid).heartbeat()

    def make_straggler(self, pid: str, factor: float) -> None:
        self.wire.set_straggler(pid, factor)

    # ---------------------------------------------------- background maintenance
    def start_monitor(self, interval: float = 0.5, stall_timeout: float = 5.0) -> None:
        """Heartbeat sweep + stalled-writer recovery loop (beyond paper)."""
        if self.clock.is_virtual:
            raise RuntimeError(
                "start_monitor spawns a real thread; under a virtual clock "
                "spawn a simulated maintenance task instead "
                "(see core/scenarios.py)"
            )

        def loop() -> None:
            agent = self.client("recovery-agent")
            while not self._monitor_stop.wait(interval):
                self.pm.check_heartbeats()
                for blob_id, rec in self.vm.find_stalled(stall_timeout):
                    try:
                        agent.rebuild_metadata(blob_id, rec.version)
                    except Exception:
                        pass  # retried next sweep

        self._monitor = threading.Thread(target=loop, daemon=True)
        self._monitor.start()

    def stop_monitor(self) -> None:
        self._monitor_stop.set()
        if self._monitor is not None:
            self._monitor.join(timeout=2.0)
            self._monitor = None
        self._monitor_stop.clear()

    def recover_stalled(self, stall_timeout: float = 0.0) -> int:
        """One-shot recovery sweep; returns number of updates recovered."""
        agent = self.client("recovery-agent")
        n = 0
        for blob_id, rec in self.vm.find_stalled(stall_timeout):
            agent.rebuild_metadata(blob_id, rec.version)
            n += 1
        return n

    # ------------------------------------------------------- full restart
    @classmethod
    def restore(
        cls,
        spool_dir: str,
        wal_path: str,
        n_providers: int,
        n_meta_shards: int = 4,
        resweep: bool = True,
        **kwargs,
    ) -> "BlobSeerService":
        """Cold-restart a deployment from durable state.

        Pages come back from the provider spool directories; the version
        manager replays its WAL; the (volatile) metadata DHT is rebuilt
        by replaying BUILD_META for every completed update in version
        order — possible because page descriptors are journaled at
        version-assignment time (see version_manager.assign_version).

        ``resweep=False`` skips the retirement re-apply pass (callers
        that want to schedule ``gc.resweep_after_restore`` themselves,
        e.g. after reviving providers that were down at restart).
        """
        svc = cls(
            n_providers=n_providers, n_meta_shards=n_meta_shards,
            spool_dir=spool_dir, **kwargs,
        )
        svc.vm = VersionManager.recover_from_wal(wal_path, wire=svc.wire)
        agent = svc.client("rebuild-agent")
        for blob_id in list(svc.vm._blobs):
            b = svc.vm._blobs[blob_id]
            for v in range(b.base_version + 1, b.last_assigned + 1):
                rec = b.updates.get(v)
                if rec is None or not rec.complete:
                    continue
                info = svc.vm.assign_info_for_recovery(blob_id, v)
                # replay strictly in order: border nodes resolve against
                # the just-rebuilt tree of v-1
                info = type(info)(
                    version=info.version, offset=info.offset,
                    prev_size=info.prev_size, new_size=info.new_size,
                    root_pages=info.root_pages, p0=info.p0, p1=info.p1,
                    vp=v - 1 if v > 1 else None,
                    vp_root_pages=(svc.vm.update_log(blob_id, v - 1).root_pages
                                   if v > 1 else 0),
                    recent_updates=(),
                )
                agent._build_and_complete(blob_id, info, rec.pd)
        # Re-apply retirement: the rebuild above resurrects retired
        # versions' metadata (snapshot v's border chaining needs v-1's
        # tree), so the WAL's retire records are re-enforced — swept
        # versions stay typed-unreadable and their garbage is deleted
        # again through the wire.
        if resweep:
            from repro.core.gc import resweep_after_restore

            resweep_after_restore(svc)
        return svc

    # -------------------------------------------------------------- accounting
    def rpc_report(self) -> Dict[str, int]:
        """Per-operation RPC/round-trip counters for the whole deployment.

        ``wire_round_trips`` counts every RPC issued on the wire (a
        batched transfer counts once).  The ``dht_*`` entries break the
        metadata plane down: ``dht_get_keys`` is what a per-node read
        path would have paid in round trips, ``dht_get_rounds`` is the
        number of batched latency waves actually paid, and
        ``dht_get_shard_rpcs`` the per-shard requests those waves fanned
        out into.  ``provider_read_rounds``/``provider_read_pages`` are
        the data-plane analogue.
        """
        report: Dict[str, int] = {
            "wire_round_trips": self.wire.total_round_trips(),
        }
        for k, v in self.dht.rpc_counters().items():
            report[f"dht_{k}"] = v
        report["provider_read_rounds"] = self.pm.read_rounds
        report["provider_read_pages"] = self.pm.read_pages
        report["provider_sweep_rounds"] = self.pm.sweep_rounds
        report["provider_swept_pages"] = self.pm.swept_pages
        return report

    def reset_rpc_counters(self) -> None:
        self.dht.reset_rpc_counters()
        self.pm.reset_counters()
        self.wire.reset_accounting()

    def storage_report(self) -> Dict[str, object]:
        provs = self.pm.all_providers()
        return {
            "providers": len(provs),
            "pages": sum(p.page_count() for p in provs),
            "page_bytes": sum(p.stored_bytes() for p in provs),
            "metadata_nodes": self.dht.total_keys(),
            "wire_bytes": self.wire.total_bytes(),
        }
