"""Deployment facade: wire + version manager + DHT + providers in one box.

Mirrors the paper's §5 experimental deployments ("we deploy each the
version manager and the provider manager on two distinct dedicated
nodes, and we co-deploy a data provider and a metadata provider on the
other nodes").  Tests, benchmarks, the checkpoint layer and the data
pipeline all build one of these.
"""

from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional

from repro.core.blob import BlobClient
from repro.core.cache import InvalidationSubscriber, PageCache
from repro.core.dedup_index import DedupIndex
from repro.core.dht import MetadataDHT
from repro.core.provider import DataProvider, ProviderManager
from repro.core.sim import Clock
from repro.core.transport import EndpointDown, Wire
from repro.core.version_manager import (
    VMGR_ENDPOINT,
    VersionManager,
    VersionUnpublished,
)
from repro.store.file import FilePageStore
from repro.store.memory import MemoryPageStore
from repro.store.s3 import S3PageStore

# Default byte budget of the shared read-path page cache.  Sized so the
# paper-scale experiments (64 KiB pages, MB-scale hot sets) fit whole,
# while still exercising eviction in the space benchmarks; pass
# ``page_cache_bytes=0`` to disable caching entirely.
DEFAULT_PAGE_CACHE_BYTES = 64 * 1024 * 1024


class BlobSeerService:
    """One BlobSeer deployment (in-process, simulated wire)."""

    def __init__(
        self,
        n_providers: int = 4,
        n_meta_shards: int = 4,
        *,
        data_replication: int = 1,
        meta_replication: int = 1,
        placement: str = "ring",
        verify_digests: bool = False,
        wire: Optional[Wire] = None,
        wal_path: Optional[str] = None,
        spool_dir: Optional[str] = None,
        heartbeat_timeout: float = 5.0,
        io_workers: int = 0,
        clock: Optional[Clock] = None,
        page_cache_bytes: int = DEFAULT_PAGE_CACHE_BYTES,
        read_prefetch_pages: int = 0,
        dedup: bool = False,
        vm_replication: int = 0,
        vm_lease_ttl: float = 0.25,
        wal_fsync: str = "batch",
        n_cold_providers: int = 0,
        spool_fsync: str = "never",
    ) -> None:
        """``clock``: scheduling backend for every blocking point in the
        deployment (wall-clock threads by default; pass a
        ``repro.core.sim.Simulator`` for deterministic virtual time).
        Ignored when an explicit ``wire`` is supplied — the wire's
        clock wins, so a deployment never mixes time sources.

        ``page_cache_bytes``: byte budget of the shared read-path page
        cache (0 disables it).  ``read_prefetch_pages``: default
        sibling-page prefetch depth handed to every client this service
        creates (see :class:`~repro.core.blob.BlobClient`).

        ``dedup``: default for every client's write-burst dedup
        handshake.  The content-hash index itself is ALWAYS deployed
        (its counters report zero and its GC verbs self-disable while
        nothing was ever registered), so flipping the flag changes
        client behavior only — never the deployment topology.

        ``vm_replication``: follower replicas per version-manager
        lineage shard (0 = the single shared ``vmgr`` endpoint, the
        pre-HA behavior).  ``vm_lease_ttl``: leader lease duration —
        failover waits it out before promoting.  ``wal_fsync``: the
        manager WAL's fsync policy (``never``/``batch``/``always``).

        ``n_cold_providers``: S3-class cold-tier endpoints
        (``cold-NNNN``); they never take new-page placement, only
        lifecycle demotions (see :meth:`set_lifecycle` /
        ``core/durability.py``).  ``spool_fsync``: the page spool's
        fsync policy (``never``/``always``), mirroring ``wal_fsync``
        for the data plane when ``spool_dir`` is set."""
        if wire is not None:
            self.wire = wire
        elif clock is not None:
            self.wire = Wire(clock=clock)
        else:
            self.wire = Wire()
        self.clock = self.wire.clock
        self.vm = VersionManager(wire=self.wire, wal_path=wal_path,
                                 replication=vm_replication,
                                 lease_ttl=vm_lease_ttl,
                                 fsync_policy=wal_fsync)
        self.dht = MetadataDHT(self.wire, n_meta_shards, replication=meta_replication)
        self.page_cache = PageCache(page_cache_bytes, clock=self.clock)
        self.dedup_index = DedupIndex(self.wire)
        self.dedup = dedup
        self.pm = ProviderManager(
            self.wire,
            strategy=placement,
            replication=data_replication,
            heartbeat_timeout=heartbeat_timeout,
            page_cache=self.page_cache,
            dedup_index=self.dedup_index,
        )
        # GC/cache coherence: evict a retired version's pages at
        # retire-intent time (epoch bump), before any sweep delete.
        # Delivery is push-modelled: the retiring leader ships one
        # batched fire-and-forget invalidation event to the cache's
        # subscriber endpoint (see InvalidationSubscriber).
        self.cache_invalidation = InvalidationSubscriber(
            self.page_cache, self.wire)
        self.vm.add_gc_listener(self._on_retire_intent)
        self.read_prefetch_pages = read_prefetch_pages
        self.io_workers = io_workers
        self._spool_dir = spool_dir
        self._spool_fsync = spool_fsync
        self._verify = verify_digests
        # Per-blob lifecycle policy: blob_id -> demote-after age
        # (simulated seconds).  Pages older than the threshold are moved
        # to the cold tier by ``durability.lifecycle_round``; blobs with
        # a ``promote_reads`` threshold move cold pages back to the hot
        # tier once their read tally crosses it.
        self.lifecycles: Dict[str, float] = {}
        self.promote_reads: Dict[str, int] = {}
        self._monitor: Optional[threading.Thread] = None
        self._monitor_stop = threading.Event()
        self._monitor_errors = 0   # retryable recovery failures (see rpc_report)
        self._monitor_fatal: Optional[BaseException] = None
        for i in range(n_providers):
            self.add_provider(f"prov-{i:04d}")
        for i in range(n_cold_providers):
            self.add_provider(f"cold-{i:04d}", tier="cold")

    # ------------------------------------------------------------- membership
    def add_provider(self, pid: str, tier: str = "hot") -> DataProvider:
        """A provider joins and registers with the provider manager.

        ``tier="cold"`` endpoints carry an S3-class object store (cheap
        durable capacity, per-request billing — see
        ``repro.store.s3``); they are excluded from new-page placement
        and filled only by lifecycle demotion.  Reads through them are
        fronted by the deployment's shared ``PageCache`` like any other
        endpoint, so only the first touch of a demoted page pays the
        cold path."""
        if tier == "cold":
            store: object = S3PageStore(bucket=pid)
        elif self._spool_dir:
            store = FilePageStore(f"{self._spool_dir}/{pid}",
                                  fsync=self._spool_fsync)
        else:
            store = MemoryPageStore()
        prov = DataProvider(pid=pid, wire=self.wire, store=store,
                            verify_digests=self._verify, tier=tier)
        self.pm.register(prov)
        return prov

    def join_provider(self, pid: str, tier: str = "hot"):
        """A provider joins the live ring: registered for new-page
        placement immediately, and the returned plan (run it with
        :meth:`run_migration`) transfers it exactly the already-stored
        pages the ring now assigns to it."""
        from repro.core.membership import join_provider

        return join_provider(self, pid, tier=tier)

    def start_drain(self, pid: str):
        """Take a provider out of placement (it keeps serving reads)
        and return its transfer-out plan; call :meth:`finish_drain`
        once the plan has run to deregister it."""
        from repro.core.membership import start_drain

        return start_drain(self, pid)

    def finish_drain(self, pid: str) -> int:
        """Straggler sweep + deregistration closing out a drain."""
        from repro.core.membership import finish_drain

        return finish_drain(self, pid)

    def drain_provider(self, pid: str, *, budget_bytes: Optional[int] = None,
                       round_sleep: float = 0.0) -> Dict[str, int]:
        """Full provider drain: plan, budgeted transfer concurrent with
        client traffic, straggler sweep, deregistration — zero failed
        ops (see ``core/membership.py``)."""
        from repro.core.membership import (
            DEFAULT_MIGRATION_BUDGET,
            drain_provider,
        )

        return drain_provider(
            self, pid, round_sleep=round_sleep,
            budget_bytes=(DEFAULT_MIGRATION_BUDGET if budget_bytes is None
                          else budget_bytes))

    def run_migration(self, plan, *, budget_bytes: Optional[int] = None,
                      round_sleep: float = 0.0) -> Dict[str, int]:
        """Drive a join/drain plan's budget-capped rounds."""
        from repro.core.membership import (
            DEFAULT_MIGRATION_BUDGET,
            run_migration,
        )

        return run_migration(
            self, plan, round_sleep=round_sleep,
            budget_bytes=(DEFAULT_MIGRATION_BUDGET if budget_bytes is None
                          else budget_bytes))

    def add_meta_shard(self, shard_id: str,
                       budget_bytes: int = 1 << 20) -> None:
        """Grow the metadata DHT online: the shard joins the ring and
        its owed key ranges migrate over in budgeted rounds (ARES-style
        per-arc pointer flips — see ``core/dht.py``)."""
        self.dht.begin_join(shard_id)
        while not self.dht.migration_round(budget_bytes)["done"]:
            pass

    def drain_meta_shard(self, shard_id: str,
                         budget_bytes: int = 1 << 20) -> None:
        """Shrink the metadata DHT online: the shard's ranges transfer
        out arc by arc, then it deregisters empty."""
        self.dht.begin_drain(shard_id)
        while not self.dht.migration_round(budget_bytes)["done"]:
            pass

    def mitigate_flash_crowd(self, *, threshold: int = 32, extra: int = 1,
                             blob_id: Optional[str] = None):
        """One flash-crowd relief pass: widen every hot page's replica
        set onto its next ring owners (see ``core/membership.py``)."""
        from repro.core.membership import mitigate_flash_crowd

        return mitigate_flash_crowd(self, threshold=threshold, extra=extra,
                                    blob_id=blob_id)

    def ring_report(self) -> Dict[str, object]:
        """Elastic-membership introspection: ring members on each
        plane, in-flight reconfiguration state, migration counters."""
        pm_ctr = self.pm.rpc_counters()
        return {
            "data_ring": sorted(self.pm.ring.nodes())
            if self.pm.ring is not None else [],
            "data_draining": sorted(self.pm._draining),
            "data_departed": sorted(self.pm._departed),
            "meta_ring": sorted(self.dht.ring.nodes()),
            "meta_reconfiguring": self.dht.reconfiguring,
            "migrated_pages": pm_ctr["migrated_pages"],
            "migrated_bytes": pm_ctr["migrated_bytes"],
            "migrated_payload_bytes": pm_ctr["migrated_payload_bytes"],
            "widened_pages": pm_ctr["widened_pages"],
            "promoted_pages": pm_ctr["promoted_pages"],
        }

    # ----------------------------------------------------- durability policy
    def set_blob_placement(self, blob_id: str, spec) -> None:
        """Select this blob's placement for future pages: ``"rep:N"``
        or ``"ec:K+M"`` (see ``repro.core.placement``)."""
        self.pm.set_blob_policy(blob_id, spec)

    def set_lifecycle(self, blob_id: str, demote_after: float,
                      promote_reads: Optional[int] = None) -> None:
        """Demote this blob's pages to the cold tier once they are
        ``demote_after`` simulated seconds old (applied by
        ``durability.lifecycle_round``).  ``promote_reads`` adds the
        reverse transition: a cold page read at least that many times
        since the last lifecycle pass moves back to the hot tier."""
        self.lifecycles[blob_id] = float(demote_after)
        if promote_reads is not None:
            self.promote_reads[blob_id] = int(promote_reads)

    def scrub(self, budget_bytes: Optional[int] = None,
              peer: str = "scrubber") -> Dict[str, int]:
        """One scrub/repair round (facade over
        :func:`repro.core.durability.scrub_round`)."""
        from repro.core.durability import scrub_round

        if budget_bytes is None:
            return scrub_round(self, peer=peer)
        return scrub_round(self, budget_bytes=budget_bytes, peer=peer)

    def client(self, name: Optional[str] = None,
               prefetch_pages: Optional[int] = None) -> BlobClient:
        """A new client process.  ``prefetch_pages`` overrides the
        deployment's ``read_prefetch_pages`` default for this client.
        The service keeps no reference to it — clients (and their node
        caches) die with their owners; their metadata cache hits
        survive in the deployment counter ``dht_get_keys_cached``."""
        return BlobClient(
            self.vm, self.dht, self.pm, self.wire, name=name,
            io_workers=self.io_workers,
            prefetch_pages=(self.read_prefetch_pages
                            if prefetch_pages is None else prefetch_pages),
            dedup_index=self.dedup_index,
            dedup=self.dedup,
        )

    def _on_retire_intent(self, blob_id, versions, epoch, page_ids) -> None:
        """gc_epoch listener: drop a retired version's pages from the
        shared page cache the instant the intent lands.

        Deliberately conservative: a retired version's pd may include
        pages a kept snapshot still shares (the sweep defers those) —
        they are evicted anyway and cost one refetch if re-read.  The
        coherence invariant itself is carried by the second hook
        (``ProviderManager.delete_pages`` invalidates before any delete
        RPC); this one closes the intent-to-sweep window early and
        keeps the cache from holding data of versions that already
        answer ``RetiredVersion``.  Delivery goes through the
        wire-accounted push subscriber (one batched fire-and-forget
        invalidation event per intent — see
        :class:`~repro.core.cache.InvalidationSubscriber`)."""
        self.cache_invalidation(blob_id, versions, epoch, page_ids)

    # -------------------------------------------------------- failure injection
    def kill_provider(self, pid: str) -> None:
        """Down an endpoint (failure injection): every RPC to it raises
        :class:`~repro.core.transport.EndpointDown` until revived."""
        self.wire.set_down(pid, True)

    def revive_provider(self, pid: str) -> None:
        """Bring a downed endpoint back (and refresh its heartbeat so
        the next sweep does not immediately re-mark it dead)."""
        self.wire.set_down(pid, False)
        self.pm.get(pid).heartbeat()

    def make_straggler(self, pid: str, factor: float) -> None:
        """Make an endpoint ``factor``x slower on the simulated wire
        (replica racing/balancing then naturally deprioritizes it)."""
        self.wire.set_straggler(pid, factor)

    def vm_leader_endpoint(self, blob_id: str) -> str:
        """The version-manager endpoint currently serving this blob's
        lineage (``vmgr`` with replication off)."""
        return self.vm.leader_endpoint(blob_id)

    def kill_vm_leader(self, blob_id: str) -> str:
        """Down the CURRENT leader endpoint of the blob's lineage shard
        (failure injection for the HA control plane).  The next verb on
        the lineage waits out the lease and promotes a follower; other
        lineages are untouched.  Returns the endpoint killed."""
        ep = self.vm.leader_endpoint(blob_id)
        if ep == VMGR_ENDPOINT:
            raise RuntimeError(
                "vm_replication=0: no per-lineage leader to kill "
                "(build the service with vm_replication >= 1)")
        self.wire.set_down(ep, True)
        return ep

    # ---------------------------------------------------- background maintenance
    #: errors the recovery loop may safely retry on the next sweep: a
    #: downed endpoint, a blocking-verb timeout, or a version whose
    #: assignment raced retirement/recovery.  Anything else is a bug —
    #: retrying it forever would only hide it.
    MONITOR_RETRYABLE = (EndpointDown, TimeoutError, VersionUnpublished)

    def start_monitor(self, interval: float = 0.5, stall_timeout: float = 5.0) -> None:
        """Heartbeat sweep + stalled-writer recovery loop (beyond paper).

        Retryable failures (:attr:`MONITOR_RETRYABLE`) are counted in
        ``monitor_errors`` (see ``rpc_report``) and retried next sweep.
        An unexpected exception also counts, then stops the loop and is
        re-raised by the next :meth:`stop_monitor` — a permanently
        failing rebuild can no longer retry silently forever."""
        if self.clock.is_virtual:
            raise RuntimeError(
                "start_monitor spawns a real thread; under a virtual clock "
                "spawn a simulated maintenance task instead "
                "(see core/scenarios.py)"
            )

        def loop() -> None:
            agent = self.client("recovery-agent")
            while not self._monitor_stop.wait(interval):
                self.pm.check_heartbeats()
                for blob_id, rec in self.vm.find_stalled(stall_timeout):
                    try:
                        agent.rebuild_metadata(blob_id, rec.version)
                    except self.MONITOR_RETRYABLE:
                        self._monitor_errors += 1
                    except Exception as exc:
                        self._monitor_errors += 1
                        self._monitor_fatal = exc
                        return

        self._monitor = threading.Thread(target=loop, daemon=True)
        self._monitor.start()

    def stop_monitor(self) -> None:
        """Stop the background maintenance thread started by
        :meth:`start_monitor` (joins it; safe to call when stopped).
        Re-raises the unexpected exception that killed the loop, if
        any — the deferred surfacing point for monitor bugs."""
        self._monitor_stop.set()
        if self._monitor is not None:
            self._monitor.join(timeout=2.0)
            self._monitor = None
        self._monitor_stop.clear()
        if self._monitor_fatal is not None:
            exc = self._monitor_fatal
            self._monitor_fatal = None
            raise exc

    def recover_stalled(self, stall_timeout: float = 0.0) -> int:
        """One-shot recovery sweep; returns number of updates recovered."""
        agent = self.client("recovery-agent")
        n = 0
        for blob_id, rec in self.vm.find_stalled(stall_timeout):
            agent.rebuild_metadata(blob_id, rec.version)
            n += 1
        return n

    # ------------------------------------------------------- full restart
    @classmethod
    def restore(
        cls,
        spool_dir: str,
        wal_path: str,
        n_providers: int,
        n_meta_shards: int = 4,
        resweep: bool = True,
        **kwargs,
    ) -> "BlobSeerService":
        """Cold-restart a deployment from durable state.

        Pages come back from the provider spool directories; the version
        manager replays its WAL; the (volatile) metadata DHT is rebuilt
        by replaying BUILD_META for every completed update in version
        order — possible because page descriptors are journaled at
        version-assignment time (see version_manager.assign_version).

        ``resweep=False`` skips the retirement re-apply pass (callers
        that want to schedule ``gc.resweep_after_restore`` themselves,
        e.g. after reviving providers that were down at restart).
        """
        svc = cls(
            n_providers=n_providers, n_meta_shards=n_meta_shards,
            spool_dir=spool_dir, **kwargs,
        )
        # recover with the same HA/durability config __init__ resolved
        # (vm_replication / vm_lease_ttl / wal_fsync kwargs): the
        # recovered manager rebuilds each lineage's replica group and
        # bulk-streams the journal to the fresh followers
        svc.vm = VersionManager.recover_from_wal(
            wal_path, wire=svc.wire,
            replication=svc.vm._replication,
            lease_ttl=svc.vm._lease_ttl,
            fsync_policy=svc.vm._fsync_policy,
        )
        # the recovered manager replaces the one __init__ subscribed to;
        # re-attach the cache-eviction hook so post-restore GC rounds
        # keep the page cache coherent
        svc.vm.add_gc_listener(svc._on_retire_intent)
        agent = svc.client("rebuild-agent")
        for blob_id in svc.vm.known_blobs():
            base, last = svc.vm.version_bounds(blob_id)
            for v in range(base + 1, last + 1):
                try:
                    rec = svc.vm.update_log(blob_id, v)
                except VersionUnpublished:
                    continue  # never assigned; anything else fails loudly
                if not rec.complete:
                    continue
                info = svc.vm.assign_info_for_recovery(blob_id, v)
                # replay strictly in order: border nodes resolve against
                # the just-rebuilt tree of v-1
                info = type(info)(
                    version=info.version, offset=info.offset,
                    prev_size=info.prev_size, new_size=info.new_size,
                    root_pages=info.root_pages, p0=info.p0, p1=info.p1,
                    vp=v - 1 if v > 1 else None,
                    vp_root_pages=(svc.vm.update_log(blob_id, v - 1).root_pages
                                   if v > 1 else 0),
                    recent_updates=(),
                )
                agent._build_and_complete(blob_id, info, rec.pd)
        # Re-apply retirement: the rebuild above resurrects retired
        # versions' metadata (snapshot v's border chaining needs v-1's
        # tree), so the WAL's retire records are re-enforced — swept
        # versions stay typed-unreadable and their garbage is deleted
        # again through the wire.
        if resweep:
            from repro.core.gc import resweep_after_restore

            resweep_after_restore(svc)
        return svc

    # -------------------------------------------------------------- accounting
    def rpc_report(self) -> Dict[str, int]:
        """Per-operation RPC/round-trip counters for the whole deployment.

        ``wire_round_trips`` counts every RPC issued on the wire (a
        batched transfer counts once).  The ``dht_*`` entries break the
        metadata plane down: ``dht_get_keys`` is what a per-node read
        path would have paid in round trips, ``dht_get_rounds`` is the
        number of batched latency waves actually paid, and
        ``dht_get_shard_rpcs`` the per-shard requests those waves fanned
        out into.  ``provider_read_rounds``/``provider_read_pages`` are
        the data-plane analogue, and
        ``provider_write_rounds``/``provider_write_pages`` the
        write-side mirror (page-replica stores vs batched per-endpoint
        store round trips).

        ``vm_*`` exposes the version-manager control plane:
        ``vm_ops`` logical verbs, ``vm_round_trips`` control RPCs
        actually paid (a batched ``assign_versions_many`` /
        ``metadata_complete_many`` counts once — ``vm_ops /
        vm_round_trips`` is the write plane's amortization factor),
        ``vm_batched_ops`` the verbs that rode batches, plus per-verb
        batch counts.

        Cache-hit vs RPC accounting: requests served by the read-path
        caches never count as RPCs.  ``page_cache_*`` exposes the shared
        page cache's counters; ``node_cache_hits``/``_hit_bytes`` are
        the deployment-wide metadata-cache hits every client's
        :class:`~repro.core.cache.NodeCache` reports into
        ``dht_get_keys_cached`` (deterministic and monotone — the
        service deliberately keeps no client registry); and
        ``wire_local_hit_bytes`` is the byte volume page-cache hits
        kept off the wire (compare with ``storage_report()['wire_bytes']``).

        ``dedup_*`` exposes the content-hash index's handshake:
        ``dedup_lookup_rounds`` batched digest probes (≤1 per write
        burst), ``dedup_hits``/``dedup_hit_bytes`` pages (and payload
        bytes) that matched and never shipped, ``dedup_registered`` new
        entries, ``dedup_released``/``dedup_dropped`` the GC-side
        refcount traffic.

        Every counter family lives in one registry (see
        ``_counter_families``), so ``rpc_report`` and
        ``reset_rpc_counters`` can never drift apart — a family present
        in one is present in the other, which ``tests/test_dedup.py``
        asserts key-for-key.
        """
        report: Dict[str, int] = {}
        for prefix, get, _reset in self._counter_families():
            for k, v in get().items():
                report[f"{prefix}{k}"] = v
        # Derived entries (no reset of their own; zeroed via dht_*):
        cached_keys = report["dht_get_keys_cached"]
        report["node_cache_hits"] = cached_keys
        report["node_cache_hit_bytes"] = cached_keys * self.dht.node_nbytes
        return report

    def _counter_families(self):
        """The single registry of every RPC/cache counter family:
        ``(report_prefix, get_counters, reset_counters)`` per family.
        Late-bound through ``self`` so :meth:`restore`'s version-manager
        replacement is picked up automatically."""
        return [
            ("wire_", lambda: {
                "round_trips": self.wire.total_round_trips(),
                "local_hits": self.wire.total_local_hits(),
                "local_hit_bytes": self.wire.total_local_hit_bytes(),
            }, self.wire.reset_accounting),
            ("dht_", lambda: self.dht.rpc_counters(),
             lambda: self.dht.reset_rpc_counters()),
            ("vm_", lambda: self.vm.rpc_counters(),
             lambda: self.vm.reset_rpc_counters()),
            ("provider_", lambda: self.pm.rpc_counters(),
             lambda: self.pm.reset_counters()),
            ("page_cache_", lambda: self.page_cache.counters(),
             lambda: self.page_cache.reset_counters()),
            ("dedup_", lambda: self.dedup_index.rpc_counters(),
             lambda: self.dedup_index.reset_rpc_counters()),
            ("watch_", lambda: self.vm.watch_counters(),
             lambda: self.vm.reset_watch_counters()),
            ("cache_push_", lambda: self.cache_invalidation.counters(),
             lambda: self.cache_invalidation.reset_counters()),
            ("monitor_", lambda: {"errors": self._monitor_errors},
             lambda: setattr(self, "_monitor_errors", 0)),
        ]

    def reset_rpc_counters(self) -> None:
        """Zero every RPC/cache counter (cache *contents* are kept —
        a counter reset brackets a measurement, it must not change the
        wire schedule).  Per-client ``NodeCache`` counters are the
        clients' own; the deployment-level view they feed
        (``dht_get_keys_cached``) is reset here.  Iterates the same
        registry ``rpc_report`` reads, so no family can be reported but
        not reset (or vice versa)."""
        for _prefix, _get, reset in self._counter_families():
            reset()

    def storage_report(self) -> Dict[str, object]:
        """Deployment-wide space accounting: provider count, stored page
        replicas and bytes, metadata keys, and total bytes that crossed
        the wire (cache hits excluded — see ``rpc_report``)."""
        provs = self.pm.all_providers()
        return {
            "providers": len(provs),
            "pages": sum(p.page_count() for p in provs),
            "page_bytes": sum(p.stored_bytes() for p in provs),
            "metadata_nodes": self.dht.total_keys(),
            "wire_bytes": self.wire.total_bytes(),
        }
