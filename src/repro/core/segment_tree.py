"""The distributed versioned segment tree (paper §4).

Each snapshot version ``v`` of a blob has a *virtual* binary segment
tree over the page range ``[0, root_pages(v))``.  A node is keyed by
``(owner_blob, version, offset, size)`` (offset/size in pages); inner
nodes store the versions of their two children (``vl``, ``vr``), leaves
store the page id and its replica providers.  Trees of successive
snapshots share every subtree whose range does not intersect the update
that produced the newer snapshot — the "weaving" of new metadata with
old metadata that gives copy-on-write versioning.

This module implements, faithfully:

* ``read_meta``  — Algorithm 3 (READ_META): descend from the snapshot
  root, explore children intersecting the requested range, collect page
  descriptors from the leaves.
* ``build_meta`` — Algorithm 4 (BUILD_META): build the new tree
  bottom-up from the freshly written leaves, wiring border children
  (subtrees outside the update range) to the versions resolved by a
  :class:`BorderResolver`.
* ``BorderResolver`` — §4.2's two-source border lookup: ranges touched
  by *concurrent, not-yet-published* updates are resolved from the
  version manager's in-flight registry (handed to the writer at version
  assignment), everything else by descending the latest *published*
  snapshot's tree with ``GET_NODE``.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.dht import MetadataDHT
from repro.core.pages import (
    UpdateExtent,
    intersects,
    iter_created_nodes,
    node_children,
    node_parent,
)

# A node key in the DHT: (owner_blob_id, version, page_offset, page_size).
NodeKey = Tuple[str, int, int, int]

# Resolves a version number to the blob id that owns its tree nodes.
# Branch lineage: versions <= branch point belong to the ancestor blob.
OwnerFn = Callable[[int], str]


@dataclass(frozen=True)
class InnerNode:
    """Inner tree node: versions of the left/right children.

    ``None`` marks a child range that has never been written (it lies
    beyond the blob's size inside the power-of-two root range); READ
    never descends there because reads are bounds-checked upfront.
    """

    vl: Optional[int]
    vr: Optional[int]


@dataclass(frozen=True)
class LeafNode:
    """Leaf: one page. ``providers`` lists the replica endpoints."""

    page_id: str
    providers: Tuple[str, ...]
    length: int  # actual stored bytes (the blob's last page may be short)


@dataclass(frozen=True)
class PageDescriptor:
    """Element of the PD set of Algorithms 1/2."""

    page_index: int  # absolute page number within the blob
    page_id: str
    providers: Tuple[str, ...]
    length: int


class MetadataMissing(RuntimeError):
    """A tree node expected to exist was not found in the DHT."""


def _get_many(dht, keys: List[NodeKey], peer: Optional[str]):
    """Batched node fetch; falls back to per-key gets for plain dicts
    or other stores without a ``get_many``."""
    getter = getattr(dht, "get_many", None)
    if getter is None:
        return {key: dht.get(key, peer=peer) for key in keys}
    return getter(keys, peer=peer)


# ---------------------------------------------------------------------------
# Algorithm 3 — READ_META
# ---------------------------------------------------------------------------


def read_meta(
    dht: MetadataDHT,
    owner_of: OwnerFn,
    version: int,
    root_pages: int,
    p0: int,
    p1: int,
    peer: Optional[str] = None,
) -> List[PageDescriptor]:
    """Collect page descriptors covering pages ``[p0, p1)`` of a snapshot.

    Faithful to Algorithm 3 (explore exactly the subtrees whose range
    intersects the requested range), but traversed *level-synchronously*:
    the whole frontier of one tree level is fetched with a single
    ``get_many`` (one batched round trip per touched shard), so a read
    costs at most ``depth + 1`` latency waves instead of one serial DHT
    round trip per visited node.  Every update creates its own root, so
    the snapshot root is node ``(version, 0, root_pages)``.
    """
    if p0 >= p1:
        return []
    out: List[PageDescriptor] = []
    frontier: List[Tuple[int, int, int]] = [(version, 0, root_pages)]
    while frontier:
        keys = [(owner_of(v), v, off, size) for v, off, size in frontier]
        nodes = _get_many(dht, keys, peer)
        nxt: List[Tuple[int, int, int]] = []
        for (v, off, size), key in zip(frontier, keys):
            node = nodes.get(key)
            if node is None:
                raise MetadataMissing(f"node v={v} range=({off},{size})")
            if isinstance(node, LeafNode):
                out.append(PageDescriptor(off, node.page_id, node.providers,
                                          node.length))
                continue
            (lo, ls), (ro, rs) = node_children(off, size)
            if node.vl is not None and intersects(lo, lo + ls, p0, p1):
                nxt.append((node.vl, lo, ls))
            if node.vr is not None and intersects(ro, ro + rs, p0, p1):
                nxt.append((node.vr, ro, rs))
        frontier = nxt
    out.sort(key=lambda d: d.page_index)
    return out


# ---------------------------------------------------------------------------
# §4.2 — border-set resolution
# ---------------------------------------------------------------------------


def border_ranges(extent: UpdateExtent) -> List[Tuple[int, int]]:
    """Every border range BUILD_META will ask a resolver for, upfront.

    An update creates exactly the tree nodes whose range intersects its
    page extent (``pages.iter_created_nodes``); the *border set* is the
    sibling range of every created node whose sibling the update does
    NOT create.  Both facts are pure tree-shape math on
    ``(p0, p1, root_pages)`` — no DHT traffic — which is why the
    version manager's :class:`~repro.core.version_manager.AssignInfo`
    is enough context for a writer to call
    :meth:`BorderResolver.prefetch` on this set *before* the weave
    starts: all levels' border descents then run as ONE level-batched
    ``resolve_many`` cohort (≤ depth waves total) instead of one cohort
    per tree level, and ``build_meta``'s own lookups become pure cache
    hits.
    """
    out: List[Tuple[int, int]] = []
    for off, size in iter_created_nodes(extent):
        if size >= extent.root_pages:
            continue  # the root has no sibling
        p_off, p_size, is_left = node_parent(off, size)
        (lo, ls), (ro, rs) = node_children(p_off, p_size)
        sib = (ro, rs) if is_left else (lo, ls)
        if not extent.creates_node(*sib):
            out.append(sib)
    return list(dict.fromkeys(out))


_DESCEND = object()  # sentinel: border range needs a published-tree descent


class BorderResolver:
    """Resolve the snapshot version owning any range outside the update.

    ``recent_updates``: every update with version in ``(vp, vw)``
    — published or not by now — as ``(version, p0, p1)``, newest first.
    This is exactly the information the version manager registers at
    version-assignment time (paper §4.2: the VM "will build the partial
    set of border nodes and provide it to the writer"); ranges touched
    by it resolve locally with zero DHT traffic, which is also what
    makes burst writers (``BlobClient.append_many``) weave against
    their own in-flight versions for free.

    ``vp``/``vp_root_pages``: a recently published snapshot used to
    resolve all remaining border ranges by descending its tree.
    Descents are level-batched and shared across the cohort
    (:meth:`resolve_many`); the pipelined write path calls
    :meth:`prefetch` with :func:`border_ranges` so the whole update's
    border set costs ≤ tree-depth batched waves, resolved before
    BUILD_META starts.  Results are cached for the resolver's lifetime
    (one update), so repeated lookups are free.
    """

    def __init__(
        self,
        dht: MetadataDHT,
        owner_of: OwnerFn,
        recent_updates: Sequence[Tuple[int, int, int]],
        vp: Optional[int],
        vp_root_pages: int,
        peer: Optional[str] = None,
    ) -> None:
        self.dht = dht
        self.owner_of = owner_of
        self.recent = sorted(recent_updates, key=lambda r: -r[0])
        self.vp = vp
        self.vp_root_pages = vp_root_pages
        self.peer = peer
        self._cache: Dict[Tuple[int, int], Optional[int]] = {}

    def resolve(self, off: int, size: int) -> Optional[int]:
        """Version of the node covering pages ``[off, off+size)``.

        Highest version < vw whose update range intersects the node
        range; ``None`` if the range was never written.
        """
        return self.resolve_many([(off, size)])[(off, size)]

    def prefetch(self, ranges: Sequence[Tuple[int, int]]) -> None:
        """Warm the resolver for every range BUILD_META will need.

        ``ranges`` is normally :func:`border_ranges` of the update's
        extent — computable from the :class:`AssignInfo` alone, before
        any page store or metadata put.  All published-tree descents
        run as one level-batched :meth:`resolve_many` cohort (shared
        ``get_many`` waves, ≤ tree depth rounds for the *entire* border
        set), after which ``build_meta``'s per-level lookups are pure
        cache hits — the weave pays zero border round trips of its own.
        """
        if ranges:
            self.resolve_many(ranges)

    def resolve_many(
        self, ranges: Sequence[Tuple[int, int]]
    ) -> Dict[Tuple[int, int], Optional[int]]:
        """Resolve many border ranges with shared batched descents.

        All ranges that need the published tree descend it together,
        level-synchronously: at each step the distinct nodes the whole
        cohort needs are fetched with one ``get_many`` (targets sitting
        on the same node share a single key), so one BUILD_META level's
        border set costs at most ``depth`` batched rounds — not one
        serial descent per border node.
        """
        out: Dict[Tuple[int, int], Optional[int]] = {}
        # position of each still-descending target in the published tree
        pos: Dict[Tuple[int, int], Tuple[int, int, int]] = {}
        for key in dict.fromkeys(ranges):
            if key in self._cache:
                out[key] = self._cache[key]
                continue
            off, size = key
            v = self._resolve_local(off, size)
            if v is not _DESCEND:
                self._cache[key] = v
                out[key] = v
                continue
            pos[key] = (self.vp, 0, self.vp_root_pages)

        while pos:
            done = [k for k, (v, o, s) in pos.items() if (o, s) == k]
            for k in done:
                v = pos.pop(k)[0]
                self._cache[k] = v
                out[k] = v
            if not pos:
                break
            keys = list(dict.fromkeys(
                (self.owner_of(v), v, o, s) for v, o, s in pos.values()
            ))
            nodes = _get_many(self.dht, keys, self.peer)
            for target, (v, o, s) in list(pos.items()):
                node = nodes.get((self.owner_of(v), v, o, s))
                if node is None:
                    raise MetadataMissing(f"border descent v={v} range=({o},{s})")
                if isinstance(node, LeafNode):
                    raise MetadataMissing(
                        f"border descent hit leaf above target range {target}"
                    )
                off, size = target
                (lo, ls), (ro, rs) = node_children(o, s)
                if off >= lo and off + size <= lo + ls:
                    v, o, s = node.vl, lo, ls
                elif off >= ro and off + size <= ro + rs:
                    v, o, s = node.vr, ro, rs
                else:
                    raise MetadataMissing(
                        f"range ({off},{size}) not aligned under ({o},{s})"
                    )
                if v is None:
                    del pos[target]
                    self._cache[target] = None
                    out[target] = None
                else:
                    pos[target] = (v, o, s)
        return out

    def _resolve_local(self, off: int, size: int):
        """Resolve without DHT traffic; ``_DESCEND`` if the published
        tree must be consulted."""
        # 1. concurrent / recent updates (registry info, no DHT traffic)
        for u, q0, q1 in self.recent:
            if intersects(off, off + size, q0, q1):
                return u
        # 2. descend the published tree
        if self.vp is None:
            return None
        if off + size > self.vp_root_pages:
            # Beyond the published root and not touched by any recent
            # update: never written.
            return None
        return _DESCEND


# ---------------------------------------------------------------------------
# Algorithm 4 — BUILD_META
# ---------------------------------------------------------------------------


def build_meta(
    dht: MetadataDHT,
    owner_of: OwnerFn,
    vw: int,
    root_pages: int,
    leaves: Sequence[PageDescriptor],
    border: BorderResolver,
    peer: Optional[str] = None,
) -> int:
    """Build + store the tree for snapshot ``vw``; returns #nodes written.

    Bottom-up construction per Algorithm 4: start from the new leaves,
    create each parent once, wiring the child on the update side to
    ``vw`` and the other child to the version resolved by ``border``.
    Each level first *collects* every unresolved border range and hands
    them to ``border.resolve_many`` as one cohort (shared batched
    descents), instead of one serial descent per border node; a caller
    that already ran ``border.prefetch(border_ranges(extent))`` (the
    pipelined write path — see ``BlobClient._update``) pays zero border
    round trips here, because every per-level cohort hits the
    resolver's cache.  All nodes are then written to the DHT in one
    ``put_many`` (the paper writes them in parallel; under a virtual
    clock the per-shard batches genuinely overlap).
    """
    if not leaves:
        raise ValueError("update with no pages")
    blob = owner_of(vw)
    nodes: Dict[Tuple[int, int], object] = {}
    for d in leaves:
        nodes[(d.page_index, 1)] = LeafNode(d.page_id, tuple(d.providers), d.length)

    frontier = sorted(nodes.keys())
    while frontier:
        # Plan this level: which parents to create, which of their
        # children the update supplies (the rest are border ranges).
        plans: Dict[Tuple[int, int], List[bool]] = {}  # pkey -> [has_l, has_r]
        for off, size in frontier:
            if size >= root_pages:
                continue  # reached the root
            if off % (2 * size) == 0:
                p_off, p_size, pos_left = off, 2 * size, True
            else:
                p_off, p_size, pos_left = off - size, 2 * size, False
            plan = plans.setdefault((p_off, p_size), [False, False])
            plan[0 if pos_left else 1] = True

        need: List[Tuple[int, int]] = []
        for (p_off, p_size), (has_l, has_r) in plans.items():
            (lo, ls), (ro, rs) = node_children(p_off, p_size)
            if has_l and not has_r:
                need.append((ro, rs))
            elif has_r and not has_l:
                need.append((lo, ls))
        resolved = border.resolve_many(need)

        nxt: List[Tuple[int, int]] = []
        for pkey in sorted(plans):
            has_l, has_r = plans[pkey]
            (lo, ls), (ro, rs) = node_children(*pkey)
            nodes[pkey] = InnerNode(
                vl=vw if has_l else resolved[(lo, ls)],
                vr=vw if has_r else resolved[(ro, rs)],
            )
            nxt.append(pkey)
        frontier = nxt

    if (0, root_pages) not in nodes:
        raise AssertionError("BUILD_META did not reach the root")

    # "write N to the metadata provider" for all nodes in parallel
    # (Alg 4 line 34): batched per home shard.
    dht.put_many(
        [((blob, vw, off, size), node) for (off, size), node in nodes.items()],
        peer=peer,
    )
    return len(nodes)
