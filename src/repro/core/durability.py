"""Self-healing durability plane: scrub, repair, and lifecycle tiering.

The paper defers "volatility and failures" to future work; the repo's
only recovery primitive so far was the manual ``rereplicate_from``.
This module adds the background maintenance plane a real deployment
runs continuously on the simulated clock:

* :func:`scrub_round` — one verification + repair pass.  Every
  provider re-digests its stored pages in place
  (``DataProvider.verify_pages``, the host twin of the ``page_digest``
  Pallas kernel) and reports corruption; the version manager's
  durability inventory (``vm.page_locations``) is diffed against what
  providers actually hold to find dead-provider gaps and missing
  copies.  Damage is repaired **over the wire** under a per-round byte
  budget: replicated pages re-copy from a surviving replica,
  erasure-coded pages read any ``k`` live shards, decode, and re-encode
  exactly the lost shards.  Pages with no recoverable copy are returned
  as ``losses`` — never an exception; a scrub must always finish its
  sweep.

* :func:`lifecycle_round` — per-blob age-based demotion to the cold
  tier (``BlobSeerService.set_lifecycle``): pages older than the blob's
  threshold move from hot providers to S3-class cold endpoints.

Both passes generalize the PR 4 cache-bypass rule: maintenance reads go
*directly* to providers, never through the shared ``PageCache``, so
repair traffic cannot evict the readers' hot set or pollute hit/miss
accounting.  Both move bytes without rewriting published (immutable)
descriptors — moves land in the provider manager's **relocation
overlay**, which the read path consults once a descriptor's replica
list is exhausted, and the dedup index is refreshed in one batched
``refresh_providers`` verb so content-hash hits stop handing out dead
endpoints.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Set, Tuple

from repro.core.placement import (
    SHARD_HDR_BYTES,
    ec_decode,
    ec_encode,
    page_codec,
    shard_id,
)
from repro.core.provider import PageIntegrityError
from repro.core.transport import EndpointDown

# Anything a repair read can hit mid-flight: the endpoint died, the copy
# vanished, or the copy is corrupt despite the probe snapshot saying
# otherwise.  All transient from the scrubber's view — defer the page.
_REPAIR_ERRORS = (EndpointDown, KeyError, PageIntegrityError)

# Default per-round repair budget: enough for a handful of 64 KiB pages
# per pass — repair converges over rounds instead of bursting.
DEFAULT_SCRUB_BUDGET = 8 * 1024 * 1024


def _shard_bytes(length: int, k: int) -> int:
    return SHARD_HDR_BYTES + max(1, -(-length // k))


def _alive(svc, pid: str) -> bool:
    return not svc.wire.is_down(pid)


def _provider(svc, pid: str):
    try:
        return svc.pm.get(pid)
    except KeyError:
        return None


def _pick_target(svc, exclude: Set[str]):
    """Least-loaded alive hot provider outside ``exclude`` (repair
    target selection; pid tie-break keeps replays deterministic)."""
    pool = [p for p in svc.pm.placement_pool() if p.pid not in exclude]
    if not pool:
        return None
    return min(pool, key=lambda p: (p.page_count(), p.pid))


def _restore_copy(svc, prov, phys: str, payload: bytes, peer: str) -> None:
    """Overwrite-safe re-store: drop the (possibly corrupt) copy first —
    stores reject a same-id put with different bytes."""
    prov.delete_pages([phys], peer=peer)
    prov.put_pages([(phys, payload)], peer=peer)


def scrub_round(
    svc,
    *,
    budget_bytes: int = DEFAULT_SCRUB_BUDGET,
    peer: str = "scrubber",
) -> Dict[str, object]:
    """One scrub/repair pass over the whole deployment.

    Returns a stats dict: ``pages_checked`` (logical pages in the
    inventory), ``providers_probed``, ``corrupt_copies`` /
    ``missing_copies`` (physical damage found), ``damaged_pages``,
    ``repaired_pages`` / ``repaired_copies`` / ``repair_bytes`` (what
    this round fixed and what it cost the wire), ``deferred_pages``
    (damage left for the next round — budget exhausted or a transient
    failure mid-repair), and ``losses`` (logical page ids with no
    recoverable copy: fewer than ``k`` shards / zero replicas).

    ``budget_bytes`` caps the round's repair traffic (reads + writes);
    a repair whose estimate does not fit is deferred, so
    ``repair_bytes <= budget_bytes`` always holds.  Detection traffic
    (inventory listings, digest probes) is not budgeted — it is cheap
    and must run to completion for losses to be trustworthy.
    """
    inventory = svc.vm.page_locations()
    stats: Dict[str, object] = {
        "pages_checked": len(inventory),
        "providers_probed": 0,
        "corrupt_copies": 0,
        "missing_copies": 0,
        "damaged_pages": 0,
        "repaired_pages": 0,
        "repaired_copies": 0,
        "repair_bytes": 0,
        "deferred_pages": 0,
        "losses": [],
    }

    # ---- probe: what does each provider actually hold, and is it sane?
    present: Dict[str, Set[str]] = {}
    corrupt: Dict[str, Set[str]] = {}
    reachable: Set[str] = set()
    for prov in sorted(svc.pm.all_providers(), key=lambda p: p.pid):
        if svc.wire.is_down(prov.pid):
            continue
        try:
            listing = prov.list_pages(peer=peer)
            bad = prov.verify_pages(peer=peer)
        except EndpointDown:
            continue  # died between the is_down check and the probe
        present[prov.pid] = {pid for pid, _at in listing}
        corrupt[prov.pid] = set(bad)
        reachable.add(prov.pid)
        stats["providers_probed"] += 1

    def copy_state(holder: str, phys: str) -> str:
        """healthy | corrupt | missing | dead (holder unreachable)."""
        if holder not in reachable:
            return "dead"
        if phys in corrupt[holder]:
            return "corrupt"
        if phys not in present[holder]:
            return "missing"
        return "healthy"

    # ---- diff + repair, page by page, deterministic order
    spent = 0
    refreshed: List[Tuple[str, Tuple[str, ...]]] = []
    for pid in sorted(inventory):
        _blob, provs, length = inventory[pid]
        codec = page_codec(pid)
        try:
            if codec is None:
                result = _scrub_replicated(
                    svc, pid, provs, copy_state, stats, peer,
                    budget_bytes - spent)
            else:
                result = _scrub_ec(
                    svc, pid, codec, provs, length, copy_state, stats,
                    peer, budget_bytes - spent)
        except _REPAIR_ERRORS:
            # a provider died (or a copy changed) mid-repair: leave the
            # page for the next round
            stats["deferred_pages"] += 1
            continue
        if result is None:
            continue
        copies, nbytes, new_locs = result
        spent += nbytes
        stats["repair_bytes"] += nbytes
        if copies:
            stats["repaired_pages"] += 1
            stats["repaired_copies"] += copies
            svc.pm.note_repair(copies, nbytes)
        if new_locs is not None:
            refreshed.append((pid, new_locs))

    # ---- stale-descriptor hygiene: one batched dedup refresh
    if refreshed and getattr(svc.dedup_index, "ever_registered", False):
        svc.dedup_index.refresh_providers(refreshed, peer=peer)
    return stats


def _scrub_replicated(
    svc, pid: str, provs: Tuple[str, ...], copy_state, stats,
    peer: str, budget_left: int,
) -> Optional[Tuple[int, int, Optional[Tuple[str, ...]]]]:
    """Diff + repair one replicated page.  Returns
    ``(copies_restored, bytes_moved, new_locations_or_None)`` or None
    when the page is healthy/lost/deferred (stats updated in place)."""
    overlay = svc.pm.relocated(pid)
    holders = list(overlay) if overlay else list(dict.fromkeys(provs))
    states = {h: copy_state(h, pid) for h in holders}
    healthy = [h for h in holders if states[h] == "healthy"]
    damaged = [h for h in holders if states[h] != "healthy"]
    stats["corrupt_copies"] += sum(
        1 for h in damaged if states[h] == "corrupt")
    stats["missing_copies"] += sum(
        1 for h in damaged if states[h] in ("missing", "dead"))
    if not damaged:
        return None
    stats["damaged_pages"] += 1
    if not healthy:
        stats["losses"].append(pid)
        return None
    # read once (direct, cache-bypass), restore every damaged copy
    src = _provider(svc, healthy[0])
    if src is None:
        stats["deferred_pages"] += 1
        return None
    payload = src.get_page(pid, peer=peer)
    est = len(payload) * (1 + len(damaged))
    if est > budget_left:
        stats["deferred_pages"] += 1
        return None
    new_holders = list(healthy)
    copies = 0
    for h in damaged:
        prov = _provider(svc, h)
        if prov is not None and h in {p.pid for p in svc.pm.alive_providers()}:
            # live holder lost/corrupted the copy: restore it in place
            _restore_copy(svc, prov, pid, payload, peer)
            new_holders.append(h)
        else:
            target = _pick_target(svc, exclude=set(new_holders))
            if target is None:
                continue
            target.put_pages([(pid, payload)], peer=peer)
            new_holders.append(target.pid)
        copies += 1
    if copies == 0:
        stats["deferred_pages"] += 1
        return None
    moved = tuple(new_holders)
    changed = set(moved) != set(dict.fromkeys(provs))
    if changed or overlay:
        svc.pm.record_relocation(pid, moved)
    nbytes = len(payload) * (1 + copies)
    return copies, nbytes, (moved if changed else None)


def _scrub_ec(
    svc, pid: str, codec: Tuple[int, int], provs: Tuple[str, ...],
    length: int, copy_state, stats, peer: str, budget_left: int,
) -> Optional[Tuple[int, int, Optional[Tuple[str, ...]]]]:
    """Diff + repair one erasure-coded page (k data + m parity shards)."""
    k, m = codec
    homes: List[Optional[str]] = [
        provs[j] if j < len(provs) else None for j in range(k + m)]
    serving: Dict[int, str] = {}
    damaged: Dict[int, Optional[str]] = {}
    for j in range(k + m):
        sid = shard_id(pid, j)
        overlay = svc.pm.relocated(sid)
        holder = overlay[0] if overlay else homes[j]
        state = copy_state(holder, sid) if holder else "missing"
        if state == "healthy":
            serving[j] = holder
        else:
            damaged[j] = holder
            if state == "corrupt":
                stats["corrupt_copies"] += 1
            else:
                stats["missing_copies"] += 1
    if not damaged:
        return None
    stats["damaged_pages"] += 1
    if len(serving) < k:
        stats["losses"].append(pid)
        return None
    slen = _shard_bytes(length, k)
    est = k * slen + len(damaged) * slen
    if est > budget_left:
        stats["deferred_pages"] += 1
        return None
    # read any k live shards (direct, cache-bypass), decode, re-encode
    got: List[Tuple[int, bytes]] = []
    read_bytes = 0
    for j in sorted(serving):
        if len(got) >= k:
            break
        prov = _provider(svc, serving[j])
        if prov is None:
            continue
        try:
            raw = prov.get_page(shard_id(pid, j), peer=peer)
        except _REPAIR_ERRORS:
            continue
        got.append((j, raw))
        read_bytes += len(raw)
    if len(got) < k:
        stats["deferred_pages"] += 1
        return None
    payload = ec_decode(got, k, m)
    fresh = ec_encode(payload, k, m)
    new_homes = list(homes)
    for j in serving:
        new_homes[j] = serving[j]
    copies = 0
    written = 0
    alive_pids = {p.pid for p in svc.pm.alive_providers()}
    for j in sorted(damaged):
        sid = shard_id(pid, j)
        holder = damaged[j]
        prov = _provider(svc, holder) if holder else None
        if prov is not None and holder in alive_pids:
            _restore_copy(svc, prov, sid, fresh[j], peer)
            target_pid = holder
        else:
            # shards must stay on distinct providers or parity is void
            exclude = {h for h in new_homes if h} - {holder or ""}
            target = _pick_target(svc, exclude=exclude)
            if target is None:
                continue
            target.put_pages([(sid, fresh[j])], peer=peer)
            target_pid = target.pid
        written += len(fresh[j])
        copies += 1
        new_homes[j] = target_pid
        if target_pid != homes[j]:
            svc.pm.record_relocation(sid, (target_pid,))
    if copies == 0:
        stats["deferred_pages"] += 1
        return None
    moved = tuple(h for h in new_homes if h is not None)
    changed = len(moved) == k + m and list(moved) != list(provs[:k + m])
    return copies, read_bytes + written, (moved if changed else None)


def lifecycle_round(
    svc,
    *,
    budget_bytes: Optional[int] = None,
    peer: str = "lifecycle",
) -> Dict[str, int]:
    """One lifecycle pass: demote aged pages to the cold tier.

    For every blob with a registered lifecycle
    (``BlobSeerService.set_lifecycle``), each physical copy older than
    the blob's ``demote_after`` threshold moves from its hot provider
    to the least-loaded cold endpoint: read direct, put cold, delete
    hot, record the move in the relocation overlay (published
    descriptors are immutable — reads find the cold copy through
    ``ProviderManager.locate`` after the descriptor's replicas miss).
    EC shards demote individually; replicated pages converge to ONE
    cold copy (cold durability is the object store's own).

    The reverse transition (ROADMAP item 1 follow-up): for blobs with a
    ``promote_reads`` threshold (``set_lifecycle(..., promote_reads=N)``)
    a cold page whose served-read tally (``ProviderManager.read_tallies``)
    reached ``N`` moves back to a hot ring owner — repeated access
    un-demotes, so a working set that turns hot again stops paying the
    cold path on every read.  Returns ``{"demoted", "demoted_bytes",
    "promoted", "promoted_bytes", "deferred"}``.
    """
    stats = {"demoted": 0, "demoted_bytes": 0,
             "promoted": 0, "promoted_bytes": 0, "deferred": 0}
    if not svc.lifecycles:
        return stats
    cold_pool = sorted(
        (p for p in svc.pm.all_providers()
         if getattr(p, "tier", "hot") == "cold"
         and not svc.wire.is_down(p.pid)),
        key=lambda p: p.pid)
    if not cold_pool:
        return stats
    blob_of: Dict[str, str] = {}
    for pid, (blob, _provs, _length) in svc.vm.page_locations().items():
        if blob in svc.lifecycles:
            blob_of[pid] = blob
    if not blob_of:
        return stats
    now = svc.clock.now()
    spent = 0
    refreshed: List[Tuple[str, Tuple[str, ...]]] = []
    from repro.core.placement import logical_pid

    for prov in sorted(svc.pm.all_providers(), key=lambda p: p.pid):
        if getattr(prov, "tier", "hot") != "hot" or svc.wire.is_down(prov.pid):
            continue
        try:
            listing = prov.list_pages(peer=peer)
        except EndpointDown:
            continue
        for phys, stored_at in sorted(listing):
            logical = logical_pid(phys)
            blob = blob_of.get(logical)
            if blob is None or now - stored_at < svc.lifecycles[blob]:
                continue
            payload = prov.store.get(phys)
            if payload is None:
                continue
            if budget_bytes is not None and spent + 2 * len(payload) > budget_bytes:
                stats["deferred"] += 1
                continue
            cold = min(cold_pool, key=lambda p: (p.page_count(), p.pid))
            try:
                # demotion is a wire move: read out of the hot endpoint,
                # write into the cold one, then drop the hot copy
                data = prov.get_page(phys, peer=peer)
                cold.put_pages([(phys, data)], peer=peer)
                prov.delete_pages([phys], peer=peer)
            except EndpointDown:
                stats["deferred"] += 1
                continue
            svc.pm.record_relocation(phys, (cold.pid,))
            if phys == logical:  # replicated page: refresh dedup descriptor
                refreshed.append((logical, (cold.pid,)))
            nbytes = 2 * len(data)
            spent += nbytes
            stats["demoted"] += 1
            stats["demoted_bytes"] += nbytes
            svc.pm.note_repair(0, nbytes)

    # ---- cold -> hot promotion on repeated access
    promote_thresholds = getattr(svc, "promote_reads", {})
    if promote_thresholds:
        tallies = svc.pm.read_tallies()
        for cold in cold_pool:
            try:
                listing = cold.list_pages(peer=peer)
            except EndpointDown:
                continue
            for phys, _stored_at in sorted(listing):
                logical = logical_pid(phys)
                blob = blob_of.get(logical)
                threshold = promote_thresholds.get(blob) if blob else None
                if threshold is None or tallies.get(logical, 0) < threshold:
                    continue
                payload = cold.store.get(phys)
                if payload is None:
                    continue
                if budget_bytes is not None and \
                        spent + 2 * len(payload) > budget_bytes:
                    stats["deferred"] += 1
                    continue
                if svc.pm.ring is not None:
                    owners = svc.pm.ring_owners(
                        svc.pm.place_key(logical), 1)
                    target = _provider(svc, owners[0]) if owners else None
                else:
                    target = _pick_target(svc, exclude=set())
                if target is None:
                    stats["deferred"] += 1
                    continue
                try:
                    # promotion mirrors demotion: read cold, write hot,
                    # drop the cold copy, flip the overlay pointer
                    data = cold.get_page(phys, peer=peer)
                    target.put_pages([(phys, data)], peer=peer)
                    cold.delete_pages([phys], peer=peer)
                except EndpointDown:
                    stats["deferred"] += 1
                    continue
                svc.pm.record_relocation(phys, (target.pid,))
                if phys == logical:
                    refreshed.append((logical, (target.pid,)))
                nbytes = 2 * len(data)
                spent += nbytes
                stats["promoted"] += 1
                stats["promoted_bytes"] += nbytes
                svc.pm.note_promotion(1, nbytes)
        # the threshold is "reads since the last lifecycle pass": start
        # the next observation window now, or a once-hot page would
        # re-promote forever on a stale tally
        svc.pm.reset_read_tallies()

    if refreshed and getattr(svc.dedup_index, "ever_registered", False):
        svc.dedup_index.refresh_providers(
            list(dict.fromkeys(refreshed)), peer=peer)
    return stats
