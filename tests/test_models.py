"""Per-arch smoke tests (reduced configs) + cache-consistency properties."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.configs.shapes import SHAPES, applicable
from repro.models import build_model
from repro.models import lm as LM

RNG = jax.random.PRNGKey(0)


def _batch_for(cfg, B, T):
    batch = {
        "tokens": jax.random.randint(RNG, (B, T), 0, cfg.vocab_size),
        "labels": jax.random.randint(RNG, (B, T), 0, cfg.vocab_size),
    }
    if cfg.frontend == "vision_stub":
        batch["vision_embeds"] = 0.01 * jnp.ones(
            (B, cfg.n_frontend_tokens, cfg.d_model), jnp.float32)
    if cfg.arch_kind == "encdec":
        batch["enc_embeds"] = 0.01 * jnp.ones((B, T, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch):
    """Reduced config: one forward/train step, output shapes, no NaNs."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params, axes = model.init(RNG)
    # axes tree mirrors params tree (axes leaves are name tuples)
    assert jax.tree.structure(params) == jax.tree.structure(
        axes, is_leaf=lambda x: isinstance(x, tuple))
    # every leaf's rank matches its axes names
    jax.tree.map(lambda p, a: None if p.ndim == len(a) else 1 / 0, params,
                 jax.tree.map(lambda x: x, axes,
                              is_leaf=lambda x: isinstance(x, tuple)))
    B, T = 2, 16
    batch = _batch_for(cfg, B, T)
    loss, metrics = jax.jit(lambda p, b: model.loss_fn(p, b))(params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss)
    assert metrics["tokens"] == B * T
    # gradients exist + finite
    g = jax.grad(lambda p: model.loss_fn(p, batch)[0])(params)
    gleaves = jax.tree.leaves(g)
    assert all(jnp.isfinite(x).all() for x in gleaves)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_serve(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params, _ = model.init(RNG)
    B, T = 2, 8
    batch = _batch_for(cfg, B, T)
    batch.pop("labels")
    cache = model.init_cache(B, max_len=24)
    if cfg.arch_kind == "encdec":
        logits, cache, mem = model.prefill(params, batch, cache)
        step2 = model.decode_step(params, jnp.argmax(logits, -1).astype(jnp.int32),
                                  jnp.asarray(T), cache, mem)
    else:
        logits, cache = model.prefill(params, batch, cache)
        step2 = model.decode_step(params, jnp.argmax(logits, -1).astype(jnp.int32),
                                  jnp.asarray(T), cache)
    logits2 = step2[0]
    assert logits.shape == (B, cfg.vocab_size)
    assert logits2.shape == (B, cfg.vocab_size)
    assert jnp.isfinite(logits).all() and jnp.isfinite(logits2).all()


@pytest.mark.parametrize("arch", ["olmo-1b", "h2o-danube-3-4b",
                                  "recurrentgemma-2b", "xlstm-350m"])
def test_decode_matches_teacher_forcing(arch):
    """Cached decode must reproduce the full-forward logits."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(1))
    B, T, T0 = 2, 12, 6
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, T), 0, cfg.vocab_size)
    x = params["embed"]["table"][toks]
    full, _ = LM.apply_stack_train(params, cfg, x, jnp.arange(T))
    full_logits = LM._logits(params, cfg, full)
    cache = model.init_cache(B, max_len=T + 4)
    lg, cache = model.prefill(params, {"tokens": toks[:, :T0]}, cache)
    errs = [float(jnp.max(jnp.abs(lg - full_logits[:, T0 - 1])))]
    for t in range(T0, T):
        lg, cache = model.decode_step(params, toks[:, t], jnp.asarray(t), cache)
        errs.append(float(jnp.max(jnp.abs(lg - full_logits[:, t]))))
    assert max(errs) < 2e-2, errs


def test_remat_policies_agree():
    cfg = get_config("olmo-1b").reduced()
    model = build_model(cfg)
    params, _ = model.init(RNG)
    batch = _batch_for(cfg, 2, 16)
    l_none = model.loss_fn(params, batch, "none")[0]
    l_full = model.loss_fn(params, batch, "full")[0]
    l_dots = model.loss_fn(params, batch, "dots")[0]
    np.testing.assert_allclose(l_none, l_full, rtol=1e-6)
    np.testing.assert_allclose(l_none, l_dots, rtol=1e-6)
    # gradients agree too
    g1 = jax.grad(lambda p: model.loss_fn(p, batch, "none")[0])(params)
    g2 = jax.grad(lambda p: model.loss_fn(p, batch, "full")[0])(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=1e-6)


def test_moe_load_balance_aux_in_metrics():
    cfg = get_config("olmoe-1b-7b").reduced()
    model = build_model(cfg)
    params, _ = model.init(RNG)
    batch = _batch_for(cfg, 2, 16)
    loss, metrics = model.loss_fn(params, batch)
    assert metrics["aux"] > 0.0


def test_param_count_analytics_roughly_match():
    for arch in ["olmo-1b", "qwen3-32b", "olmoe-1b-7b"]:
        cfg = get_config(arch).reduced()
        model = build_model(cfg)
        params, _ = model.init(RNG)
        real = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
        approx = cfg.param_count()
        assert 0.5 < approx / real < 2.0, (arch, approx, real)


def test_long_500k_applicability_flags():
    sub = {a: get_config(a).sub_quadratic for a in ARCH_IDS}
    assert sub["recurrentgemma-2b"] and sub["xlstm-350m"] and sub["h2o-danube-3-4b"]
    assert not sub["qwen3-32b"] and not sub["internvl2-76b"]
    cell = SHAPES["long_500k"]
    ok, why = applicable(get_config("qwen3-32b"), cell)
    assert not ok and "quadratic" in why
