"""Training stack: optimizer math, accumulation, partitioning guards."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.distributed import partitioning as PT
from repro.launch.mesh import make_mesh
from repro.models import build_model
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update
from repro.train.step import TrainStepBuilder


def test_adamw_matches_reference_impl():
    cfg = AdamWConfig(lr=1e-2, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                      clip_norm=None, warmup_steps=0, total_steps=10**9,
                      min_lr_frac=1.0)
    params = {"w": jnp.asarray([1.0, -2.0, 3.0])}
    opt = adamw_init(params)
    g = {"w": jnp.asarray([0.1, -0.2, 0.3])}
    new_p, new_opt, _ = adamw_update(cfg, g, opt, params)
    # step 1: mhat = g, nhat = g^2 -> update = g/(|g| + eps) = sign(g)
    np.testing.assert_allclose(
        np.asarray(new_p["w"]), np.asarray(params["w"]) - 1e-2 * np.sign([0.1, -0.2, 0.3]),
        rtol=1e-5,
    )


def test_grad_clipping_bounds_norm():
    cfg = AdamWConfig(clip_norm=1.0, warmup_steps=0, total_steps=10**9)
    params = {"w": jnp.zeros(4)}
    opt = adamw_init(params)
    g = {"w": jnp.full((4,), 100.0)}
    _, _, stats = adamw_update(cfg, g, opt, params)
    assert float(stats["grad_norm"]) == pytest.approx(200.0)


def test_train_loss_decreases_memorization():
    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = get_config("olmo-1b").reduced()
    model = build_model(cfg)
    b = TrainStepBuilder(model, mesh, strategy="tp",
                         opt=AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=50),
                         remat_policy="none")
    state = b.init_state(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 17), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    ap, ax = model.abstract()
    step = b.jit_train_step(ap, ax, jax.eval_shape(lambda: batch))
    losses = []
    for _ in range(12):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.9


def test_grad_accumulation_equivalent():
    """accum=2 over a 2x batch == accum=1 on the same data (same grads)."""
    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = get_config("olmo-1b").reduced()
    model = build_model(cfg)
    opt = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=100, clip_norm=None)
    toks = jax.random.randint(jax.random.PRNGKey(1), (8, 17), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    outs = {}
    for accum in (1, 2):
        b = TrainStepBuilder(model, mesh, strategy="tp", opt=opt,
                             remat_policy="none", accum=accum)
        state = b.init_state(jax.random.PRNGKey(0))
        ap, ax = model.abstract()
        step = b.jit_train_step(ap, ax, jax.eval_shape(lambda: batch))
        state, _ = step(state, batch)
        outs[accum] = state["params"]
    for a, b_ in zip(jax.tree.leaves(outs[1]), jax.tree.leaves(outs[2])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=5e-4, atol=1e-6)


# ----------------------------------------------------------- partitioning
def test_spec_divisibility_guard():
    mesh = make_mesh((2, 4), ("data", "model")) if len(jax.devices()) >= 8 else None
    if mesh is None:
        mesh = make_mesh((1, 1), ("data", "model"))
    rules = PT.get_rules("tp")
    # 8 kv heads on a model axis of size 4 or 1 -> shards; of 16 -> drops
    spec = PT.spec_for(mesh, rules, ("embed", "kv_heads", "head"), (64, 8, 16))
    model_size = mesh.shape["model"]
    if 8 % model_size == 0:
        assert spec == P(None, "model", None)
    else:
        assert spec == P(None, None, None)


def test_spec_one_axis_per_array():
    mesh = make_mesh((1, 1), ("data", "model"))
    rules = PT.get_rules("tp")
    # two dims both mapping to "model": only the first gets it
    spec = PT.spec_for(mesh, rules, ("q_heads", "mlp"), (16, 32))
    assert spec == P("model", None)


def test_fsdp_rules_shard_embed_dim():
    rules = PT.get_rules("tp_fsdp")
    assert rules["embed"] == ("pod", "data")
    assert PT.get_rules("tp")["embed"] is None


def test_serve_rules_kv_seq_fallback():
    mesh = make_mesh((1, 1), ("data", "model"))
    rules = PT.get_rules("tp_serve")
    # kv_heads divisible -> heads sharded, seq not
    spec = PT.spec_for(mesh, rules, ("batch", "kv_heads", "kv_seq", "head"),
                       (4, 1, 64, 8))
    assert spec[2] is None or spec[1] is None  # one of them, never both


def test_logical_rules_respect_missing_mesh_axis():
    from repro.distributed import axes as AX
    mesh = make_mesh((1, 1), ("data", "model"))
    AX.set_logical_rules(PT.get_rules("tp_fsdp"), mesh)
    try:
        spec = AX.logical_to_spec(("batch", None, "embed_act"))
        assert spec == P("data", None, None)  # "pod" dropped: not in mesh
    finally:
        AX.clear_logical_rules()


def test_int8_compressed_allreduce_roundtrip():
    from repro.distributed.collectives import compressed_grad_mean
    mesh = make_mesh((1, 1), ("data", "model"))
    g = {"w": jnp.linspace(-1, 1, 256), "b": jnp.asarray([0.5])}
    out = compressed_grad_mean(g, mesh, "data", jax.random.PRNGKey(0))
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(g)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1 / 60)
