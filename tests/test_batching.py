"""The batched metadata/data plane.

Covers the read-side batching refactor: ``MetadataDHT.get_many``,
level-synchronous READ_META, shared batched border descents,
``ProviderManager.fetch_pages`` grouping, the client node cache's LRU
bound — and the failure-injection semantics (a downed shard/provider
mid-batch falls over to replicas exactly like the single-get paths).
"""

import random

import pytest

from repro.core import BlobSeerService, EndpointDown
from repro.core import segment_tree as st
from repro.core.blob import _NodeCache
from repro.core.dht import MetadataDHT
from repro.core.transport import Wire


# ---------------------------------------------------------------------------
# MetadataDHT.get_many
# ---------------------------------------------------------------------------


def _fill(dht, n=40):
    items = [(("blob", 1, i, 1), {"node": i}) for i in range(n)]
    dht.put_many(items, peer="c")
    return items


def test_get_many_matches_single_gets():
    dht = MetadataDHT(Wire(), 8)
    items = _fill(dht)
    keys = [k for k, _ in items] + [("blob", 9, 0, 1)]  # one absent key
    got = dht.get_many(keys, peer="c")
    for key in keys:
        assert got[key] == dht.get(key, peer="c")
    assert got[("blob", 9, 0, 1)] is None


def test_get_many_batches_per_shard():
    dht = MetadataDHT(Wire(), 4)
    items = _fill(dht)
    dht.reset_rpc_counters()
    dht.get_many([k for k, _ in items])
    ctr = dht.rpc_counters()
    assert ctr["get_keys"] == len(items)
    assert ctr["get_rounds"] == 1            # one batched wave
    assert ctr["get_shard_rpcs"] <= 4        # at most one RPC per shard


def test_get_many_fails_over_to_replicas_mid_batch():
    wire = Wire()
    # 10 shards, 40 keys: the keys disqualify at most 40 of the 45
    # shard pairs, so a pair that never co-owns a key always exists
    dht = MetadataDHT(wire, 10, replication=2)
    items = _fill(dht)
    # down two shards that never co-own a key, so every key keeps a
    # live replica (the pair depends on the ring layout, so compute it)
    import itertools
    owner_sets = [
        frozenset(s.shard_id for s in dht._home_shards(k)) for k, _ in items]
    for a, b in itertools.combinations(dht.shards, 2):
        if frozenset((a.shard_id, b.shard_id)) not in owner_sets:
            wire.set_down(a.shard_id, True)
            wire.set_down(b.shard_id, True)
            break
    got = dht.get_many([k for k, _ in items])
    assert got == {k: v for k, v in items}


def test_get_many_raises_when_all_replicas_down():
    wire = Wire()
    dht = MetadataDHT(wire, 3, replication=1)
    items = _fill(dht)
    for i in range(3):
        wire.set_down(f"meta-{i:04d}", True)
    with pytest.raises(EndpointDown):
        dht.get_many([items[0][0]])


def test_get_replica_hole_falls_through():
    """A partial put (one replica down at write time) leaves a hole; a
    later get that races to the holey replica must keep looking."""
    wire = Wire()
    dht = MetadataDHT(wire, 4, replication=2)
    key = ("blob", 7, 3, 1)
    primary, backup = dht._home_shards(key)
    wire.set_down(primary.shard_id, True)
    dht.put(key, {"v": 7})                 # lands only on the backup
    wire.set_down(primary.shard_id, False)
    # force the racing order to try the holey primary first
    wire.stats(backup.shard_id).sim_busy_until = 1e9
    assert dht.get(key) == {"v": 7}
    assert dht.get_many([key])[key] == {"v": 7}


# ---------------------------------------------------------------------------
# Level-synchronous READ_META + batched border descents
# ---------------------------------------------------------------------------


def test_read_meta_round_trips_bounded_by_depth():
    svc = BlobSeerService(n_providers=4, n_meta_shards=16)
    c = svc.client()
    bid = c.create(psize=64)
    c.append(bid, b"x" * 64 * 1024)        # 1024 pages -> depth 10
    v = c.get_recent(bid)
    root = svc.vm.root_pages_published(bid, v)
    svc.dht.reset_rpc_counters()
    pd = st.read_meta(svc.dht, c._owner_fn(bid), v, root, 100, 164)
    ctr = svc.dht.rpc_counters()
    assert len(pd) == 64
    assert ctr["get_rounds"] <= root.bit_length()          # <= depth + 1
    assert ctr["get_keys"] >= 5 * ctr["get_rounds"]        # >=5x vs per-node


def test_read_meta_against_plain_dict_fallback():
    """read_meta accepts any store with get(); the batched path must
    degrade gracefully when get_many is absent."""

    class DictStore(dict):
        def get(self, key, peer=None):
            return dict.get(self, key)

    svc = BlobSeerService(n_providers=4, n_meta_shards=2)
    c = svc.client()
    bid = c.create(psize=16)
    c.write(bid, bytes(range(256)), 0)
    v = c.get_recent(bid)
    root = svc.vm.root_pages_published(bid, v)
    mirror = DictStore()
    for shard in svc.dht.shards:
        mirror.update(shard._kv)
    pd = st.read_meta(mirror, c._owner_fn(bid), v, root, 0, 16)
    assert [d.page_index for d in pd] == list(range(16))


def test_batched_border_resolution_preserves_versioning():
    """Random writes/appends: every snapshot stays byte-identical to a
    flat oracle (build_meta now resolves borders level-batched)."""
    svc = BlobSeerService(n_providers=4, n_meta_shards=4)
    c = svc.client()
    bid = c.create(psize=16)
    rnd = random.Random(7)
    versions = {0: b""}
    cur = b""
    for _ in range(25):
        data = bytes([rnd.randrange(256)]) * rnd.randrange(1, 70)
        if not cur or rnd.random() < 0.5:
            c.append(bid, data)
            cur = cur + data
        else:
            off = rnd.randrange(0, len(cur))
            c.write(bid, data, off)
            buf = bytearray(cur)
            buf[off : off + len(data)] = data
            cur = bytes(buf)
        versions[max(versions) + 1] = cur
    for v, want in versions.items():
        if v == 0:
            continue
        assert c.read(bid, v, 0, len(want)) == want
        # a cold client (no node cache) agrees
    cold = svc.client()
    top = max(versions)
    assert cold.read(bid, top, 0, len(versions[top])) == versions[top]


# ---------------------------------------------------------------------------
# ProviderManager.fetch_pages
# ---------------------------------------------------------------------------


def test_fetch_pages_matches_fetch_page():
    svc = BlobSeerService(n_providers=4, n_meta_shards=2)
    c = svc.client()
    bid = c.create(psize=32)
    v = c.write(bid, bytes(range(128)), 0)
    pd = st.read_meta(svc.dht, c._owner_fn(bid), v,
                      svc.vm.root_pages_published(bid, v), 0, 4)
    reqs = [(d.providers, d.page_id, 1, 7) for d in pd]
    batched = svc.pm.fetch_pages(reqs)
    singles = [svc.pm.fetch_page(d.providers, d.page_id, 1, 7) for d in pd]
    assert batched == singles


def test_fetch_pages_groups_per_provider():
    svc = BlobSeerService(n_providers=2, n_meta_shards=2)
    c = svc.client()
    bid = c.create(psize=32)
    v = c.write(bid, b"p" * 32 * 8, 0)     # 8 pages over 2 providers
    pd = st.read_meta(svc.dht, c._owner_fn(bid), v,
                      svc.vm.root_pages_published(bid, v), 0, 8)
    before = svc.wire.total_round_trips()
    svc.pm.fetch_pages([(d.providers, d.page_id, 0, None) for d in pd])
    # 8 pages on 2 endpoints -> 2 batched round trips, not 8
    assert svc.wire.total_round_trips() - before == 2


def test_fetch_pages_fails_over_mid_batch():
    svc = BlobSeerService(n_providers=4, n_meta_shards=2, data_replication=2)
    c = svc.client()
    bid = c.create(psize=64)
    payload = bytes(range(256)) * 16
    v = c.write(bid, payload, 0)
    svc.kill_provider("prov-0001")
    pd = st.read_meta(svc.dht, c._owner_fn(bid), v,
                      svc.vm.root_pages_published(bid, v), 0, 64)
    chunks = svc.pm.fetch_pages([(d.providers, d.page_id, 0, None) for d in pd])
    assert b"".join(chunks) == payload
    # and the client read path agrees end-to-end
    assert c.read(bid, v, 0, len(payload)) == payload


def test_fetch_pages_raises_after_all_replicas_down():
    svc = BlobSeerService(n_providers=2, n_meta_shards=2, data_replication=1)
    c = svc.client()
    bid = c.create(psize=64)
    v = c.write(bid, b"z" * 1024, 0)
    pd = st.read_meta(svc.dht, c._owner_fn(bid), v,
                      svc.vm.root_pages_published(bid, v), 0, 16)
    svc.kill_provider("prov-0000")
    svc.kill_provider("prov-0001")
    with pytest.raises(EndpointDown):
        svc.pm.fetch_pages([(d.providers, d.page_id, 0, None) for d in pd])


# ---------------------------------------------------------------------------
# _NodeCache: batch-aware LRU
# ---------------------------------------------------------------------------


def test_node_cache_lru_is_bounded_and_evicts_oldest(monkeypatch):
    dht = MetadataDHT(Wire(), 2)
    cache = _NodeCache(dht)
    monkeypatch.setattr(_NodeCache, "MAX_ENTRIES", 4)
    for i in range(6):
        cache.put(("k", i), {"v": i})
    assert len(cache._cache) == 4           # bounded, no clear-all
    assert ("k", 0) not in cache._cache and ("k", 1) not in cache._cache
    assert cache.get(("k", 5)) == {"v": 5}  # newest still resident

    # touching an entry protects it from eviction (true LRU order)
    cache.get(("k", 2))
    cache.put(("k", 6), {"v": 6})
    assert ("k", 2) in cache._cache
    assert ("k", 3) not in cache._cache


def test_node_cache_get_many_serves_hits_locally():
    dht = MetadataDHT(Wire(), 4)
    items = _fill(dht, 10)
    cache = _NodeCache(dht)
    keys = [k for k, _ in items]
    first = cache.get_many(keys)
    assert first == {k: v for k, v in items}
    assert cache.misses == 10
    dht.reset_rpc_counters()
    second = cache.get_many(keys)
    assert second == first
    assert cache.hits == 10
    assert dht.rpc_counters()["get_keys"] == 0   # pure local hits


def test_read_after_cache_eviction_still_correct(monkeypatch):
    monkeypatch.setattr(_NodeCache, "MAX_ENTRIES", 8)
    svc = BlobSeerService(n_providers=4, n_meta_shards=2)
    c = svc.client()
    bid = c.create(psize=16)
    payload = bytes(range(256)) * 4
    v = c.write(bid, payload, 0)           # 64 pages >> 8 cache slots
    assert c.read(bid, v, 0, len(payload)) == payload
    assert c.read(bid, v, 100, 500) == payload[100:600]
