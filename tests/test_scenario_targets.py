"""Failure-target parsing and application for the scenario runner.

``run_scenario``'s chaos task historically inlined the target-spec
dispatch; it now lives in ``parse_failure_target`` (pure, rejects
malformed specs with ``ValueError``) and ``apply_failure_target``
(fires one spec against a live deployment).  These tests pin both.
"""

import pytest

from repro.core import BlobSeerService, Simulator, Wire
from repro.core.scenarios import (
    apply_failure_target,
    parse_failure_target,
    run_scenario,
)

PS = 4 * 1024


# ------------------------------------------------------------------ parsing


@pytest.mark.parametrize("spec,expected", [
    ("vm-leader:0", ("vm-leader", 0)),
    ("vm-leader:3", ("vm-leader", 3)),
    ("corrupt:prov-0001", ("corrupt", "prov-0001")),
    ("prov-0002", ("kill", "prov-0002")),
    ("meta-0000", ("kill", "meta-0000")),
    ("join:prov-0005", ("join", "prov-0005")),
    ("drain:prov-0001", ("drain", "prov-0001")),
    ("flashcrowd:0", ("flashcrowd", 0)),
    ("flashcrowd:2", ("flashcrowd", 2)),
])
def test_parse_accepts_well_formed_specs(spec, expected):
    assert parse_failure_target(spec) == expected


@pytest.mark.parametrize("spec,msg", [
    ("", "empty"),
    ("vm-leader:", "integer"),
    ("vm-leader:x", "integer"),
    ("vm-leader:1.5", "integer"),
    ("vm-leader:-1", ">= 0"),
    ("corrupt:", "no provider"),
    ("join:", "no provider"),
    ("drain:", "no provider"),
    ("flashcrowd:", "integer"),
    ("flashcrowd:x", "integer"),
    ("flashcrowd:1.5", "integer"),
    ("flashcrowd:-1", ">= 0"),
])
def test_parse_rejects_malformed_specs(spec, msg):
    with pytest.raises(ValueError, match=msg):
        parse_failure_target(spec)


def test_run_scenario_rejects_malformed_targets_before_running():
    with pytest.raises(ValueError, match="integer"):
        run_scenario("appenders", 2, seed=0, ops_per_client=1,
                     failures=[(0.001, "vm-leader:oops")])


# -------------------------------------------------------------- application


def _deployment(**kw):
    sim = Simulator(seed=3)
    kw.setdefault("n_providers", 4)
    kw.setdefault("n_meta_shards", 2)
    svc = BlobSeerService(wire=Wire(clock=sim), **kw)
    return sim, svc


def test_apply_kill_downs_the_provider_endpoint():
    _, svc = _deployment()
    assert apply_failure_target(svc, {}, "prov-0001") == "prov-0001"
    assert svc.wire.is_down("prov-0001")
    assert not svc.wire.is_down("prov-0000")


def test_apply_corrupt_flips_a_stored_byte_silently():
    _, svc = _deployment()
    c = svc.client("w")
    bid = c.create(psize=PS)
    c.append(bid, b"\x11" * PS)
    # find a provider actually holding a page
    pid = next(p.pid for p in svc.pm.all_providers()
               if sorted(p.store.iter_pids()))
    prov = svc.pm.get(pid)
    vic = sorted(prov.store.iter_pids())[0]
    before = prov.store.get(vic)
    assert apply_failure_target(svc, {}, f"corrupt:{pid}") == f"corrupt:{pid}"
    after = prov.store.get(vic)
    assert after[0] == before[0] ^ 0xFF and after[1:] == before[1:]
    assert not svc.wire.is_down(pid)   # bitrot, not an outage


def test_apply_corrupt_on_empty_provider_is_a_noop():
    _, svc = _deployment()
    assert apply_failure_target(svc, {}, "corrupt:prov-0003") \
        == "corrupt:prov-0003"


def test_apply_vm_leader_kills_the_lineage_leader():
    _, svc = _deployment(vm_replication=2, vm_lease_ttl=0.01)
    c = svc.client("w")
    state = {"blobs": [c.create(psize=PS), c.create(psize=PS)]}
    killed = apply_failure_target(svc, state, "vm-leader:1")
    assert killed == f"vm-{state['blobs'][1]}"
    assert svc.wire.is_down(killed)
    assert not svc.wire.is_down(f"vm-{state['blobs'][0]}")


def test_apply_join_registers_the_provider_and_streams_owed_pages():
    sim, svc = _deployment(data_replication=2)
    c = svc.client("w")
    bid = c.create(psize=PS)
    v = 0
    for k in range(6):
        v = c.append(bid, bytes([k + 1]) * PS)
    assert apply_failure_target(svc, {}, "join:prov-extra") \
        == "join:prov-extra"
    assert "prov-extra" in {p.pid for p in svc.pm.all_providers()}
    # owed pages actually landed — the new member serves inventory
    assert sorted(svc.pm.get("prov-extra").list_pages(peer="t"))
    for k in range(6):
        assert c.read(bid, v, k * PS, PS) == bytes([k + 1]) * PS


def test_apply_drain_empties_and_deregisters_the_provider():
    sim, svc = _deployment(data_replication=2)
    c = svc.client("w")
    bid = c.create(psize=PS)
    v = 0
    for k in range(6):
        v = c.append(bid, bytes([k + 11]) * PS)
    victim = next(p.pid for p in svc.pm.all_providers()
                  if sorted(p.store.iter_pids()))
    assert apply_failure_target(svc, {}, f"drain:{victim}") \
        == f"drain:{victim}"
    assert victim not in {p.pid for p in svc.pm.all_providers()}
    for k in range(6):
        assert c.read(bid, v, k * PS, PS) == bytes([k + 11]) * PS


def test_apply_flashcrowd_widens_the_hot_pages():
    # distinct crowd nodes share no cache: every read hits a provider
    _, svc = _deployment(page_cache_bytes=0)
    c = svc.client("w")
    bid = c.create(psize=PS)
    v = c.append(bid, b"\x55" * PS)
    for _ in range(40):
        assert c.read(bid, v, 0, PS) == b"\x55" * PS
    state = {"blobs": [bid], "flashcrowd_threshold": 8,
             "flashcrowd_extra": 1}
    before = svc.pm.rpc_counters()["widened_pages"]
    assert apply_failure_target(svc, state, "flashcrowd:0") \
        == "flashcrowd:0"
    assert svc.pm.rpc_counters()["widened_pages"] > before
    assert c.read(bid, v, 0, PS) == b"\x55" * PS


def test_apply_flashcrowd_requires_setup_blobs_in_state():
    _, svc = _deployment()
    with pytest.raises(ValueError, match="env.state"):
        apply_failure_target(svc, {}, "flashcrowd:0")
    c = svc.client("w")
    state = {"blobs": [c.create(psize=PS)]}
    with pytest.raises(ValueError, match="out of range"):
        apply_failure_target(svc, state, "flashcrowd:1")


def test_apply_vm_leader_requires_setup_blobs_in_state():
    _, svc = _deployment(vm_replication=2, vm_lease_ttl=0.01)
    with pytest.raises(ValueError, match="env.state"):
        apply_failure_target(svc, {}, "vm-leader:0")
    c = svc.client("w")
    state = {"blobs": [c.create(psize=PS)]}
    with pytest.raises(ValueError, match="out of range"):
        apply_failure_target(svc, state, "vm-leader:1")
