"""Seeded property tests: watch/notify delivery under random histories.

Hypothesis drives random interleavings of append / overwrite / branch /
GC / watch / unwatch / lease-expiry across blob pools on the
deterministic Simulator, and checks every lease's delivered stream
against a poll-twin oracle: the catch-up at registration is exactly the
unretired versions above ``from_version``, every version published
while the lease is live arrives exactly once in order, and a lease that
was unwatched or has expired receives nothing afterwards.

Pools are disjoint — each client task owns its own blobs — so the
oracle is exact for any interleaving the scheduler explores.  GC and
lease TTLs are only drawn in single-pool histories: a GC round sweeps
*globally* and virtual time is shared, so in multi-pool histories a
neighbour's sleep could expire a lease (or a neighbour's GC round could
retire a catch-up version) at a point the per-pool oracle cannot see.
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    # No hypothesis: fall back to a fixed seed grid instead of skipping
    # — the histories are seeded and deterministic either way, random
    # search just explores more of the space when it is available.
    HAVE_HYPOTHESIS = False

from repro.core import BlobSeerService, Simulator, Wire
from repro.core.gc import collect_garbage


PSIZE = 16
TTL = 10.0        # lease TTL; ADVANCE jumps far past it
ADVANCE = 50.0    # virtual-time jump of an "advance" op


def _payload(tag: int) -> bytes:
    return bytes([tag % 250 + 1]) * PSIZE


def _run_watch_history(seed, n_pools, ops_per_pool):
    """Random per-pool op sequences; returns (svc, expected, delivered,
    late) where ``expected[wid]`` is the oracle stream, ``delivered``
    what the inbox actually handed out while the lease was entitled,
    and ``late[wid]`` anything that leaked out afterwards."""
    single = n_pools == 1
    sim = Simulator(seed=seed)
    svc = BlobSeerService(wire=Wire(clock=sim), n_providers=4,
                          n_meta_shards=4)
    setup = svc.client("setup")
    pools = [[setup.create(psize=PSIZE)] for _ in range(n_pools)]
    expected = {}   # wid -> oracle stream (grows while the lease lives)
    delivered = {}  # wid -> what poll_notifications handed out
    late = {}       # wid -> deliveries after expiry (must stay empty)

    def pool_program(p):
        def prog():
            c = svc.client(f"c{p:02d}")
            blobs = pools[p]
            live = {}   # wid -> (blob_id, has_ttl)
            ttl_wids = []

            def drain(wid):
                delivered.setdefault(wid, []).extend(
                    c.poll_notifications(wid))

            for k in range(ops_per_pool):
                kind = (p * 31 + k * 17 + seed) % 12
                bid = blobs[(p + k) % len(blobs)]
                tag = p * ops_per_pool + k
                if kind == 7 and not single:
                    kind = 0      # GC sweeps globally: single-pool only
                if kind < 5:                        # publish via append
                    v = c.append(bid, _payload(tag))
                    for wid, (wbid, _t) in live.items():
                        if wbid == bid:
                            expected[wid].append(v)
                elif kind < 7:                      # publish via overwrite
                    v = c.write(bid, _payload(tag), 0)
                    for wid, (wbid, _t) in live.items():
                        if wbid == bid:
                            expected[wid].append(v)
                elif kind == 7:                     # GC round, mid-traffic
                    c.set_retention(bid, keep_last=2)
                    collect_garbage(svc, client=f"gc{p:02d}",
                                    orphan_grace=None)
                elif kind == 8:                     # branch joins the pool
                    v = c.get_recent(bid)
                    if v > 0:
                        blobs.append(c.branch(bid, v))
                elif kind == 9:                     # register a lease
                    frm = 0 if k % 2 == 0 else c.get_recent(bid)
                    use_ttl = single and k % 3 == 0
                    wid = c.watch(bid, from_version=frm,
                                  ttl=TTL if use_ttl else None)
                    pub = c.get_recent(bid)
                    expected[wid] = [
                        v for v in range(frm + 1, pub + 1)
                        if v not in svc.vm.retired_versions(
                            svc.vm.owner_of(bid, v))
                    ]
                    live[wid] = (bid, use_ttl)
                    if use_ttl:
                        ttl_wids.append(wid)
                elif kind == 10 and live:           # unwatch one lease
                    wid = sorted(live)[k % len(live)]
                    sim.sleep(0.5)                  # settle in-flight sends
                    drain(wid)
                    c.unwatch(wid)
                    if wid in ttl_wids:
                        ttl_wids.remove(wid)
                    del live[wid]
                else:                               # time passes: TTLs lapse
                    sim.sleep(ADVANCE)
                    for wid in ttl_wids:
                        drain(wid)                  # entitled up to expiry
                        del live[wid]
                    ttl_wids.clear()
            sim.sleep(1.0)                          # settle the tail
            for wid in sorted(live):
                drain(wid)
                c.unwatch(wid)
            # expired leases (never unwatched): anything still arriving
            # would be a delivery after death
            for wid in set(delivered) - set(live):
                if wid in expected and wid not in late:
                    late[wid] = c.poll_notifications(wid)
            return None

        return prog

    for p in range(n_pools):
        sim.spawn(pool_program(p), name=f"pool{p:02d}")
    sim.run()
    return svc, expected, delivered, late


def _seeds(pairs):
    """hypothesis search when installed, a fixed grid otherwise."""
    def deco(fn):
        if HAVE_HYPOTHESIS:
            return settings(max_examples=8, deadline=None)(given(
                seed=st.integers(min_value=0, max_value=2**16),
                n_pools=st.integers(min_value=1, max_value=3),
            )(fn))
        return pytest.mark.parametrize("seed,n_pools", pairs)(fn)
    return deco


@_seeds([(0, 1), (7, 2), (1234, 3), (42, 1), (99, 2)])
def test_delivered_streams_match_the_poll_twin_oracle(seed, n_pools):
    svc, expected, delivered, late = _run_watch_history(
        seed, n_pools, ops_per_pool=14)
    assert set(delivered) == set(expected)
    for wid in sorted(expected):
        assert delivered[wid] == expected[wid], (
            f"{wid}: delivered {delivered[wid]}, oracle {expected[wid]}")
        # per-watcher monotone, no duplicates (implied by the oracle,
        # asserted independently so a wrong oracle cannot mask it)
        assert delivered[wid] == sorted(set(delivered[wid]))
    for wid, tail in late.items():
        assert tail == [], f"{wid} delivered after expiry/unwatch: {tail}"


def _replay_seeds(fn):
    if HAVE_HYPOTHESIS:
        return settings(max_examples=4, deadline=None)(given(
            seed=st.integers(min_value=0, max_value=2**16))(fn))
    return pytest.mark.parametrize("seed", [0, 7, 1234])(fn)


@_replay_seeds
def test_watch_histories_replay_identically(seed):
    """Same seed -> identical delivered streams and trace digest (the
    subscription plane is deterministic under the virtual clock)."""
    a = _run_watch_history(seed, n_pools=2, ops_per_pool=12)
    b = _run_watch_history(seed, n_pools=2, ops_per_pool=12)
    assert a[2] == b[2]   # delivered streams
    assert a[1] == b[1]   # oracle streams
