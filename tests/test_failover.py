"""HA control plane: replicated lineage shards, lease-based failover,
idempotent re-drive, monitor/fsync/GC fault-handling fixes."""

import time

import pytest

from repro.core import BlobSeerService, EndpointDown
from repro.core.gc import resweep_after_restore
from repro.core.scenarios import run_scenario
from repro.core.sim import Simulator
from repro.core.transport import Wire
from repro.core.version_manager import (
    VMGR_ENDPOINT,
    VersionManager,
    VersionUnpublished,
)

PS = 4 * 1024


def _ha_service(**kw):
    kw.setdefault("n_providers", 4)
    kw.setdefault("n_meta_shards", 2)
    kw.setdefault("vm_replication", 2)
    kw.setdefault("vm_lease_ttl", 0.01)
    return BlobSeerService(**kw)


# --------------------------------------------------------------- replication


def test_replication_off_is_the_default_noop():
    svc = BlobSeerService(n_providers=4, n_meta_shards=2)
    c = svc.client("w")
    bid = c.create(psize=PS)
    c.append(bid, b"x" * PS)
    assert svc.vm_leader_endpoint(bid) == VMGR_ENDPOINT
    rep = svc.vm.replication_report(bid)
    assert rep["followers"] == [] and rep["epoch"] == 0
    ctr = svc.vm.rpc_counters()
    assert ctr["wal_records"] == 0 and ctr["failovers"] == 0
    with pytest.raises(RuntimeError):
        svc.kill_vm_leader(bid)


def test_wal_streams_identically_to_every_follower():
    svc = _ha_service()
    c = svc.client("w")
    bid = c.create(psize=PS)
    for _ in range(3):
        c.append(bid, b"y" * PS)
    f0 = svc.vm.follower_records(bid, 0)
    f1 = svc.vm.follower_records(bid, 1)
    assert f0 == f1 and len(f0) > 0
    ops = [r["op"] for r in f0]
    assert ops[0] == "create"
    for op in ("assign", "complete", "publish"):
        assert op in ops
    rep = svc.vm.replication_report(bid)
    assert rep["leader"] == f"vm-{bid}"
    assert [lost for _, _, lost in rep["followers"]] == [False, False]
    assert svc.vm.rpc_counters()["wal_records"] == 2 * len(f0)


def _canon_pd(pd):
    # journal round-trips pd through [list(x) ...]; normalize so the
    # digest compares content, not list-vs-tuple
    return tuple(
        tuple(tuple(e) if isinstance(e, (list, tuple)) else e for e in d)
        for d in pd
    )


def _digest_of_blobs(blobs):
    """Comparable snapshot of a lineage's full version state."""
    out = {}
    for b in blobs.values():
        out[b.blob_id] = (
            b.psize, b.parent, b.base_version, b.last_assigned,
            b.published, b.keep_last, frozenset(b.retired),
            frozenset(b.swept),
            tuple(sorted(
                (r.version, r.offset, r.size, r.new_blob_size,
                 r.complete, r.vp, _canon_pd(r.pd))
                for r in b.updates.values())),
        )
    return out


def _lineage_digest(vm, bid):
    sh = vm._shard_of(bid)
    with sh.lock:
        return _digest_of_blobs(sh.blobs)


def test_follower_replay_equivalence_property():
    """After every verb, replaying the follower's journal prefix yields
    exactly the leader's lineage state — the invariant failover's
    promotion step relies on."""
    vm = VersionManager(replication=2)
    bid = vm.create(psize=PS)

    def step_and_check():
        follower = vm.follower_records(bid, 0)
        blobs, _pins, _keys, _watches = vm.replay_lineage(follower)
        assert _digest_of_blobs(blobs) == _lineage_digest(vm, bid)

    step_and_check()
    infos = []
    for i in range(4):
        infos.append(vm.assign_version(bid, None, PS, "w",
                                       pd=((f"p{i}", ("prov-0000",)),)))
        step_and_check()
    for info in infos:
        vm.metadata_complete(bid, info.version, "w")
        step_and_check()
    vm.set_retention(bid, keep_last=2)
    step_and_check()
    fork = vm.branch(bid, 2, "w")
    step_and_check()
    blobs, _, _, _ = vm.replay_lineage(vm.follower_records(bid, 0))
    assert fork in blobs and blobs[fork].parent == (bid, 2)


def test_leader_death_between_assign_ack_and_complete_never_double_assigns():
    """The ISSUE's regression: assign acked, leader dies, writer drives
    metadata_complete into the failover — the promoted follower must
    already hold the assignment (no version lost, none double-assigned)."""
    svc = _ha_service()
    c = svc.client("w")
    bid = c.create(psize=PS)
    c.append(bid, b"a" * PS)                       # v1 published
    info = svc.vm.assign_version(bid, None, PS, "w")
    assert info.version == 2
    svc.kill_vm_leader(bid)
    # complete retries through the failover; the replicated journal
    # already has the v2 assign record
    svc.vm.metadata_complete(bid, 2, "w")
    assert svc.vm.rpc_counters()["failovers"] == 1
    assert svc.vm.get_recent(bid) == 2
    nxt = svc.vm.assign_version(bid, None, PS, "w")
    assert nxt.version == 3                        # NOT a re-issued 2
    rep = svc.vm.replication_report(bid)
    assert rep["epoch"] == 2 and len(rep["followers"]) == 1


def test_idempotency_keys_re_drive_to_the_same_versions():
    svc = _ha_service()
    c = svc.client("w")
    bid = c.create(psize=PS)
    reqs = [(bid, None, PS, ()), (bid, None, PS, ())]
    keys = ["w/1", "w/2"]
    first = svc.vm.assign_versions_many(reqs, "w", keys=keys)
    again = svc.vm.assign_versions_many(reqs, "w", keys=keys)
    assert [i.version for i in first] == [i.version for i in again] == [1, 2]
    svc.kill_vm_leader(bid)
    redriven = svc.vm.assign_versions_many(reqs, "w", keys=keys)
    assert [i.version for i in redriven] == [1, 2]
    assert svc.vm.rpc_counters()["failovers"] == 1
    # a fresh key still assigns the next version exactly once
    assert svc.vm.assign_versions_many(
        [(bid, None, PS, ())], "w", keys=["w/3"])[0].version == 3


def test_pin_leases_survive_failover_but_not_cold_restart(tmp_path):
    wal = str(tmp_path / "vm.wal")
    svc = _ha_service(wal_path=wal)
    c = svc.client("w")
    bid = c.create(psize=PS)
    c.append(bid, b"p" * PS)
    c.append(bid, b"q" * PS)
    svc.vm.pin(bid, 1, client="w")
    svc.kill_vm_leader(bid)
    assert svc.vm.get_recent(bid) == 2             # drives the failover
    assert svc.vm.rpc_counters()["failovers"] == 1
    assert 1 in svc.vm.pinned_versions(bid)        # lease carried over
    # cold restart: process death releases pins
    vm2 = VersionManager.recover_from_wal(wal, replication=2)
    assert vm2.pinned_versions(bid) == frozenset()
    assert vm2.get_recent(bid) == 2


def test_failover_waits_out_the_dead_leaders_lease():
    sim = Simulator(seed=3)
    svc = BlobSeerService(n_providers=4, n_meta_shards=2,
                          wire=Wire(clock=sim), vm_replication=1,
                          vm_lease_ttl=0.5)
    c = svc.client("w")
    bid = c.create(psize=PS)

    def prog():
        c.append(bid, b"x" * PS)
        svc.kill_vm_leader(bid)
        lease = svc.vm.replication_report(bid)["lease_expires_at"]
        c.append(bid, b"y" * PS)
        return {"lease": lease, "after": sim.now()}

    task = sim.spawn(prog, name="w")
    sim.run()
    res = task.result
    # promotion may not happen before the old lease has provably expired
    assert res["after"] >= res["lease"]
    assert svc.vm.rpc_counters()["failovers"] == 1
    assert svc.vm.get_recent(bid) == 2


def test_no_live_follower_surfaces_endpoint_down():
    svc = _ha_service(vm_replication=1)
    c = svc.client("w")
    bid = c.create(psize=PS)
    c.append(bid, b"x" * PS)
    svc.kill_vm_leader(bid)
    svc.wire.set_down(f"vm-{bid}-f1", True)
    with pytest.raises(EndpointDown):
        svc.vm.get_recent(bid)


# ------------------------------------------------------- mid-burst failover


def test_mid_burst_failover_loses_nothing_and_stays_deterministic():
    base = run_scenario("vm_failover", 8, seed=5, ops_per_client=2)
    assert not base.errors
    failures = [(0.4 * base.makespan, "vm-leader:0")]
    kill = run_scenario("vm_failover", 8, seed=5, ops_per_client=2,
                        failures=failures)
    replay = run_scenario("vm_failover", 8, seed=5, ops_per_client=2,
                          failures=failures)
    assert not kill.errors
    assert kill.rpc["vm_failovers"] == 1
    assert kill.ops == base.ops
    assert kill.trace_digest == replay.trace_digest
    # exact version cover per lineage: nothing lost, nothing doubled
    cover = {}
    for res in kill.client_results.values():
        if isinstance(res, dict) and "versions" in res:
            cover.setdefault(res["lineage"], []).extend(res["versions"])
    for vs in cover.values():
        assert sorted(vs) == list(range(1, len(vs) + 1))


# ------------------------------------------------- monitor error handling


class _FailingAgent:
    def __init__(self, exc):
        self.exc = exc
        self.calls = 0

    def rebuild_metadata(self, blob_id, version):
        self.calls += 1
        raise self.exc


def _stalled_service():
    svc = BlobSeerService(n_providers=2, n_meta_shards=2)
    c = svc.client("w")
    bid = c.create(psize=PS)
    svc.vm.assign_version(bid, None, PS, "w")   # assigned, never completed
    return svc


def test_monitor_counts_retryable_errors_and_keeps_running():
    svc = _stalled_service()
    agent = _FailingAgent(EndpointDown("prov-0000 down"))
    svc.client = lambda *a, **kw: agent
    svc.start_monitor(interval=0.01, stall_timeout=0.0)
    deadline = time.monotonic() + 2.0
    while agent.calls < 3 and time.monotonic() < deadline:
        time.sleep(0.01)
    svc.stop_monitor()                           # must NOT raise
    assert agent.calls >= 3                      # retried, not dead
    assert svc.rpc_report()["monitor_errors"] >= 3


def test_monitor_unexpected_error_stops_loop_and_reraises_on_stop():
    svc = _stalled_service()
    agent = _FailingAgent(RuntimeError("metadata corrupt"))
    svc.client = lambda *a, **kw: agent
    svc.start_monitor(interval=0.01, stall_timeout=0.0)
    deadline = time.monotonic() + 2.0
    while agent.calls < 1 and time.monotonic() < deadline:
        time.sleep(0.01)
    time.sleep(0.05)                             # loop had time to keep going
    with pytest.raises(RuntimeError, match="metadata corrupt"):
        svc.stop_monitor()
    assert agent.calls == 1                      # stopped, no silent retry
    # the fatal is surfaced once, then cleared
    svc.stop_monitor()


# ----------------------------------------------------- GC narrow catch


def test_resweep_skips_only_never_assigned_versions(monkeypatch):
    svc = BlobSeerService(n_providers=2, n_meta_shards=2)
    c = svc.client("w")
    bid = c.create(psize=PS)
    c.append(bid, b"x" * PS)
    monkeypatch.setattr(svc.vm, "retired_versions", lambda b: frozenset({1}))

    def never_assigned(blob_id, version):
        raise VersionUnpublished(f"{blob_id} v{version}")
    monkeypatch.setattr(svc.vm, "update_log", never_assigned)
    out = resweep_after_restore(svc)
    assert out["swept_pages"] == 0               # skipped, no crash


def test_resweep_propagates_unexpected_errors(monkeypatch):
    svc = BlobSeerService(n_providers=2, n_meta_shards=2)
    c = svc.client("w")
    bid = c.create(psize=PS)
    c.append(bid, b"x" * PS)
    monkeypatch.setattr(svc.vm, "retired_versions", lambda b: frozenset({1}))

    def corrupt(blob_id, version):
        raise RuntimeError("journal corrupt")
    monkeypatch.setattr(svc.vm, "update_log", corrupt)
    with pytest.raises(RuntimeError, match="journal corrupt"):
        resweep_after_restore(svc)


# ------------------------------------------------------------ fsync policy


def _drive(vm):
    bid = vm.create(psize=PS)
    for _ in range(3):
        info = vm.assign_version(bid, None, PS, "w")
        vm.metadata_complete(bid, info.version, "w")
    return bid


def test_fsync_always_syncs_every_record(tmp_path):
    vm = VersionManager(wal_path=str(tmp_path / "w.wal"),
                        fsync_policy="always")
    _drive(vm)
    assert vm.rpc_counters()["wal_fsyncs"] == len(vm._wal)


def test_fsync_batch_coalesces_but_syncs_at_publication(tmp_path):
    vm = VersionManager(wal_path=str(tmp_path / "w.wal"))   # batch default
    _drive(vm)
    ctr = vm.rpc_counters()
    assert 1 <= ctr["wal_fsyncs"] < len(vm._wal)


def test_fsync_never_never_syncs(tmp_path):
    vm = VersionManager(wal_path=str(tmp_path / "w.wal"),
                        fsync_policy="never")
    bid = _drive(vm)
    assert vm.rpc_counters()["wal_fsyncs"] == 0
    # records still hit the file (flushed, just not synced)
    vm2 = VersionManager.recover_from_wal(str(tmp_path / "w.wal"))
    assert vm2.get_recent(bid) == 3


def test_fsync_policy_validated():
    with pytest.raises(ValueError):
        VersionManager(fsync_policy="sometimes")
    with pytest.raises(ValueError):
        VersionManager(replication=-1)


# -------------------------------------------------------------- restart


def test_restore_bootstraps_replica_groups(tmp_path):
    spool = str(tmp_path / "spool")
    wal = str(tmp_path / "vm.wal")
    svc = _ha_service(spool_dir=spool, wal_path=wal)
    c = svc.client("w")
    bid = c.create(psize=PS)
    v = c.append(bid, b"r" * PS)

    svc2 = BlobSeerService.restore(spool, wal, n_providers=4,
                                   n_meta_shards=2, vm_replication=2,
                                   vm_lease_ttl=0.01)
    assert svc2.vm_leader_endpoint(bid) == f"vm-{bid}"
    f0 = svc2.vm.follower_records(bid, 0)
    f1 = svc2.vm.follower_records(bid, 1)
    assert f0 == f1 and len(f0) > 0              # journal bulk-streamed
    c2 = svc2.client("r")
    assert c2.read(bid, v, 0, PS) == b"r" * PS
    # the recovered group fails over like a live one
    svc2.kill_vm_leader(bid)
    assert svc2.vm.get_recent(bid) == v
    assert svc2.vm.rpc_counters()["failovers"] == 1
