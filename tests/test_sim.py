"""Unit tests for the deterministic discrete-event engine (core/sim.py)."""

import time

import pytest

from repro.core import SimDeadlock, Simulator, WallClock, Wire
from repro.core.sim import SimCondition


def test_virtual_sleep_orders_tasks_and_advances_clock():
    sim = Simulator(seed=0)
    log = []

    def a():
        sim.sleep(2.0)
        log.append(("a", sim.now()))

    def b():
        sim.sleep(1.0)
        log.append(("b", sim.now()))

    sim.spawn(a, name="a")
    sim.spawn(b, name="b")
    sim.run()
    assert log == [("b", 1.0), ("a", 2.0)]
    assert sim.now() == 2.0


def test_same_seed_identical_trace_different_seed_differs():
    def build(seed):
        sim = Simulator(seed=seed)
        for i in range(10):
            # all tasks spawn at t=0: dispatch order is pure tie-break
            sim.spawn(lambda: sim.sleep(0.5), name=f"t{i}")
        sim.run()
        return sim.trace_digest(), [e[1] for e in sim.trace]

    d1, order1 = build(42)
    d2, order2 = build(42)
    d3, order3 = build(43)
    assert d1 == d2 and order1 == order2
    assert d3 != d1  # seeded tie-break reshuffles same-time events


def test_condition_notify_wakes_waiters_in_virtual_time():
    sim = Simulator(seed=1)
    cond = sim.condition()
    assert isinstance(cond, SimCondition)
    state = {"ready": False}
    log = []

    def waiter(name):
        def prog():
            with cond:
                while not state["ready"]:
                    assert cond.wait(timeout=100.0)
            log.append((name, sim.now()))
        return prog

    def setter():
        sim.sleep(3.0)
        with cond:
            state["ready"] = True
            cond.notify_all()

    sim.spawn(waiter("w1"), name="w1")
    sim.spawn(waiter("w2"), name="w2")
    sim.spawn(setter, name="s")
    sim.run()
    assert sorted(log) == [("w1", 3.0), ("w2", 3.0)]


def test_condition_timeout_fires_on_virtual_clock():
    sim = Simulator(seed=1)
    cond = sim.condition()
    out = {}

    def waiter():
        with cond:
            out["notified"] = cond.wait(timeout=2.5)
        out["at"] = sim.now()

    sim.spawn(waiter, name="w")
    sim.run()
    assert out == {"notified": False, "at": 2.5}


def test_deadlock_detection():
    sim = Simulator(seed=0)
    cond = sim.condition()

    def stuck():
        with cond:
            cond.wait()  # nobody will ever notify

    sim.spawn(stuck, name="stuck")
    with pytest.raises(SimDeadlock, match="stuck"):
        sim.run()


def test_task_errors_propagate_and_are_recorded():
    sim = Simulator(seed=0)

    def boom():
        sim.sleep(1.0)
        raise ValueError("boom")

    sim.spawn(boom, name="boom")
    with pytest.raises(ValueError, match="boom"):
        sim.run()

    sim2 = Simulator(seed=0)
    sim2.spawn(lambda: (_ for _ in ()).throw(ValueError("x")), name="b")
    sim2.run(raise_errors=False)
    assert "b" in sim2.errors()


def test_wire_endpoint_queueing_serializes_in_virtual_time():
    """Two tasks hitting the SAME endpoint queue; distinct endpoints
    overlap — the §4.3 contention model as an actual schedule."""
    sim = Simulator(seed=0)
    wire = Wire(clock=sim, bandwidth=1e6, latency=0.0)
    done = {}

    def hit(name, endpoint):
        def prog():
            wire.transfer(endpoint, 1_000_000, inbound=True)  # 1 virtual s
            done[name] = sim.now()
        return prog

    sim.spawn(hit("a", "ep0"), name="a")
    sim.spawn(hit("b", "ep0"), name="b")
    sim.spawn(hit("c", "ep1"), name="c")
    sim.run()
    # ep0's two requests serialize: one finishes at 1s, the other at 2s;
    # ep1's single request overlaps and finishes at 1s.
    assert sorted((done["a"], done["b"])) == [1.0, 2.0]
    assert done["c"] == 1.0
    assert wire.sim_span() == 2.0


def test_driver_thread_work_is_free():
    sim = Simulator(seed=0)
    wire = Wire(clock=sim)
    wire.transfer("ep", 10_000_000, inbound=True)  # setup: no task, no time
    assert sim.now() == 0.0
    sim.sleep(5.0)  # driver-thread sleep is a no-op
    assert sim.now() == 0.0


def test_wall_clock_backend_is_default_and_real():
    wire = Wire()
    assert isinstance(wire.clock, WallClock)
    assert not wire.clock.is_virtual
    t0 = wire.clock.now()
    wire.transfer("ep", 1024, inbound=True)  # no virtual clock: no sleep
    assert wire.clock.now() - t0 < 1.0


def test_virtual_time_keeps_big_scenarios_fast():
    """The whole point: a 128-client experiment spans tens of virtual
    milliseconds of simulated contention but only ~a second of wall
    time.  The generous bound is the CI budget backstop."""
    from repro.core.scenarios import run_scenario

    t0 = time.perf_counter()
    r = run_scenario("appenders", 128, seed=1)
    wall = time.perf_counter() - t0
    assert not r.errors, r.errors
    assert r.makespan > 0.01      # real simulated contention happened
    assert wall < 20.0, f"virtual-time run took {wall:.1f}s wall"


def test_spawn_during_run_and_results():
    sim = Simulator(seed=0)

    def child():
        sim.sleep(1.0)
        return "child-done"

    def parent():
        sim.spawn(child, name="child")
        sim.sleep(0.5)
        return "parent-done"

    sim.spawn(parent, name="parent")
    sim.run()
    assert sim.results() == {"parent": "parent-done", "child": "child-done"}
