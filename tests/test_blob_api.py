"""Blob primitive semantics (paper §2.1), incl. unaligned + branch."""

import pytest

from repro.core import BlobSeerService, ReadError, WriteBeyondEnd
from repro.core.version_manager import VersionUnpublished


def test_create_empty_v0(client):
    bid = client.create(psize=16)
    assert client.get_recent(bid) == 0
    assert client.get_size(bid, 0) == 0
    assert client.read(bid, 0, 0, 0) == b""
    with pytest.raises(ReadError):
        client.read(bid, 0, 0, 1)


def test_write_read_roundtrip(client):
    bid = client.create(psize=16)
    v = client.write(bid, b"A" * 64, 0)
    assert v == 1
    assert client.read(bid, v, 0, 64) == b"A" * 64
    assert client.read(bid, v, 5, 20) == b"A" * 20


def test_versions_immutable(client):
    bid = client.create(psize=16)
    v1 = client.write(bid, b"A" * 48, 0)
    v2 = client.write(bid, b"B" * 16, 16)
    assert client.read(bid, v1, 0, 48) == b"A" * 48
    assert client.read(bid, v2, 0, 48) == b"A" * 16 + b"B" * 16 + b"A" * 16


def test_append_extends(client):
    bid = client.create(psize=16)
    client.write(bid, b"x" * 10, 0)       # unaligned size
    v2 = client.append(bid, b"y" * 30)
    assert client.get_size(bid, v2) == 40
    assert client.read(bid, v2, 0, 40) == b"x" * 10 + b"y" * 30


def test_unaligned_write_merges_boundaries(client):
    bid = client.create(psize=16)
    client.write(bid, bytes(range(64)), 0)
    v = client.write(bid, b"\xff" * 5, 13)  # crosses page 0/1 boundary
    got = client.read(bid, v, 0, 64)
    exp = bytearray(range(64))
    exp[13:18] = b"\xff" * 5
    assert got == bytes(exp)


def test_write_beyond_end_fails(client):
    bid = client.create(psize=16)
    client.write(bid, b"a" * 8, 0)
    with pytest.raises(WriteBeyondEnd):
        client.write(bid, b"b" * 4, 100)


def test_write_at_exact_end_is_append(client):
    bid = client.create(psize=16)
    client.write(bid, b"a" * 8, 0)
    v = client.write(bid, b"b" * 8, 8)
    assert client.read(bid, v, 0, 16) == b"a" * 8 + b"b" * 8


def test_read_unpublished_fails(client):
    bid = client.create(psize=16)
    client.write(bid, b"a" * 8, 0)
    with pytest.raises(ReadError):
        client.read(bid, 2, 0, 4)
    with pytest.raises(VersionUnpublished):
        client.get_size(bid, 2)


def test_read_oob_fails(client):
    bid = client.create(psize=16)
    v = client.write(bid, b"a" * 8, 0)
    with pytest.raises(ReadError):
        client.read(bid, v, 4, 8)


def test_get_recent_monotone(client):
    bid = client.create(psize=16)
    seen = [client.get_recent(bid)]
    for i in range(5):
        client.append(bid, b"z" * 10)
        seen.append(client.get_recent(bid))
    assert seen == sorted(seen)


def test_sync_read_your_writes(client):
    bid = client.create(psize=16)
    v = client.append(bid, b"q" * 40)
    client.sync(bid, v, timeout=5)
    assert client.read(bid, v, 0, 40) == b"q" * 40


def test_branch_semantics(client):
    bid = client.create(psize=16)
    v1 = client.write(bid, b"A" * 32, 0)
    v2 = client.append(bid, b"B" * 16)
    b2 = client.branch(bid, v1)
    # branch shares history <= v1
    assert client.get_size(b2, v1) == 32
    assert client.read(b2, v1, 0, 32) == b"A" * 32
    # divergence
    vb = client.append(b2, b"C" * 8)
    assert vb == v1 + 1
    assert client.read(b2, vb, 0, 40) == b"A" * 32 + b"C" * 8
    assert client.read(bid, v2, 0, 48) == b"A" * 32 + b"B" * 16


def test_branch_of_branch(client):
    bid = client.create(psize=16)
    client.write(bid, b"1" * 16, 0)
    b2 = client.branch(bid, 1)
    client.append(b2, b"2" * 16)
    b3 = client.branch(b2, 2)
    v = client.append(b3, b"3" * 16)
    assert client.read(b3, v, 0, 48) == b"1" * 16 + b"2" * 16 + b"3" * 16


def test_branch_unpublished_fails(client):
    bid = client.create(psize=16)
    client.write(bid, b"a" * 8, 0)
    with pytest.raises(VersionUnpublished):
        client.branch(bid, 7)


def test_space_efficiency_cow(service):
    """§4.3: unchanged pages are shared between snapshot versions."""
    c = service.client()
    bid = c.create(psize=16)
    c.write(bid, b"0" * 1024, 0)          # 64 pages
    pages_after_v1 = service.storage_report()["pages"]
    c.write(bid, b"1" * 16, 512)          # 1 page
    pages_after_v2 = service.storage_report()["pages"]
    assert pages_after_v2 - pages_after_v1 == 1
