"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.delta_mask import delta_mask_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.linear_scan import linear_scan_pallas
from repro.kernels.page_digest import page_digest_pallas

RNG = np.random.default_rng(42)


# ---------------------------------------------------------------- page digest
@pytest.mark.parametrize("n_pages,n_words", [(1, 512), (3, 512), (8, 1024), (17, 1536)])
def test_page_digest_matches_ref(n_pages, n_words):
    x = jnp.asarray(RNG.integers(0, 2**32, (n_pages, n_words), dtype=np.uint32))
    got = page_digest_pallas(x, interpret=True)
    want = ref.ref_page_digest(x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_page_digest_order_sensitive():
    x = jnp.asarray(RNG.integers(0, 2**32, (1, 512), dtype=np.uint32))
    perm = x[:, ::-1]
    a = np.asarray(page_digest_pallas(x, interpret=True))
    b = np.asarray(page_digest_pallas(perm, interpret=True))
    assert not np.array_equal(a, b)


def test_page_digest_single_bit_sensitivity():
    x = jnp.zeros((2, 512), jnp.uint32)
    for word in [0, 137, 511]:
        y = x.at[1, word].set(1)
        d = np.asarray(page_digest_pallas(y, interpret=True))
        assert not np.array_equal(d[0], d[1]), f"word {word} collision"


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
def test_ops_page_digest_dtypes(dtype, monkeypatch):
    from repro.kernels import ops
    monkeypatch.setenv("REPRO_PALLAS", "interpret")
    x = jnp.asarray(RNG.standard_normal(5000), jnp.float32).astype(dtype)
    d_pal = ops.page_digest(x, page_bytes=4096)
    monkeypatch.setenv("REPRO_PALLAS", "off")
    d_ref = ops.page_digest(x, page_bytes=4096)
    np.testing.assert_array_equal(np.asarray(d_pal), np.asarray(d_ref))


# ---------------------------------------------------------------- delta mask
def test_delta_mask_matches_ref():
    new = jnp.asarray(RNG.integers(0, 2**32, (300, 2), dtype=np.uint32))
    old = new.at[17, 0].add(1).at[255, 1].add(3)
    got = delta_mask_pallas(new, old, interpret=True) != 0
    want = ref.ref_delta_mask(new, old)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert int(got.sum()) == 2


# ------------------------------------------------------------ flash attention
@pytest.mark.parametrize(
    "B,Hq,Hkv,Tq,Tk,D,causal,window",
    [
        (2, 4, 2, 64, 64, 32, True, None),     # GQA causal
        (1, 8, 1, 37, 37, 16, True, None),     # MQA, ragged T
        (2, 2, 2, 50, 70, 8, False, None),     # cross-ish, pad_k
        (1, 4, 2, 96, 96, 64, True, 24),       # sliding window
        (1, 2, 1, 1, 40, 16, True, None),      # decode shape
        (1, 4, 4, 128, 128, 128, True, None),  # TPU-aligned
    ],
)
def test_flash_attention_matches_ref(B, Hq, Hkv, Tq, Tk, D, causal, window):
    q = jnp.asarray(RNG.standard_normal((B, Hq, Tq, D)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, Hkv, Tk, D)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, Hkv, Tk, D)), jnp.float32)
    qo = Tk - Tq if causal else 0
    got = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 q_offset=qo, interpret=True)
    want = ref.ref_attention(q, k, v, causal=causal, window=window, q_offset=qo)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_flash_attention_bf16():
    q = jnp.asarray(RNG.standard_normal((1, 4, 64, 32)), jnp.bfloat16)
    k = jnp.asarray(RNG.standard_normal((1, 2, 64, 32)), jnp.bfloat16)
    v = jnp.asarray(RNG.standard_normal((1, 2, 64, 32)), jnp.bfloat16)
    got = flash_attention_pallas(q, k, v, interpret=True)
    want = ref.ref_attention(q, k, v)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=3e-2
    )


def test_flash_attention_softcap():
    q = jnp.asarray(RNG.standard_normal((1, 2, 32, 16)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((1, 2, 32, 16)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((1, 2, 32, 16)), jnp.float32)
    got = flash_attention_pallas(q, k, v, softcap=20.0, interpret=True)
    want = ref.ref_attention(q, k, v, softcap=20.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


# --------------------------------------------------------------- linear scan
@pytest.mark.parametrize("B,T,D", [(2, 64, 32), (3, 100, 17), (1, 1, 8), (4, 257, 130)])
def test_linear_scan_matches_ref(B, T, D):
    a = jnp.asarray(RNG.uniform(0.5, 0.999, (B, T, D)), jnp.float32)
    x = jnp.asarray(RNG.standard_normal((B, T, D)), jnp.float32)
    got = linear_scan_pallas(a, x, interpret=True)
    want = ref.ref_linear_scan(a, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_linear_scan_is_exclusive_prefix_correct():
    # h_0 must equal x_0 (no pre-existing state)
    a = jnp.full((1, 4, 2), 0.5, jnp.float32)
    x = jnp.ones((1, 4, 2), jnp.float32)
    h = linear_scan_pallas(a, x, interpret=True)
    np.testing.assert_allclose(np.asarray(h[0, 0]), [1.0, 1.0])
    np.testing.assert_allclose(np.asarray(h[0, 1]), [1.5, 1.5])
