"""Snapshot-retirement GC: sweep unreferenced pages, keep live ones."""

import numpy as np
import pytest

from repro.core import BlobSeerService
from repro.core.gc import collect_garbage


def test_gc_sweeps_retired_versions_keeps_live():
    svc = BlobSeerService(n_providers=4, n_meta_shards=4)
    c = svc.client()
    bid = c.create(psize=16)
    c.write(bid, b"A" * 256, 0)                    # v1
    for i in range(2, 8):
        c.write(bid, bytes([i]) * 64, 64)          # v2..v7 rewrite same range
    latest = c.get_recent(bid)
    pages_before = svc.storage_report()["pages"]

    stats = collect_garbage(svc, {bid: [1, latest]})
    assert stats["swept_pages"] > 0
    pages_after = svc.storage_report()["pages"]
    assert pages_after < pages_before

    # kept versions remain fully readable
    c2 = svc.client()
    assert c2.read(bid, 1, 0, 256) == b"A" * 256
    want = bytearray(b"A" * 256)
    want[64:128] = bytes([7]) * 64
    assert c2.read(bid, latest, 0, 256) == bytes(want)

    # retired versions are gone (metadata swept)
    from repro.core.segment_tree import MetadataMissing
    from repro.core.transport import EndpointDown
    with pytest.raises((MetadataMissing, EndpointDown, KeyError)):
        c2.read(bid, 3, 64, 64)


def test_gc_preserves_branch_lineage():
    svc = BlobSeerService(n_providers=4, n_meta_shards=4)
    c = svc.client()
    bid = c.create(psize=16)
    c.write(bid, b"base" * 16, 0)                  # v1
    fork = c.branch(bid, 1)
    c.append(fork, b"F" * 32)                      # fork v2
    c.write(bid, b"T" * 32, 0)                     # trunk v2

    collect_garbage(svc, {bid: [1, 2], fork: [2]})
    c2 = svc.client()
    assert c2.read(fork, 2, 64, 32) == b"F" * 32
    assert c2.read(fork, 2, 0, 8) == b"base" * 2   # shared base pages live
    assert c2.read(bid, 2, 0, 32) == b"T" * 32
