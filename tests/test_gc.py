"""Distributed snapshot-retirement GC: retention, pins, typed errors,
and the all-RPC mark/sweep plane (no shard/provider-store reach-ins)."""

import pytest

from repro.core import BlobSeerService, RetiredVersion
from repro.core.gc import collect_garbage
from repro.core.version_manager import VersionUnpublished


def test_gc_sweeps_retired_versions_keeps_live():
    svc = BlobSeerService(n_providers=4, n_meta_shards=4)
    c = svc.client()
    bid = c.create(psize=16)
    c.write(bid, b"A" * 256, 0)                    # v1
    for i in range(2, 8):
        c.write(bid, bytes([i]) * 64, 64)          # v2..v7 rewrite same range
    latest = c.get_recent(bid)
    pages_before = svc.storage_report()["pages"]

    stats = collect_garbage(svc, {bid: [1, latest]})
    assert stats["swept_pages"] > 0
    assert stats["reclaimed_bytes"] > 0
    pages_after = svc.storage_report()["pages"]
    assert pages_after < pages_before

    # kept versions remain fully readable
    c2 = svc.client()
    assert c2.read(bid, 1, 0, 256) == b"A" * 256
    want = bytearray(b"A" * 256)
    want[64:128] = bytes([7]) * 64
    assert c2.read(bid, latest, 0, 256) == bytes(want)

    # retired versions answer the typed error — from read, size and pin
    with pytest.raises(RetiredVersion):
        c2.read(bid, 3, 64, 64)
    with pytest.raises(RetiredVersion):
        c2.get_size(bid, 4)
    with pytest.raises(RetiredVersion):
        c2.pin(bid, 5)
    with pytest.raises(RetiredVersion):
        c2.branch(bid, 2)


def test_gc_preserves_branch_lineage():
    svc = BlobSeerService(n_providers=4, n_meta_shards=4)
    c = svc.client()
    bid = c.create(psize=16)
    c.write(bid, b"base" * 16, 0)                  # v1
    fork = c.branch(bid, 1)
    c.append(fork, b"F" * 32)                      # fork v2
    c.write(bid, b"T" * 32, 0)                     # trunk v2

    collect_garbage(svc, {bid: [1, 2], fork: [2]})
    c2 = svc.client()
    assert c2.read(fork, 2, 64, 32) == b"F" * 32
    assert c2.read(fork, 2, 0, 8) == b"base" * 2   # shared base pages live
    assert c2.read(bid, 2, 0, 32) == b"T" * 32


def test_gc_goes_through_the_wire_only():
    """Acceptance: zero direct shard/provider mutations — every sweep
    delete is a batched RPC visible in rpc_report()."""
    svc = BlobSeerService(n_providers=4, n_meta_shards=4)
    c = svc.client()
    bid = c.create(psize=16)
    for i in range(8):
        c.write(bid, bytes([i + 1]) * 128, 0)
    c.set_retention(bid, keep_last=2)
    svc.reset_rpc_counters()

    stats = collect_garbage(svc)
    rep = svc.rpc_report()
    assert stats["retired_versions"] == 6
    assert stats["swept_nodes"] > 0 and stats["swept_pages"] > 0
    # the sweep is batched: per-shard delete RPCs, not per-key
    assert rep["dht_delete_keys"] >= stats["swept_nodes"]
    assert 0 < rep["dht_delete_shard_rpcs"] <= svc.dht.replication * len(svc.dht.shards)
    assert rep["dht_delete_shard_rpcs"] < rep["dht_delete_keys"]
    # page deletes grouped per provider endpoint
    assert rep["provider_swept_pages"] == stats["swept_pages"]
    assert 0 < rep["provider_sweep_rounds"] <= len(svc.pm.all_providers())
    # the mark phase is level-batched too
    assert stats["mark_rounds"] < stats["mark_keys"]


def test_gc_retention_policy_keeps_last_k():
    svc = BlobSeerService(n_providers=4, n_meta_shards=4)
    c = svc.client()
    bid = c.create(psize=16)
    for i in range(10):
        c.append(bid, bytes([i + 1]) * 32)
    c.set_retention(bid, keep_last=3)
    collect_garbage(svc)
    assert sorted(svc.vm.retired_versions(bid)) == list(range(1, 8))
    for v in (8, 9, 10):
        assert len(c.read(bid, v, 0, c.get_size(bid, v))) == 32 * v
    with pytest.raises(RetiredVersion):
        c.read(bid, 7, 0, 16)


def test_gc_respects_pin_leases_and_expiry():
    svc = BlobSeerService(n_providers=4, n_meta_shards=4)
    c = svc.client()
    bid = c.create(psize=16)
    for i in range(6):
        c.append(bid, bytes([i + 1]) * 32)
    c.set_retention(bid, keep_last=1)
    lease = c.pin(bid, 2)                  # no expiry
    c.pin(bid, 3, ttl=0.0)                 # expires immediately

    collect_garbage(svc)
    assert c.read(bid, 2, 0, 64) == b"\x01" * 32 + b"\x02" * 32
    with pytest.raises(RetiredVersion):
        c.read(bid, 3, 0, 16)

    c.unpin(lease)
    collect_garbage(svc)
    with pytest.raises(RetiredVersion):
        c.read(bid, 2, 0, 16)
    # the newest published version always survives
    assert len(c.read(bid, 6, 0, c.get_size(bid, 6))) == 32 * 6


def test_gc_sweep_is_incremental():
    """A second GC round with no new retirement issues no new deletes:
    mark cost tracks the live set, sweep cost tracks the retired delta."""
    svc = BlobSeerService(n_providers=4, n_meta_shards=4)
    c = svc.client()
    bid = c.create(psize=16)
    for i in range(8):
        c.write(bid, bytes([i + 1]) * 64, 0)   # overwrites: old pages die
    c.set_retention(bid, keep_last=2)
    s1 = collect_garbage(svc)
    assert s1["retired_versions"] == 6 and s1["swept_pages"] > 0
    svc.reset_rpc_counters()
    s2 = collect_garbage(svc)
    assert s2["retired_versions"] == 0
    assert s2["swept_pages"] == 0 and s2["swept_nodes"] == 0
    rep = svc.rpc_report()
    assert rep["dht_delete_shard_rpcs"] == 0
    assert rep["provider_sweep_rounds"] == 0


def test_gc_recollects_shared_garbage_when_keeper_retires():
    """A retired version whose pages are still shared by a kept snapshot
    stays *pending*; when the keeper retires later, the shared pages
    become candidates again and are reclaimed — no permanent leak."""
    svc = BlobSeerService(n_providers=4, n_meta_shards=4)
    c = svc.client()
    bid = c.create(psize=16)
    c.write(bid, b"A" * 64, 0)             # v1: pages 0-3
    c.write(bid, b"B" * 16, 0)             # v2: page 0 only (shares 1-3 w/ v1)
    c.set_retention(bid, keep_last=1)
    s1 = collect_garbage(svc)
    assert s1["retired_versions"] == 1     # v1 retired...
    assert s1["deferred_versions"] == 1    # ...but pending: pages shared by v2
    c.write(bid, b"C" * 64, 0)             # v3 overwrites everything
    collect_garbage(svc)                   # retires v2; v1's shares now dead
    s3 = collect_garbage(svc)
    assert s3["deferred_versions"] == 0
    # all that remains is exactly what v3 (the only live version) reaches
    live = s3["live_pages"]
    assert sum(p.page_count() for p in svc.pm.all_providers()) == live


def test_gc_orphan_scan_reclaims_unjournaled_pages():
    """Pages stored but never registered with the version manager (a
    restriped optimistic append, a writer dead before assignment) are
    reclaimed by the wire-accounted inventory pass once past grace."""
    svc = BlobSeerService(n_providers=2, n_meta_shards=2)
    c = svc.client()
    bid = c.create(psize=16)
    c.append(bid, b"x" * 24)               # unaligned tail
    c.append(bid, b"y" * 24)               # phase-1 restripe orphans a page
    stats = collect_garbage(svc, orphan_grace=None)
    pages_with_orphan = sum(p.page_count() for p in svc.pm.all_providers())
    assert pages_with_orphan > stats["live_pages"]  # the orphan exists
    stats = collect_garbage(svc)           # default grace: too young, spared
    assert stats["orphan_pages"] == 0
    stats = collect_garbage(svc, orphan_grace=0.0)
    assert stats["orphan_pages"] >= 1
    assert (sum(p.page_count() for p in svc.pm.all_providers())
            == stats["live_pages"])
    # every snapshot still reads back
    assert c.read(bid, 2, 0, 48) == b"x" * 24 + b"y" * 24


def test_gc_unpublished_and_version0_untouchable():
    svc = BlobSeerService(n_providers=2, n_meta_shards=2)
    c = svc.client()
    bid = c.create(psize=16)
    c.append(bid, b"x" * 32)
    c.set_retention(bid, keep_last=1)
    collect_garbage(svc)
    assert c.read(bid, 0, 0, 0) == b""
    with pytest.raises(VersionUnpublished):
        c.pin(bid, 0)
    assert c.read(bid, 1, 0, 32) == b"x" * 32


def test_gc_keeps_nested_branch_roots_at_inherited_versions():
    """A fork taken through an intermediate branch at an *inherited*
    version (C = branch(B, 3) with B = branch(A, 5): v3 is owned by A)
    is protected on the owner blob — GC on A must not retire v3, or
    C's published root snapshot would be permanently unreadable."""
    svc = BlobSeerService(n_providers=4, n_meta_shards=4)
    c = svc.client()
    a = c.create(psize=16)
    for i in range(5):
        c.write(a, bytes([i + 1]) * 64, 0)     # v1..v5 overwrite the range
    b = c.branch(a, 5)
    cc = c.branch(b, 3)                        # fork point owned by A, via B

    c.set_retention(a, 1)
    collect_garbage(svc)
    assert sorted(svc.vm.retired_versions(a)) == [1, 2, 4]  # v3 + v5 kept
    # C's root snapshot stays byte-identical and extensible
    assert c.read(cc, 3, 0, 64) == bytes([3]) * 64
    c.append(cc, b"z" * 16)                    # C v4
    assert c.read(cc, 4, 64, 16) == b"z" * 16
    assert c.read(b, 5, 0, 64) == bytes([5]) * 64  # B's direct root too


def test_admitted_read_survives_retire_intent():
    """A read the lease admitted completes even when the retire-intent
    lands before its metadata walk: enter_read returns (size,
    root_pages) atomically, so the read path makes no further
    retired-checked version-manager call — 'rejected at enter_read or
    drained', with no third outcome."""
    from repro.core import segment_tree as st
    from repro.core.pages import pages_spanned
    from repro.core.version_manager import RetiredVersion as RV

    svc = BlobSeerService(n_providers=4, n_meta_shards=4)
    c = svc.client()
    bid = c.create(psize=16)
    c.write(bid, b"A" * 64, 0)                 # v1
    c.write(bid, b"B" * 64, 0)                 # v2

    total, root = svc.vm.enter_read(bid, 1, client="r")  # admitted
    try:
        _, newly = svc.vm.plan_retirement(bid, keep_extra=[2],
                                          explicit=True, client="gc")
        assert 1 in newly                      # intent landed mid-read
        with pytest.raises(RV):                # new admissions rejected...
            svc.vm.enter_read(bid, 1)
        # ...but the in-flight read still completes off its admission
        # snapshot (the sweep's drain barrier is waiting on the lease)
        p0, p1 = pages_spanned(0, total, 16)
        pd = st.read_meta(svc.dht, c._owner_fn(bid), 1, root, p0, p1,
                          peer="r")
        assert c._fetch_ranges(pd, 0, total, 16) == b"A" * 64
    finally:
        svc.vm.exit_read(bid, 1, client="r")


def test_restore_resweep_failure_unfinalizes_for_retry(tmp_path):
    """A version finalized pre-crash whose restore-time re-deletes fail
    (providers down during recovery) is pulled back out of the
    finalized set, so ordinary live rounds retry it — the resurrected
    nodes/pages don't leak until the next restart."""
    from repro.core.gc import resweep_after_restore

    spool = str(tmp_path / "spool")
    wal = str(tmp_path / "wal.jsonl")
    svc = BlobSeerService(n_providers=3, n_meta_shards=3,
                          spool_dir=spool, wal_path=wal)
    c = svc.client()
    bid = c.create(psize=16)
    for i in range(8):
        c.write(bid, bytes([i + 1]) * 128, 0)  # overwrites: old pages die
    c.set_retention(bid, keep_last=2)
    s = collect_garbage(svc)
    assert s["retired_versions"] == 6 and s["failed_deletes"] == 0
    assert svc.vm.sweep_pending(bid) == []     # all finalized pre-crash

    svc2 = BlobSeerService.restore(spool, wal, n_providers=3,
                                   n_meta_shards=3, resweep=False)
    for p in svc2.pm.all_providers():          # every endpoint down...
        svc2.kill_provider(p.pid)
    rs = resweep_after_restore(svc2)           # ...during the resweep
    assert rs["failed_deletes"] > 0
    # failed versions are un-finalized (WAL'd): live rounds see them
    assert svc2.vm.sweep_pending(bid)
    for p in svc2.pm.all_providers():
        svc2.revive_provider(p.pid)
    s2 = collect_garbage(svc2)
    assert s2["failed_deletes"] == 0
    assert svc2.vm.sweep_pending(bid) == []    # retried and re-finalized
    # and the WAL round-trips the unswept records: a third cold start
    # replays to the same settled state
    svc3 = BlobSeerService.restore(spool, wal, n_providers=3,
                                   n_meta_shards=3)
    assert svc3.vm.sweep_pending(bid) == []
    with pytest.raises(RetiredVersion):
        svc3.client().read(bid, 3, 0, 16)


def test_restore_never_resurrects_swept_versions(tmp_path):
    """WAL retire records survive a cold restart: swept versions stay
    typed-unreadable and their garbage is re-deleted after rebuild."""
    spool = str(tmp_path / "spool")
    wal = str(tmp_path / "wal.jsonl")
    svc = BlobSeerService(n_providers=3, n_meta_shards=3,
                          spool_dir=spool, wal_path=wal)
    c = svc.client()
    bid = c.create(psize=16)
    for i in range(6):
        c.append(bid, bytes([i + 1]) * 48)
    c.set_retention(bid, keep_last=2)
    stats = collect_garbage(svc)
    assert stats["retired_versions"] == 4

    svc2 = BlobSeerService.restore(spool, wal, n_providers=3, n_meta_shards=3)
    c2 = svc2.client()
    assert sorted(svc2.vm.retired_versions(bid)) == [1, 2, 3, 4]
    with pytest.raises(RetiredVersion):
        c2.read(bid, 2, 0, 16)
    for v in (5, 6):
        assert len(c2.read(bid, v, 0, c2.get_size(bid, v))) == 48 * v
    # rebuilt-then-resweeped: no retired-only metadata left behind
    live_nodes = collect_garbage(svc2)["live_nodes"]
    assert svc2.dht.total_keys() <= live_nodes * svc2.dht.replication
