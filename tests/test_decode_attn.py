"""shard_map decode attention vs the reference GSPMD decode path."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.distributed import axes as AX
from repro.distributed import partitioning as PT
from repro.launch.mesh import make_mesh
from repro.models import build_model


@pytest.mark.parametrize("arch", ["olmo-1b", "qwen1.5-32b"])
def test_shard_decode_matches_reference(arch):
    """On a 1x1 mesh the shard_map schedule must agree numerically with
    the plain decode path (single shard = pure reordering of the math)."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(1))
    B, T, T0 = 2, 12, 6
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, T), 0, cfg.vocab_size)
    mesh = make_mesh((1, 1), ("data", "model"))

    def run(strategy):
        AX.set_logical_rules(PT.get_rules(strategy), mesh)
        try:
            cache = model.init_cache(B, max_len=T + 4)
            lg, cache = model.prefill(params, {"tokens": toks[:, :T0]}, cache)
            outs = [np.asarray(lg)]
            for t in range(T0, T):
                lg, cache = model.decode_step(params, toks[:, t], jnp.asarray(t),
                                              cache)
                outs.append(np.asarray(lg))
            return outs
        finally:
            AX.clear_logical_rules()

    ref = run("tp_serve")
    got = run("tp_serve_sm")
    for a, b in zip(ref, got):
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-5)


def test_shard_decode_flag_resolution():
    rules = PT.get_rules("tp_serve_sm")
    assert rules.get(PT.SHARD_DECODE_FLAG)
    assert not PT.get_rules("tp_serve").get(PT.SHARD_DECODE_FLAG)
