import os
import sys

# tests must see exactly ONE device (the dry-run sets its own XLA_FLAGS
# in a separate process); make sure nothing leaks in from the caller.
os.environ.pop("XLA_FLAGS", None)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import pytest


@pytest.fixture
def service():
    from repro.core import BlobSeerService

    return BlobSeerService(n_providers=8, n_meta_shards=4)


@pytest.fixture
def client(service):
    return service.client()
