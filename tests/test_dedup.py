"""Content-addressed dedup: digest twins, handshake, refcount GC.

Covers the PR contract end to end at unit scale:

* the numpy-only ``hostdigest`` twin is bit-identical to the kernel
  reference over page sizes, tails and dtypes;
* ``write_many`` with dedup fingerprints every page, batches exactly
  one lookup round per burst, ships only unmatched pages, and reuses
  descriptors for matched ones;
* ``dedup=False`` never touches the index (the pre-dedup wire
  schedule survives untouched);
* refcounted pages survive their co-owner's retirement and are
  deleted only when the last referencing version retires;
* a restarted checkpointer (no digest cache) re-ships nothing the
  index already holds;
* the RPC counter registry: ``rpc_report()`` and
  ``reset_rpc_counters()`` walk the same family list, so no counter
  can be reported but never reset (or vice versa).
"""

import numpy as np
import pytest

from repro.core import BlobSeerService
from repro.core.gc import collect_garbage
from repro.kernels.hostdigest import host_page_digest

PSIZE = 4096


def _page(tag: int, n: int = PSIZE) -> bytes:
    return bytes([tag % 251 + 1]) * n


def _svc(**kw):
    kw.setdefault("n_providers", 4)
    kw.setdefault("n_meta_shards", 2)
    return BlobSeerService(**kw)


# ---------------------------------------------------------------------------
# digest twins
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("psize,total", [
    (64 * 1024, 3 * 64 * 1024),   # whole pages, block-aligned
    (4096, 4096 * 2 + 100),       # short tail page
    (100, 7 * 100),               # page smaller than one digest block
    (8, 8),                       # degenerate single tiny page
])
def test_host_digest_matches_kernel_ref(psize, total):
    jnp = pytest.importorskip("jax.numpy")
    from repro.kernels.ops import as_page_words
    from repro.kernels.ref import ref_page_digest

    rng = np.random.default_rng(total)
    data = rng.integers(0, 256, size=total, dtype=np.uint8).tobytes()

    words = as_page_words(jnp.asarray(np.frombuffer(data, np.uint8)), psize)
    kernel = np.asarray(ref_page_digest(words))

    n_pages = -(-len(data) // psize)
    for p in range(n_pages):
        host = host_page_digest(data[p * psize:(p + 1) * psize], psize)
        assert host == (int(kernel[p, 0]), int(kernel[p, 1]))


def test_host_digest_distinguishes_order_and_length():
    a = host_page_digest(b"\x01\x02\x03\x04", PSIZE)
    b = host_page_digest(b"\x04\x03\x02\x01", PSIZE)
    assert a != b  # polynomial digest is order-sensitive
    # zero-padding alone must not collide across payload lengths...
    assert host_page_digest(b"\x01\x00", PSIZE) == \
        host_page_digest(b"\x01\x00\x00", PSIZE)
    # ...which is why the index key includes the payload length too.


# ---------------------------------------------------------------------------
# two-phase handshake on the write path
# ---------------------------------------------------------------------------


def test_write_many_dedup_one_lookup_round_per_burst():
    svc = _svc(dedup=True)
    c = svc.client("w")
    bid = c.create(psize=PSIZE)
    bufs = [_page(t) for t in range(4)]

    c.append_many(bid, bufs)
    r1 = svc.rpc_report()
    assert r1["dedup_lookup_rounds"] == 1       # one batched probe
    assert r1["dedup_lookup_keys"] == 4
    assert r1["dedup_hits"] == 0
    assert r1["dedup_registered"] == 4
    pages_before = svc.storage_report()["pages"]

    # identical burst: every page matches, zero new pages stored
    v2 = c.append_many(bid, bufs)[-1]
    r2 = svc.rpc_report()
    assert r2["dedup_lookup_rounds"] == 2
    assert r2["dedup_hits"] == 4
    assert r2["dedup_hit_bytes"] == 4 * PSIZE
    assert svc.storage_report()["pages"] == pages_before

    # both versions read back correctly through the shared pages
    assert c.read(bid, v2, 0, 8 * PSIZE) == b"".join(bufs) * 2


def test_write_many_accepts_precomputed_digests():
    svc = _svc(dedup=True)
    c = svc.client("w")
    bid = c.create(psize=PSIZE)
    bufs = [_page(9), _page(10)]
    digests = [[host_page_digest(b, PSIZE)] for b in bufs]
    c.write_many(bid, [(bufs[0], 0), (bufs[1], PSIZE)], digests=digests)
    # same content again, digests passed through: all hits
    v = c.write_many(bid, [(bufs[1], 0), (bufs[0], PSIZE)],
                     digests=[digests[1], digests[0]])[-1]
    rpc = svc.rpc_report()
    assert rpc["dedup_hits"] == 2
    assert c.read(bid, v, 0, 2 * PSIZE) == bufs[1] + bufs[0]


def test_dedup_disabled_never_touches_index():
    svc = _svc()        # dedup defaults off; index deployed but idle
    c = svc.client("w")
    bid = c.create(psize=PSIZE)
    bufs = [_page(t) for t in range(3)]
    c.append_many(bid, bufs)
    c.append_many(bid, bufs)    # identical content, still shipped
    # digests passed but dedup off: ignored, not an error
    c.write_many(bid, [(bufs[0], 0)],
                 digests=[[host_page_digest(bufs[0], PSIZE)]])
    rpc = svc.rpc_report()
    assert not any(v for k, v in rpc.items() if k.startswith("dedup_"))
    assert not svc.dedup_index.ever_registered
    # GC takes the fast path too: no release/guard RPCs ever issued
    collect_garbage(svc, client="gc")
    assert svc.rpc_report()["dedup_release_rounds"] == 0


# ---------------------------------------------------------------------------
# refcount-aware GC
# ---------------------------------------------------------------------------


def test_shared_pages_survive_co_owner_retirement():
    svc = _svc(dedup=True)
    c = svc.client("w")
    a = c.create(psize=PSIZE)
    b = c.create(psize=PSIZE)
    shared = [_page(t) for t in range(3)]
    c.append_many(a, shared)
    c.append_many(b, shared)            # all hits: refcounts now 2
    assert svc.rpc_report()["dedup_hits"] == 3
    shared_pids = set(svc.dedup_index.indexed_pages())
    assert all(svc.dedup_index.refcount(p) == 2 for p in shared_pids)

    # retire blob a's versions (overwrite everything, GC the history):
    # shared pages must survive at refcount 1
    c.set_retention(a, keep_last=1)
    c.write(a, _page(50) * 3, 0)        # v4 references none of v1..v3
    collect_garbage(svc, client="gc")
    assert c.read(b, 3, 0, 3 * PSIZE) == b"".join(shared)
    assert all(svc.dedup_index.refcount(p) == 1 for p in shared_pids)
    assert svc.rpc_report()["dedup_dropped"] == 0

    # retire blob b's versions too: last reference gone, bytes deleted
    c.set_retention(b, keep_last=1)
    c.write(b, _page(51) * 3, 0)
    collect_garbage(svc, client="gc")
    assert not shared_pids & set(svc.dedup_index.indexed_pages())
    assert svc.rpc_report()["dedup_dropped"] >= 3
    # only the two overwrites' pages remain in the store
    assert svc.storage_report()["pages"] == 6


def test_restart_checkpoint_ships_no_known_pages():
    from repro.checkpoint.blobckpt import BlobCheckpointer

    svc = _svc(dedup=True)
    model = {"w": np.arange(8 * PSIZE // 4, dtype=np.int32)}
    ck = BlobCheckpointer(svc.client("ck"), psize=PSIZE, header_pages=2)
    ck.save(model, step=0)

    def provider_in():
        return sum(svc.wire.stats(p.pid).bytes_in
                   for p in svc.pm.all_providers())

    # fresh checkpointer, no digest cache: every page scans dirty, but
    # the handshake matches all model leaves — only the manifest and
    # commit-pointer pages (never dedupable) ship bytes
    ck2 = BlobCheckpointer(svc.client("ck2"), blob_id=ck.blob_id,
                           psize=PSIZE, header_pages=2)
    before = provider_in()
    stats = ck2.save(model, step=1)
    assert stats.pages_written == 8     # all scanned dirty...
    assert provider_in() - before <= 3 * PSIZE   # ...~none shipped
    got = ck2.restore({"w": np.zeros(8 * PSIZE // 4, dtype=np.int32)})
    assert np.array_equal(got["w"], model["w"])


# ---------------------------------------------------------------------------
# counter-registry audit (report and reset walk the same families)
# ---------------------------------------------------------------------------


def test_rpc_counter_registry_reset_covers_report():
    svc = _svc(dedup=True)
    c = svc.client("w")
    bid = c.create(psize=PSIZE)
    c.append_many(bid, [_page(1), _page(1)])
    c.read(bid, 1, 0, PSIZE)
    collect_garbage(svc, client="gc")

    before = svc.rpc_report()
    assert any(before.values())         # workload actually counted
    registry_keys = {f"{prefix}{k}"
                     for prefix, get, _reset in svc._counter_families()
                     for k in get()}
    svc.reset_rpc_counters()
    after = svc.rpc_report()

    # same key set before and after, every raw counter back to zero,
    # and every reported raw counter belongs to a registered family
    # (derived node_cache_* keys are computed from dht_ counters;
    # page_cache occupancy gauges survive reset by design — a counter
    # reset brackets a measurement, it must not evict cache contents)
    assert set(before) == set(after)
    derived = {"node_cache_hits", "node_cache_hit_bytes"}
    gauges = {"page_cache_used_bytes", "page_cache_entries"}
    assert set(after) - derived == registry_keys
    assert not any(v for k, v in after.items() if k not in gauges), after
