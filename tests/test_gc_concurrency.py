"""GC epochs inside a 64-client simulated workload with failure injection.

Acceptance (ISSUE 3): the gc_mixed scenario — GC rounds racing pinned
readers and appenders, with a provider downed mid-run — passes
deterministically: the same seed produces an identical event-trace
digest with GC in the schedule, no read of a kept (pinned) version ever
fails mid-sweep, and the sweep is visible as batched RPCs in
``rpc_report()``.
"""

from repro.core.scenarios import run_scenario

N_CLIENTS = 64
SEED = 7
FAILURES = [(0.004, "prov-0003")]


def _run(seed=SEED):
    return run_scenario(
        "gc_mixed", N_CLIENTS, seed=seed, ops_per_client=3,
        data_replication=2, failures=FAILURES,
    )


def _sum(result, key):
    return sum(v.get(key, 0) for v in result.client_results.values()
               if isinstance(v, dict))


def test_gc_while_active_no_kept_read_ever_fails():
    r = _run()
    assert r.errors == {}
    # every pinned read of every reader, across every GC epoch: zero failures
    assert _sum(r, "pinned_failures") == 0
    # GC actually ran and retired history mid-traffic
    assert _sum(r, "retired_versions") > 0
    gc_result = r.client_results["gc_mixed-000"]
    assert gc_result["ops"] >= 2


def test_gc_while_active_sweeps_through_the_wire():
    r = _run()
    # the sweep shows up as batched delete RPCs, grouped per shard and
    # per provider endpoint — never as silent store mutations
    assert r.rpc["dht_delete_keys"] > 0
    assert 0 < r.rpc["dht_delete_shard_rpcs"] < r.rpc["dht_delete_keys"]
    assert r.rpc["provider_swept_pages"] > 0
    assert 0 < r.rpc["provider_sweep_rounds"] < r.rpc["provider_swept_pages"]


def test_gc_while_active_replays_identically():
    a, b = _run(), _run()
    assert a.trace_digest == b.trace_digest
    assert a.rpc == b.rpc
    assert a.ops == b.ops and a.bytes_moved == b.bytes_moved


def test_gc_schedule_varies_with_seed():
    a, b = _run(seed=SEED), _run(seed=SEED + 1)
    assert a.trace_digest != b.trace_digest  # different interleavings
    # ... but the safety property holds on every schedule
    assert _sum(b, "pinned_failures") == 0
    assert b.errors == {}
