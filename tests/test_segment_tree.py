"""Property tests: the versioned segment tree against a flat oracle."""

import random

import pytest

try:
    from hypothesis import HealthCheck, given, settings, strategies as st
except ImportError:  # property tests skip when hypothesis is unavailable
    class _AnyStrategy:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()
    HealthCheck = _AnyStrategy()

    def given(*a, **k):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*a, **k):
        return lambda fn: fn

from repro.core import BlobSeerService


class Oracle:
    """Flat reference model of a versioned blob."""

    def __init__(self):
        self.versions = {0: b""}

    def write(self, data: bytes, offset: int) -> int:
        v = max(self.versions)
        cur = bytearray(self.versions[v])
        if offset > len(cur):
            raise ValueError
        cur[offset : offset + len(data)] = data
        self.versions[v + 1] = bytes(cur)
        return v + 1

    def append(self, data: bytes) -> int:
        return self.write(data, len(self.versions[max(self.versions)]))


ops_strategy = st.lists(
    st.tuples(
        st.sampled_from(["write", "append"]),
        st.integers(1, 70),        # size
        st.floats(0.0, 1.0),       # relative offset
        st.integers(0, 255),       # fill byte
    ),
    min_size=1,
    max_size=25,
)


@given(ops=ops_strategy, psize=st.sampled_from([4, 16, 64]))
@settings(max_examples=40, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
def test_blob_matches_oracle(ops, psize):
    svc = BlobSeerService(n_providers=4, n_meta_shards=2)
    c = svc.client()
    bid = c.create(psize=psize)
    oracle = Oracle()
    rnd = random.Random(0)
    for kind, size, rel_off, fill in ops:
        data = bytes([fill]) * size
        if kind == "append" or not oracle.versions[max(oracle.versions)]:
            v = c.append(bid, data)
            oracle.append(data)
        else:
            cur_len = len(oracle.versions[max(oracle.versions)])
            off = int(rel_off * cur_len)
            v = c.write(bid, data, off)
            oracle.write(data, off)
    # every version fully readable + random subranges
    for v, want in oracle.versions.items():
        if v == 0:
            continue
        assert c.get_size(bid, v) == len(want)
        assert c.read(bid, v, 0, len(want)) == want
        for _ in range(3):
            if len(want) < 2:
                break
            off = rnd.randrange(0, len(want) - 1)
            n = rnd.randrange(1, len(want) - off)
            assert c.read(bid, v, off, n) == want[off : off + n]


def test_metadata_node_sharing():
    """A one-page update to an N-page blob creates O(log N) nodes."""
    svc = BlobSeerService(n_providers=4, n_meta_shards=2)
    c = svc.client()
    bid = c.create(psize=16)
    c.write(bid, b"x" * 16 * 256, 0)       # 256 pages
    before = svc.dht.total_keys()
    c.write(bid, b"y" * 16, 128 * 16)      # one page
    created = svc.dht.total_keys() - before
    # path from leaf to root: log2(256)+1 = 9 nodes
    assert created == 9


def test_append_grows_tree_with_shared_left_subtree():
    svc = BlobSeerService(n_providers=4, n_meta_shards=2)
    c = svc.client()
    bid = c.create(psize=16)
    c.write(bid, b"a" * 16 * 4, 0)         # 4 pages, root (0,4)
    before = svc.dht.total_keys()
    c.append(bid, b"b" * 16)               # page 4 -> root (0,8)
    created = svc.dht.total_keys() - before
    # new: leaf(4,1), (4,2), (4,4), root(0,8) = 4 nodes (paper Fig 1c)
    assert created == 4


def test_dht_distribution_is_balanced():
    svc = BlobSeerService(n_providers=4, n_meta_shards=8)
    c = svc.client()
    bid = c.create(psize=4)
    for i in range(40):
        c.append(bid, bytes([i]) * 24)
    loads = [n for _, n in svc.dht.shard_loads()]
    assert min(loads) > 0
    assert max(loads) < 4 * (sum(loads) / len(loads))
