"""Seeded property tests: cache coherence under random read/GC races.

Hypothesis drives random interleavings of pinned readers, recency
readers, garbage-making writers and GC rounds on the deterministic
Simulator, with the shared page cache enabled.  Invariants:

* **no swept page is ever served from (or left in) the cache** — after
  every GC round, and at the end of the history, every cached page id
  still exists on at least one provider store;
* **a pinned read that a cache-free run would admit never fails** — the
  same seeded history replayed with the cache disabled admits exactly
  the reads the cached run admits; in both runs pinned reads succeed
  with byte-identical content;
* retired-version reads answer the typed ``RetiredVersion`` in both
  runs (never a stray ``KeyError`` from a swept page a cache might have
  resurrected).
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip when hypothesis is unavailable
    class _AnyStrategy:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def given(*a, **k):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*a, **k):
        return lambda fn: fn

from repro.core import BlobSeerService, RetiredVersion, Simulator, Wire
from repro.core.gc import collect_garbage

PSIZE = 16
CHUNK = 4 * PSIZE


def _stored_page_ids(svc):
    stored = set()
    for p in svc.pm.all_providers():
        stored.update(p.store.iter_pids())
    return stored


def _run_history(seed, n_clients, ops_per_client, keep_last, cache_bytes):
    """One seeded concurrent history; returns per-client stats + svc."""
    sim = Simulator(seed=seed)
    svc = BlobSeerService(wire=Wire(clock=sim), n_providers=4,
                          n_meta_shards=4, page_cache_bytes=cache_bytes)
    setup = svc.client("setup")
    bid = setup.create(psize=PSIZE)
    pin_payload = bytes([199]) * CHUNK
    setup.append(bid, pin_payload)
    setup.set_retention(bid, keep_last)
    v_pin = setup.get_recent(bid)

    def program(ci):
        def prog():
            c = svc.client(f"c{ci:02d}")
            stats = {"pinned_fail": 0, "retired": 0, "reads": 0, "ops": 0}
            role = ci % 3
            lease = c.pin(bid, v_pin) if role == 0 else None
            try:
                for k in range(ops_per_client):
                    if role == 0:          # pinned reader: must NEVER fail
                        try:
                            data = c.read(bid, v_pin, 0, CHUNK)
                            assert data == pin_payload
                            stats["reads"] += 1
                        except Exception:  # noqa: BLE001 - any failure is a bug
                            stats["pinned_fail"] += 1
                    elif role == 1:        # garbage-making writer
                        tag = (ci * 37 + k * 11) % 251 + 1
                        if k % 2 == 0:
                            c.append(bid, bytes([tag]) * CHUNK)
                        else:
                            c.write(bid, bytes([tag]) * CHUNK, 0)
                    else:                  # recency reader + GC driver
                        if k % 2 == 0:
                            try:
                                v = c.get_recent(bid)
                                size = c.get_size(bid, v)
                                take = min(CHUNK, size)
                                c.read(bid, v, size - take, take)
                                stats["reads"] += 1
                            except RetiredVersion:
                                stats["retired"] += 1  # typed answer: allowed
                        else:
                            collect_garbage(svc, client=f"gc{ci:02d}",
                                            orphan_grace=None)
                            # coherence invariant, checked mid-history:
                            # nothing cached points at a swept page
                            cached = svc.page_cache.cached_page_ids()
                            assert cached <= _stored_page_ids(svc), (
                                "cache holds swept pages: "
                                f"{cached - _stored_page_ids(svc)}"
                            )
                    stats["ops"] += 1
            finally:
                if lease is not None:
                    c.unpin(lease)
            return stats

        return prog

    for ci in range(n_clients):
        sim.spawn(program(ci), name=f"c{ci:02d}")
    sim.run()
    return svc, sim.results()


@settings(max_examples=8, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**16),
    keep_last=st.integers(min_value=1, max_value=3),
)
def test_cache_never_serves_swept_pages_nor_fails_pinned_reads(seed, keep_last):
    svc, results = _run_history(seed, n_clients=6, ops_per_client=4,
                                keep_last=keep_last,
                                cache_bytes=64 * 1024 * 1024)
    # the cache-free twin admits the same programs; its pinned reads
    # must succeed too (the cache may only remove RPCs, not admissions)
    svc0, results0 = _run_history(seed, n_clients=6, ops_per_client=4,
                                  keep_last=keep_last, cache_bytes=0)
    for name, r in list(results.items()) + list(results0.items()):
        assert r["pinned_fail"] == 0, (name, r)
    # a cached run performs at least every pinned read the cache-free
    # run performed (same programs, same per-client op counts)
    assert sum(r["ops"] for r in results.values()) == \
        sum(r["ops"] for r in results0.values())
    # end-state coherence: no cached page id outlived its sweep
    assert svc.page_cache.cached_page_ids() <= _stored_page_ids(svc)
    assert svc0.page_cache.cached_page_ids() == set()


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**16))
def test_cached_history_replays_identically(seed):
    """Cache hits, single-flight waits and prefetch arrivals are part of
    the deterministic schedule: same seed -> same retired sets, same
    storage, same cache contents."""
    a_svc, _ = _run_history(seed, n_clients=5, ops_per_client=3,
                            keep_last=2, cache_bytes=64 * 1024 * 1024)
    b_svc, _ = _run_history(seed, n_clients=5, ops_per_client=3,
                            keep_last=2, cache_bytes=64 * 1024 * 1024)
    for bid in a_svc.vm.known_blobs():
        assert a_svc.vm.retired_versions(bid) == b_svc.vm.retired_versions(bid)
    assert a_svc.storage_report()["pages"] == b_svc.storage_report()["pages"]
    # page *ids* are process-global gensyms (they differ between runs);
    # the cache's shape and every counter must still replay exactly
    assert (len(a_svc.page_cache.cached_page_ids())
            == len(b_svc.page_cache.cached_page_ids()))
    assert a_svc.page_cache.counters() == b_svc.page_cache.counters()
