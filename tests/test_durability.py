"""Durability tier: erasure coding, storage classes, scrub/repair.

Covers the self-healing plane end to end — the RS-style codec itself,
EC blobs through the full write/read path, survival of any ``m``
provider losses (and typed failure at ``m + 1``), the scrub plane's
detect/repair loop (gaps, bitrot, budget deferral), the cold tier with
lifecycle demotion, and the four repair-path bugfix regressions
(rereplicate losses, dedup refresh, ``steps()`` error typing,
``FilePageStore`` fsync/tmp hygiene).
"""

import itertools
import os

import pytest

from repro.core.durability import lifecycle_round, scrub_round
from repro.core.placement import (
    ErasureCodedPolicy,
    ReplicationPolicy,
    ec_decode,
    ec_encode,
    logical_pid,
    page_codec,
    parse_policy,
    shard_id,
    split_shard,
)
from repro.core.provider import PageIntegrityError
from repro.core.service import BlobSeerService
from repro.core.sim import Simulator
from repro.core.transport import EndpointDown, Wire


def _corrupt(prov, pid=None) -> str:
    """Flip one byte of a stored page behind the provider's back
    (digest bookkeeping untouched — silent bitrot)."""
    vic = pid if pid is not None else sorted(prov.store.iter_pids())[0]
    raw = prov.store.get(vic)
    prov.store.delete(vic)
    prov.store.put(vic, bytes([raw[0] ^ 0xFF]) + raw[1:])
    return vic


# ---------------------------------------------------------------- codec


def test_ec_codec_roundtrip_all_loss_patterns():
    payload = bytes(range(256)) * 17 + b"tail"
    for k, m in ((2, 1), (3, 2), (6, 2)):
        shards = ec_encode(payload, k, m)
        assert len(shards) == k + m
        for subset in itertools.combinations(range(k + m), k):
            got = ec_decode([(j, shards[j]) for j in subset], k, m)
            assert got == payload, (k, m, subset)


def test_ec_codec_small_and_unaligned_payloads():
    for length in (0, 1, 5, 6, 7, 4095, 4096, 4097):
        payload = bytes((i * 31) % 256 for i in range(length))
        shards = ec_encode(payload, 6, 2)
        # parity-heavy subset: drop two data shards
        subset = [2, 3, 4, 5, 6, 7]
        assert ec_decode([(j, shards[j]) for j in subset], 6, 2) == payload


def test_ec_codec_insufficient_shards_raises():
    shards = ec_encode(b"x" * 100, 3, 2)
    with pytest.raises(ValueError):
        ec_decode([(0, shards[0]), (1, shards[1])], 3, 2)


def test_policy_parsing_and_page_ids():
    assert parse_policy("rep:3") == ReplicationPolicy(3)
    assert parse_policy("ec:6+2") == ErasureCodedPolicy(6, 2)
    p = parse_policy("ec:4+2")
    assert p.width(1) == 6 and p.tag == "ec4+2"
    from repro.core.pages import fresh_page_id

    pid = fresh_page_id(tag=p.tag)
    assert page_codec(pid) == (4, 2)
    sid = shard_id(pid, 3)
    assert split_shard(sid) == (pid, 3)
    assert logical_pid(sid) == pid
    plain = fresh_page_id()
    assert page_codec(plain) is None
    assert split_shard(plain) is None
    assert logical_pid(plain) == plain


# ------------------------------------------------------- EC blob end-to-end


def _ec_service(n_providers=10, psize=4096, **kw):
    svc = BlobSeerService(n_providers=n_providers, n_meta_shards=2,
                          verify_digests=True, **kw)
    c = svc.client("w")
    bid = c.create(psize=psize)
    svc.set_blob_placement(bid, "ec:6+2")
    return svc, c, bid


def test_ec_blob_write_read_and_overhead():
    svc, c, bid = _ec_service()
    payload = bytes((i * 7) % 256 for i in range(4 * 4096))
    v = c.append(bid, payload)
    assert c.read(bid, v, 0, len(payload)) == payload
    # sub-range reads decode the page once and slice
    assert c.read(bid, v, 5000, 1000) == payload[5000:6000]
    stored = sum(p.stored_bytes() for p in svc.pm.all_providers())
    assert stored / len(payload) <= 1.5  # 8/6 + shard headers


def test_ec_survives_any_m_provider_losses():
    svc, c, bid = _ec_service()
    payload = b"\xa5" * (2 * 4096)
    v = c.append(bid, payload)
    # find one page's shard group and kill any 2 of its 8 providers
    provs = {pid: info[1] for pid, info in svc.vm.page_locations().items()}
    group = next(iter(provs.values()))
    for a, b in ((0, 1), (3, 7), (6, 7)):
        svc.kill_provider(group[a])
        svc.kill_provider(group[b])
        assert svc.client("r").read(bid, v, 0, len(payload)) == payload
        svc.revive_provider(group[a])
        svc.revive_provider(group[b])


def test_ec_typed_failure_past_m_losses():
    svc, c, bid = _ec_service(page_cache_bytes=0)
    payload = b"\x42" * 4096
    v = c.append(bid, payload)
    group = next(iter(svc.vm.page_locations().values()))[1]
    for pid in group[:3]:  # m + 1 = 3 of the 8 shard homes
        svc.kill_provider(pid)
    with pytest.raises(EndpointDown):
        svc.client("r").read(bid, v, 0, len(payload))


def test_ec_placement_requires_width_providers():
    svc = BlobSeerService(n_providers=4, n_meta_shards=2)
    c = svc.client("w")
    bid = c.create(psize=1024)
    with pytest.raises(RuntimeError):
        svc.set_blob_placement(bid, "ec:6+2")


# ------------------------------------------------------------ scrub/repair


def test_scrub_repairs_dead_provider_gaps_and_relocates():
    svc, c, bid = _ec_service()
    payload = bytes((i * 3) % 256 for i in range(3 * 4096))
    v = c.append(bid, payload)
    svc.kill_provider("prov-0000")
    svc.kill_provider("prov-0003")
    stats = svc.scrub()
    assert stats["damaged_pages"] > 0
    assert stats["losses"] == []
    assert svc.scrub()["damaged_pages"] == 0  # converged
    # repaired shards live on NEW providers via the relocation overlay:
    # kill a third original home — decode now needs a relocated shard
    group = next(iter(svc.vm.page_locations().values()))[1]
    alive_homes = [p for p in group if not svc.wire.is_down(p)]
    svc.kill_provider(alive_homes[0])
    assert svc.client("r").read(bid, v, 0, len(payload)) == payload
    assert svc.pm.rpc_counters()["repair_pages"] > 0
    assert svc.pm.rpc_counters()["repair_bytes"] > 0


def test_scrub_detects_and_repairs_corruption_in_place():
    svc = BlobSeerService(n_providers=4, n_meta_shards=2,
                          data_replication=2, verify_digests=True)
    c = svc.client("w")
    bid = c.create(psize=1024)
    v = c.append(bid, b"A" * 4096)
    prov = svc.pm.get("prov-0001")
    vic = _corrupt(prov)
    good = bytes([prov.store.get(vic)[0] ^ 0xFF]) + prov.store.get(vic)[1:]
    # reads fail over past the corrupt copy meanwhile
    assert c.read(bid, v, 0, 4096) == b"A" * 4096
    stats = svc.scrub()
    assert stats["corrupt_copies"] == 1
    assert stats["repaired_pages"] >= 1
    assert prov.store.get(vic) == good  # restored in place
    assert svc.scrub()["damaged_pages"] == 0


def test_scrub_budget_defers_and_converges():
    svc = BlobSeerService(n_providers=6, n_meta_shards=2,
                          data_replication=2, verify_digests=True)
    c = svc.client("w")
    bid = c.create(psize=1024)
    c.append(bid, b"B" * 8192)
    svc.kill_provider("prov-0002")
    first = svc.scrub(budget_bytes=3000)
    assert first["repair_bytes"] <= 3000
    if first["damaged_pages"] > first["repaired_pages"]:
        assert first["deferred_pages"] > 0
    for _ in range(16):
        if svc.scrub(budget_bytes=3000)["damaged_pages"] == 0:
            break
    assert svc.scrub(budget_bytes=3000)["damaged_pages"] == 0


def test_scrub_reports_unrecoverable_pages_as_losses():
    svc = BlobSeerService(n_providers=3, n_meta_shards=2,
                          data_replication=1, verify_digests=True)
    c = svc.client("w")
    bid = c.create(psize=1024)
    c.append(bid, b"C" * 2048)
    # replication 1: killing a page's only holder is unrecoverable
    holders = {info[1][0] for info in svc.vm.page_locations().values()}
    for h in holders:
        svc.kill_provider(h)
    stats = svc.scrub()
    assert len(stats["losses"]) == len(svc.vm.page_locations())
    assert stats["repaired_pages"] == 0


def test_read_fails_over_corrupt_replica_typed():
    """verify_digests=True: a corrupt copy raises PageIntegrityError at
    the provider; with no surviving replica the reader sees the typed
    EndpointDown, never silent bad bytes."""
    svc = BlobSeerService(n_providers=2, n_meta_shards=2,
                          data_replication=1, verify_digests=True,
                          page_cache_bytes=0)
    c = svc.client("w")
    bid = c.create(psize=1024)
    v = c.append(bid, b"D" * 1024)
    (pid, (holder, *_rest), _len), = [
        (p, i[1], i[2]) for p, i in svc.vm.page_locations().items()]
    prov = svc.pm.get(holder)
    _corrupt(prov, pid)
    with pytest.raises(PageIntegrityError):
        prov.get_page(pid)
    with pytest.raises(EndpointDown):
        c.read(bid, v, 0, 1024)


# --------------------------------------------------- cold tier + lifecycle


def test_cold_tier_lifecycle_demotion_and_read_through():
    sim = Simulator(seed=0)
    svc = BlobSeerService(n_providers=4, n_meta_shards=2,
                          wire=Wire(clock=sim), n_cold_providers=2,
                          verify_digests=True)

    def prog():
        c = svc.client("w")
        bid = c.create(psize=1024)
        v = c.append(bid, b"E" * 4096)
        svc.set_lifecycle(bid, 0.5)
        assert lifecycle_round(svc)["demoted"] == 0  # too young
        svc.clock.sleep(1.0)
        stats = lifecycle_round(svc)
        assert stats["demoted"] == 4
        cold = [p for p in svc.pm.all_providers() if p.tier == "cold"]
        hot_pages = sum(p.page_count() for p in svc.pm.all_providers()
                        if p.tier == "hot")
        assert sum(p.page_count() for p in cold) == 4
        assert hot_pages == 0
        # S3-class backend bills per request
        assert sum(p.store.op_counts["put"] for p in cold) == 4
        # reads find the demoted pages through the relocation overlay
        assert c.read(bid, v, 0, 4096) == b"E" * 4096
        assert svc.pm.rpc_counters()["locate_lookups"] > 0
        # scrub agrees the cold copies are the expected holders
        assert scrub_round(svc)["damaged_pages"] == 0
        return {"ok": True}

    sim.spawn(prog, name="t")
    sim.run()
    assert sim.results()["t"] == {"ok": True}


def test_cold_pages_promote_back_to_hot_on_repeated_access():
    """ROADMAP item 1 follow-up: demotion is no longer one-way — a cold
    page read ``promote_reads`` times since the last lifecycle pass
    moves back to a hot ring owner, and later passes leave it hot until
    it ages out again."""
    sim = Simulator(seed=0)
    svc = BlobSeerService(n_providers=4, n_meta_shards=2,
                          wire=Wire(clock=sim), n_cold_providers=2,
                          page_cache_bytes=0, verify_digests=True)

    def prog():
        c = svc.client("w")
        bid = c.create(psize=1024)
        v = c.append(bid, b"P" * 4096)
        svc.set_lifecycle(bid, 0.5, promote_reads=3)
        svc.clock.sleep(1.0)
        assert lifecycle_round(svc)["demoted"] == 4
        cold = [p for p in svc.pm.all_providers() if p.tier == "cold"]
        assert sum(p.page_count() for p in cold) == 4

        # below the threshold: the pass leaves everything cold
        assert c.read(bid, v, 0, 1024) == b"P" * 1024
        stats = lifecycle_round(svc)
        assert stats["promoted"] == 0
        assert sum(p.page_count() for p in cold) == 4

        # hammer the first page past the threshold: it promotes, alone
        for _ in range(3):
            assert c.read(bid, v, 0, 1024) == b"P" * 1024
        stats = lifecycle_round(svc)
        assert stats["promoted"] == 1
        # wire-byte convention, like demoted_bytes: cold read + hot put
        assert stats["promoted_bytes"] == 2 * 1024
        assert sum(p.page_count() for p in cold) == 3
        assert svc.pm.rpc_counters()["promoted_pages"] == 1

        # the promoted copy serves from the hot tier and reads back
        assert c.read(bid, v, 0, 4096) == b"P" * 4096
        hot_pages = sum(p.page_count() for p in svc.pm.all_providers()
                        if p.tier == "hot")
        assert hot_pages == 1
        # scrub agrees the post-promotion holders are the real ones
        assert scrub_round(svc)["damaged_pages"] == 0

        # it ages out again once it goes quiet: promotion is a cycle,
        # not a one-shot escape from the lifecycle
        svc.clock.sleep(1.0)
        assert lifecycle_round(svc)["demoted"] == 1
        assert sum(p.page_count() for p in cold) == 4
        assert c.read(bid, v, 0, 4096) == b"P" * 4096
        return {"ok": True}

    sim.spawn(prog, name="t")
    sim.run()
    assert sim.results()["t"] == {"ok": True}


def test_cold_providers_excluded_from_placement():
    svc = BlobSeerService(n_providers=2, n_meta_shards=2,
                          n_cold_providers=2)
    c = svc.client("w")
    bid = c.create(psize=1024)
    c.append(bid, b"F" * 4096)
    for p in svc.pm.all_providers():
        if p.tier == "cold":
            assert p.page_count() == 0


# --------------------------------------------------------------- EC + GC


def test_ec_pages_sweep_and_orphan_scan():
    from repro.core.gc import collect_garbage

    svc, c, bid = _ec_service()
    payload = b"\x33" * 4096
    for _ in range(3):
        c.write(bid, payload, 0)
    c.set_retention(bid, keep_last=1)
    stats = collect_garbage(svc, client="gc", orphan_grace=None)
    assert stats["retired_versions"] == 2
    assert stats["swept_pages"] > 0
    # shard stores hold exactly the kept version's shards; the orphan
    # scan (grace 0) maps shard ids to logical pages and keeps them all
    stats2 = collect_garbage(svc, client="gc", orphan_grace=0.0)
    assert stats2["orphan_pages"] == 0
    v = c.get_recent(bid)
    assert c.read(bid, v, 0, len(payload)) == payload


# ------------------------------------------------ determinism of the plane


def test_durability_scenario_deterministic():
    from repro.core.scenarios import build_env, run_scenario

    def once():
        env = build_env(4, seed=7, ops_per_client=2, scenario="durability")
        return run_scenario(
            "durability", 4, seed=7, env=env,
            failures=[(0.03, "prov-0000"), (0.04, "corrupt:prov-0002")])

    a, b = once(), once()
    assert not a.errors
    assert a.trace_digest == b.trace_digest
    readers = [r for r in a.client_results.values()
               if isinstance(r, dict) and "failed_reads" in r]
    assert sum(r["failed_reads"] for r in readers) == 0


# ------------------------------------------------- satellite regressions


def test_rereplicate_continues_past_unrecoverable_pages():
    """Regression: the sweep used to raise EndpointDown at the first
    page with no serving replica, stranding every later page."""
    svc = BlobSeerService(n_providers=4, n_meta_shards=2,
                          data_replication=2)
    c = svc.client("w")
    bid = c.create(psize=64)
    c.write(bid, b"q" * 2048, 0)
    locations = {pid: list(info[1])
                 for pid, info in svc.vm.page_locations().items()}
    # fabricate an unrecoverable entry that sorts FIRST: its survivor
    # list names a provider that does not hold the page (KeyError path)
    lost_locs = ["prov-0001", "prov-0000"]
    locations["pg-0000-lost"] = list(lost_locs)
    expected = sum(1 for p, locs in locations.items()
                   if p != "pg-0000-lost" and "prov-0001" in locs)
    svc.kill_provider("prov-0001")
    moved, losses = svc.pm.rereplicate_from("prov-0001", locations)
    assert losses == ["pg-0000-lost"]
    assert moved == expected > 0
    for pid, locs in locations.items():
        if pid == "pg-0000-lost":
            continue
        assert "prov-0001" not in locs and len(locs) == 2


def test_rereplicate_refreshes_dedup_providers():
    """Regression: dedup hits used to keep handing out descriptors
    pointing at the dead provider after repair moved the page."""
    svc = BlobSeerService(n_providers=3, n_meta_shards=2,
                          data_replication=1, dedup=True)
    c = svc.client("w")
    bid = c.create(psize=1024)
    c.append_many(bid, [b"G" * 1024], dedup=True)
    pid, (provs, ) = next(((p, (i[1],))
                           for p, i in svc.vm.page_locations().items()))
    dead = provs[0]
    locations = {pid: list(provs)}
    # a second holder so the page survives the kill
    survivor = next(p for p in svc.pm.all_providers()
                    if p.pid != dead and p.tier == "hot")
    survivor.put_pages([(pid, b"G" * 1024)])
    locations[pid].append(survivor.pid)
    svc.kill_provider(dead)
    moved, losses = svc.pm.rereplicate_from(dead, locations)
    assert moved == 1 and losses == []
    assert svc.dedup_index.rpc_counters()["refreshed"] == 1
    # the index now hands out the refreshed location set
    entry = svc.dedup_index._by_digest[svc.dedup_index._by_pid[pid]]
    assert dead not in entry.providers
    assert set(entry.providers) == set(locations[pid])


def test_dedup_refresh_providers_verb():
    svc = BlobSeerService(n_providers=2, n_meta_shards=2, dedup=True)
    c = svc.client("w")
    bid = c.create(psize=1024)
    c.append_many(bid, [b"H" * 1024], dedup=True)
    idx = svc.dedup_index
    pid = next(iter(idx._by_pid))
    n = idx.refresh_providers([(pid, ("prov-0001",)),
                               ("pg-missing", ("prov-0000",))])
    assert n == 1  # unknown ids are skipped, not an error
    ctr = idx.rpc_counters()
    assert ctr["refresh_rounds"] == 1 and ctr["refreshed"] == 1
    assert idx._by_digest[idx._by_pid[pid]].providers == ("prov-0001",)


def test_checkpointer_steps_propagates_wire_errors():
    """Regression: steps() used to catch bare Exception as
    end-of-history — a downed endpoint silently truncated the list."""
    np = pytest.importorskip("numpy")
    from repro.checkpoint.blobckpt import BlobCheckpointer

    svc = BlobSeerService(n_providers=2, n_meta_shards=2,
                          page_cache_bytes=0)
    ckpt = BlobCheckpointer(svc.client("ck"), psize=1024, header_pages=2)
    state = {"w": np.arange(512, dtype=np.int32)}
    ckpt.save(state, step=1)
    state["w"][0] = 99
    ckpt.save(state, step=2)
    assert [s for _v, s in ckpt.steps()] == [1, 2]
    for p in svc.pm.all_providers():
        svc.kill_provider(p.pid)
    with pytest.raises(EndpointDown):
        ckpt.steps()


def test_file_store_fsync_policy_and_tmp_cleanup(tmp_path, monkeypatch):
    from repro.store.file import FilePageStore

    with pytest.raises(ValueError):
        FilePageStore(str(tmp_path / "bad"), fsync="sometimes")

    store = FilePageStore(str(tmp_path / "spool"), fsync="always")
    store.put("pg-1", b"hello")
    assert store.get("pg-1") == b"hello"

    # regression: a failed replace used to leak the .tmp file
    calls = {"n": 0}
    real_replace = os.replace

    def boom(src, dst):
        calls["n"] += 1
        raise OSError("disk full")

    monkeypatch.setattr(os, "replace", boom)
    with pytest.raises(OSError):
        store.put("pg-2", b"x" * 10)
    monkeypatch.setattr(os, "replace", real_replace)
    assert calls["n"] == 1
    leftovers = [f for f in os.listdir(tmp_path / "spool")
                 if f.endswith(".tmp")]
    assert leftovers == []
    assert not store.has("pg-2")
    store.put("pg-2", b"x" * 10)  # store still usable after the failure
    assert store.get("pg-2") == b"x" * 10


def test_service_spool_fsync_threads_through(tmp_path):
    svc = BlobSeerService(n_providers=1, n_meta_shards=2,
                          spool_dir=str(tmp_path), spool_fsync="always")
    prov = svc.pm.get("prov-0000")
    assert prov.store.fsync == "always"
    c = svc.client("w")
    bid = c.create(psize=1024)
    v = c.append(bid, b"I" * 1024)
    assert c.read(bid, v, 0, 1024) == b"I" * 1024
