"""Subscription plane: watch/notify version leases.

Unit coverage for lease registration/catch-up, per-endpoint coalescing,
expiry/renewal, unwatch idempotence, ``wait_for_version``, the
push-invalidation cache subscriber, and the failover regression: a
lineage leader killed mid-burst must resume deliveries from the
promoted follower with no gap and no duplicate.
"""

import pytest

from repro.core import BlobSeerService, Simulator, Wire
from repro.core.gc import collect_garbage

PS = 4 * 1024


def _svc(**kw):
    kw.setdefault("n_providers", 4)
    kw.setdefault("n_meta_shards", 2)
    return BlobSeerService(**kw)


# ------------------------------------------------------------- registration


def test_watch_catches_up_from_version_zero():
    svc = _svc()
    c = svc.client("w")
    bid = c.create(psize=PS)
    for _ in range(3):
        c.append(bid, b"x" * PS)
    wid = c.watch(bid, from_version=0)
    assert c.poll_notifications(wid) == [1, 2, 3]
    assert svc.vm.watch_counters()["registered"] == 1


def test_watch_floor_excludes_versions_at_or_below_from_version():
    svc = _svc()
    c = svc.client("w")
    bid = c.create(psize=PS)
    for _ in range(4):
        c.append(bid, b"x" * PS)
    wid = c.watch(bid, from_version=2)
    assert c.poll_notifications(wid) == [3, 4]
    with pytest.raises(ValueError):
        c.watch(bid, from_version=-1)


def test_watch_catch_up_skips_retired_versions():
    svc = _svc()
    c = svc.client("w")
    bid = c.create(psize=PS)
    for _ in range(4):
        c.append(bid, b"x" * PS)
    c.set_retention(bid, keep_last=2)
    collect_garbage(svc, client="gc", orphan_grace=None)
    wid = c.watch(bid, from_version=0)
    assert c.poll_notifications(wid) == [3, 4]


def test_watch_report_and_unknown_blob():
    svc = _svc()
    c = svc.client("w")
    bid = c.create(psize=PS)
    wid = c.watch(bid)
    leases = svc.vm.watch_report(bid)
    assert [lease.watch_id for lease in leases] == [wid]
    assert leases[0].expires_at is None
    with pytest.raises(KeyError):
        c.watch("blob-9999")


# --------------------------------------------------------------- coalescing


def test_burst_coalesces_to_one_rpc_per_endpoint():
    sim = Simulator(seed=5)
    svc = _svc(wire=Wire(clock=sim))
    c = svc.client("w")
    g = svc.client("gw")
    bid = c.create(psize=PS)
    wids = [g.watch(bid) for _ in range(10)]
    svc.vm.reset_watch_counters()

    def writer():
        c.append_many(bid, [b"x" * PS] * 4)

    def reader():
        sim.sleep(1.0)
        return {w: g.poll_notifications(w) for w in wids}

    sim.spawn(writer, name="writer")
    sim.spawn(reader, name="reader")
    sim.run()
    delivered = sim.results()["reader"]
    assert all(delivered[w] == [1, 2, 3, 4] for w in wids)
    ctr = svc.vm.watch_counters()
    # one publication flush, ONE send to the single inbox endpoint:
    # 10 leases ride it as 10 coalesced entries covering 40 versions
    assert ctr["notify_rpcs"] == 1
    assert ctr["notify_entries"] == 10
    assert ctr["notify_versions"] == 40
    assert ctr["dropped_sends"] == 0


def test_notify_fan_out_counts_endpoints_not_watchers():
    sim = Simulator(seed=6)
    svc = _svc(wire=Wire(clock=sim))
    c = svc.client("w")
    bid = c.create(psize=PS)
    gws = [svc.client(f"gw{i}") for i in range(3)]
    for g in gws:
        for _ in range(5):
            g.watch(bid)
    svc.vm.reset_watch_counters()

    def writer():
        c.append(bid, b"x" * PS)

    sim.spawn(writer, name="writer")
    sim.run()
    ctr = svc.vm.watch_counters()
    assert ctr["notify_rpcs"] == 3        # one per gateway endpoint
    assert ctr["notify_entries"] == 15    # one per lease


# ------------------------------------------------------ lifecycle: lease ops


def test_unwatch_stops_deliveries_and_is_idempotent():
    svc = _svc()
    c = svc.client("w")
    bid = c.create(psize=PS)
    c.append(bid, b"x" * PS)
    wid = c.watch(bid)
    assert c.poll_notifications(wid) == [1]
    c.unwatch(wid)
    c.append(bid, b"x" * PS)
    assert c.poll_notifications(wid) == []
    c.unwatch(wid)            # unknown lease: charged, not an error
    c.unwatch("watch-none")   # never existed: same
    assert svc.vm.watch_counters()["unwatched"] == 1
    assert svc.vm.watch_report(bid) == []


def test_expired_lease_receives_nothing_afterwards():
    sim = Simulator(seed=7)
    svc = _svc(wire=Wire(clock=sim))
    c = svc.client("w")
    bid = c.create(psize=PS)
    wid_holder = {}

    def prog():
        wid = wid_holder["wid"] = c.watch(bid, ttl=0.05)
        sim.sleep(0.2)                 # lease lapses, nothing renewed
        c.append(bid, b"x" * PS)       # flush prunes the expired lease
        assert c.poll_notifications(wid) == []

    sim.spawn(prog, name="p")
    sim.run()
    ctr = svc.vm.watch_counters()
    assert ctr["expired"] == 1
    assert ctr["notify_entries"] == 0
    assert svc.vm.watch_report(bid) == []


def test_renewed_lease_outlives_its_original_ttl():
    sim = Simulator(seed=8)
    svc = _svc(wire=Wire(clock=sim))
    c = svc.client("w")
    bid = c.create(psize=PS)

    def prog():
        wid = c.watch(bid, ttl=0.05)
        sim.sleep(0.04)
        c.renew_watch(wid, ttl=1.0)
        sim.sleep(0.1)                 # past the ORIGINAL expiry
        c.append(bid, b"x" * PS)
        sim.sleep(0.05)
        assert c.poll_notifications(wid) == [1]

    sim.spawn(prog, name="p")
    sim.run()
    ctr = svc.vm.watch_counters()
    assert ctr["renewed"] == 1 and ctr["expired"] == 0
    with pytest.raises(KeyError):
        c.renew_watch("watch-none", ttl=1.0)


def test_wait_for_version_blocks_until_published():
    sim = Simulator(seed=9)
    svc = _svc(wire=Wire(clock=sim))
    bid = svc.client("setup").create(psize=PS)

    def writer():
        c = svc.client("w")
        for _ in range(3):
            sim.sleep(0.05)
            c.append(bid, b"x" * PS)

    def waiter():
        c = svc.client("r")
        t0 = sim.now()
        assert c.wait_for_version(bid, 3, timeout=600.0) == 3
        assert sim.now() >= t0 + 0.15   # genuinely waited for the writes
        with pytest.raises(TimeoutError):
            c.wait_for_version(bid, 99, timeout=0.1)

    sim.spawn(writer, name="w")
    sim.spawn(waiter, name="r")
    sim.run()
    # the temporary leases cleaned up after themselves
    assert svc.vm.watch_report(bid) == []


# ----------------------------------------------------- cache push-invalidate


def test_retirement_pushes_cache_invalidations():
    svc = _svc(page_cache_bytes=1 << 20)
    c = svc.client("w")
    bid = c.create(psize=PS)
    for _ in range(4):
        c.append(bid, b"x" * PS)
    c.read(bid, 2, 0, PS)   # populate the cache from an old version
    c.set_retention(bid, keep_last=2)
    collect_garbage(svc, client="gc", orphan_grace=None)
    ctr = svc.cache_invalidation.counters()
    assert ctr["pushes"] >= 1
    assert ctr["page_ids"] >= 1
    assert ctr["invalidated"] >= 1
    svc.cache_invalidation.reset_counters()
    assert svc.cache_invalidation.counters()["pushes"] == 0


# ------------------------------------------------------- failover regression


def test_watch_deliveries_survive_leader_failover_no_gap_no_dup():
    """Kill the lineage leader mid-burst: the promoted follower must
    resume notify deliveries exactly where the dead leader stopped —
    the client-side inbox watermark absorbs any re-sent tail, so the
    delivered stream stays ``1..final`` with no gap and no duplicate."""
    sim = Simulator(seed=10)
    svc = _svc(wire=Wire(clock=sim), vm_replication=2, vm_lease_ttl=0.01)
    bid = svc.client("setup").create(psize=PS)
    g = svc.client("gw")
    wids = [g.watch(bid) for _ in range(5)]
    final = 6 * 4

    def writer():
        c = svc.client("w")
        for _ in range(6):
            c.append_many(bid, [b"x" * PS] * 4)

    def gateway():
        out = {}
        for wid in wids:
            g.inbox.wait_for(wid, final, timeout=600.0)
            out[wid] = g.poll_notifications(wid)
        return out

    def chaos():
        svc.kill_vm_leader(bid)

    sim.spawn(writer, name="writer")
    sim.spawn(gateway, name="gateway")
    sim.spawn_at(0.003, chaos, name="chaos")
    sim.run()
    assert not sim.errors()
    assert svc.vm.rpc_counters()["failovers"] == 1
    streams = sim.results()["gateway"]
    for wid in wids:
        assert streams[wid] == list(range(1, final + 1)), (
            wid, streams[wid])
    # the re-flush after promotion may legitimately re-send the
    # un-journaled tail; the inbox watermark must have dropped it
    assert g.inbox.duplicates_dropped >= 0
