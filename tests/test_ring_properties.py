"""Seeded property tests: elastic membership under random histories.

Hypothesis drives random interleavings of append / overwrite / read /
join / drain / kill+revive / GC across client pools on the
deterministic Simulator and checks three contracts:

* **Byte-identical reads.**  Every read — issued while joins, drains
  and transient kills run concurrently from the operator pool — must
  return exactly what a static fleet would: the oracle is the pool's
  op history replayed over a plain ``bytearray``.
* **Near-minimal movement.**  Each drain moves at most
  ``SLACK`` (1.25x) the bytes the drained member held; each join lands
  at most ``SLACK`` x the bytes the ring owes the joiner (its resident
  bytes afterwards).  The consistent-hash ring must not shuffle
  bystander pages.
* **Same-seed determinism.**  Replaying a history from the same seed
  produces the identical trace digest and the identical final page
  layout (journal + relocation overlay), so any churn bug found by
  random search is replayable.

Membership and chaos events are confined to pool 0 (one operator, like
a real deployment's control loop); data pools own disjoint blobs so the
per-pool byte oracle is exact for any interleaving the scheduler
explores.  The deployment keeps ``data_replication=2`` and at most one
endpoint down at a time, so every page always has a live copy — the
zero-failed-ops regime the tentpole promises.
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    # No hypothesis: fall back to a fixed seed grid instead of skipping
    # — the histories are seeded and deterministic either way, random
    # search just explores more of the space when it is available.
    HAVE_HYPOTHESIS = False

from repro.core import BlobSeerService, Simulator, Wire
from repro.core.gc import collect_garbage

PSIZE = 2048
SLACK = 1.25      # moved payload vs inventory minimum (the bench gate)
MIN_FLEET = 4     # never drain below this many hot providers


def _payload(tag: int) -> bytes:
    return bytes([tag % 250 + 1]) * PSIZE


def _resident_bytes(svc, pid):
    """Live inventory bytes with a copy on ``pid`` (journal holders
    overridden by the relocation overlay) — the rebalance minimum."""
    total = 0
    for lg, (_b, provs, length) in svc.vm.page_locations().items():
        overlay = svc.pm.relocated(lg)
        holders = overlay if overlay else tuple(dict.fromkeys(provs))
        if pid in holders:
            total += length
    return total


def _payload_moved(svc):
    return svc.pm.rpc_counters()["migrated_payload_bytes"]


def _layout(svc):
    """Final placement fingerprint: journal + overlay, with raw page
    ids normalized to (blob, allocation-rank) — ids come from a
    process-global counter, so two same-seed services in one process
    mint different ids for identical layouts."""
    rank = {}
    rows = []
    inventory = svc.vm.page_locations()
    for lg in sorted(inventory):      # hex ids sort in allocation order
        blob, provs, length = inventory[lg]
        seq = rank[blob] = rank.get(blob, -1) + 1
        holders = tuple(svc.pm.relocated(lg)) or tuple(
            dict.fromkeys(provs))
        rows.append((blob, seq, holders, length))
    return rows


def _run_membership_history(seed, n_pools, ops_per_pool):
    """Random per-pool op sequences; pool 0 is the operator (joins,
    drains, kills, GC), pools >= 1 are data pools with disjoint blobs.
    Returns (svc, sim, violations) — violations collects any
    oracle mismatch or movement-bound breach with context."""
    sim = Simulator(seed=seed)
    svc = BlobSeerService(wire=Wire(clock=sim), n_providers=6,
                          n_meta_shards=4, data_replication=2,
                          page_cache_bytes=0)
    setup = svc.client("setup")
    blobs = [setup.create(psize=PSIZE) for _ in range(n_pools)]
    oracles = [bytearray() for _ in range(n_pools)]
    versions = [0] * n_pools
    violations = []

    def data_program(p):
        def prog():
            c = svc.client(f"c{p:02d}")
            bid, oracle = blobs[p], oracles[p]
            for k in range(ops_per_pool):
                sim.sleep(0.002)
                kind = (p * 31 + k * 17 + seed) % 8
                tag = p * ops_per_pool + k
                if kind < 3:                       # append
                    versions[p] = c.append(bid, _payload(tag))
                    oracle.extend(_payload(tag))
                elif kind < 5 and oracle:          # overwrite a page
                    off = ((tag * 7919) % max(len(oracle) // PSIZE, 1)) \
                        * PSIZE
                    versions[p] = c.write(bid, _payload(tag + 100), off)
                    oracle[off:off + PSIZE] = _payload(tag + 100)
                elif oracle:                       # read vs the oracle
                    off = ((tag * 104729) % max(len(oracle) // PSIZE, 1)) \
                        * PSIZE
                    got = c.read(bid, versions[p], off, PSIZE)
                    want = bytes(oracle[off:off + PSIZE])
                    if got != want:
                        violations.append(
                            (p, k, "read mismatch", off, versions[p]))
                else:
                    versions[p] = c.append(bid, _payload(tag))
                    oracle.extend(_payload(tag))
            return None
        return prog

    def operator_program():
        def prog():
            joined = 0
            for k in range(ops_per_pool):
                sim.sleep(0.003)
                kind = (k * 13 + seed) % 8
                hot = sorted(p.pid for p in svc.pm.all_providers()
                             if getattr(p, "tier", "hot") == "hot")
                if kind < 2:                       # join a fresh member
                    pid = f"prov-x{joined:02d}"
                    joined += 1
                    before = _payload_moved(svc)
                    plan = svc.join_provider(pid)
                    svc.run_migration(plan, round_sleep=0.002)
                    moved = _payload_moved(svc) - before
                    owed = _resident_bytes(svc, pid)
                    if moved > SLACK * owed:
                        violations.append(
                            (0, k, "join moved too much", moved, owed))
                elif kind < 4 and len(hot) > MIN_FLEET:   # drain one
                    victim = hot[(k + seed) % len(hot)]
                    held = _resident_bytes(svc, victim)
                    before = _payload_moved(svc)
                    svc.drain_provider(victim, round_sleep=0.002)
                    moved = _payload_moved(svc) - before
                    if moved > SLACK * held:
                        violations.append(
                            (0, k, "drain moved too much", moved, held))
                elif kind < 6 and len(hot) > 2:    # transient outage
                    victim = hot[(k * 3 + seed) % len(hot)]
                    svc.kill_provider(victim)
                    sim.sleep(0.01)                # readers ride replicas
                    svc.revive_provider(victim)
                else:                              # GC mid-churn
                    for bid in blobs:
                        svc.client("gc-op").set_retention(bid, keep_last=2)
                    collect_garbage(svc, client="gc-op", orphan_grace=None)
            return None
        return prog

    sim.spawn(operator_program(), name="operator")
    for p in range(1, n_pools):
        sim.spawn(data_program(p), name=f"pool{p:02d}")
    sim.run()

    # the quiesced tail: every blob reads back byte-identical, page by
    # page, through whatever fleet the churn left behind
    tail = svc.client("tail")
    for p in range(1, n_pools):
        oracle = oracles[p]
        for off in range(0, len(oracle), PSIZE):
            got = tail.read(blobs[p], versions[p], off, PSIZE)
            if got != bytes(oracle[off:off + PSIZE]):
                violations.append((p, -1, "tail read mismatch", off,
                                   versions[p]))
    return svc, sim, violations


def _history_seeds(pairs):
    """hypothesis search when installed, a fixed grid otherwise."""
    def deco(fn):
        if HAVE_HYPOTHESIS:
            return settings(max_examples=6, deadline=None)(given(
                seed=st.integers(min_value=0, max_value=2**16),
                n_pools=st.integers(min_value=2, max_value=4),
            )(fn))
        return pytest.mark.parametrize("seed,n_pools", pairs)(fn)
    return deco


@_history_seeds([(0, 2), (7, 3), (1234, 4), (42, 2), (99, 3)])
def test_reads_stay_byte_identical_under_churn(seed, n_pools):
    svc, _sim, violations = _run_membership_history(
        seed, n_pools, ops_per_pool=12)
    assert violations == [], violations
    # churn really happened and really deregistered members cleanly
    report = svc.ring_report()
    assert report["data_draining"] == []
    assert not svc.dht.reconfiguring


def _replay_seeds(fn):
    if HAVE_HYPOTHESIS:
        return settings(max_examples=3, deadline=None)(given(
            seed=st.integers(min_value=0, max_value=2**16))(fn))
    return pytest.mark.parametrize("seed", [0, 7, 1234])(fn)


@_replay_seeds
def test_membership_histories_replay_identically(seed):
    """Same seed -> identical trace digest AND identical final page
    layout (journal + overlay): churn placement must be a pure function
    of (seed, history), never of dict order or wall clock."""
    a_svc, a_sim, a_viol = _run_membership_history(seed, 3, ops_per_pool=10)
    b_svc, b_sim, b_viol = _run_membership_history(seed, 3, ops_per_pool=10)
    assert a_viol == [] and b_viol == []
    assert a_sim.trace_digest() == b_sim.trace_digest()
    assert _layout(a_svc) == _layout(b_svc)
    assert sorted(a_svc.ring_report()["data_ring"]) \
        == sorted(b_svc.ring_report()["data_ring"])
