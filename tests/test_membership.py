"""Elastic membership at unit scale: join/drain plans, the metadata
ring's ARES-style reconfiguration, flash-crowd widening, and the two
bookkeeping planes churn must not strand — the dedup index and GC.

The scenario-level contracts (zero failed ops under a rolling restart,
near-minimal movement, replayability) live in
``tests/test_ring_properties.py`` and ``benchmarks/bench_ring.py``;
these tests pin the mechanisms one layer down.
"""

import pytest

from repro.core import BlobSeerService, Simulator, Wire
from repro.core.membership import build_drain_plan, build_join_plan

PS = 4 * 1024


def _payload(tag: int, n: int = PS) -> bytes:
    return bytes([tag % 251 + 1]) * n


def _svc(**kw):
    kw.setdefault("n_providers", 5)
    kw.setdefault("n_meta_shards", 4)
    kw.setdefault("data_replication", 2)
    kw.setdefault("page_cache_bytes", 0)
    sim = Simulator(seed=11)
    return sim, BlobSeerService(wire=Wire(clock=sim), **kw)


def _holders(svc, lg, provs):
    overlay = svc.pm.relocated(lg)
    return tuple(overlay) if overlay else tuple(dict.fromkeys(provs))


def _preload(svc, chunks=8):
    c = svc.client("w")
    bid = c.create(psize=PS)
    v = 0
    for k in range(chunks):
        v = c.append(bid, _payload(k))
    return c, bid, v


# ---------------------------------------------------------------------- join


def test_join_plan_is_exactly_the_ring_owed_set():
    _, svc = _svc()
    _preload(svc)
    svc.add_provider("prov-new")
    plan = build_join_plan(svc, "prov-new")
    inventory = svc.vm.page_locations()
    owed = set()
    for lg, (_b, provs, _n) in inventory.items():
        width = len(dict.fromkeys(provs))
        desired = svc.pm.ring_owners(svc.pm.place_key(lg), width)
        if "prov-new" in desired and "prov-new" not in _holders(
                svc, lg, provs):
            owed.add(lg)
    assert {m.logical for m in plan} == owed
    # and every move targets the joiner, sourced from a current holder
    for m in plan:
        assert m.dst == "prov-new"
        assert m.src in _holders(svc, m.logical, inventory[m.logical][1])


def test_join_lands_owed_pages_and_reads_stay_byte_identical():
    _, svc = _svc()
    c, bid, v = _preload(svc)
    plan = svc.join_provider("prov-new")
    planned = [m.phys for m in plan]   # run_migration consumes the plan
    stats = svc.run_migration(plan)
    assert stats["moves"] == len(planned)
    listed = {p for p, _at in svc.pm.get("prov-new").list_pages(peer="t")}
    assert set(planned) <= listed
    for k in range(8):
        assert c.read(bid, v, k * PS, PS) == _payload(k)
    # idempotent: a second plan for the same member owes nothing
    assert build_join_plan(svc, "prov-new") == []


# --------------------------------------------------------------------- drain


def test_drain_empties_deregisters_and_keeps_reads_identical():
    _, svc = _svc()
    c, bid, v = _preload(svc)
    victim = next(p.pid for p in svc.pm.all_providers()
                  if sorted(p.store.iter_pids()))
    stats = svc.drain_provider(victim)
    assert stats["moves"] > 0
    assert victim not in {p.pid for p in svc.pm.all_providers()}
    for k in range(8):
        assert c.read(bid, v, k * PS, PS) == _payload(k)
    # no live page's holder set names the departed member
    for lg, (_b, provs, _n) in svc.vm.page_locations().items():
        assert victim not in _holders(svc, lg, provs)


def test_drain_below_replication_floor_is_refused():
    _, svc = _svc(n_providers=2)
    _preload(svc)
    with pytest.raises(RuntimeError):
        svc.drain_provider("prov-0000")


def test_drain_moves_erasure_coded_shards_positionally():
    _, svc = _svc(n_providers=6)
    c = svc.client("w")
    bid = c.create(psize=PS)
    svc.set_blob_placement(bid, "ec:2+1")
    v = 0
    for k in range(4):
        v = c.append(bid, _payload(k + 40))
    victim = next(p.pid for p in svc.pm.all_providers()
                  if sorted(p.store.iter_pids()))
    svc.drain_provider(victim)
    assert victim not in {p.pid for p in svc.pm.all_providers()}
    for k in range(4):
        assert c.read(bid, v, k * PS, PS) == _payload(k + 40)


def test_draining_member_still_serves_until_its_moves_land():
    _, svc = _svc()
    c, bid, v = _preload(svc)
    victim = next(p.pid for p in svc.pm.all_providers()
                  if sorted(p.store.iter_pids()))
    plan = svc.start_drain(victim)
    assert plan, "drain victim held nothing"
    # nothing has moved yet: the old owner answers every read
    for k in range(8):
        assert c.read(bid, v, k * PS, PS) == _payload(k)
    svc.run_migration(plan)
    assert svc.finish_drain(victim) >= 0
    assert victim not in {p.pid for p in svc.pm.all_providers()}


# ---------------------------------------------- dedup index under migration


def test_migration_refreshes_dedup_provider_tuples():
    """Regression: a dedup hit after a drain must hand out descriptors
    naming the page's *new* holders — before the fix the index kept the
    frozen put-time tuple, so content written after the drain journaled
    descriptors pointing at the departed endpoint."""
    _, svc = _svc(dedup=True, data_replication=1)
    c = svc.client("w")
    a = c.create(psize=PS)
    c.append_many(a, [_payload(7)])   # dedup runs on burst writes
    (lg, (_b, provs, _n)), = svc.vm.page_locations().items()
    victim = _holders(svc, lg, provs)[0]
    svc.drain_provider(victim)
    new_holders = _holders(svc, lg, svc.vm.page_locations()[lg][1])
    assert victim not in new_holders
    # the index entry was refreshed in the same migration round
    ent = svc.dedup_index._by_digest[svc.dedup_index._by_pid[lg]]
    assert tuple(ent.providers) == tuple(new_holders)
    # and a post-drain dedup hit reads back through the live holder
    b = c.create(psize=PS)
    vb = c.append_many(b, [_payload(7)])[-1]
    assert svc.rpc_report()["dedup_hits"] >= 1
    assert c.read(b, vb, 0, PS) == _payload(7)


def test_flash_crowd_widening_refreshes_dedup_tuples():
    """Same contract on the widening path: the widened copies join the
    entry's provider tuple so dedup hits spread across them too."""
    _, svc = _svc(dedup=True, data_replication=1)
    c = svc.client("w")
    a = c.create(psize=PS)
    va = c.append_many(a, [_payload(9)])[-1]
    for _ in range(40):
        c.read(a, va, 0, PS)
    widened = svc.mitigate_flash_crowd(threshold=8, extra=1, blob_id=a)
    assert widened
    (lg, holders), = widened
    assert len(set(holders)) >= 2
    ent = svc.dedup_index._by_digest[svc.dedup_index._by_pid[lg]]
    assert tuple(ent.providers) == tuple(holders)


# ------------------------------------------------------- GC after departure


def test_gc_sweep_completes_after_a_drain_no_failed_deletes():
    """The journal still names the departed member; ``delete_pages``
    must skip cleanly-drained endpoints instead of counting them as
    failed deletes forever."""
    _, svc = _svc()
    c, bid, _v = _preload(svc)
    for k in range(3):     # dead pages for the sweep to reclaim
        c.write(bid, _payload(k + 60), 0)
    victim = next(p.pid for p in svc.pm.all_providers()
                  if sorted(p.store.iter_pids()))
    svc.drain_provider(victim)
    c.set_retention(bid, keep_last=1)
    from repro.core.gc import collect_garbage
    stats = collect_garbage(svc, client="gc-t", orphan_grace=None)
    assert stats["failed_deletes"] == 0
    assert stats["swept_pages"] > 0
    # second round: nothing left pending on the departed endpoint
    again = collect_garbage(svc, client="gc-t", orphan_grace=None)
    assert again["failed_deletes"] == 0


# ------------------------------------------------------- flash-crowd widen


def test_widened_copy_serves_reads_when_the_hot_holder_dies():
    _, svc = _svc(data_replication=1)
    c = svc.client("w")
    bid = c.create(psize=PS)
    v = c.append(bid, _payload(3))
    for _ in range(40):
        c.read(bid, v, 0, PS)
    widened = svc.mitigate_flash_crowd(threshold=8, extra=1, blob_id=bid)
    assert widened, "hot page was not widened"
    (lg, holders), = widened
    assert len(set(holders)) >= 2
    # kill the original holder: the widened copy must carry the crowd
    original = _holders(svc, lg, svc.vm.page_locations()[lg][1])[0]
    survivors = [h for h in holders if h != original]
    assert survivors
    svc.kill_provider(original)
    assert c.read(bid, v, 0, PS) == _payload(3)


def test_mitigation_is_a_noop_below_threshold():
    _, svc = _svc()
    c = svc.client("w")
    bid = c.create(psize=PS)
    v = c.append(bid, _payload(5))
    c.read(bid, v, 0, PS)
    assert svc.mitigate_flash_crowd(threshold=8, blob_id=bid) == []
    assert svc.pm.rpc_counters()["widened_pages"] == 0


# -------------------------------------------------------- metadata ring


def _key_placement(dht):
    placed = {}
    for s in dht.shards:
        for k in s.keys():
            placed.setdefault(k, set()).add(s.shard_id)
    return placed


def test_meta_join_rebalances_keys_onto_ring_owners():
    _, svc = _svc()
    _preload(svc)
    before_total = sum(len(s.keys()) for s in svc.dht.shards)
    svc.add_meta_shard("meta-new")
    assert not svc.dht.reconfiguring
    assert sum(len(s.keys()) for s in svc.dht.shards) == before_total
    for k, holders in _key_placement(svc.dht).items():
        want = {s.shard_id for s in svc.dht._home_shards(k)}
        assert holders == want, k
    assert "meta-new" in {s.shard_id for s in svc.dht.shards}


def test_meta_drain_removes_the_shard_and_preserves_every_key():
    _, svc = _svc()
    c, bid, v = _preload(svc)
    before_total = sum(len(s.keys()) for s in svc.dht.shards)
    svc.drain_meta_shard("meta-0001")
    assert "meta-0001" not in {s.shard_id for s in svc.dht.shards}
    assert sum(len(s.keys()) for s in svc.dht.shards) == before_total
    for k, holders in _key_placement(svc.dht).items():
        want = {s.shard_id for s in svc.dht._home_shards(k)}
        assert holders == want, k
    # the control plane still answers: reads traverse the moved tree
    for k in range(8):
        assert c.read(bid, v, k * PS, PS) == _payload(k)


def test_meta_puts_and_gets_stay_safe_mid_reconfiguration():
    _, svc = _svc()
    c, bid, v = _preload(svc)
    svc.dht.begin_join("meta-mid")
    assert svc.dht.reconfiguring
    # one budget-capped round, then live traffic against half-moved arcs
    svc.dht.migration_round(2048)
    assert c.read(bid, v, 0, PS) == _payload(0)
    v2 = c.append(bid, _payload(77))
    assert c.read(bid, v2, 8 * PS, PS) == _payload(77)
    while not svc.dht.migration_round(1 << 20)["done"]:
        pass
    assert not svc.dht.reconfiguring
    assert c.read(bid, v2, 8 * PS, PS) == _payload(77)
    for k, holders in _key_placement(svc.dht).items():
        want = {s.shard_id for s in svc.dht._home_shards(k)}
        assert holders == want, k


def test_meta_join_rejects_overlapping_reconfigurations():
    _, svc = _svc()
    svc.dht.begin_join("meta-a")
    with pytest.raises(RuntimeError):
        svc.dht.begin_join("meta-b")
    with pytest.raises(RuntimeError):
        svc.dht.begin_drain("meta-0000")
    while not svc.dht.migration_round(1 << 20)["done"]:
        pass
    with pytest.raises(ValueError):
        svc.dht.begin_join("meta-a")   # already a member


def test_drain_plan_skips_pages_not_in_the_live_inventory():
    _, svc = _svc()
    _preload(svc)
    victim = next(p.pid for p in svc.pm.all_providers()
                  if sorted(p.store.iter_pids()))
    # plant a garbage page the journal never saw
    svc.pm.get(victim).put_pages([("pg-ghost", b"\xff" * 16)], peer="t")
    svc.pm.mark_draining(victim)
    plan = build_drain_plan(svc, victim)
    assert all(m.phys != "pg-ghost" for m in plan)
