"""Fault tolerance: replication, failures, stragglers, recovery."""

import os
import time

import pytest

from repro.core import BlobSeerService, EndpointDown
from repro.core.version_manager import VersionManager
import repro.core.blob as blobmod


def test_replicated_read_survives_provider_failure():
    svc = BlobSeerService(n_providers=6, n_meta_shards=4,
                          data_replication=2, meta_replication=2)
    c = svc.client()
    bid = c.create(psize=64)
    v = c.write(bid, bytes(range(256)) * 16, 0)
    svc.kill_provider("prov-0003")
    assert c.read(bid, v, 0, 4096) == bytes(range(256)) * 16


def test_unreplicated_read_fails_after_all_copies_lost():
    svc = BlobSeerService(n_providers=2, n_meta_shards=2, data_replication=1)
    c = svc.client()
    bid = c.create(psize=64)
    v = c.write(bid, b"z" * 1024, 0)
    svc.kill_provider("prov-0000")
    svc.kill_provider("prov-0001")
    with pytest.raises(EndpointDown):
        c.read(bid, v, 0, 1024)


def test_rereplication_restores_fault_tolerance():
    svc = BlobSeerService(n_providers=4, n_meta_shards=2, data_replication=2)
    c = svc.client()
    bid = c.create(psize=64)
    v = c.write(bid, b"q" * 2048, 0)
    # collect locations from metadata
    from repro.core import segment_tree as st
    pd = st.read_meta(svc.dht, c._owner_fn(bid), v,
                      svc.vm.root_pages_published(bid, v), 0, 32)
    locations = {d.page_id: list(d.providers) for d in pd}
    svc.kill_provider("prov-0001")
    moved, losses = svc.pm.rereplicate_from("prov-0001", locations)
    assert moved > 0
    assert losses == []
    for pid, locs in locations.items():
        assert "prov-0001" not in locs
        assert len(locs) == 2


def test_straggler_replica_racing():
    svc = BlobSeerService(n_providers=4, n_meta_shards=2, data_replication=2)
    c = svc.client()
    bid = c.create(psize=64)
    v = c.write(bid, b"s" * 4096, 0)
    svc.make_straggler("prov-0000", 100.0)
    # reads keep working and prefer non-straggler replicas
    assert c.read(bid, v, 0, 4096) == b"s" * 4096


def test_heartbeat_marks_dead_provider():
    svc = BlobSeerService(n_providers=3, n_meta_shards=2,
                          heartbeat_timeout=0.01)
    time.sleep(0.05)
    svc.pm.get("prov-0001").heartbeat()
    dead = svc.pm.check_heartbeats()
    assert "prov-0000" in dead and "prov-0002" in dead
    assert svc.pm.n_alive() == 1


class _DyingClient(blobmod.BlobClient):
    def _build_and_complete(self, blob_id, info, pd_final, **kwargs):
        raise RuntimeError("writer crashed before BUILD_META")


def test_stalled_writer_recovery_unblocks_pipeline():
    svc = BlobSeerService(n_providers=4, n_meta_shards=2)
    c = svc.client()
    bid = c.create(psize=16)
    c.write(bid, b"x" * 64, 0)
    dc = _DyingClient(svc.vm, svc.dht, svc.pm, svc.wire, name="dying")
    with pytest.raises(RuntimeError):
        dc.write(bid, b"y" * 32, 16)
    c.write(bid, b"z" * 16, 0)          # v3, blocked behind dead v2
    assert c.get_recent(bid) == 1
    assert svc.recover_stalled(0.0) == 1
    c.sync(bid, 3, timeout=5)
    assert c.read(bid, 3, 0, 64) == b"z" * 16 + b"y" * 32 + b"x" * 16


def test_monitor_thread_recovers_automatically():
    svc = BlobSeerService(n_providers=4, n_meta_shards=2)
    c = svc.client()
    bid = c.create(psize=16)
    c.write(bid, b"x" * 64, 0)
    dc = _DyingClient(svc.vm, svc.dht, svc.pm, svc.wire, name="dying")
    with pytest.raises(RuntimeError):
        dc.append(bid, b"y" * 32)
    svc.start_monitor(interval=0.05, stall_timeout=0.0)
    try:
        c.sync(bid, 2, timeout=5)
    finally:
        svc.stop_monitor()
    assert c.read(bid, 2, 64, 32) == b"y" * 32


def test_vm_wal_recovery(tmp_path):
    wal = str(tmp_path / "vm.wal")
    svc = BlobSeerService(n_providers=4, n_meta_shards=2, wal_path=wal)
    c = svc.client()
    bid = c.create(psize=32)
    v1 = c.write(bid, b"A" * 100, 0)
    b2 = c.branch(bid, v1)
    c.append(b2, b"B" * 20)
    vm2 = VersionManager.recover_from_wal(wal, wire=svc.wire)
    assert vm2.get_recent(bid) == 1
    assert vm2.get_size(bid, 1) == 100
    assert vm2.get_recent(b2) == 2
    assert vm2.get_size(b2, 2) == 120
    assert vm2.lineage(b2) == ((b2, 1), (bid, 0))


def test_full_service_restart_from_durable_state(tmp_path):
    spool = str(tmp_path / "spool")
    wal = str(tmp_path / "vm.wal")
    svc = BlobSeerService(n_providers=4, n_meta_shards=2,
                          spool_dir=spool, wal_path=wal)
    c = svc.client()
    bid = c.create(psize=32)
    c.write(bid, b"A" * 100, 0)
    c.append(bid, b"B" * 60)
    v = c.get_recent(bid)
    del svc, c
    svc2 = BlobSeerService.restore(spool, wal, n_providers=4, n_meta_shards=2)
    c2 = svc2.client()
    assert c2.get_recent(bid) == v
    assert c2.read(bid, v, 0, 160) == b"A" * 100 + b"B" * 60
    # service keeps working after restart
    v2 = c2.append(bid, b"C" * 10)
    assert c2.read(bid, v2, 150, 20) == b"B" * 10 + b"C" * 10


def test_elastic_provider_join_rebalances():
    svc = BlobSeerService(n_providers=2, n_meta_shards=2,
                          placement="least_loaded")
    c = svc.client()
    bid = c.create(psize=64)
    c.write(bid, b"x" * 64 * 64, 0)
    svc.add_provider("prov-new")
    c.append(bid, b"y" * 64 * 30)
    new = svc.pm.get("prov-new")
    assert new.page_count() > 0
