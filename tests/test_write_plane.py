"""Scale-out write plane: lineage sharding, batched writer verbs,
pipelined weave.

Covers the PR-5 contracts:

* per-lineage locks — publication on blob B proceeds while blob A's
  lineage lock is held / while blob A's writer is stalled
  pre-``metadata_complete`` (cross-blob publication independence);
* ``assign_versions_many`` / ``metadata_complete_many`` amortize
  version-manager round trips and show up in ``rpc_report()``;
* ``append_many`` / ``write_many`` produce byte-identical state to
  their sequential equivalents, including the unaligned-append
  phase-2 re-stripe and intra-batch boundary merges;
* WAL records carry lineage ids and recovery rebuilds the shard
  layout;
* the ``append_burst`` scenario replays deterministically.
"""

from __future__ import annotations

import threading

import pytest

from repro.core import BlobSeerService
from repro.core import blob as blobmod
from repro.core.gc import collect_orphans
from repro.core.scenarios import run_scenario
from repro.core.transport import Wire
from repro.core.version_manager import VersionManager


# ---------------------------------------------------------------------------
# Lineage sharding / cross-blob publication independence
# ---------------------------------------------------------------------------


def test_lineages_are_disjoint_and_branches_join_parent():
    svc = BlobSeerService(n_providers=2, n_meta_shards=2)
    c = svc.client()
    a = c.create(psize=16)
    b = c.create(psize=16)
    assert svc.vm.lineage_id(a) != svc.vm.lineage_id(b)
    c.write(a, b"x" * 32, 0)
    br = c.branch(a, 1)
    assert svc.vm.lineage_id(br) == svc.vm.lineage_id(a)
    # distinct lineages really are distinct lock domains
    assert svc.vm._shard_of(a) is svc.vm._shard_of(br)
    assert svc.vm._shard_of(a) is not svc.vm._shard_of(b)


def test_publication_on_b_proceeds_while_a_lineage_lock_held():
    """Structural independence: a task squatting on blob A's lineage
    critical section cannot delay an assignment+publication on blob B
    (pre-PR, one global VM lock serialized every verb)."""
    svc = BlobSeerService(n_providers=2, n_meta_shards=2)
    c = svc.client()
    a = c.create(psize=16)
    b = c.create(psize=16)

    done = threading.Event()

    def write_b():
        w = svc.client("writer-b")
        w.write(b, b"y" * 32, 0)
        done.set()

    with svc.vm._shard_of(a).lock:          # a "slow writer" on A's lineage
        t = threading.Thread(target=write_b, daemon=True)
        t.start()
        assert done.wait(timeout=10.0), (
            "blob B's write blocked on blob A's lineage lock"
        )
        t.join(timeout=5.0)
    assert c.get_recent(b) == 1


class _CrashBeforeWeave(blobmod.BlobClient):
    def _build_and_complete(self, blob_id, info, pd_final, **kwargs):
        raise RuntimeError("writer crashed before BUILD_META")


def test_stalled_writer_on_a_does_not_block_publication_on_b():
    """Behavioral independence (the ISSUE's regression test): blob A has
    an assigned-but-incomplete update stalling ITS publication pipeline;
    blob B keeps assigning and publishing normally."""
    svc = BlobSeerService(n_providers=4, n_meta_shards=2)
    c = svc.client()
    a = c.create(psize=16)
    b = c.create(psize=16)
    c.write(a, b"a" * 32, 0)

    dc = _CrashBeforeWeave(svc.vm, svc.dht, svc.pm, svc.wire, name="dying")
    with pytest.raises(RuntimeError):
        dc.write(a, b"A" * 16, 0)           # v2 on A: assigned, never complete

    # A is stalled pre-metadata_complete; B publishes freely
    for i in range(3):
        c.append(b, bytes([i + 1]) * 16)
        assert c.get_recent(b) == i + 1
    c.sync(b, 3, timeout=5.0)
    assert c.get_recent(a) == 1             # A still stalled
    assert svc.recover_stalled(0.0) == 1    # recovery completes A's v2
    c.sync(a, 2, timeout=5.0)
    assert c.read(a, 2, 0, 16) == b"A" * 16


def test_sync_timeout_on_stalled_blob_while_other_lineage_publishes():
    """A SYNC waiter of blob A times out on A's own shard condition even
    as blob B's lineage publishes continuously (no cross-lineage
    wakeups needed, none relied on)."""
    svc = BlobSeerService(n_providers=2, n_meta_shards=2)
    c = svc.client()
    a = c.create(psize=16)
    b = c.create(psize=16)
    c.append(b, b"z" * 16)
    with pytest.raises(TimeoutError):
        c.sync(a, 1, timeout=0.05)
    c.append(b, b"z" * 16)
    assert c.get_recent(b) == 2


# ---------------------------------------------------------------------------
# Batched writer verbs
# ---------------------------------------------------------------------------


def test_batched_verbs_amortize_vm_round_trips():
    svc = BlobSeerService(n_providers=4, n_meta_shards=2)
    c = svc.client()
    bid = c.create(psize=16)
    svc.reset_rpc_counters()
    vs = c.append_many(bid, [b"q" * 16] * 8)
    rep = svc.rpc_report()
    # one assign batch + one complete batch for the whole burst
    assert rep["vm_assign_batches"] == 1
    assert rep["vm_complete_batches"] == 1
    assert rep["vm_round_trips"] == 2
    assert rep["vm_ops"] == 16 and rep["vm_batched_ops"] == 16
    assert vs == list(range(1, 9))
    assert c.get_recent(bid) == 8


def test_assign_versions_many_routes_across_lineages():
    svc = BlobSeerService(n_providers=2, n_meta_shards=2)
    c = svc.client()
    a = c.create(psize=16)
    b = c.create(psize=16)
    infos = svc.vm.assign_versions_many(
        [(a, None, 16, ()), (b, None, 32, ()), (a, None, 16, ())],
        client="t",
    )
    assert [i.version for i in infos] == [1, 1, 2]
    assert infos[2].offset == 16            # saw the first request's append
    assert infos[2].recent_updates == ((1, 0, 1),)
    svc.vm.metadata_complete_many([(a, 1), (a, 2), (b, 1)], client="t")
    # publication is per blob, batched completion included
    assert svc.vm.get_recent(a) == 2 and svc.vm.get_recent(b) == 1


def test_assign_versions_many_is_atomic_on_validation_failure():
    """A batch containing an invalid request assigns NOTHING — no
    half-assigned updates left stalling a publication pipeline."""
    from repro.core import WriteBeyondEnd

    svc = BlobSeerService(n_providers=2, n_meta_shards=2)
    c = svc.client()
    a = c.create(psize=16)
    b = c.create(psize=16)
    with pytest.raises(WriteBeyondEnd):
        svc.vm.assign_versions_many(
            [(b, None, 16, ()),          # valid, listed first
             (a, 999, 16, ())],          # WRITE far beyond a's size 0
            client="t",
        )
    # neither blob saw an assignment; both stay fully usable
    assert svc.vm.version_bounds(a) == (0, 0)
    assert svc.vm.version_bounds(b) == (0, 0)
    assert c.append(b, b"x" * 16) == 1
    c.sync(b, 1, timeout=5.0)
    # validation runs against the batch's own running size: an append
    # extending the blob makes a later in-batch write offset legal
    infos = svc.vm.assign_versions_many(
        [(a, None, 32, ()), (a, 16, 16, ())], client="t")
    assert [i.version for i in infos] == [1, 2]
    assert infos[1].offset == 16


def test_append_many_matches_sequential_appends():
    def build(batched: bool):
        svc = BlobSeerService(n_providers=4, n_meta_shards=2)
        c = svc.client()
        bid = c.create(psize=16)
        bufs = [b"a" * 40, b"b" * 7, b"c" * 16, b"d" * 100]
        if batched:
            vs = c.append_many(bid, bufs)
        else:
            vs = [c.append(bid, b) for b in bufs]
        v = c.get_recent(bid)
        return vs, c.read(bid, v, 0, c.get_size(bid, v))

    vs_a, data_a = build(True)
    vs_b, data_b = build(False)
    assert vs_a == vs_b == [1, 2, 3, 4]
    assert data_a == data_b


def test_write_many_boundary_merge_intra_batch():
    svc = BlobSeerService(n_providers=4, n_meta_shards=2)
    c = svc.client()
    bid = c.create(psize=16)
    c.write(bid, b"x" * 64, 0)
    vs = c.write_many(bid, [(b"y" * 10, 5), (b"z" * 20, 60), (b"w" * 3, 12)])
    assert vs == [2, 3, 4]
    ref = bytearray(b"x" * 64 + b"\0" * 16)
    ref[5:15] = b"y" * 10
    ref[60:80] = b"z" * 20
    ref[12:15] = b"w" * 3
    got = c.read(bid, 4, 0, c.get_size(bid, 4))
    assert got == bytes(ref)
    # every intermediate snapshot is independently readable (weave ok)
    assert c.read(bid, 2, 0, 64) == b"x" * 5 + b"y" * 10 + b"x" * 49


def test_mixed_append_write_batch_rejected():
    svc = BlobSeerService(n_providers=2, n_meta_shards=2)
    c = svc.client()
    bid = c.create(psize=16)
    with pytest.raises(ValueError):
        c._update_many(bid, [(b"a" * 16, None), (b"b" * 16, 0)])


# ---------------------------------------------------------------------------
# Unaligned-append restripe (phase-2 re-stripe rule)
# ---------------------------------------------------------------------------


def test_single_append_unaligned_restripe_content_and_orphans():
    svc = BlobSeerService(n_providers=4, n_meta_shards=2)
    c = svc.client()
    bid = c.create(psize=16)
    c.append(bid, b"a" * 10)                # size 10: next base unaligned
    v = c.append(bid, b"b" * 40)            # optimistic striping was wrong
    assert c.read(bid, v, 0, 50) == b"a" * 10 + b"b" * 40
    # the optimistically stored full pages became orphans: stored page
    # replicas exceed the journaled descriptors
    referenced = svc.vm.all_page_ids()
    stored = sum(p.page_count() for p in svc.pm.all_providers())
    assert stored > len(referenced)
    # the GC orphan inventory reclaims them (zero grace for the test)
    stats = collect_orphans(svc, grace=0.0)
    assert stats["orphan_pages"] == stored - len(referenced)
    assert sum(p.page_count() for p in svc.pm.all_providers()) == len(referenced)
    assert c.read(bid, v, 0, 50) == b"a" * 10 + b"b" * 40


def test_append_many_unaligned_restripe():
    svc = BlobSeerService(n_providers=4, n_meta_shards=2)
    c = svc.client()
    bid = c.create(psize=16)
    c.append(bid, b"s" * 13)                # unaligned burst base
    vs = c.append_many(bid, [b"1" * 40, b"2" * 7, b"3" * 33])
    assert vs == [2, 3, 4]
    expect = b"s" * 13 + b"1" * 40 + b"2" * 7 + b"3" * 33
    assert c.read(bid, 4, 0, len(expect)) == expect
    # intermediate versions too (burst members published in order)
    assert c.read(bid, 2, 0, 53) == b"s" * 13 + b"1" * 40
    assert c.read(bid, 3, 0, 60) == b"s" * 13 + b"1" * 40 + b"2" * 7


# ---------------------------------------------------------------------------
# WAL lineage ids + recovery
# ---------------------------------------------------------------------------


def test_wal_records_carry_lineage_ids_and_recovery_rebuilds_shards(tmp_path):
    import json

    wal = str(tmp_path / "wal")
    vm = VersionManager(wire=Wire(), wal_path=wal)
    a = vm.create(16, client="t")
    b = vm.create(16, client="t")
    vm.assign_versions_many([(a, None, 16, ()), (b, None, 16, ())], client="t")
    vm.metadata_complete_many([(a, 1), (b, 1)], client="t")
    br = vm.branch(a, 1, client="t")
    vm.assign_version(br, None, 16, client="t")

    with open(wal) as f:
        recs = [json.loads(line) for line in f]
    assert all("lineage" in r for r in recs)
    by_blob = {r["blob"]: r["lineage"] for r in recs if "blob" in r}
    assert by_blob[a] == a and by_blob[b] == b and by_blob[br] == a

    vm2 = VersionManager.recover_from_wal(wal)
    assert vm2.lineage_id(br) == a
    assert vm2.lineage_id(b) == b
    assert vm2.get_recent(a) == 1 and vm2.get_recent(b) == 1
    assert vm2.known_blobs() == [a, b, br]
    base, last = vm2.version_bounds(br)
    assert (base, last) == (1, 2)
    assert not vm2.update_log(br, 2).complete  # in-flight update survived


def test_recovered_manager_keeps_publishing_per_lineage(tmp_path):
    wal = str(tmp_path / "wal")
    spool = str(tmp_path / "spool")
    svc = BlobSeerService(n_providers=4, n_meta_shards=2, wal_path=wal,
                          spool_dir=spool)
    c = svc.client()
    a = c.create(psize=16)
    b = c.create(psize=16)
    c.append_many(a, [b"1" * 16, b"2" * 16])
    c.append(b, b"3" * 32)

    svc2 = BlobSeerService.restore(spool, wal, n_providers=4, n_meta_shards=2)
    c2 = svc2.client()
    assert c2.read(a, 2, 0, 32) == b"1" * 16 + b"2" * 16
    assert c2.read(b, 1, 0, 32) == b"3" * 32
    # the recovered shards stay independent and writable
    assert svc2.vm.lineage_id(a) != svc2.vm.lineage_id(b)
    assert c2.append(a, b"4" * 16) == 3
    assert c2.read(a, 3, 16, 32) == b"2" * 16 + b"4" * 16


# ---------------------------------------------------------------------------
# Simulator determinism of the burst scenario
# ---------------------------------------------------------------------------


def test_append_burst_same_seed_identical_digest():
    r1 = run_scenario("append_burst", 24, seed=11, ops_per_client=2)
    r2 = run_scenario("append_burst", 24, seed=11, ops_per_client=2)
    assert r1.trace_digest == r2.trace_digest
    assert r1.rpc == r2.rpc
    assert not r1.errors
    # total appends = n_clients * ops_per_client * BURST
    assert r1.ops == 24 * 2 * 4


def test_append_burst_under_simulator_beats_singles_on_vm_rpcs():
    rb = run_scenario("append_burst", 32, seed=5, ops_per_client=2)
    rs = run_scenario("appenders", 32, seed=5, ops_per_client=2)
    burst_per_op = rb.rpc["vm_round_trips"] / rb.ops
    single_per_op = rs.rpc["vm_round_trips"] / rs.ops
    assert single_per_op / burst_per_op >= 2.0
