"""The immutability-aware read-path cache hierarchy.

Covers ``core/cache.py`` (byte-budgeted PageCache LRU, single-flight
de-duplication, the promoted NodeCache) and its integration under
``ProviderManager.fetch_pages``: shared hits across clients, replica
load-balancing, sibling-page prefetch (fire-and-forget + arrival
gating), GC/eviction coherence (retire-intent and sweep hooks), and the
determinism of the cached schedule under the Simulator.
"""

import pytest

from repro.core import (
    BlobSeerService,
    NodeCache,
    PageCache,
    RetiredVersion,
    Simulator,
    Wire,
)
from repro.core.dht import MetadataDHT
from repro.core.gc import collect_garbage
from repro.core.scenarios import run_scenario

PSIZE = 1024
CHUNK = 4 * PSIZE


# ---------------------------------------------------------------------------
# PageCache unit behavior
# ---------------------------------------------------------------------------


def test_page_cache_byte_budget_lru():
    pc = PageCache(budget_bytes=10)
    for i in range(4):
        pc.fill((f"p{i}", 0, 3), b"abc")   # fill without claim: pure insert
    assert len(pc) == 3 and pc.used_bytes() == 9   # 4th insert evicted p0
    assert pc.evictions == 1
    assert "p0" not in pc.cached_page_ids()
    # touching an entry protects it from eviction (true LRU order)
    hits, _, _ = pc.claim([("p1", 0, 3)])
    assert hits[("p1", 0, 3)][0] == b"abc"
    pc.fill(("p4", 0, 3), b"xyz")
    assert "p1" in pc.cached_page_ids() and "p2" not in pc.cached_page_ids()
    # an entry larger than the whole budget is never cached
    pc.fill(("big", 0, 99), b"z" * 99)
    assert "big" not in pc.cached_page_ids()


def test_page_cache_disabled_at_zero_budget():
    pc = PageCache(0)
    assert not pc.enabled
    pc.fill(("p", 0, 3), b"abc")
    assert len(pc) == 0


def test_page_cache_single_flight_claim_protocol():
    pc = PageCache(1 << 20)
    hits, leaders, waiters = pc.claim([("p", 0, 4)])
    assert not hits and leaders == [("p", 0, 4)] and not waiters
    # second claimant of an in-flight key becomes a waiter
    _, l2, w2 = pc.claim([("p", 0, 4)])
    assert not l2 and w2 == [("p", 0, 4)]
    pc.fill(("p", 0, 4), b"data")
    assert pc.wait(("p", 0, 4))[0] == b"data"
    # abandon releases the claim so the next claimant leads
    _, l3, _ = pc.claim([("q", 0, 4)])
    assert l3
    pc.abandon(("q", 0, 4))
    _, l4, _ = pc.claim([("q", 0, 4)])
    assert l4 == [("q", 0, 4)]


def test_page_cache_invalidate_dooms_inflight_fill():
    pc = PageCache(1 << 20)
    pc.fill(("res", 0, 3), b"abc")
    _, leaders, _ = pc.claim([("fly", 0, 3)])
    assert leaders
    assert pc.invalidate_pages(["res", "fly"]) == 1   # one resident entry
    assert pc.cached_page_ids() == set()
    # the in-flight fetch was doomed: its fill is discarded
    pc.fill(("fly", 0, 3), b"abc")
    assert pc.cached_page_ids() == set()


# ---------------------------------------------------------------------------
# NodeCache promotion + counter surfacing
# ---------------------------------------------------------------------------


def test_node_cache_promoted_and_counted():
    # old import path still works (back-compat alias)
    from repro.core.blob import _NodeCache
    assert _NodeCache is NodeCache

    svc = BlobSeerService(n_providers=4, n_meta_shards=4)
    c = svc.client()
    bid = c.create(psize=PSIZE)
    c.append(bid, b"n" * CHUNK)
    v = c.get_recent(bid)
    c.read(bid, v, 0, CHUNK)
    c.read(bid, v, 0, CHUNK)      # re-descends the same tree: node hits
    rep = svc.rpc_report()
    assert rep["node_cache_hits"] > 0
    assert rep["node_cache_hit_bytes"] > 0
    # hits are mirrored into the DHT's cache-hit-vs-RPC accounting
    assert rep["dht_get_keys_cached"] == rep["node_cache_hits"]
    svc.reset_rpc_counters()
    assert svc.rpc_report()["node_cache_hits"] == 0


def test_node_cache_standalone_counters():
    dht = MetadataDHT(Wire(), 4)
    cache = NodeCache(dht)
    cache.put(("k", 1), {"v": 1})
    assert cache.get(("k", 1)) == {"v": 1}
    assert cache.get(("k", 2)) is None
    ctr = cache.counters()
    assert ctr["hits"] == 1 and ctr["misses"] == 1
    assert ctr["hit_bytes"] == dht.node_nbytes


# ---------------------------------------------------------------------------
# fetch_pages integration: shared hits, single-flight, balancing, prefetch
# ---------------------------------------------------------------------------


def _preloaded(n_chunks=4, **kwargs):
    svc = BlobSeerService(n_providers=8, n_meta_shards=4, **kwargs)
    c = svc.client("setup")
    bid = c.create(psize=PSIZE)
    for i in range(n_chunks):
        c.append(bid, bytes([i + 1]) * CHUNK)
    return svc, bid, c.get_recent(bid)


def test_cache_shared_across_clients():
    svc, bid, v = _preloaded()
    a, b = svc.client("a"), svc.client("b")
    want = a.read(bid, v, 0, CHUNK)
    svc.reset_rpc_counters()
    assert b.read(bid, v, 0, CHUNK) == want
    rep = svc.rpc_report()
    assert rep["provider_read_pages"] == 0          # pure cache hits
    assert rep["page_cache_hits"] == CHUNK // PSIZE
    assert rep["wire_local_hit_bytes"] == CHUNK


def test_cache_is_page_granular_for_overlapping_subranges():
    """A resident whole page serves any overlapping smaller read; the
    same bytes are never cached twice under different sub-range keys."""
    svc, bid, v = _preloaded()
    c = svc.client("r")
    c.read(bid, v, 0, PSIZE)                     # caches page 0 whole
    svc.reset_rpc_counters()
    assert c.read(bid, v, 0, PSIZE // 2) == bytes([1]) * (PSIZE // 2)
    assert c.read(bid, v, 16, 64) == bytes([1]) * 64
    rep = svc.rpc_report()
    assert rep["provider_read_pages"] == 0       # both served from cache
    assert rep["page_cache_hits"] == 2
    # one entry per page id, not one per sub-range
    assert len(svc.page_cache) == len(svc.page_cache.cached_page_ids())


def test_cache_disabled_service_fetches_every_time():
    svc, bid, v = _preloaded(page_cache_bytes=0)
    c = svc.client("r")
    c.read(bid, v, 0, CHUNK)
    svc.reset_rpc_counters()
    c.read(bid, v, 0, CHUNK)
    rep = svc.rpc_report()
    assert rep["provider_read_pages"] == CHUNK // PSIZE
    assert rep["page_cache_hits"] == 0


def test_single_flight_dedups_concurrent_readers():
    sim = Simulator(seed=5)
    # pinned to the legacy pool strategy: the test's interleaving (a
    # reader must arrive while another reader's fetch is in flight)
    # depends on the page spread this seed produces under round_robin
    svc = BlobSeerService(n_providers=8, n_meta_shards=4,
                          wire=Wire(clock=sim), placement="round_robin")
    setup = svc.client("setup")
    bid = setup.create(psize=PSIZE)
    setup.append(bid, b"\xaa" * CHUNK)
    v = setup.get_recent(bid)
    svc.reset_rpc_counters()

    def reader(i):
        def prog():
            c = svc.client(f"r{i}")
            assert c.read(bid, v, 0, CHUNK) == b"\xaa" * CHUNK
            return {"ops": 1}
        return prog

    for i in range(8):
        sim.spawn(reader(i), name=f"r{i}")
    sim.run()
    rep = svc.rpc_report()
    # 8 concurrent readers of the same 4 pages: each page fetched ONCE
    assert rep["provider_read_pages"] == CHUNK // PSIZE
    assert rep["page_cache_inflight_waits"] > 0     # somebody really waited


def test_replica_load_balancing_spreads_cold_read():
    svc = BlobSeerService(n_providers=4, n_meta_shards=2,
                          data_replication=2, page_cache_bytes=0)
    c = svc.client()
    bid = c.create(psize=PSIZE)
    v = c.write(bid, b"r" * PSIZE * 16, 0)
    svc.reset_rpc_counters()
    c.read(bid, v, 0, PSIZE * 16)
    served = {p.pid: svc.wire.stats(p.pid).requests
              for p in svc.pm.all_providers()}
    # outstanding-bytes balancing routes work to every replica holder,
    # not just each page's primary
    assert all(n > 0 for n in served.values()), served


def test_prefetch_hides_sequential_latency():
    def makespan(prefetch):
        sim = Simulator(seed=11)
        svc = BlobSeerService(n_providers=8, n_meta_shards=4,
                              wire=Wire(clock=sim),
                              read_prefetch_pages=prefetch)
        setup = svc.client("setup")
        bid = setup.create(psize=PSIZE)
        for i in range(8):
            setup.append(bid, bytes([i + 1]) * CHUNK)
        v = setup.get_recent(bid)

        def prog():
            c = svc.client("seq")
            for k in range(8):
                assert c.read(bid, v, k * CHUNK, CHUNK) == bytes([k + 1]) * CHUNK
            return {"ops": 8}

        sim.spawn(prog, name="seq")
        sim.run()
        return sim.now(), svc.rpc_report()

    t0, rep0 = makespan(0)
    t1, rep1 = makespan(CHUNK // PSIZE)
    assert rep1["page_cache_prefetch_fills"] > 0
    assert t1 < t0, f"prefetch did not hide latency: {t0} -> {t1}"
    # correctness is asserted inside the programs (bytes compared)


def test_prefetch_never_past_blob_end():
    svc, bid, v = _preloaded(n_chunks=2, read_prefetch_pages=64)
    c = svc.client("tail")
    size = c.get_size(bid, v)
    assert c.read(bid, v, size - PSIZE, PSIZE) == bytes([2]) * PSIZE


def test_prefetch_serves_unaligned_reads():
    """Prefetch-enabled clients fetch whole pages and slice locally, so
    a prefetched page serves a later NON-page-aligned read too."""
    svc, bid, v = _preloaded(read_prefetch_pages=CHUNK // PSIZE)
    c = svc.client("unaligned")
    want0 = bytes([1]) * (CHUNK - 16) + bytes([2]) * 16
    assert c.read(bid, v, 16, CHUNK) == want0          # prefetches chunk 2
    svc.reset_rpc_counters()
    want1 = bytes([2]) * (CHUNK - 16) + bytes([3]) * 16
    assert c.read(bid, v, CHUNK + 16, CHUNK) == want1
    rep = svc.rpc_report()
    # pages 5..8 were prefetched (whole pages) by the first read; the
    # second unaligned read is served from cache except its own last
    # boundary page (index 8) which the first prefetch window missed
    assert rep["page_cache_hits"] >= CHUNK // PSIZE


def test_prefetch_probe_does_not_inflate_hit_counters():
    svc, bid, v = _preloaded(read_prefetch_pages=CHUNK // PSIZE)
    c = svc.client("seq")
    c.read(bid, v, 0, CHUNK)
    c.read(bid, v, 0, CHUNK)   # re-read: prefetch probes find residents
    rep = svc.rpc_report()
    # hits == pages actually served to the reader (4 on the re-read,
    # plus the arrival-gated prefetched none on the first); probe
    # claims of already-resident siblings count nothing
    assert rep["page_cache_hits"] == CHUNK // PSIZE


def test_prefetch_skips_metadata_widening_when_cache_disabled():
    svc, bid, v = _preloaded(page_cache_bytes=0, read_prefetch_pages=8)
    c = svc.client("r")
    svc.reset_rpc_counters()
    c.read(bid, v, 0, CHUNK)
    keys_disabled = svc.rpc_report()["dht_get_keys"]
    svc2, bid2, v2 = _preloaded(page_cache_bytes=0, read_prefetch_pages=0)
    c2 = svc2.client("r")
    svc2.reset_rpc_counters()
    c2.read(bid2, v2, 0, CHUNK)
    # no cache to land prefetches in => no widened descent, same keys
    assert keys_disabled == svc2.rpc_report()["dht_get_keys"]


# ---------------------------------------------------------------------------
# GC / cache coherence
# ---------------------------------------------------------------------------


def test_sweep_evicts_cached_pages_and_read_raises_retired():
    svc, bid, v = _preloaded()
    c = svc.client("r")
    c.read(bid, v, 0, CHUNK)                      # warm the cache
    warm = svc.page_cache.cached_page_ids()
    assert warm
    c.set_retention(bid, keep_last=1)
    c.write(bid, b"\xff" * CHUNK, 0)              # v+1 supersedes v's pages
    collect_garbage(svc)
    # v is retired: a read must answer the typed error even though its
    # pages were resident moments ago
    with pytest.raises(RetiredVersion):
        c.read(bid, v, 0, CHUNK)
    # no cached page outlives its sweep: everything still cached exists
    # on at least one provider
    stored = set()
    for p in svc.pm.all_providers():
        stored.update(p.store.iter_pids())
    assert svc.page_cache.cached_page_ids() <= stored


def test_retire_intent_evicts_before_any_delete():
    """The gc_epoch listener alone (no sweep RPC yet) must already have
    dropped the retired version's pages from the cache."""
    svc, bid, v = _preloaded()
    c = svc.client("r")
    c.read(bid, v, 0, CHUNK)
    before = svc.page_cache.cached_page_ids()
    assert before
    epoch0 = svc.vm.gc_epoch(bid)
    c.set_retention(bid, keep_last=1)
    _kept, newly = svc.vm.plan_retirement(bid, client="t")
    assert newly, "test needs at least one retired version"
    assert svc.vm.gc_epoch(bid) == epoch0 + 1
    retired_pds = {pid for vv in newly
                   for pid, *_ in svc.vm.update_log(bid, vv).pd}
    assert not (svc.page_cache.cached_page_ids() & retired_pds)


def test_delete_pages_invalidates_even_on_miss():
    svc, bid, v = _preloaded()
    c = svc.client("r")
    c.read(bid, v, 0, CHUNK)
    cached = svc.page_cache.cached_page_ids()
    assert cached
    target = sorted(cached)[0]
    # endpoint down: the delete is missed — the cache entry must go anyway
    for p in svc.pm.all_providers():
        svc.kill_provider(p.pid)
    _, _, missed = svc.pm.delete_pages([(tuple(p.pid for p in
                                               svc.pm.all_providers()), target)])
    assert missed == [target]
    assert target not in svc.page_cache.cached_page_ids()


# ---------------------------------------------------------------------------
# Determinism
# ---------------------------------------------------------------------------


def test_hot_set_scenario_replays_identically():
    a = run_scenario("hot_set", 16, seed=9, ops_per_client=3)
    b = run_scenario("hot_set", 16, seed=9, ops_per_client=3)
    assert not a.errors and not b.errors
    assert a.trace_digest == b.trace_digest
    assert a.rpc == b.rpc
    c = run_scenario("hot_set", 16, seed=10, ops_per_client=3)
    assert c.trace_digest != a.trace_digest   # seeds explore schedules


def test_hot_set_cache_cuts_data_plane_rpcs():
    cold = run_scenario("hot_set", 16, seed=9, ops_per_client=3,
                        page_cache_bytes=0)
    warm = run_scenario("hot_set", 16, seed=9, ops_per_client=3)
    assert warm.rpc["provider_read_rounds"] * 2 <= cold.rpc["provider_read_rounds"]
    assert warm.ops == cold.ops


def test_paper_scenarios_pin_cache_off():
    """The §5 reproductions model distinct nodes sharing nothing: their
    runs must not serve repeat reads from a shared in-process cache."""
    r = run_scenario("readers", 8, seed=3, ops_per_client=3)
    assert not r.errors
    assert r.rpc["page_cache_hits"] == 0
    assert r.rpc["page_cache_misses"] == 0   # cache disabled, not just cold
    # explicit override still wins
    r2 = run_scenario("readers", 8, seed=3, ops_per_client=3,
                      page_cache_bytes=64 * 1024 * 1024)
    assert not r2.errors
